//! Fig. 1 companion: covariance-memory accounting across adaptive
//! methods, from asymptotic formulas and live optimizer instances.
//!
//! Run: cargo run --release --example memory_budget -- [--m 4096 --n 1024]

use sketchy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let report = sketchy::experiments::fig1::run(&args)?;
    println!("{report}");
    Ok(())
}
