//! Online convex optimization demo (the Appendix A setting): S-AdaGrad
//! vs the FD baselines on a synthetic logistic stream, with regret
//! against the offline comparator.
//!
//! Run: cargo run --release --example oco_convex -- [--n 3000 --d 100]

use sketchy::data::synthetic::{DatasetKind, SyntheticLogistic};
use sketchy::oco::losses::LogisticLoss;
use sketchy::oco::runner::{best_fixed_logistic, run_online};
use sketchy::oco::OnlineLoss;
use sketchy::optim::{AdaFd, AdaGradDiag, FdSon, Ogd, RfdSon, SAdaGrad, VectorOptimizer};
use sketchy::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 3000);
    let d = args.get_usize("d", 100);
    let seed = args.get_u64("seed", 1);
    let ds = SyntheticLogistic::with_size(DatasetKind::Gisette, n, d, seed);
    println!("synthetic gisette-like stream: {n} examples x {d} features, sketch size 10\n");

    let mut opts: Vec<Box<dyn VectorOptimizer>> = vec![
        Box::new(SAdaGrad::new(d, 10, 0.3)),
        Box::new(AdaGradDiag::new(d, 0.3)),
        Box::new(Ogd::new(0.3, true)),
        Box::new(AdaFd::new(d, 10, 0.3, 1e-3)),
        Box::new(FdSon::new(d, 10, 1.0, 1.0)),
        Box::new(RfdSon::new(d, 10, 1.0, 0.0)),
    ];
    let mut results = vec![];
    for opt in &mut opts {
        let mem = opt.mem_bytes();
        let mut stream = ds.iter().map(|(f, y)| {
            Box::new(LogisticLoss { features: f, label: y }) as Box<dyn OnlineLoss>
        });
        let res = run_online(opt.as_mut(), &mut stream, d, None, 10);
        results.push((res, mem));
    }
    // Regret against the offline comparator.
    let feats: Vec<Vec<f64>> = ds.iter().map(|(f, _)| f).collect();
    let labels: Vec<f64> = ds.iter().map(|(_, y)| y).collect();
    let (_, best) = best_fixed_logistic(&feats, &labels, 150);
    println!("offline comparator total loss: {best:.1}\n");
    println!("{:<12} {:>12} {:>12} {:>10}", "algorithm", "avg loss", "regret", "mem (B)");
    results.sort_by(|a, b| a.0.total_loss.partial_cmp(&b.0.total_loss).unwrap());
    for (res, mem) in &results {
        println!(
            "{:<12} {:>12.4} {:>12.1} {:>10}",
            res.name,
            res.total_loss / n as f64,
            res.total_loss - best,
            mem
        );
    }
    println!("\navg-cumulative-loss curve for the winner ({}):", results[0].0.name);
    for &(t, v) in &results[0].0.curve {
        println!("  t={t:>6}  {v:.4}");
    }
}
