//! Quickstart: train a small MLP-shaped set of matrix parameters with
//! S-Shampoo on a synthetic regression task — pure Rust, no artifacts
//! needed. Shows the optimizer API in ~60 lines.
//!
//! Run: cargo run --release --example quickstart

use sketchy::optim::{GraftType, Optimizer, SShampoo, SShampooConfig, ShampooConfig};
use sketchy::tensor::{matmul, Matrix};
use sketchy::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(0);
    // Two-layer "network": Y ≈ W2 · relu(W1 · X).
    let (d_in, d_hidden, d_out) = (32, 64, 8);
    let w1_true = Matrix::randn(d_hidden, d_in, &mut rng).scale(0.3);
    let w2_true = Matrix::randn(d_out, d_hidden, &mut rng).scale(0.3);
    let mut params = vec![
        Matrix::randn(d_hidden, d_in, &mut rng).scale(0.01),
        Matrix::randn(d_out, d_hidden, &mut rng).scale(0.01),
    ];
    let shapes = [(d_hidden, d_in), (d_out, d_hidden)];

    // S-Shampoo with rank-8 FD sketches: the 64×64 covariance factor is
    // tracked in a 64×8 sketch instead.
    let cfg = SShampooConfig {
        base: ShampooConfig {
            lr: 0.02,
            start_preconditioning_step: 5,
            graft: GraftType::Rmsprop,
            ..Default::default()
        },
        rank: 8,
    };
    let mut opt = SShampoo::new(&shapes, cfg);
    println!(
        "optimizer: {} | covariance bytes: {}",
        opt.name(),
        opt.second_moment_bytes(),
    );

    let batch = 16;
    for step in 0..300 {
        // Synthetic batch + forward.
        let x = Matrix::randn(d_in, batch, &mut rng);
        let pre1 = matmul(&params[0], &x);
        let h = pre1.map(|v| v.max(0.0));
        let y_pred = matmul(&params[1], &h);
        let y_true = matmul(&w2_true, &matmul(&w1_true, &x).map(|v| v.max(0.0)));
        let err = y_pred.sub(&y_true);
        let loss = err.fro_norm().powi(2) / batch as f64;

        // Backward (hand-derived for the 2-layer net).
        let g2 = matmul(&err, &h.t()).scale(2.0 / batch as f64);
        let dh = matmul(&params[1].t(), &err);
        let dh_relu = Matrix::from_fn(d_hidden, batch, |i, j| {
            if pre1[(i, j)] > 0.0 { dh[(i, j)] } else { 0.0 }
        });
        let g1 = matmul(&dh_relu, &x.t()).scale(2.0 / batch as f64);

        opt.step(&mut params, &[g1, g2]);
        if step % 50 == 0 || step == 299 {
            let (el, er) = opt.escaped_mass()[0];
            println!("step {step:>4}  loss {loss:.5}  escaped mass (L, R) = ({el:.3}, {er:.3})");
        }
    }
    println!("done — see `sketchy repro` for the paper experiments.");
}
