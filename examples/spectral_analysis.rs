//! Fig. 3 companion: spectral analysis of EMA Kronecker covariance
//! factors collected during live proxy training, plus the §5.2 random-
//! Wishart control. Thin wrapper over `sketchy repro fig3`.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example spectral_analysis -- [--task image]

use sketchy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let report = sketchy::experiments::fig3::run(&args)?;
    println!("{report}");
    Ok(())
}
