//! End-to-end driver (E10): transformer LM training through the full
//! three-layer stack — JAX/Pallas-lowered gradient artifact, PJRT
//! runtime, data-parallel coordinator, Rust S-Shampoo vs Adam — on the
//! synthetic Markov corpus, reporting loss curves and throughput.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example train_transformer -- \
//!       [--preset small] [--steps 200] [--workers 2] [--rank 16]

use sketchy::data::MarkovCorpus;
use sketchy::optim::{
    Adam, GraftType, Optimizer, SShampoo, SShampooConfig, ShampooConfig, WarmupCosine,
};
use sketchy::train::{CurveLog, LmTrainer};
use sketchy::util::cli::Args;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "small");
    let steps = args.get_usize("steps", 200);
    let workers = args.get_usize("workers", 2);
    let rank = args.get_usize("rank", 16);
    let lr = args.get_f64("lr", 2e-3);
    let runtime = Arc::new(sketchy::runtime::Runtime::load("artifacts")?);

    let mut report = String::from("| optimizer | final train loss | eval loss | steps/s | covariance bytes |\n|---|---|---|---|---|\n");
    for opt_name in ["adam", "s-shampoo"] {
        let mut trainer = LmTrainer::new(runtime.clone(), &preset, 3)?;
        if opt_name == "adam" {
            println!(
                "preset={preset}: {} params, vocab={}, seq={}, batch={}x{workers} workers",
                trainer.param_count(),
                trainer.vocab,
                trainer.seq,
                trainer.batch
            );
        }
        let shapes = trainer.shapes.clone();
        let mut opt: Box<dyn Optimizer> = match opt_name {
            "adam" => {
                let mut a = Adam::new(&shapes, lr);
                a.weight_decay = 1e-4;
                a.clip = 10.0;
                Box::new(a)
            }
            _ => Box::new(SShampoo::new(
                &shapes,
                SShampooConfig {
                    base: ShampooConfig {
                        lr,
                        weight_decay: 1e-4,
                        clip: 10.0,
                        start_preconditioning_step: steps / 20 + 2,
                        stat_interval: 2,
                        precond_interval: 2,
                        graft: GraftType::RmspropNormalized,
                        ..Default::default()
                    },
                    rank,
                },
            )),
        };
        let schedule = WarmupCosine { peak: lr, warmup: steps / 20 + 1, total: steps };
        let mut corpus = MarkovCorpus::new(trainer.vocab, 11);
        let mut curve = CurveLog::new(&opt.name());
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            opt.set_lr(schedule.at(s));
            let (loss, _) = trainer.step(opt.as_mut(), &mut corpus, workers)?;
            curve.push(s, loss);
            if s % (steps / 10).max(1) == 0 {
                println!("  [{}] step {s:>5}  loss {loss:.4}", opt.name());
            }
        }
        let elapsed = t0.elapsed();
        let eval = trainer.eval(&mut corpus, 4)?;
        let sps = steps as f64 / elapsed.as_secs_f64();
        println!(
            "{}: {steps} steps in {elapsed:?} ({sps:.2} steps/s), final loss {:.4}, eval {:.4}\n",
            opt.name(),
            curve.tail_mean(5),
            eval
        );
        report += &format!(
            "| {} | {:.4} | {:.4} | {:.2} | {} |\n",
            opt.name(),
            curve.tail_mean(5),
            eval,
            sps,
            opt.second_moment_bytes()
        );
        sketchy::train::metrics::write_report(
            &format!("reports/e2e_{preset}_{opt_name}.csv"),
            &curve.to_csv(),
        )?;
    }
    println!("{report}");
    sketchy::train::metrics::write_report("reports/e2e_summary.md", &report)?;
    Ok(())
}
