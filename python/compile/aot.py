"""AOT pipeline: lower every L2 compute graph to HLO **text** artifacts
plus a manifest and cross-language numeric fixtures.

Interchange format is HLO text, NOT serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
runtime behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example and DESIGN.md §1).

Outputs under --out (default ../artifacts):
  <name>.hlo.txt   one per artifact
  manifest.json    input/output specs per artifact (consumed by
                   rust/src/runtime/artifact.rs)
  fixtures.json    seeded input/output pairs for Rust integration tests

Usage: python -m compile.aot --out ../artifacts [--preset small] [--skip-fixtures]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model, models_proxy as proxy
from compile.kernels.cov_update import cov_update
from compile.kernels.precond_apply import precond_apply
from compile.kernels.sketch_gram import sketch_gram


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    _check_no_ffi_custom_calls(text)
    return text


def _check_no_ffi_custom_calls(text):
    """Guard: typed-FFI custom calls cannot run on xla_extension 0.5.1."""
    if "custom-call" in text and "api_version=API_VERSION_TYPED_FFI" in text:
        raise RuntimeError(
            "artifact contains a typed-FFI custom call (eigh/svd/qr?) — "
            "these must run on the Rust side instead"
        )


def _spec(arr_or_shape, dtype=jnp.float32):
    if hasattr(arr_or_shape, "shape"):
        return jax.ShapeDtypeStruct(arr_or_shape.shape, arr_or_shape.dtype)
    return jax.ShapeDtypeStruct(arr_or_shape, dtype)


def _dtype_name(dt):
    return {"float32": "f32", "int32": "i32", "float64": "f64"}[np.dtype(dt).name]


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"artifacts": []}
        self.fixtures = {}

    def add(self, name, fn, input_specs, input_names, n_params,
            fixture_inputs=None):
        """Lower `fn` at `input_specs`, write HLO text, record manifest.

        If `fixture_inputs` (concrete arrays) is given, also run the jitted
        fn and record the input/output pair in fixtures.json.
        """
        lowered = jax.jit(fn).lower(*input_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *input_specs)
        self.manifest["artifacts"].append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                for n, s in zip(input_names, input_specs)
            ],
            "n_params": n_params,
            "n_outputs": len(outs),
            "output_shapes": [list(o.shape) for o in outs],
        })
        if fixture_inputs is not None:
            outputs = jax.jit(fn)(*fixture_inputs)
            self.fixtures[name] = {
                "inputs": [
                    {"name": n, "shape": list(np.asarray(a).shape),
                     "data": np.asarray(a, dtype=np.float64).ravel().tolist()
                     if np.asarray(a).dtype != np.int32
                     else np.asarray(a).ravel().tolist()}
                    for n, a in zip(input_names, fixture_inputs)
                ],
                "outputs": [
                    np.asarray(o, dtype=np.float64).ravel().tolist()
                    for o in outputs
                ],
                "output_shapes": [list(np.asarray(o).shape) for o in outputs],
            }
        print(f"  wrote {name}: {len(text)} chars, "
              f"{len(input_specs)} inputs, {len(outs)} outputs")

    def finish(self, preset):
        self.manifest["preset"] = preset
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        with open(os.path.join(self.out_dir, "fixtures.json"), "w") as f:
            json.dump(self.fixtures, f)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts")


def build_lm(b, preset, with_fixture):
    cfg = model.config(preset)
    shapes = model.param_shapes(cfg)
    names = [n for n, _ in shapes] + ["tokens"]
    tok_spec = _spec((cfg["batch"], cfg["seq"] + 1), jnp.int32)
    specs = [_spec(s) for _, s in shapes] + [tok_spec]
    fixture = None
    if with_fixture:
        params = model.init_params(cfg, seed=0)
        rng = np.random.default_rng(1)
        tokens = rng.integers(
            0, cfg["vocab"], size=(cfg["batch"], cfg["seq"] + 1), dtype=np.int32
        )
        fixture = [jnp.asarray(p) for p in params] + [jnp.asarray(tokens)]
    b.add(f"lm_{preset}_grad", model.grad_fn(cfg), specs, names,
          n_params=len(shapes))
    b.add(f"lm_{preset}_eval", model.eval_fn(cfg), specs, names,
          n_params=len(shapes), fixture_inputs=fixture)


def build_proxies(b, with_fixtures):
    # --- CNN (image) ---
    cfg = proxy.CNN_CFG
    shapes = proxy.cnn_param_shapes(cfg)
    np_ = len(shapes)
    names = [n for n, _ in shapes] + ["images", "labels"]
    specs = [_spec(s) for _, s in shapes] + [
        _spec((cfg["batch"], cfg["h"] * cfg["w"])),
        _spec((cfg["batch"],), jnp.int32),
    ]
    b.add("cnn_grad", proxy.make_grad_fn(proxy.cnn_loss, np_), specs, names, np_)
    b.add("cnn_eval", proxy.make_eval_fn(proxy.cnn_loss, proxy.cnn_logits, np_),
          specs, names, np_)

    # --- Conformer (audio) ---
    cfg = proxy.CONF_CFG
    shapes = proxy.conformer_param_shapes(cfg)
    np_ = len(shapes)
    names = [n for n, _ in shapes] + ["spect", "labels"]
    specs = [_spec(s) for _, s in shapes] + [
        _spec((cfg["batch"], cfg["frames"] * cfg["bins"])),
        _spec((cfg["batch"],), jnp.int32),
    ]
    b.add("conformer_grad", proxy.make_grad_fn(proxy.conformer_loss, np_),
          specs, names, np_)
    b.add("conformer_eval",
          proxy.make_eval_fn(proxy.conformer_loss, proxy.conformer_logits, np_),
          specs, names, np_)

    # --- GNN (graph) ---
    cfg = proxy.GNN_CFG
    shapes = proxy.gnn_param_shapes(cfg)
    np_ = len(shapes)
    names = [n for n, _ in shapes] + ["adjacency", "feats", "labels"]
    specs = [_spec(s) for _, s in shapes] + [
        _spec((cfg["batch"], cfg["nodes"] * cfg["nodes"])),
        _spec((cfg["batch"], cfg["nodes"] * cfg["feat"])),
        _spec((cfg["batch"], cfg["tasks"])),
    ]
    b.add("gnn_grad", proxy.make_grad_fn(proxy.gnn_loss, np_), specs, names, np_)

    def gnn_eval(*args):
        params = list(args[:np_])
        adjacency, feats, labels = args[np_:]
        return (proxy.gnn_loss(params, adjacency, feats, labels),
                proxy.gnn_logits(params, adjacency, feats))

    b.add("gnn_eval", gnn_eval, specs, names, np_)
    _ = with_fixtures


def build_kernels(b, with_fixtures):
    """Optimizer hot-spot kernels as standalone artifacts (L1 -> runtime).

    These are the Pallas kernels lowered inside jitted wrappers; the Rust
    runtime can offload covariance updates / preconditioner applications
    to XLA through them (used by the perf benches to compare the native
    Rust path against the XLA path).
    """
    for n in (64, 256):
        name = f"cov_update_{n}"
        fn = lambda c, g: (cov_update(c, g, 0.999),)
        specs = [_spec((n, n)), _spec((n, n))]
        fixture = None
        if with_fixtures and n == 64:
            rng = np.random.default_rng(2)
            c0 = rng.standard_normal((n, n)).astype(np.float32)
            c0 = c0 @ c0.T
            g0 = rng.standard_normal((n, n)).astype(np.float32)
            fixture = [jnp.asarray(c0), jnp.asarray(g0)]
        b.add(name, fn, specs, ["c", "g"], 0, fixture_inputs=fixture)

    fn = lambda pl_r, g, pr_r: (precond_apply(pl_r, g, pr_r),)
    specs = [_spec((128, 128)), _spec((128, 64)), _spec((64, 64))]
    rng = np.random.default_rng(3)
    fixture = None
    if with_fixtures:
        fixture = [
            jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32)),
            jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32)),
            jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)),
        ]
    b.add("precond_apply_128x64", fn, specs, ["pl", "g", "pr"], 0,
          fixture_inputs=fixture)

    fn = lambda bmat, y: (sketch_gram(bmat, y, 0.999),)
    specs = [_spec((512, 32)), _spec((512, 8))]
    b.add("sketch_gram_512", fn, specs, ["b", "y"], 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=list(model.PRESETS))
    ap.add_argument("--skip-fixtures", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    b = Builder(args.out)
    with_fixtures = not args.skip_fixtures
    # Tiny LM always built: integration tests + fixtures.
    build_lm(b, "tiny", with_fixture=with_fixtures)
    if args.preset != "tiny":
        build_lm(b, args.preset, with_fixture=False)
    build_proxies(b, with_fixtures)
    build_kernels(b, with_fixtures)
    b.finish(args.preset)


if __name__ == "__main__":
    main()
