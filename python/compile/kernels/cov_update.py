"""Pallas kernel: Kronecker-factor covariance update C' = beta2*C + G^T G.

TPU-shaped tiling (DESIGN.md section 5, Hardware Adaptation):

- Output C' is tiled into (bn, bn) VMEM blocks; the contraction over the
  m rows of G streams (bk, bn) slabs of G from HBM.
- Grid = (n/bn, n/bn, m/bk) with the reduction as the innermost grid axis,
  so each output tile stays resident in VMEM across the K loop
  (accumulation in f32 — the MXU-native pattern).
- VMEM footprint per program instance: two G slabs (bk x bn each) plus the
  C tile (bn x bn) = (2*bk*bn + bn*bn) * 4 bytes; with the default
  bn = bk = 128 that is 192 KiB, far under the ~16 MiB TPU VMEM budget,
  and the inner contraction is an MXU-systolic (128, 128, 128) matmul.

Runs under interpret=True here (CPU PJRT cannot execute Mosaic
custom-calls); on real TPU the same BlockSpecs compile natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _pick_block(dim, preferred):
    """Largest divisor of dim that is <= preferred (keeps tiling exact)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _cov_update_kernel(c_ref, gi_ref, gj_ref, beta2_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] accumulates gi_k^T gj_k.

    k is the innermost grid axis; on k == 0 the output tile is seeded with
    beta2 * C tile, afterwards it accumulates in place (VMEM-resident).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _seed():
        o_ref[...] = beta2_ref[0] * c_ref[...]

    # (bk, bn_i)^T @ (bk, bn_j) -> (bn_i, bn_j) partial product.
    o_ref[...] += jnp.dot(
        gi_ref[...].T, gj_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def cov_update(c, g, beta2, block_n=DEFAULT_BLOCK, block_k=DEFAULT_BLOCK):
    """C' = beta2 * C + G^T G via the tiled Pallas kernel.

    Args:
      c: (n, n) current factor.
      g: (m, n) gradient (pass g.T to update the left factor).
      beta2: scalar decay (traced; packed into a (1,) operand).
      block_n / block_k: preferred tile sizes (clipped to divisors).
    """
    n = c.shape[1]
    m = g.shape[0]
    assert c.shape == (n, n) and g.shape[1] == n, (c.shape, g.shape)
    bn = _pick_block(n, block_n)
    bk = _pick_block(m, block_k)
    grid = (n // bn, n // bn, m // bk)
    beta2_arr = jnp.asarray([beta2], dtype=c.dtype)
    return pl.pallas_call(
        _cov_update_kernel,
        grid=grid,
        in_specs=[
            # C tile for seeding: block (i, j), constant in k.
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
            # G slab feeding the row index of the output tile.
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, i)),
            # G slab feeding the column index.
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            # beta2 broadcast to every program instance.
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), c.dtype),
        interpret=True,
    )(c, g, g, beta2_arr)


def vmem_bytes(block_n=DEFAULT_BLOCK, block_k=DEFAULT_BLOCK, dtype_bytes=4):
    """Estimated VMEM footprint per program instance (DESIGN.md section 5)."""
    return (2 * block_k * block_n + 2 * block_n * block_n + 1) * dtype_bytes
