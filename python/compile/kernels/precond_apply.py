"""Pallas kernel: preconditioned direction P = PL @ G @ PR (Alg. 3 line 6).

The inverse fourth-roots PL = L^{-1/4}, PR = R^{-1/4} are computed
host-side (Rust eigh); this kernel fuses the two matmuls so the m x n
intermediate T = PL @ G never round-trips to HBM:

- Grid = (m/bm, n/bn); each program instance owns a (bm, bn) output tile.
- The instance streams PL's (bm, m) row band and G in full columns /
  PR's (n, bn) column band through VMEM, computing (PL_band @ G) @ PR_band.
- VMEM per instance with bm = bn = 128 and the paper's 1024-square blocks:
  bm*m + m*n + n*bn + bm*bn floats = (128*1024 + 1024*1024 + 1024*128 +
  128*128)*4 B ~ 5.3 MiB — inside the 16 MiB VMEM budget, which is exactly
  why the fusion is profitable on TPU (the threadblock-staged GEMM-chain
  pattern GPU implementations use, re-expressed with BlockSpecs).

interpret=True for CPU-PJRT execution; see cov_update.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _pick_block(dim, preferred):
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _precond_kernel(pl_ref, g_ref, pr_ref, o_ref):
    """o[i, j] = (PL_rowband_i @ G) @ PR_colband_j, fused in VMEM."""
    t = jnp.dot(pl_ref[...], g_ref[...], preferred_element_type=o_ref.dtype)
    o_ref[...] = jnp.dot(t, pr_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def precond_apply(pl_root, g, pr_root, block_m=DEFAULT_BLOCK, block_n=DEFAULT_BLOCK):
    """P = PL @ G @ PR with the fused two-stage Pallas kernel.

    Args:
      pl_root: (m, m) left inverse root.
      g: (m, n) gradient.
      pr_root: (n, n) right inverse root.
    """
    m, n = g.shape
    assert pl_root.shape == (m, m) and pr_root.shape == (n, n)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _precond_kernel,
        grid=grid,
        in_specs=[
            # PL row band for output row block i.
            pl.BlockSpec((bm, m), lambda i, j: (i, 0)),
            # Full G (streamed once per instance).
            pl.BlockSpec((m, n), lambda i, j: (0, 0)),
            # PR column band for output column block j.
            pl.BlockSpec((n, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), g.dtype),
        interpret=True,
    )(pl_root, g, pr_root)


def vmem_bytes(m, n, block_m=DEFAULT_BLOCK, block_n=DEFAULT_BLOCK, dtype_bytes=4):
    """Estimated VMEM footprint per program instance."""
    return (block_m * m + m * n + n * block_n + block_m * block_n) * dtype_bytes
