"""Pure-jnp oracles for the Pallas kernels.

These are the correctness signal for L1: pytest checks every Pallas kernel
against these references with assert_allclose across a randomized grid of
shapes and dtypes (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def cov_update_ref(c, g, beta2):
    """Right Kronecker-factor statistics update: C' = beta2*C + G^T G.

    The compute hot-spot of Shampoo-family optimizers (Alg. 3 line 5 news
    term): for a layer gradient G of shape (m, n), the right factor R
    accumulates G^T G (n x n). The left factor L accumulates G G^T, which
    callers obtain by passing G^T.
    """
    return beta2 * c + g.T @ g


def precond_apply_ref(pl_root, g, pr_root):
    """Preconditioned direction: P = L^{-1/4} G R^{-1/4} (Alg. 3 line 6).

    The roots are computed host-side (Rust eigh — see DESIGN.md); this
    kernel applies them.
    """
    return pl_root @ g @ pr_root


def sketch_gram_ref(b, y, beta2):
    """Augmented FD Gram matrix (factored Alg. 1 / Obs. 6 update).

    A = [sqrt(beta2)*B | Y] with B the d x ell sketch factor and Y the
    d x r news factor; returns A^T A of shape (ell+r, ell+r). The (small)
    eigendecomposition of this Gram matrix is what the FD update
    diagonalizes instead of anything d x d.
    """
    a = jnp.concatenate([jnp.sqrt(beta2) * b, y], axis=1)
    return a.T @ a


def matmul_ref(a, b):
    """Plain matmul (building block used by the fused kernels' tests)."""
    return a @ b
