"""Pallas kernel: augmented FD Gram matrix A^T A with A = [sqrt(b2)B | Y].

The factored FD update (Alg. 1 / Obs. 6, and rust/src/sketch/fd.rs)
eigendecomposes the small (ell+r)^2 Gram matrix of the augmented factor
instead of anything d x d. Building that Gram matrix is the only O(d)
work in the update, so it is the kernel worth pushing to the accelerator:

- The augmented A is never materialized: the kernel reads B and Y tiles
  and applies the sqrt(beta2) scaling to B columns on the fly.
- Grid = (s/bs, s/bs, d/bk) over the (s, s) output (s = ell + r), with the
  long d axis streamed innermost (the HBM->VMEM covariance-streaming
  schedule; output tiles stay VMEM-resident across the reduction).
- VMEM per instance: 2 slabs (bk x bs) + out tile (bs x bs); with
  bk = 512, bs = 64 that's ~280 KiB.

interpret=True for CPU-PJRT execution; see cov_update.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, preferred):
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _gram_kernel(ai_ref, aj_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        ai_ref[...].T, aj_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_s", "block_k"))
def sketch_gram(b, y, beta2, block_s=64, block_k=512):
    """Gram matrix of [sqrt(beta2)*B | Y] of shape (ell+r, ell+r).

    Args:
      b: (d, ell) sketch factor.
      y: (d, r) news factor.
      beta2: scalar decay.
    """
    d, ell = b.shape
    r = y.shape[1]
    assert y.shape[0] == d
    # Scale + concatenate outside the kernel tile loop (one fused pass,
    # still O(d(ell+r)) and XLA fuses it with the pallas prologue); the
    # heavy O(d*(ell+r)^2) contraction happens inside the kernel.
    a = jnp.concatenate([jnp.sqrt(beta2).astype(b.dtype) * b, y], axis=1)
    s = ell + r
    bs = _pick_block(s, block_s)
    bk = _pick_block(d, block_k)
    grid = (s // bs, s // bs, d // bk)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, s), b.dtype),
        interpret=True,
    )(a, a)
