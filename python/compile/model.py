"""L2: decoder-only transformer LM in pure jnp (fwd + bwd), AOT-lowered
for the Rust training loop (E10, the end-to-end driver).

Conventions imposed by the three-layer architecture:

- **Every parameter is a 2-D matrix** (vectors are (d, 1)): the Rust
  optimizer family (Shampoo/S-Shampoo) operates on matrix-shaped tensors,
  exactly as the paper treats layers. Anything naturally higher-rank is
  stored 2-D and reshaped inside the forward pass.
- The exported gradient artifact has signature
  `(param_0, ..., param_{P-1}, tokens) -> (loss, grad_0, ..., grad_{P-1})`
  with `tokens` int32 of shape (batch, seq+1); inputs are the first seq
  positions, targets the last. No optimizer state crosses the boundary —
  the optimizer is Rust's job.
- No custom-call-lowering ops (eigh/svd/qr/sort-based topk): the PJRT
  runtime in this image rejects typed-FFI custom calls (DESIGN.md §1).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np


PRESETS = {
    # vocab, dim, layers, heads, ff, seq, batch
    "tiny": dict(vocab=32, dim=32, layers=1, heads=2, ff=64, seq=16, batch=4),
    "small": dict(vocab=64, dim=128, layers=2, heads=4, ff=256, seq=64, batch=8),
    "base": dict(vocab=256, dim=512, layers=4, heads=8, ff=2048, seq=128, batch=8),
    # ~97M parameters — the paper-scale config (compile-only on CPU).
    "large": dict(vocab=8192, dim=768, layers=12, heads=12, ff=3072, seq=256, batch=8),
}


def config(preset):
    return dict(PRESETS[preset])


def param_shapes(cfg):
    """Ordered (name, (rows, cols)) list — the artifact input order."""
    v, d, f, s = cfg["vocab"], cfg["dim"], cfg["ff"], cfg["seq"]
    shapes = [("embed", (v, d)), ("pos", (s, d))]
    for i in range(cfg["layers"]):
        shapes += [
            (f"l{i}.ln1_scale", (d, 1)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_scale", (d, 1)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.w2", (f, d)),
        ]
    shapes += [("lnf_scale", (d, 1)), ("out", (d, v))]
    return shapes


def param_count(cfg):
    return sum(r * c for _, (r, c) in param_shapes(cfg))


def init_params(cfg, seed=0):
    """Scaled-gaussian init, returned in param_shapes order (numpy f32)."""
    rng = np.random.default_rng(seed)
    params = []
    for name, (r, c) in param_shapes(cfg):
        if name.endswith("_scale"):
            w = np.ones((r, c), np.float32)
        elif name == "pos":
            w = (0.01 * rng.standard_normal((r, c))).astype(np.float32)
        else:
            w = (rng.standard_normal((r, c)) / math.sqrt(r)).astype(np.float32)
        params.append(w)
    return params


def _rmsnorm(x, scale):
    # RMSNorm (scale only): no mean subtraction keeps the op count low and
    # avoids degenerate LN gradients at tiny dims.
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(var + 1e-6) * scale.reshape(-1)


def _attention(x, wq, wk, wv, wo, heads):
    b, s, d = x.shape
    hd = d // heads
    q = (x @ wq).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), logits.dtype))
    logits = jnp.where(mask == 0, jnp.asarray(-1e9, logits.dtype), logits)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def forward(cfg, params, tokens_in):
    """Logits for input tokens (batch, seq) -> (batch, seq, vocab)."""
    names = [n for n, _ in param_shapes(cfg)]
    p = dict(zip(names, params))
    s = tokens_in.shape[1]
    x = p["embed"][tokens_in] + p["pos"][:s][None, :, :]
    for i in range(cfg["layers"]):
        h = _rmsnorm(x, p[f"l{i}.ln1_scale"])
        x = x + _attention(
            h, p[f"l{i}.wq"], p[f"l{i}.wk"], p[f"l{i}.wv"], p[f"l{i}.wo"], cfg["heads"]
        )
        h = _rmsnorm(x, p[f"l{i}.ln2_scale"])
        x = x + jnp.maximum(h @ p[f"l{i}.w1"], 0.0) @ p[f"l{i}.w2"]
    x = _rmsnorm(x, p["lnf_scale"])
    return x @ p["out"]


def loss_fn(cfg, params, tokens):
    """Mean next-token cross-entropy. tokens: (batch, seq+1) int32."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def grad_fn(cfg):
    """Callable (*params, tokens) -> (loss, *grads) for AOT lowering."""

    def f(*args):
        params = list(args[:-1])
        tokens = args[-1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, ps, tokens)
        )(params)
        return (loss, *grads)

    return f


def eval_fn(cfg):
    """Callable (*params, tokens) -> (loss,) — held-out evaluation."""

    def f(*args):
        params = list(args[:-1])
        tokens = args[-1]
        return (loss_fn(cfg, params, tokens),)

    return f
