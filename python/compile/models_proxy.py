"""L2: the three Fig. 2 proxy models (DESIGN.md §6 substitutions).

- `cnn`     — residual CNN on synthetic images     (ResNet-50/ImageNet →)
- `conformer` — attention + depthwise-conv block   (Conformer/Librispeech →)
- `gnn`     — dense message-passing, multi-task    (GNN/ogbg-molpcba →)

Same conventions as model.py: all parameters 2-D, gradient artifacts
`(params..., batch_inputs...) -> (loss, grads...)`, eval artifacts return
`(loss, logits)` so the Rust side computes the test metric (error rate /
1−AP analogue).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _init(rng, shapes):
    params = []
    for name, (r, c) in shapes:
        if name.endswith("_scale"):
            w = np.ones((r, c), np.float32)
        else:
            w = (rng.standard_normal((r, c)) / math.sqrt(r)).astype(np.float32)
        params.append(w)
    return params


def _softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)
    return jnp.mean(nll)


def _conv2d(x, w2d, kh, kw, cin, cout, stride):
    """NHWC conv; the kernel is stored 2-D as (kh*kw*cin, cout)."""
    w = w2d.reshape(kh, kw, cin, cout)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


# ---------------------------------------------------------------------------
# CNN image proxy
# ---------------------------------------------------------------------------

CNN_CFG = dict(h=16, w=16, classes=8, c1=16, c2=32, batch=16)


def cnn_param_shapes(cfg=CNN_CFG):
    c1, c2, classes = cfg["c1"], cfg["c2"], cfg["classes"]
    return [
        ("conv1", (9 * 1, c1)),        # 3x3x1 -> c1
        ("conv2", (9 * c1, c2)),       # 3x3xc1 -> c2, stride 2
        ("conv3", (9 * c2, c2)),       # 3x3xc2 -> c2, stride 2 (residual)
        ("conv4", (9 * c2, c2)),       # residual block second conv
        ("head", (c2, classes)),
    ]


def cnn_init(seed=0, cfg=CNN_CFG):
    return _init(np.random.default_rng(seed), cnn_param_shapes(cfg))


def cnn_logits(params, images, cfg=CNN_CFG):
    """images: (B, h*w) flat f32 -> (B, classes)."""
    c1, c2 = cfg["c1"], cfg["c2"]
    conv1, conv2, conv3, conv4, head = params
    x = images.reshape(-1, cfg["h"], cfg["w"], 1)
    x = jnp.maximum(_conv2d(x, conv1, 3, 3, 1, c1, 1), 0.0)
    x = jnp.maximum(_conv2d(x, conv2, 3, 3, c1, c2, 2), 0.0)
    # Residual block (the ResNet-shaped covariance structure).
    h = jnp.maximum(_conv2d(x, conv3, 3, 3, c2, c2, 1), 0.0)
    h = _conv2d(h, conv4, 3, 3, c2, c2, 1)
    x = jnp.maximum(x + h, 0.0)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x @ head


def cnn_loss(params, images, labels, cfg=CNN_CFG):
    return _softmax_xent(cnn_logits(params, images, cfg), labels)


# ---------------------------------------------------------------------------
# Conformer-block audio proxy
# ---------------------------------------------------------------------------

CONF_CFG = dict(frames=16, bins=32, dim=64, heads=4, ff=128, classes=8,
                dw_kernel=7, batch=16)


def conformer_param_shapes(cfg=CONF_CFG):
    d, f = cfg["dim"], cfg["ff"]
    return [
        ("proj", (cfg["bins"], d)),
        ("ln1_scale", (d, 1)),
        ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d)),
        ("dw", (cfg["dw_kernel"], d)),     # depthwise conv over time
        ("ln2_scale", (d, 1)),
        ("ff1", (d, f)), ("ff2", (f, d)),
        ("head", (d, cfg["classes"])),
    ]


def conformer_init(seed=0, cfg=CONF_CFG):
    return _init(np.random.default_rng(seed), conformer_param_shapes(cfg))


def _rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(var + 1e-6) * scale.reshape(-1)


def conformer_logits(params, spect, cfg=CONF_CFG):
    """spect: (B, frames*bins) flat f32 -> (B, classes)."""
    (proj, ln1, wq, wk, wv, wo, dw, ln2, ff1, ff2, head) = params
    b = spect.shape[0]
    t, nb, d, heads = cfg["frames"], cfg["bins"], cfg["dim"], cfg["heads"]
    x = spect.reshape(b, t, nb) @ proj  # (B, T, D)
    # Self-attention sub-block.
    h = _rmsnorm(x, ln1)
    hd = d // heads
    q = (h @ wq).reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    k = (h @ wk).reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    v = (h @ wv).reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    att = jax.nn.softmax(jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd), -1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + o @ wo
    # Depthwise temporal convolution sub-block (the conformer signature).
    kernel = dw.reshape(cfg["dw_kernel"], 1, d)  # (W, I/groups=1, O=D)
    conv = jax.lax.conv_general_dilated(
        x, kernel, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=d,
    )
    x = x + jnp.maximum(conv, 0.0)
    # Feed-forward sub-block (the rectangular narrow-to-wide kernels that
    # motivate sketching, §3.4).
    h = _rmsnorm(x, ln2)
    x = x + jnp.maximum(h @ ff1, 0.0) @ ff2
    return jnp.mean(x, axis=1) @ head


def conformer_loss(params, spect, labels, cfg=CONF_CFG):
    return _softmax_xent(conformer_logits(params, spect, cfg), labels)


# ---------------------------------------------------------------------------
# GNN molecular proxy
# ---------------------------------------------------------------------------

GNN_CFG = dict(nodes=16, feat=8, dim=64, steps=3, tasks=8, batch=16)


def gnn_param_shapes(cfg=GNN_CFG):
    d = cfg["dim"]
    shapes = [("embed", (cfg["feat"], d))]
    for i in range(cfg["steps"]):
        shapes.append((f"msg{i}", (d, d)))
    shapes.append(("head", (d, cfg["tasks"])))
    return shapes


def gnn_init(seed=0, cfg=GNN_CFG):
    return _init(np.random.default_rng(seed), gnn_param_shapes(cfg))


def gnn_logits(params, adjacency, feats, cfg=GNN_CFG):
    """adjacency: (B, N*N) flat; feats: (B, N*feat) flat -> (B, tasks)."""
    n, fdim = cfg["nodes"], cfg["feat"]
    b = adjacency.shape[0]
    a = adjacency.reshape(b, n, n)
    # Symmetric degree normalization A_hat = D^{-1/2} A D^{-1/2}.
    deg = jnp.sum(a, axis=-1, keepdims=True)
    dinv = 1.0 / jnp.sqrt(jnp.maximum(deg, 1.0))
    a_hat = a * dinv * dinv.transpose(0, 2, 1)
    h = feats.reshape(b, n, fdim) @ params[0]
    for i in range(cfg["steps"]):
        msg = a_hat @ h @ params[1 + i]
        h = jnp.maximum(h + msg, 0.0)  # residual message passing
    pooled = jnp.mean(h, axis=1)
    return pooled @ params[-1]


def gnn_loss(params, adjacency, feats, labels, cfg=GNN_CFG):
    """Multi-task binary cross-entropy; labels (B, tasks) in {0,1}."""
    logits = gnn_logits(params, adjacency, feats, cfg)
    # Stable BCE-with-logits.
    losses = jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits))
    )
    return jnp.mean(losses)


# ---------------------------------------------------------------------------
# AOT wrappers
# ---------------------------------------------------------------------------

def make_grad_fn(loss, n_params):
    """(*params, *batch) -> (loss, *grads)."""

    def f(*args):
        params = list(args[:n_params])
        batch = args[n_params:]
        val, grads = jax.value_and_grad(
            lambda ps: loss(ps, *batch)
        )(params)
        return (val, *grads)

    return f


def make_eval_fn(loss, logits_fn, n_params):
    """(*params, *batch) -> (loss, logits). The last batch arg is labels."""

    def f(*args):
        params = list(args[:n_params])
        batch = args[n_params:]
        return (loss(params, *batch), logits_fn(params, *batch[:-1]))

    return f
