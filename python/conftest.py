"""Pytest root config: enable 64-bit types (kernel tests exercise the f64
path; artifacts themselves remain f32 for the Rust runtime)."""

import jax

jax.config.update("jax_enable_x64", True)
