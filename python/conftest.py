"""Pytest root config.

When JAX is importable: enable 64-bit types (kernel tests exercise the
f64 path; artifacts themselves remain f32 for the Rust runtime).

When JAX is missing (hermetic/offline runners), skip collection of the
test tree with an explicit reason instead of erroring at import time —
every test module imports jax at module scope.
"""

try:
    import jax
except ImportError:  # pragma: no cover - exercised only on jax-less runners
    import sys

    print(
        "SKIP: jax is unavailable — skipping python/tests "
        "(install jax[cpu]; Pallas kernels run with interpret=True, no TPU needed)",
        file=sys.stderr,
    )
    collect_ignore_glob = ["tests/*"]
else:
    jax.config.update("jax_enable_x64", True)
