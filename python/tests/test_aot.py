"""AOT pipeline tests: lowering produces loadable HLO text, the manifest
is consistent, and no typed-FFI custom calls leak into artifacts."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile import models_proxy as proxy


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_ffi_guard_rejects_eigh():
    def fn(x):
        w, v = jnp.linalg.eigh(x @ x.T)
        return (w, v)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    with pytest.raises(RuntimeError, match="typed-FFI"):
        aot.to_hlo_text(lowered)


def test_lm_tiny_artifact_has_no_custom_calls():
    cfg = model.config("tiny")
    shapes = model.param_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes] + [
        jax.ShapeDtypeStruct((cfg["batch"], cfg["seq"] + 1), jnp.int32)
    ]
    lowered = jax.jit(model.grad_fn(cfg)).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text, "LM artifact must be pure HLO"


def test_proxy_artifacts_have_no_custom_calls():
    # Conv models are the risky ones (cuDNN-style lowering on GPU); on CPU
    # they must stay as plain HLO convolution ops.
    cfg = proxy.CNN_CFG
    shapes = proxy.cnn_param_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes] + [
        jax.ShapeDtypeStruct((cfg["batch"], cfg["h"] * cfg["w"]), jnp.float32),
        jax.ShapeDtypeStruct((cfg["batch"],), jnp.int32),
    ]
    lowered = jax.jit(proxy.make_grad_fn(proxy.cnn_loss, len(shapes))).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text
    assert "convolution" in text


def test_builder_writes_manifest_and_fixture(tmp_path=None):
    out = tempfile.mkdtemp()
    b = aot.Builder(out)

    def fn(x):
        return (2.0 * x,)

    spec = jax.ShapeDtypeStruct((2, 3), jnp.float32)
    x0 = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    b.add("double", fn, [spec], ["x"], 0, fixture_inputs=[x0])
    b.finish("test")
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["artifacts"][0]["name"] == "double"
    assert manifest["artifacts"][0]["inputs"][0]["shape"] == [2, 3]
    assert manifest["artifacts"][0]["n_outputs"] == 1
    fixtures = json.load(open(os.path.join(out, "fixtures.json")))
    np.testing.assert_allclose(
        fixtures["double"]["outputs"][0], (2 * x0).ravel()
    )
    assert os.path.exists(os.path.join(out, "double.hlo.txt"))


def test_manifest_input_order_matches_param_shapes():
    # The Rust runtime feeds parameters positionally; the manifest order
    # must equal model.param_shapes order.
    cfg = model.config("tiny")
    names = [n for n, _ in model.param_shapes(cfg)]
    assert names[0] == "embed" and names[-1] == "out"
    assert len(names) == len(set(names)), "duplicate param names"
