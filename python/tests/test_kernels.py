"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Randomized shape/dtype sweeps (seeded — hypothesis is not installed in
this environment, so the sweep is an explicit randomized grid).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.cov_update import cov_update, vmem_bytes
from compile.kernels.precond_apply import precond_apply
from compile.kernels.sketch_gram import sketch_gram


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


COV_SHAPES = [
    (4, 4), (8, 16), (16, 8), (32, 32), (128, 64), (64, 128),
    (256, 128), (1, 8), (128, 1),
]


@pytest.mark.parametrize("m,n", COV_SHAPES)
def test_cov_update_matches_ref(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    c = _rand(rng, n, n)
    c = c @ c.T  # PSD like a real accumulator
    g = _rand(rng, m, n)
    for beta2 in (1.0, 0.999, 0.5, 0.0):
        got = cov_update(c, g, beta2)
        want = ref.cov_update_ref(c, g, beta2)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block", [8, 16, 128])
def test_cov_update_block_size_invariance(block):
    rng = np.random.default_rng(7)
    c = _rand(rng, 32, 32)
    g = _rand(rng, 48, 32)
    got = cov_update(c, g, 0.9, block_n=block, block_k=block)
    want = ref.cov_update_ref(c, g, 0.9)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_cov_update_float64():
    rng = np.random.default_rng(8)
    c = _rand(rng, 16, 16, dtype=np.float64)
    g = _rand(rng, 24, 16, dtype=np.float64)
    got = cov_update(c, g, 0.99)
    want = ref.cov_update_ref(c, g, 0.99)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_cov_update_left_factor_via_transpose():
    # L update: pass G^T so news = (G^T)^T (G^T) = G G^T.
    rng = np.random.default_rng(9)
    c = _rand(rng, 12, 12)
    g = _rand(rng, 12, 20)
    got = cov_update(c, g.T, 1.0)
    want = c + g @ g.T
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


PRECOND_SHAPES = [(8, 8), (16, 4), (4, 16), (64, 32), (128, 128), (96, 80)]


@pytest.mark.parametrize("m,n", PRECOND_SHAPES)
def test_precond_apply_matches_ref(m, n):
    rng = np.random.default_rng(m * 97 + n)
    pl_root = _rand(rng, m, m)
    g = _rand(rng, m, n)
    pr_root = _rand(rng, n, n)
    got = precond_apply(pl_root, g, pr_root)
    want = ref.precond_apply_ref(pl_root, g, pr_root)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_precond_apply_identity_roots_is_noop():
    rng = np.random.default_rng(11)
    g = _rand(rng, 32, 16)
    got = precond_apply(jnp.eye(32), g, jnp.eye(16))
    np.testing.assert_allclose(got, g, rtol=1e-6, atol=1e-6)


SKETCH_SHAPES = [
    # (d, ell, r)
    (64, 8, 1), (128, 16, 4), (256, 4, 4), (512, 32, 8), (100, 10, 2),
]


@pytest.mark.parametrize("d,ell,r", SKETCH_SHAPES)
def test_sketch_gram_matches_ref(d, ell, r):
    rng = np.random.default_rng(d + ell + r)
    b = _rand(rng, d, ell)
    y = _rand(rng, d, r)
    for beta2 in (1.0, 0.99):
        got = sketch_gram(b, y, beta2)
        want = ref.sketch_gram_ref(b, y, beta2)
        assert got.shape == (ell + r, ell + r)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_sketch_gram_is_symmetric_psd():
    rng = np.random.default_rng(13)
    b = _rand(rng, 96, 12)
    y = _rand(rng, 96, 4)
    gram = np.asarray(sketch_gram(b, y, 0.9))
    np.testing.assert_allclose(gram, gram.T, atol=1e-5)
    w = np.linalg.eigvalsh(gram)
    assert w.min() > -1e-4


def test_vmem_budget_documented():
    # The DESIGN.md section 5 claim: default tiling stays far under 16 MiB.
    assert vmem_bytes() < 16 * 2**20 / 8
