"""L2 model tests: shapes, init-loss sanity, and finite-difference
gradient checks on tiny configurations."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile import models_proxy as proxy


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------

def _tiny():
    cfg = model.config("tiny")
    params = [jnp.asarray(p) for p in model.init_params(cfg, seed=0)]
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, cfg["vocab"], size=(cfg["batch"], cfg["seq"] + 1)),
        dtype=jnp.int32,
    )
    return cfg, params, tokens


def test_lm_param_shapes_all_2d():
    for preset in ("tiny", "small", "base"):
        cfg = model.config(preset)
        for name, shape in model.param_shapes(cfg):
            assert len(shape) == 2, f"{name} is not 2-D: {shape}"


def test_lm_large_preset_is_paper_scale():
    cfg = model.config("large")
    n = model.param_count(cfg)
    assert 80e6 < n < 120e6, f"large preset should be ~100M params, got {n}"


def test_lm_init_loss_near_uniform():
    cfg, params, tokens = _tiny()
    loss = model.loss_fn(cfg, params, tokens)
    uniform = np.log(cfg["vocab"])
    assert abs(float(loss) - uniform) < 0.35 * uniform


def test_lm_grads_match_param_shapes():
    cfg, params, tokens = _tiny()
    out = model.grad_fn(cfg)(*params, tokens)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_lm_finite_difference_gradient():
    cfg, params, tokens = _tiny()
    out = model.grad_fn(cfg)(*params, tokens)
    grads = out[1:]
    # Check a few entries of the output projection gradient.
    pidx = len(params) - 1  # "out"
    f64_params = [p.astype(jnp.float64) for p in params]
    for (i, j) in [(0, 0), (3, 7), (10, 20)]:
        eps = 1e-5
        pp = [p.copy() for p in f64_params]
        pp[pidx] = pp[pidx].at[i, j].add(eps)
        pm = [p.copy() for p in f64_params]
        pm[pidx] = pm[pidx].at[i, j].add(-eps)
        fd = (model.loss_fn(cfg, pp, tokens) - model.loss_fn(cfg, pm, tokens)) / (
            2 * eps
        )
        assert abs(float(fd) - float(grads[pidx][i, j])) < 1e-3, (
            f"({i},{j}): fd={float(fd)} ad={float(grads[pidx][i, j])}"
        )


def test_lm_causality():
    # Changing a future token must not change earlier logits.
    cfg, params, tokens = _tiny()
    inputs = tokens[:, :-1]
    logits1 = model.forward(cfg, params, inputs)
    perturbed = inputs.at[:, -1].set((inputs[:, -1] + 1) % cfg["vocab"])
    logits2 = model.forward(cfg, params, perturbed)
    np.testing.assert_allclose(
        logits1[:, : cfg["seq"] - 2], logits2[:, : cfg["seq"] - 2],
        rtol=1e-5, atol=1e-5,
    )


def test_lm_learns_constant_sequence():
    # Ten SGD steps on a constant-token batch should cut the loss.
    cfg, params, _ = _tiny()
    tokens = jnp.full((cfg["batch"], cfg["seq"] + 1), 5, dtype=jnp.int32)
    f = model.grad_fn(cfg)
    loss0 = None
    for _ in range(10):
        out = f(*params, tokens)
        loss, grads = out[0], out[1:]
        if loss0 is None:
            loss0 = float(loss)
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert float(loss) < 0.5 * loss0, f"{loss0} -> {float(loss)}"


# ---------------------------------------------------------------------------
# Proxy models
# ---------------------------------------------------------------------------

def test_cnn_shapes_and_loss():
    cfg = proxy.CNN_CFG
    params = [jnp.asarray(p) for p in proxy.cnn_init(0)]
    rng = np.random.default_rng(1)
    images = jnp.asarray(
        rng.standard_normal((cfg["batch"], cfg["h"] * cfg["w"])), jnp.float32
    )
    labels = jnp.asarray(rng.integers(0, cfg["classes"], cfg["batch"]), jnp.int32)
    logits = proxy.cnn_logits(params, images)
    assert logits.shape == (cfg["batch"], cfg["classes"])
    loss = proxy.cnn_loss(params, images, labels)
    assert abs(float(loss) - np.log(cfg["classes"])) < 1.0


def test_cnn_grads_finite_and_shaped():
    cfg = proxy.CNN_CFG
    params = [jnp.asarray(p) for p in proxy.cnn_init(0)]
    rng = np.random.default_rng(2)
    images = jnp.asarray(
        rng.standard_normal((cfg["batch"], cfg["h"] * cfg["w"])), jnp.float32
    )
    labels = jnp.asarray(rng.integers(0, cfg["classes"], cfg["batch"]), jnp.int32)
    out = proxy.make_grad_fn(proxy.cnn_loss, len(params))(*params, images, labels)
    assert len(out) == len(params) + 1
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_conformer_shapes_and_grads():
    cfg = proxy.CONF_CFG
    params = [jnp.asarray(p) for p in proxy.conformer_init(0)]
    rng = np.random.default_rng(3)
    spect = jnp.asarray(
        rng.standard_normal((cfg["batch"], cfg["frames"] * cfg["bins"])),
        jnp.float32,
    )
    labels = jnp.asarray(rng.integers(0, cfg["classes"], cfg["batch"]), jnp.int32)
    logits = proxy.conformer_logits(params, spect)
    assert logits.shape == (cfg["batch"], cfg["classes"])
    out = proxy.make_grad_fn(proxy.conformer_loss, len(params))(
        *params, spect, labels
    )
    for g in out[1:]:
        assert bool(jnp.all(jnp.isfinite(g)))


def test_gnn_shapes_and_grads():
    cfg = proxy.GNN_CFG
    params = [jnp.asarray(p) for p in proxy.gnn_init(0)]
    rng = np.random.default_rng(4)
    n = cfg["nodes"]
    adj = np.zeros((cfg["batch"], n, n), np.float32)
    for b in range(cfg["batch"]):
        for v in range(1, n):
            u = rng.integers(0, v)
            adj[b, v, u] = adj[b, u, v] = 1.0
        np.fill_diagonal(adj[b], 1.0)
    adjacency = jnp.asarray(adj.reshape(cfg["batch"], n * n))
    feats = jnp.asarray(
        rng.standard_normal((cfg["batch"], n * cfg["feat"])), jnp.float32
    )
    labels = jnp.asarray(
        rng.integers(0, 2, (cfg["batch"], cfg["tasks"])), jnp.float32
    )
    logits = proxy.gnn_logits(params, adjacency, feats)
    assert logits.shape == (cfg["batch"], cfg["tasks"])
    out = proxy.make_grad_fn(proxy.gnn_loss, len(params))(
        *params, adjacency, feats, labels
    )
    assert len(out) == len(params) + 1
    for g in out[1:]:
        assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("loss_is_permutation_invariant", [True])
def test_gnn_node_permutation_invariance(loss_is_permutation_invariant):
    # Mean-pooled GNN readout must be invariant to node relabeling.
    cfg = proxy.GNN_CFG
    params = [jnp.asarray(p) for p in proxy.gnn_init(0)]
    rng = np.random.default_rng(5)
    n = cfg["nodes"]
    adj = np.eye(n, dtype=np.float32)
    adj[0, 1] = adj[1, 0] = 1.0
    adj[2, 3] = adj[3, 2] = 1.0
    feats = rng.standard_normal((1, n, cfg["feat"])).astype(np.float32)
    perm = rng.permutation(n)
    adj_p = adj[np.ix_(perm, perm)]
    feats_p = feats[:, perm, :]
    l1 = proxy.gnn_logits(params, jnp.asarray(adj.reshape(1, -1)),
                          jnp.asarray(feats.reshape(1, -1)))
    l2 = proxy.gnn_logits(params, jnp.asarray(adj_p.reshape(1, -1)),
                          jnp.asarray(feats_p.reshape(1, -1)))
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
    assert loss_is_permutation_invariant
