//! Benchmark harness (criterion is not vendored — custom harness in
//! `sketchy::util::bench`). Covers the hot paths behind every experiment:
//!
//!   tensor      matmul / Gram / eigh throughput (L3 substrate roofline)
//!   sketch      FD update at paper scale (d=1024, ℓ=256)
//!   optim       per-step latency: Adam vs Shampoo vs S-Shampoo
//!   roots       spectral vs coupled-Newton inverse roots (ablation)
//!   allreduce   coordinator reduction
//!   artifact    XLA cov_update vs native Rust (needs `make artifacts`)
//!   e2e         full LM training step (needs `make artifacts`)
//!
//! Run: cargo bench [-- --fast] [-- --filter NAME]

use sketchy::optim::{
    Adam, EngineConfig, GraftType, Optimizer, PrecondEngine, SShampoo, SShampooConfig, Shampoo,
    ShampooConfig,
};
use sketchy::sketch::FdSketch;
use sketchy::tensor::{a_at, at_a, eigh, matmul, Matrix};
use sketchy::util::bench::{gflops, Bench};
use sketchy::util::cli::Args;
use sketchy::util::rng::Pcg64;

fn bench(name: &str, fast: bool) -> Bench {
    if fast {
        Bench::fast(name)
    } else {
        Bench::new(name)
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let fast = args.has("fast");
    let filter = args.get("filter").map(|s| s.to_string());
    let run = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);
    let mut rows: Vec<String> = vec![];
    let mut record = |b: &Bench, extra: String| {
        println!("{} {extra}", b.report());
        rows.push(format!("{},{extra}", b.csv_row()));
    };
    let mut rng = Pcg64::new(0xbe);

    // ---------------- tensor substrate ----------------
    for &n in &[128usize, 256, 512] {
        let name = format!("tensor/matmul_{n}");
        if run(&name) {
            let a = Matrix::randn(n, n, &mut rng);
            let b2 = Matrix::randn(n, n, &mut rng);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                std::hint::black_box(matmul(&a, &b2));
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((2 * n * n * n) as f64, st.median)));
        }
    }
    for &(k, n) in &[(256usize, 128usize), (1024, 256)] {
        let name = format!("tensor/gram_at_a_{k}x{n}");
        if run(&name) {
            let a = Matrix::randn(k, n, &mut rng);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                std::hint::black_box(at_a(&a));
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((k * n * n) as f64, st.median)));
        }
    }
    for &n in &[64usize, 128, 256, 512] {
        let name = format!("tensor/eigh_{n}");
        if run(&name) {
            let g = Matrix::randn(2 * n, n, &mut rng);
            let a = at_a(&g);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                std::hint::black_box(eigh(&a));
            });
            record(&bh, format!("{:.1} n³flop/s-scale {:.2}", 0.0, (n * n * n) as f64 / st.median.as_secs_f64() / 1e9));
        }
    }

    // ---------------- FD sketch (paper scale) ----------------
    // Fig. 3 block size is 1024 with ℓ=256; news rank r = batch of
    // gradient columns folded per stat step.
    for &(d, ell, r) in &[(1024usize, 256usize, 1usize), (1024, 256, 32), (256, 16, 256)] {
        let name = format!("sketch/fd_update_d{d}_l{ell}_r{r}");
        if run(&name) {
            let mut fd = FdSketch::new(d, ell, 0.999);
            // Warm the sketch to steady state.
            for _ in 0..3 {
                let y = Matrix::randn(d, r.max(ell / 4), &mut rng);
                fd.update(&y);
            }
            let y = Matrix::randn(d, r, &mut rng);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                let mut f2 = fd.clone();
                std::hint::black_box(f2.update(&y));
            });
            // Dominant cost: Gram build d(ℓ+r)² + eigh (ℓ+r)³ + basis d(ℓ+r)ℓ.
            let m = ell + r;
            let fl = (d * m * m + m * m * m + d * m * ell) as f64;
            record(&bh, format!("{:.2} GFLOP/s (nominal)", gflops(fl, st.median)));
        }
    }

    // ---------------- optimizer step latency ----------------
    let shapes = [(256usize, 128usize), (128, 256), (256, 1)];
    let grads: Vec<Matrix> = shapes
        .iter()
        .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
        .collect();
    let cfg = ShampooConfig {
        lr: 1e-3,
        start_preconditioning_step: 1,
        stat_interval: 1,
        precond_interval: 1,
        graft: GraftType::RmspropNormalized,
        ..Default::default()
    };
    if run("optim/adam_step") {
        let mut params: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut opt = Adam::new(&shapes, 1e-3);
        let mut bh = bench("optim/adam_step", fast);
        bh.run(|| opt.step(&mut params, &grads));
        record(&bh, String::new());
    }
    if run("optim/shampoo_step") {
        let mut params: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut opt = Shampoo::new(&shapes, cfg.clone());
        let mut bh = bench("optim/shampoo_step", fast);
        bh.run(|| opt.step(&mut params, &grads));
        record(&bh, String::new());
    }
    for &rank in &[16usize, 64] {
        let name = format!("optim/s_shampoo_step_l{rank}");
        if run(&name) {
            let mut params: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
            let mut opt = SShampoo::new(&shapes, SShampooConfig { base: cfg.clone(), rank });
            let mut bh = bench(&name, fast);
            bh.run(|| opt.step(&mut params, &grads));
            record(&bh, String::new());
        }
    }

    // ---------------- inverse-root ablation (DESIGN.md §9) ----------------
    if run("roots/eigh_vs_newton_128") {
        let g = Matrix::randn(256, 128, &mut rng);
        let a = at_a(&g);
        let mut bh = bench("roots/eigh_inv4root_128", fast);
        bh.run(|| {
            std::hint::black_box(sketchy::tensor::inv_pth_root(&a, 4.0, 1e-6));
        });
        record(&bh, String::new());
        let mut bh = bench("roots/newton_inv4root_128", fast);
        bh.run(|| {
            std::hint::black_box(sketchy::tensor::roots::inv_pth_root_newton(&a, 4, 1e-6, 40));
        });
        record(&bh, String::new());
    }

    // ---------------- coordinator allreduce ----------------
    if run("coordinator/allreduce_8x") {
        let shards: Vec<Vec<Matrix>> = (0..8)
            .map(|_| vec![Matrix::randn(256, 256, &mut rng)])
            .collect();
        let mut bh = bench("coordinator/allreduce_8x256x256", fast);
        bh.run(|| {
            std::hint::black_box(sketchy::coordinator::tree_allreduce(shards.clone()).unwrap());
        });
        record(&bh, String::new());
    }

    // ---------------- preconditioner engine (multi-block) ----------------
    // Serial-vs-parallel step latency over the §3.4 block partition with
    // the staggered stale-refresh schedule, plus a bitwise identity check.
    // Emits bench_out/BENCH_precond_engine.json — the CI perf record,
    // which `sketchy bench-gate` compares against the committed
    // bench_out/BENCH_baseline.json. The record carries `calibration_ns`
    // (a fixed single-threaded 256×256 matmul measured in this same
    // process) so the gate can compare engine-time/calibration ratios
    // instead of raw nanoseconds — baselines stay meaningful on CI
    // runners of unknown speed.
    if run("engine/multiblock_step") {
        let eng_shapes = [(256usize, 256usize), (256, 128)];
        let block = 64;
        let refresh_interval = 4;
        let base = cfg.clone();
        let mk = |threads: usize| {
            PrecondEngine::shampoo(
                &eng_shapes,
                base.clone(),
                EngineConfig { threads, block_size: block, refresh_interval, stagger: true },
            )
        };
        let eng_grads: Vec<Matrix> = eng_shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
            .collect();
        let par_threads = sketchy::tensor::ops::num_threads().clamp(2, 8);
        let n_blocks = mk(1).blocks().len();
        // Bitwise identity: the parallel path must equal the serial path.
        let mut identical = true;
        {
            let mut serial = mk(1);
            let mut parallel = mk(par_threads);
            let mut p1: Vec<Matrix> =
                eng_shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
            let mut p2 = p1.clone();
            for _ in 0..6 {
                serial.step(&mut p1, &eng_grads);
                parallel.step(&mut p2, &eng_grads);
            }
            for (a, b) in p1.iter().zip(&p2) {
                if a.max_diff(b) != 0.0 {
                    identical = false;
                }
            }
        }
        // Machine-speed calibration for the regression gate: one fixed
        // dense workload, pinned to a single thread so runner core
        // counts cancel out of the normalized ratios.
        let cal_a = Matrix::randn(256, 256, &mut rng);
        let cal_b = Matrix::randn(256, 256, &mut rng);
        let mut bh = bench("engine/calibration_matmul256_1t", fast);
        let st_cal = bh.run(|| {
            sketchy::tensor::ops::with_single_thread(|| {
                std::hint::black_box(matmul(&cal_a, &cal_b));
            });
        });
        record(&bh, "gate calibration".to_string());
        let mut eng = mk(1);
        let mut eng_params: Vec<Matrix> =
            eng_shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut bh = bench("engine/multiblock_step_t1", fast);
        let st_serial = bh.run(|| eng.step(&mut eng_params, &eng_grads));
        record(&bh, format!("{n_blocks} blocks"));
        let mut eng = mk(par_threads);
        let mut eng_params: Vec<Matrix> =
            eng_shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let name = format!("engine/multiblock_step_t{par_threads}");
        let mut bh = bench(&name, fast);
        let st_par = bh.run(|| eng.step(&mut eng_params, &eng_grads));
        let speedup = st_serial.median.as_secs_f64() / st_par.median.as_secs_f64();
        record(&bh, format!("{n_blocks} blocks speedup x{speedup:.2} identical={identical}"));
        std::fs::create_dir_all("bench_out").ok();
        let cal_ns = st_cal.median.as_nanos();
        let serial_ns = st_serial.median.as_nanos();
        let par_ns = st_par.median.as_nanos();
        let json = format!(
            "{{\n  \"bench\": \"precond_engine\",\n  \"shapes\": \"256x256+256x128\",\n  \
             \"block_size\": {block},\n  \"blocks\": {n_blocks},\n  \
             \"refresh_interval\": {refresh_interval},\n  \"serial_threads\": 1,\n  \
             \"parallel_threads\": {par_threads},\n  \"calibration_ns\": {cal_ns},\n  \
             \"serial_median_ns\": {serial_ns},\n  \"parallel_median_ns\": {par_ns},\n  \
             \"serial_per_calibration\": {:.4},\n  \"parallel_per_calibration\": {:.4},\n  \
             \"speedup\": {speedup:.4},\n  \"identical\": {identical}\n}}\n",
            serial_ns as f64 / cal_ns as f64,
            par_ns as f64 / cal_ns as f64,
        );
        std::fs::write("bench_out/BENCH_precond_engine.json", &json).unwrap();
        println!("[engine perf record written to bench_out/BENCH_precond_engine.json]");
        assert!(identical, "parallel engine diverged from serial — perf record invalid");
    }

    // ---------------- artifact + e2e (need artifacts) ----------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = std::sync::Arc::new(sketchy::runtime::Runtime::load("artifacts").unwrap());
        if run("artifact/cov_update_256_xla") {
            let c: Vec<f32> = (0..256 * 256).map(|_| rng.gaussian() as f32).collect();
            let g: Vec<f32> = (0..256 * 256).map(|_| rng.gaussian() as f32).collect();
            rt.executable("cov_update_256").unwrap();
            let mut bh = bench("artifact/cov_update_256_xla", fast);
            let st = bh.run(|| {
                let inputs = [
                    sketchy::runtime::literal::lit_f32(&c, &[256, 256]).unwrap(),
                    sketchy::runtime::literal::lit_f32(&g, &[256, 256]).unwrap(),
                ];
                std::hint::black_box(rt.execute("cov_update_256", &inputs).unwrap());
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((2 * 256 * 256 * 256) as f64, st.median)));
            // Native Rust equivalent for the same work.
            let cm = Matrix::randn(256, 256, &mut rng);
            let gm = Matrix::randn(256, 256, &mut rng);
            let mut bh = bench("artifact/cov_update_256_native", fast);
            let st = bh.run(|| {
                let mut c2 = cm.scale(0.999);
                c2.axpy(1.0, &at_a(&gm));
                std::hint::black_box(c2);
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((2 * 256 * 256 * 256) as f64, st.median)));
            let _ = a_at(&gm);
        }
        if run("e2e/lm_tiny_step") {
            use sketchy::data::MarkovCorpus;
            use sketchy::train::LmTrainer;
            let mut trainer = LmTrainer::new(rt.clone(), "tiny", 1).unwrap();
            let shapes = trainer.shapes.clone();
            let mut corpus = MarkovCorpus::new(trainer.vocab, 1);
            let mut opt = SShampoo::new(
                &shapes,
                SShampooConfig { base: cfg.clone(), rank: 8 },
            );
            // Warm up compile.
            trainer.step(&mut opt, &mut corpus, 2).unwrap();
            let mut bh = bench("e2e/lm_tiny_step_s_shampoo_2workers", fast);
            bh.run(|| {
                trainer.step(&mut opt, &mut corpus, 2).unwrap();
            });
            record(&bh, String::new());
        }
    } else {
        eprintln!("NOTE: artifact/e2e benches skipped (run `make artifacts`)");
    }

    // CSV dump.
    std::fs::create_dir_all("bench_out").ok();
    let csv = format!(
        "name,iters,median_ns,p10_ns,p90_ns,mean_ns,extra\n{}\n",
        rows.join("\n")
    );
    std::fs::write("bench_out/bench_main.csv", csv).unwrap();
    println!("\n[csv written to bench_out/bench_main.csv]");
}
