//! Benchmark harness (criterion is not vendored — custom harness in
//! `sketchy::util::bench`). Covers the hot paths behind every experiment:
//!
//!   tensor      matmul / Gram / eigh throughput (L3 substrate roofline)
//!   sketch      FD update at paper scale (d=1024, ℓ=256)
//!   optim       per-step latency: Adam vs Shampoo vs S-Shampoo
//!   roots       spectral vs coupled-Newton inverse roots (ablation)
//!   allreduce   coordinator reduction
//!   artifact    XLA cov_update vs native Rust (needs `make artifacts`)
//!   e2e         full LM training step (needs `make artifacts`)
//!
//! Run: cargo bench [-- --fast] [-- --filter NAME]

use sketchy::optim::{
    Adam, EngineConfig, GraftType, Optimizer, PrecondEngine, SShampoo, SShampooConfig, Shampoo,
    ShampooConfig,
};
use sketchy::sketch::FdSketch;
use sketchy::tensor::{a_at, at_a, eigh, inv_pth_root, matmul, ops, Matrix};
use sketchy::util::bench::{gflops, Bench};
use sketchy::util::cli::Args;
use sketchy::util::rng::Pcg64;

fn bench(name: &str, fast: bool) -> Bench {
    if fast {
        Bench::fast(name)
    } else {
        Bench::new(name)
    }
}

fn zeros_like(shapes: &[(usize, usize)]) -> Vec<Matrix> {
    shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect()
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let fast = args.has("fast");
    let filter = args.get("filter").map(|s| s.to_string());
    let run = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);
    let mut rows: Vec<String> = vec![];
    let mut record = |b: &Bench, extra: String| {
        println!("{} {extra}", b.report());
        rows.push(format!("{},{extra}", b.csv_row()));
    };
    let mut rng = Pcg64::new(0xbe);

    // ---------------- tensor substrate ----------------
    for &n in &[128usize, 256, 512] {
        let name = format!("tensor/matmul_{n}");
        if run(&name) {
            let a = Matrix::randn(n, n, &mut rng);
            let b2 = Matrix::randn(n, n, &mut rng);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                std::hint::black_box(matmul(&a, &b2));
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((2 * n * n * n) as f64, st.median)));
        }
    }
    for &(k, n) in &[(256usize, 128usize), (1024, 256)] {
        let name = format!("tensor/gram_at_a_{k}x{n}");
        if run(&name) {
            let a = Matrix::randn(k, n, &mut rng);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                std::hint::black_box(at_a(&a));
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((k * n * n) as f64, st.median)));
        }
    }
    for &n in &[64usize, 128, 256, 512] {
        let name = format!("tensor/eigh_{n}");
        if run(&name) {
            let g = Matrix::randn(2 * n, n, &mut rng);
            let a = at_a(&g);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                std::hint::black_box(eigh(&a));
            });
            record(&bh, format!("{:.1} n³flop/s-scale {:.2}", 0.0, (n * n * n) as f64 / st.median.as_secs_f64() / 1e9));
        }
    }

    // ---------------- FD sketch (paper scale) ----------------
    // Fig. 3 block size is 1024 with ℓ=256; news rank r = batch of
    // gradient columns folded per stat step.
    for &(d, ell, r) in &[(1024usize, 256usize, 1usize), (1024, 256, 32), (256, 16, 256)] {
        let name = format!("sketch/fd_update_d{d}_l{ell}_r{r}");
        if run(&name) {
            let mut fd = FdSketch::new(d, ell, 0.999);
            // Warm the sketch to steady state.
            for _ in 0..3 {
                let y = Matrix::randn(d, r.max(ell / 4), &mut rng);
                fd.update(&y);
            }
            let y = Matrix::randn(d, r, &mut rng);
            let mut bh = bench(&name, fast);
            let st = bh.run(|| {
                let mut f2 = fd.clone();
                std::hint::black_box(f2.update(&y));
            });
            // Dominant cost: Gram build d(ℓ+r)² + eigh (ℓ+r)³ + basis d(ℓ+r)ℓ.
            let m = ell + r;
            let fl = (d * m * m + m * m * m + d * m * ell) as f64;
            record(&bh, format!("{:.2} GFLOP/s (nominal)", gflops(fl, st.median)));
        }
    }

    // ---------------- optimizer step latency ----------------
    let shapes = [(256usize, 128usize), (128, 256), (256, 1)];
    let grads: Vec<Matrix> = shapes
        .iter()
        .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
        .collect();
    let cfg = ShampooConfig {
        lr: 1e-3,
        start_preconditioning_step: 1,
        stat_interval: 1,
        precond_interval: 1,
        graft: GraftType::RmspropNormalized,
        ..Default::default()
    };
    if run("optim/adam_step") {
        let mut params: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut opt = Adam::new(&shapes, 1e-3);
        let mut bh = bench("optim/adam_step", fast);
        bh.run(|| opt.step(&mut params, &grads));
        record(&bh, String::new());
    }
    if run("optim/shampoo_step") {
        let mut params: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut opt = Shampoo::new(&shapes, cfg.clone());
        let mut bh = bench("optim/shampoo_step", fast);
        bh.run(|| opt.step(&mut params, &grads));
        record(&bh, String::new());
    }
    for &rank in &[16usize, 64] {
        let name = format!("optim/s_shampoo_step_l{rank}");
        if run(&name) {
            let mut params: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
            let mut opt = SShampoo::new(&shapes, SShampooConfig { base: cfg.clone(), rank });
            let mut bh = bench(&name, fast);
            bh.run(|| opt.step(&mut params, &grads));
            record(&bh, String::new());
        }
    }

    // ---------------- inverse-root ablation (DESIGN.md §9) ----------------
    if run("roots/eigh_vs_newton_128") {
        let g = Matrix::randn(256, 128, &mut rng);
        let a = at_a(&g);
        let mut bh = bench("roots/eigh_inv4root_128", fast);
        bh.run(|| {
            std::hint::black_box(sketchy::tensor::inv_pth_root(&a, 4.0, 1e-6));
        });
        record(&bh, String::new());
        let mut bh = bench("roots/newton_inv4root_128", fast);
        bh.run(|| {
            std::hint::black_box(sketchy::tensor::roots::inv_pth_root_newton(&a, 4, 1e-6, 40));
        });
        record(&bh, String::new());
    }

    // ---------------- coordinator allreduce ----------------
    if run("coordinator/allreduce_8x") {
        let shards: Vec<Vec<Matrix>> = (0..8)
            .map(|_| vec![Matrix::randn(256, 256, &mut rng)])
            .collect();
        let mut bh = bench("coordinator/allreduce_8x256x256", fast);
        bh.run(|| {
            std::hint::black_box(sketchy::coordinator::tree_allreduce(shards.clone()).unwrap());
        });
        record(&bh, String::new());
    }

    // ---------------- preconditioner engine (multi-block) ----------------
    // Serial-vs-parallel step latency over the §3.4 block partition with
    // the staggered stale-refresh schedule, plus a bitwise identity check.
    // Together with the per-step-overhead and overlap sections below this
    // emits bench_out/BENCH_precond_engine.json — the CI perf record,
    // which `sketchy bench-gate` compares against the committed
    // bench_out/BENCH_baseline.json. The record carries `calibration_ns`
    // (a fixed single-threaded 256×256 matmul measured in this same
    // process) so the gate can compare engine-time/calibration ratios
    // instead of raw nanoseconds — baselines stay meaningful on CI
    // runners of unknown speed.
    // Shared by the multiblock section and the gate-record assembly so
    // the committed record can never drift from the measured config.
    let mb_block = 64usize;
    let mb_refresh_interval = 4usize;
    let mut identical = true;
    let mut cal_ns: Option<u128> = None;
    let mut serial_ns: Option<u128> = None;
    let mut par_ns: Option<u128> = None;
    let mut par_threads_used = 0usize;
    let mut mb_blocks = 0usize;
    let mut mb_speedup = 0.0f64;
    let mut step_overhead_ns: Option<u128> = None;
    let mut overlap_sync_ns: Option<u128> = None;
    let mut overlap_on_ns: Option<u128> = None;
    let mut overlap_speedup: Option<f64> = None;
    let mut shard_overlap_sync_ns: Option<u128> = None;
    let mut shard_overlap_on_ns: Option<u128> = None;
    let mut shard_overlap_speedup: Option<f64> = None;
    if run("engine/multiblock_step") {
        let eng_shapes = [(256usize, 256usize), (256, 128)];
        let base = cfg.clone();
        let mk = |threads: usize| {
            PrecondEngine::shampoo(
                &eng_shapes,
                base.clone(),
                EngineConfig {
                    threads,
                    block_size: mb_block,
                    refresh_interval: mb_refresh_interval,
                    stagger: true,
                    ..Default::default()
                },
            )
        };
        let eng_grads: Vec<Matrix> = eng_shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
            .collect();
        let par_threads = ops::num_threads().clamp(2, 8);
        par_threads_used = par_threads;
        let n_blocks = mk(1).blocks().len();
        mb_blocks = n_blocks;
        // Bitwise identity: the parallel path must equal the serial path.
        {
            let mut serial = mk(1);
            let mut parallel = mk(par_threads);
            let mut p1 = zeros_like(&eng_shapes);
            let mut p2 = p1.clone();
            for _ in 0..6 {
                serial.step(&mut p1, &eng_grads);
                parallel.step(&mut p2, &eng_grads);
            }
            for (a, b) in p1.iter().zip(&p2) {
                if a.max_diff(b) != 0.0 {
                    identical = false;
                }
            }
        }
        // Machine-speed calibration for the regression gate: one fixed
        // dense workload, pinned to a single thread so runner core
        // counts cancel out of the normalized ratios.
        let cal_a = Matrix::randn(256, 256, &mut rng);
        let cal_b = Matrix::randn(256, 256, &mut rng);
        let mut bh = bench("engine/calibration_matmul256_1t", fast);
        let st_cal = bh.run(|| {
            ops::with_single_thread(|| {
                std::hint::black_box(matmul(&cal_a, &cal_b));
            });
        });
        record(&bh, "gate calibration".to_string());
        cal_ns = Some(st_cal.median.as_nanos());
        let mut eng = mk(1);
        let mut eng_params = zeros_like(&eng_shapes);
        let mut bh = bench("engine/multiblock_step_t1", fast);
        let st_serial = bh.run(|| eng.step(&mut eng_params, &eng_grads));
        record(&bh, format!("{n_blocks} blocks"));
        serial_ns = Some(st_serial.median.as_nanos());
        let mut eng = mk(par_threads);
        let mut eng_params = zeros_like(&eng_shapes);
        let name = format!("engine/multiblock_step_t{par_threads}");
        let mut bh = bench(&name, fast);
        let st_par = bh.run(|| eng.step(&mut eng_params, &eng_grads));
        let speedup = st_serial.median.as_secs_f64() / st_par.median.as_secs_f64();
        mb_speedup = speedup;
        par_ns = Some(st_par.median.as_nanos());
        record(&bh, format!("{n_blocks} blocks speedup x{speedup:.2} identical={identical}"));
        assert!(identical, "parallel engine diverged from serial — perf record invalid");
    }

    // ---------------- engine per-step overhead ----------------
    // 64 tiny diagonal (Adam) blocks: per-block math is microseconds, so
    // this measures the runtime's scheduling cost per step — the tax the
    // persistent pool removes relative to spawning scoped threads every
    // step. Gate-tracked as `step_overhead_ns`.
    if run("engine/step_overhead") {
        let oh_shapes = [(64usize, 64usize)];
        let mut eng = PrecondEngine::adam(
            &oh_shapes,
            cfg.clone(),
            EngineConfig { threads: 4, block_size: 8, ..Default::default() },
        );
        let mut oh_params = zeros_like(&oh_shapes);
        let oh_grads: Vec<Matrix> = oh_shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
            .collect();
        let mut bh = bench("engine/step_overhead_64blk_t4", fast);
        let st = bh.run(|| eng.step(&mut oh_params, &oh_grads));
        record(&bh, format!("{} tiny blocks (dispatch overhead)", eng.blocks().len()));
        step_overhead_ns = Some(st.median.as_nanos());
    }

    // ---------------- pipelined refresh overlap ----------------
    // Refresh-heavy schedule (refresh_interval 2, stagger on) with
    // simulated gradient computation between steps, sized to the
    // measured per-step eigendecomposition cost — the balanced-pipeline
    // regime where RefreshAhead should hide the refreshes that land on
    // non-ingest steps (3 of 4 at stat_interval 4). One bench iteration
    // is a full 4-step schedule period so the median is taken over
    // homogeneous samples. Gate-tracked as `overlap_sync_ns`,
    // `overlap_on_ns`, and the floored `overlap_speedup`.
    if run("engine/overlap_refresh") {
        let ov_shapes = [(192usize, 384usize)];
        let ov_base = ShampooConfig {
            lr: 1e-3,
            start_preconditioning_step: 1,
            stat_interval: 4,
            graft: GraftType::RmspropNormalized,
            ..Default::default()
        };
        let mk = |overlap: bool| {
            PrecondEngine::shampoo(
                &ov_shapes,
                ov_base.clone(),
                EngineConfig {
                    threads: 2,
                    block_size: 96,
                    refresh_interval: 2,
                    stagger: true,
                    overlap,
                    ..Default::default()
                },
            )
        };
        let ov_grads: Vec<Matrix> = ov_shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
            .collect();
        // Bitwise identity + refresh accounting: overlap ≡ synchronous.
        let mut ov_identical = true;
        {
            let mut sync = mk(false);
            let mut over = mk(true);
            let mut p1 = zeros_like(&ov_shapes);
            let mut p2 = p1.clone();
            let mut srng = Pcg64::new(0x0eef);
            for _ in 0..24 {
                let grads: Vec<Matrix> = ov_shapes
                    .iter()
                    .map(|&(r, c)| Matrix::randn(r, c, &mut srng))
                    .collect();
                sync.step(&mut p1, &grads);
                over.step(&mut p2, &grads);
            }
            for (a, b) in p1.iter().zip(&p2) {
                if a.max_diff(b) != 0.0 {
                    ov_identical = false;
                }
            }
            if sync.refreshes() != over.refreshes() {
                ov_identical = false;
            }
        }
        identical = identical && ov_identical;
        // Calibrate the simulated gradient work against the measured
        // inverse-root cost so the pipeline is balanced on any machine:
        // target ≈ one step's due refreshes (4 blocks × 2 roots of 96).
        let probe = at_a(&Matrix::randn(192, 96, &mut rng));
        let root_ns = (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                std::hint::black_box(inv_pth_root(&probe, 4.0, 1e-6));
                t0.elapsed().as_nanos()
            })
            .min()
            .unwrap()
            .max(1);
        let gw_a = Matrix::randn(256, 256, &mut rng);
        let gw_b = Matrix::randn(256, 256, &mut rng);
        let mm_ns = (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                ops::with_single_thread(|| {
                    std::hint::black_box(matmul(&gw_a, &gw_b));
                });
                t0.elapsed().as_nanos()
            })
            .min()
            .unwrap()
            .max(1);
        let reps = ((8 * root_ns) / mm_ns).clamp(1, 64) as usize;
        let grad_work = || {
            for _ in 0..reps {
                ops::with_single_thread(|| {
                    std::hint::black_box(matmul(&gw_a, &gw_b));
                });
            }
        };
        let mut sync = mk(false);
        let mut p_sync = zeros_like(&ov_shapes);
        let mut bh = bench("engine/overlap_refresh_sync4", fast);
        let st_sync = bh.run(|| {
            for _ in 0..4 {
                grad_work();
                sync.step(&mut p_sync, &ov_grads);
            }
        });
        record(&bh, format!("4-step period, grad-work x{reps} matmul256"));
        let mut over = mk(true);
        let mut p_over = zeros_like(&ov_shapes);
        let mut bh = bench("engine/overlap_refresh_on4", fast);
        let st_over = bh.run(|| {
            for _ in 0..4 {
                grad_work();
                over.step(&mut p_over, &ov_grads);
            }
        });
        let speedup = st_sync.median.as_secs_f64() / st_over.median.as_secs_f64();
        record(
            &bh,
            format!("4-step period, speedup x{speedup:.2} identical={ov_identical}"),
        );
        overlap_sync_ns = Some(st_sync.median.as_nanos());
        overlap_on_ns = Some(st_over.median.as_nanos());
        overlap_speedup = Some(speedup);
        assert!(ov_identical, "overlap engine diverged from synchronous — record invalid");
    }

    // ---------------- sharded refresh overlap ----------------
    // The same refresh-heavy 4-step period driven through a 2-shard
    // executor over the in-memory transport (full wire protocol, no
    // socket noise): with `--overlap-refresh` the t+1 due-set ships to
    // each worker as a second in-flight RefreshAhead RPC, so the
    // workers' eigendecompositions hide behind the driver's simulated
    // gradient work. Gate-tracked as `shard_overlap_sync_ns`,
    // `shard_overlap_on_ns`, and the floored `shard_overlap_speedup`.
    if run("engine/shard_overlap") {
        use sketchy::coordinator::wire::PROTO_VERSION;
        use sketchy::coordinator::{FaultInjectingTransport, FaultScript};
        use sketchy::optim::{ExecutorBuilder, UnitKind};
        use std::sync::Arc;
        use std::time::Duration;
        let sh_shapes = [(192usize, 384usize)];
        let sh_base = ShampooConfig {
            lr: 1e-3,
            start_preconditioning_step: 1,
            stat_interval: 4,
            graft: GraftType::RmspropNormalized,
            ..Default::default()
        };
        let mk = |overlap: bool| {
            // Fresh transports per engine (acceptors are single-take);
            // a generous timeout cap so a loaded runner never triggers
            // the reconnect path mid-measurement.
            let transports: Vec<Arc<FaultInjectingTransport>> = (0..2)
                .map(|_| {
                    FaultInjectingTransport::with_config(
                        FaultScript::none(),
                        usize::MAX,
                        Some(Duration::from_secs(60)),
                    )
                })
                .collect();
            // Full frames: this bench times the RefreshAhead overlap win
            // against the PR-4 baseline; wire payload size has its own
            // bench + gate below.
            ExecutorBuilder::in_proc(transports, PROTO_VERSION, false)
                .build(
                    &sh_shapes,
                    UnitKind::Shampoo,
                    sh_base.clone(),
                    EngineConfig {
                        threads: 1,
                        block_size: 96,
                        refresh_interval: 2,
                        stagger: true,
                        overlap,
                        ..Default::default()
                    },
                )
                .expect("launch in-proc sharded engine")
        };
        // Bitwise identity + refresh accounting: sharded overlap ≡
        // sharded synchronous (both are pinned ≡ local elsewhere).
        let mut sh_identical = true;
        {
            let mut sync = mk(false);
            let mut over = mk(true);
            let mut p1 = zeros_like(&sh_shapes);
            let mut p2 = p1.clone();
            let mut srng = Pcg64::new(0x5eef);
            for _ in 0..24 {
                let grads: Vec<Matrix> = sh_shapes
                    .iter()
                    .map(|&(r, c)| Matrix::randn(r, c, &mut srng))
                    .collect();
                sync.step(&mut p1, &grads);
                over.step(&mut p2, &grads);
            }
            for (a, b) in p1.iter().zip(&p2) {
                if a.max_diff(b) != 0.0 {
                    sh_identical = false;
                }
            }
            if sync.refreshes() != over.refreshes() {
                sh_identical = false;
            }
        }
        identical = identical && sh_identical;
        // Balance the simulated gradient work to the measured
        // inverse-root cost (same recipe as the in-process overlap
        // bench): target ≈ one step's due refreshes.
        let probe = at_a(&Matrix::randn(192, 96, &mut rng));
        let root_ns = (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                std::hint::black_box(inv_pth_root(&probe, 4.0, 1e-6));
                t0.elapsed().as_nanos()
            })
            .min()
            .unwrap()
            .max(1);
        let gw_a = Matrix::randn(256, 256, &mut rng);
        let gw_b = Matrix::randn(256, 256, &mut rng);
        let mm_ns = (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                ops::with_single_thread(|| {
                    std::hint::black_box(matmul(&gw_a, &gw_b));
                });
                t0.elapsed().as_nanos()
            })
            .min()
            .unwrap()
            .max(1);
        let reps = ((8 * root_ns) / mm_ns).clamp(1, 64) as usize;
        let grad_work = || {
            for _ in 0..reps {
                ops::with_single_thread(|| {
                    std::hint::black_box(matmul(&gw_a, &gw_b));
                });
            }
        };
        let sh_grads: Vec<Matrix> = sh_shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
            .collect();
        let mut sync = mk(false);
        let mut p_sync = zeros_like(&sh_shapes);
        let mut bh = bench("engine/shard_overlap_sync4_2sh", fast);
        let st_sync = bh.run(|| {
            for _ in 0..4 {
                grad_work();
                sync.step(&mut p_sync, &sh_grads);
            }
        });
        record(&bh, format!("4-step period, 2 shards, grad-work x{reps} matmul256"));
        let mut over = mk(true);
        let mut p_over = zeros_like(&sh_shapes);
        let mut bh = bench("engine/shard_overlap_on4_2sh", fast);
        let st_over = bh.run(|| {
            for _ in 0..4 {
                grad_work();
                over.step(&mut p_over, &sh_grads);
            }
        });
        let speedup = st_sync.median.as_secs_f64() / st_over.median.as_secs_f64();
        record(
            &bh,
            format!("4-step period, 2 shards, speedup x{speedup:.2} identical={sh_identical}"),
        );
        shard_overlap_sync_ns = Some(st_sync.median.as_nanos());
        shard_overlap_on_ns = Some(st_over.median.as_nanos());
        shard_overlap_speedup = Some(speedup);
        assert!(sh_identical, "sharded overlap diverged from synchronous — record invalid");
    }

    // ---------------- shard wire bytes (delta-compressed payloads) -----
    // The multi-host payoff metric: total frame bytes delivered over the
    // in-memory transport for the same stagger-refresh workload at wire
    // protocol v2 (full frames) vs v3 with delta compression. The
    // workload is LM-shaped — a one-sided embedding-style tensor whose
    // gradient touches a small rotating subset of token columns each
    // step (most of a vocab is absent from any one batch) plus a dense
    // projection — under the staggered stale-refresh schedule. Byte
    // counts are fully deterministic (no timing), so the recorded
    // `shard_wire_ratio` is machine-independent and the baseline floors
    // it at 3x (`shard_wire_ratio_min`).
    let mut shard_wire_v2_bytes: Option<u64> = None;
    let mut shard_wire_v3_bytes: Option<u64> = None;
    let mut shard_wire_ratio: Option<f64> = None;
    if run("engine/shard_wire_bytes") {
        use sketchy::coordinator::wire::PROTO_VERSION;
        use sketchy::coordinator::{FaultInjectingTransport, FaultScript};
        use sketchy::optim::{ExecutorBuilder, UnitKind};
        use std::sync::Arc;
        use std::time::Duration;
        let wb_shapes = [(32usize, 512usize), (64, 64)];
        let wb_base = ShampooConfig {
            lr: 1e-3,
            beta1: 0.0,
            weight_decay: 0.0,
            one_sided: true,
            start_preconditioning_step: 2,
            stat_interval: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let wb_ecfg = EngineConfig {
            threads: 1,
            block_size: 64,
            refresh_interval: 2,
            stagger: true,
            ..Default::default()
        };
        let wb_steps = 12usize;
        // Deterministic embedding-style gradient stream: 16 active
        // token columns per step, dense projection fully active.
        let wb_grads = |rng: &mut Pcg64| -> Vec<Matrix> {
            let (r, c) = wb_shapes[0];
            let mut emb = vec![0.0f64; r * c];
            for _ in 0..16 {
                let col = rng.below(c);
                for row in 0..r {
                    emb[row * c + col] = rng.gaussian();
                }
            }
            vec![Matrix::from_vec(r, c, emb), Matrix::randn(wb_shapes[1].0, wb_shapes[1].1, rng)]
        };
        let run_wire = |proto: u32, compress: bool| -> (u64, Vec<Matrix>, usize) {
            let transports: Vec<Arc<FaultInjectingTransport>> = (0..2)
                .map(|_| {
                    FaultInjectingTransport::with_config(
                        FaultScript::none(),
                        usize::MAX,
                        Some(Duration::from_secs(60)),
                    )
                })
                .collect();
            let mut eng = ExecutorBuilder::in_proc(transports.clone(), proto, compress)
                .build(&wb_shapes, UnitKind::Shampoo, wb_base.clone(), wb_ecfg)
                .expect("launch wire-bytes engine");
            let mut params = zeros_like(&wb_shapes);
            let mut srng = Pcg64::new(0x11173);
            for _ in 0..wb_steps {
                let grads = wb_grads(&mut srng);
                eng.try_step(&mut params, &grads).expect("wire-bytes step");
            }
            let refreshes = eng.refreshes();
            drop(eng); // count the shutdown frames too — both legs pay them
            (transports.iter().map(|t| t.bytes_delivered()).sum(), params, refreshes)
        };
        let (v2_bytes, v2_params, v2_refreshes) = run_wire(2, false);
        let (v3_bytes, v3_params, v3_refreshes) = run_wire(PROTO_VERSION, true);
        // Reference: the in-process engine on the same stream.
        let mut local = ExecutorBuilder::local()
            .build(&wb_shapes, UnitKind::Shampoo, wb_base, wb_ecfg)
            .expect("launch wire-bytes local reference");
        let mut local_params = zeros_like(&wb_shapes);
        let mut srng = Pcg64::new(0x11173);
        for _ in 0..wb_steps {
            let grads = wb_grads(&mut srng);
            local.step(&mut local_params, &grads);
        }
        let mut wb_identical = v2_refreshes == local.refreshes()
            && v3_refreshes == local.refreshes();
        for ((a, b), c) in local_params.iter().zip(&v2_params).zip(&v3_params) {
            if a.max_diff(b) != 0.0 || a.max_diff(c) != 0.0 {
                wb_identical = false;
            }
        }
        identical = identical && wb_identical;
        let ratio = v2_bytes as f64 / (v3_bytes.max(1)) as f64;
        println!(
            "engine/shard_wire_bytes_12step_2sh  v2 {v2_bytes} B, v3+delta {v3_bytes} B, \
             reduction x{ratio:.2} identical={wb_identical}"
        );
        shard_wire_v2_bytes = Some(v2_bytes);
        shard_wire_v3_bytes = Some(v3_bytes);
        shard_wire_ratio = Some(ratio);
        assert!(wb_identical, "compressed transport diverged — wire record invalid");
    }

    // ---------------- sketch state bytes (wire v4 + checkpoint v2) ----
    // The sketch-native state-format payoff: total bytes the wire v4
    // `StateSnap` RPC delivers for a full optimizer-state snapshot of
    // the same LM-shaped workload (tall one-sided embedding block +
    // small projection) when the covariance travels as rank-ℓ FD
    // factors (`engine-s-shampoo`) vs as dense Kronecker blocks
    // (`engine-shampoo`) — the O(dℓ) vs O(d²) claim, measured on the
    // metered in-proc transport. The same entries become each leg's
    // checkpoint-v2 state section, so the file sizes are recorded too.
    // Byte counts are fully deterministic, so the recorded
    // `sketch_wire_ratio` is machine-independent and the baseline
    // floors it at 10x (`sketch_wire_ratio_min`).
    let mut sketch_state_dense_bytes: Option<u64> = None;
    let mut sketch_state_v4_bytes: Option<u64> = None;
    let mut sketch_wire_ratio: Option<f64> = None;
    let mut sketch_ckpt_bytes: Option<u64> = None;
    let mut dense_ckpt_bytes: Option<u64> = None;
    if run("engine/shard_sketch_bytes") {
        use sketchy::coordinator::wire::{BlockStateMsg, PROTO_VERSION};
        use sketchy::coordinator::{FaultInjectingTransport, FaultScript};
        use sketchy::optim::{ExecutorBuilder, UnitKind};
        use std::sync::Arc;
        use std::time::Duration;
        let sk_shapes = [(384usize, 16usize), (48, 16)];
        let sk_base = ShampooConfig {
            lr: 1e-3,
            beta1: 0.9,
            weight_decay: 0.0,
            one_sided: true,
            start_preconditioning_step: 2,
            stat_interval: 1,
            graft: GraftType::None,
            ..Default::default()
        };
        let sk_ecfg = EngineConfig {
            threads: 1,
            block_size: 0,
            refresh_interval: 2,
            stagger: true,
            ..Default::default()
        };
        let sk_steps = 6usize;
        let run_state = |kind: UnitKind| -> (u64, Vec<Matrix>, Vec<BlockStateMsg>) {
            let transports: Vec<Arc<FaultInjectingTransport>> = (0..2)
                .map(|_| {
                    FaultInjectingTransport::with_config(
                        FaultScript::none(),
                        usize::MAX,
                        Some(Duration::from_secs(60)),
                    )
                })
                .collect();
            let mut eng = ExecutorBuilder::in_proc(transports.clone(), PROTO_VERSION, true)
                .build(&sk_shapes, kind, sk_base.clone(), sk_ecfg)
                .expect("launch sketch-bytes engine");
            let mut params = zeros_like(&sk_shapes);
            let mut srng = Pcg64::new(0x5ce7c);
            for _ in 0..sk_steps {
                let grads: Vec<Matrix> =
                    sk_shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut srng)).collect();
                eng.try_step(&mut params, &grads).expect("sketch-bytes step");
            }
            let before: u64 = transports.iter().map(|t| t.bytes_delivered()).sum();
            let snaps = eng.state_snapshot().expect("v4 state snapshot");
            let after: u64 = transports.iter().map(|t| t.bytes_delivered()).sum();
            let entries: Vec<BlockStateMsg> = snaps
                .iter()
                .enumerate()
                .map(|(i, s)| BlockStateMsg::from_snap(i as u32, s))
                .collect();
            (after - before, params, entries)
        };
        let (dense_bytes, _dense_params, dense_entries) = run_state(UnitKind::Shampoo);
        let (v4_bytes, sk_params, sk_entries) = run_state(UnitKind::Sketched { rank: 8 });
        // Reference: the in-process sketched engine on the same stream.
        let mut local = ExecutorBuilder::local()
            .build(&sk_shapes, UnitKind::Sketched { rank: 8 }, sk_base.clone(), sk_ecfg)
            .expect("launch sketch-bytes local reference");
        let mut local_params = zeros_like(&sk_shapes);
        let mut srng = Pcg64::new(0x5ce7c);
        for _ in 0..sk_steps {
            let grads: Vec<Matrix> =
                sk_shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut srng)).collect();
            local.step(&mut local_params, &grads);
        }
        let mut sk_identical = true;
        for (a, b) in local_params.iter().zip(&sk_params) {
            if a.max_diff(b) != 0.0 {
                sk_identical = false;
            }
        }
        identical = identical && sk_identical;
        // Checkpoint v2 carries the same typed entries: record the file
        // sizes of both legs on identical params.
        std::fs::create_dir_all("bench_out").ok();
        let dense_ckpt = "bench_out/ckpt_dense_state.bin";
        let sketch_ckpt = "bench_out/ckpt_sketch_state.bin";
        sketchy::train::save_checkpoint_with_state(
            dense_ckpt,
            sk_steps,
            &sk_params,
            Some(&dense_entries),
        )
        .expect("dense-state checkpoint");
        sketchy::train::save_checkpoint_with_state(
            sketch_ckpt,
            sk_steps,
            &sk_params,
            Some(&sk_entries),
        )
        .expect("sketch-state checkpoint");
        let dense_ckpt_len = std::fs::metadata(dense_ckpt).unwrap().len();
        let sketch_ckpt_len = std::fs::metadata(sketch_ckpt).unwrap().len();
        let ratio = dense_bytes as f64 / (v4_bytes.max(1)) as f64;
        println!(
            "engine/shard_sketch_bytes_6step_2sh  dense-state {dense_bytes} B, v4 factored \
             {v4_bytes} B, reduction x{ratio:.2}, ckpt {dense_ckpt_len} -> {sketch_ckpt_len} B \
             identical={sk_identical}"
        );
        sketch_state_dense_bytes = Some(dense_bytes);
        sketch_state_v4_bytes = Some(v4_bytes);
        sketch_wire_ratio = Some(ratio);
        dense_ckpt_bytes = Some(dense_ckpt_len);
        sketch_ckpt_bytes = Some(sketch_ckpt_len);
        assert!(sk_identical, "sharded sketch run diverged — sketch-bytes record invalid");
    }

    // ---------------- shard migration (elastic kill-and-replace) ------
    // The elastic-fleet recovery metric: an in-proc fleet of 2 seats
    // plus 1 warm spare runs the stagger-refresh workload, seat 0 is
    // killed mid-run, and the driver migrates its blocks to the spare
    // from the last sync-point snapshot plus a journal replay. Both
    // counters are fully deterministic: `shard_migrate_steps` is the
    // replayed journal length (bounded by the failover budget — the
    // baseline enforces that as the `shard_migrate_steps_max` ceiling)
    // and `shard_migrate_state_bytes` is the encoded `StateRestore`
    // traffic the handoff shipped. Bitwise identity with the local
    // engine on the same gradient stream is asserted, so the record is
    // only ever written for a correct migration.
    let mut shard_migrate_steps: Option<usize> = None;
    let mut shard_migrate_state_bytes: Option<usize> = None;
    if run("engine/shard_migration") {
        use sketchy::coordinator::wire::PROTO_VERSION;
        use sketchy::coordinator::{FaultInjectingTransport, FaultScript};
        use sketchy::optim::{ExecutorBuilder, UnitKind};
        use std::sync::Arc;
        use std::time::Duration;
        let mg_shapes = [(96usize, 128usize), (48, 48)];
        let mg_base = ShampooConfig {
            lr: 1e-3,
            start_preconditioning_step: 2,
            stat_interval: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mg_ecfg = EngineConfig {
            threads: 1,
            block_size: 48,
            refresh_interval: 2,
            stagger: true,
            ..Default::default()
        };
        let mg_steps = 12usize;
        let mg_budget = 8u64;
        // Kill after step t=10: last sync-point snapshot is t=8, so the
        // handoff ships that snapshot and replays the t=9..=10 journal.
        let kill_before = 10usize;
        let transports: Vec<Arc<FaultInjectingTransport>> = (0..3)
            .map(|_| {
                FaultInjectingTransport::with_config(
                    FaultScript::none(),
                    usize::MAX,
                    Some(Duration::from_secs(60)),
                )
            })
            .collect();
        let mut eng = ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
            .spares(1)
            .failover_budget(mg_budget)
            .build(&mg_shapes, UnitKind::Shampoo, mg_base.clone(), mg_ecfg)
            .expect("launch elastic migration engine");
        let control = eng.fleet_control().expect("elastic fleet exposes control");
        let mut local = ExecutorBuilder::local()
            .build(&mg_shapes, UnitKind::Shampoo, mg_base, mg_ecfg)
            .expect("launch migration local reference");
        let mut p_fleet = zeros_like(&mg_shapes);
        let mut p_local = p_fleet.clone();
        let mut srng = Pcg64::new(0x317e);
        for i in 0..mg_steps {
            if i == kill_before {
                control.kill_worker(0).expect("kill seat 0");
            }
            let grads: Vec<Matrix> =
                mg_shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut srng)).collect();
            eng.try_step(&mut p_fleet, &grads).expect("elastic step survives the kill");
            local.step(&mut p_local, &grads);
        }
        let mut mg_identical = eng.refreshes() == local.refreshes();
        for (a, b) in p_fleet.iter().zip(&p_local) {
            if a.max_diff(b) != 0.0 {
                mg_identical = false;
            }
        }
        identical = identical && mg_identical;
        let stats = control.stats();
        println!(
            "engine/shard_migration_12step_2sh_1spare  {} migration(s), {} replayed step(s) \
             (budget {mg_budget}), state {} B identical={mg_identical}",
            stats.migrations, stats.migrated_steps, stats.migrated_state_bytes
        );
        shard_migrate_steps = Some(stats.migrated_steps);
        shard_migrate_state_bytes = Some(stats.migrated_state_bytes);
        assert!(mg_identical, "elastic migration diverged — migration record invalid");
        assert_eq!(stats.migrations, 1, "expected exactly one migration");
        assert!(
            stats.migrated_steps as u64 <= mg_budget,
            "journal replay exceeded the failover budget"
        );
    }

    // ---------------- driver recover (durable-journal crash-resume) ----
    // The driver-durability metric: an in-proc fleet journals every step
    // write-ahead to disk, the driver is "killed" after step 10 (the
    // engine is dropped — the fsynced WAL is exactly what kill -9
    // leaves), and a relaunched driver restores the t=8 sync snapshot,
    // replays the t=9..=10 journal, and finishes the run. The replayed
    // length is a deterministic counter bounded by the failover budget —
    // the baseline enforces that as the `driver_recover_steps_max`
    // ceiling; the WAL size is a deterministic byte count (typed sketch
    // factors, never dense covariance). Bitwise identity with the
    // uninterrupted local engine is asserted, so the record is only ever
    // written for a correct recovery.
    let mut driver_recover_steps: Option<usize> = None;
    let mut driver_recover_wal_bytes: Option<u64> = None;
    if run("engine/driver_recover") {
        use sketchy::coordinator::wire::PROTO_VERSION;
        use sketchy::coordinator::{FaultInjectingTransport, FaultScript, MembershipConfig};
        use sketchy::optim::{ExecutorBuilder, UnitKind};
        use sketchy::train::load_journal;
        use std::sync::Arc;
        use std::time::Duration;
        let dr_shapes = [(96usize, 128usize), (48, 48)];
        let dr_base = ShampooConfig {
            lr: 1e-3,
            start_preconditioning_step: 2,
            stat_interval: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let dr_ecfg = EngineConfig {
            threads: 1,
            block_size: 48,
            refresh_interval: 2,
            stagger: true,
            ..Default::default()
        };
        let dr_steps = 12usize;
        let dr_budget = 8u64;
        // Crash after step 10: the last sync point is t=8, so resume
        // restores that snapshot and replays the t=9..=10 journal.
        let crash_after = 10usize;
        std::fs::create_dir_all("bench_out").ok();
        let wal = "bench_out/BENCH_driver_recover.skjl";
        let _ = std::fs::remove_file(wal);
        let grads_stream: Vec<Vec<Matrix>> = {
            let mut g = Pcg64::new(0x414c);
            (0..dr_steps)
                .map(|_| dr_shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut g)).collect())
                .collect()
        };
        let mk_fleet = || {
            let transports: Vec<Arc<FaultInjectingTransport>> = (0..2)
                .map(|_| {
                    FaultInjectingTransport::with_config(
                        FaultScript::none(),
                        usize::MAX,
                        Some(Duration::from_secs(60)),
                    )
                })
                .collect();
            ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
                .membership(MembershipConfig {
                    journal: Some(wal.to_string()),
                    failover_budget: dr_budget,
                    ..Default::default()
                })
                .build(&dr_shapes, UnitKind::Shampoo, dr_base.clone(), dr_ecfg)
                .expect("launch journaled fleet")
        };
        {
            let mut eng = mk_fleet();
            let mut p_doomed = zeros_like(&dr_shapes);
            for grads in &grads_stream[..crash_after] {
                eng.try_step(&mut p_doomed, grads).expect("journaled step");
            }
            // Dropped here: the doomed driver dies; the write-ahead WAL
            // on disk already covers all 10 applied steps.
        }
        let wal_bytes = std::fs::metadata(wal).expect("journal exists").len();
        let recover_started = std::time::Instant::now();
        let jc = load_journal(wal).expect("load crash journal");
        assert_eq!(
            jc.sync_t as usize + jc.steps.len(),
            crash_after,
            "journal must cover every applied step"
        );
        let mut eng = mk_fleet();
        let mut p_resumed = jc.params.clone();
        eng.restore_payloads(jc.sync_t as usize, jc.snaps.clone().expect("synced snapshot"))
            .expect("restore fleet from journal");
        for rs in &jc.steps {
            eng.set_lr(rs.lr);
            eng.try_step(&mut p_resumed, &rs.grads).expect("replay journaled step");
        }
        let recover_ns = recover_started.elapsed().as_nanos() as u64;
        for grads in &grads_stream[crash_after..] {
            eng.try_step(&mut p_resumed, grads).expect("post-resume step");
        }
        let mut local = ExecutorBuilder::local()
            .build(&dr_shapes, UnitKind::Shampoo, dr_base.clone(), dr_ecfg)
            .expect("launch driver-recover local reference");
        let mut p_local = zeros_like(&dr_shapes);
        for grads in &grads_stream {
            local.step(&mut p_local, grads);
        }
        // The resumed engine only counts refreshes from the restore on,
        // so the full-run refresh totals are not comparable here; the
        // binding check is bitwise parameter identity (refresh
        // accounting across a crash is covered by the determinism test
        // suite's restored-twin comparison).
        let mut dr_identical = true;
        for (a, b) in p_resumed.iter().zip(&p_local) {
            if a.max_diff(b) != 0.0 {
                dr_identical = false;
            }
        }
        identical = identical && dr_identical;
        println!(
            "engine/driver_recover_12step_2sh  crash@{crash_after}: wal {wal_bytes} B, \
             {} replayed step(s) (budget {dr_budget}), recover {recover_ns} ns \
             identical={dr_identical}",
            jc.steps.len()
        );
        driver_recover_steps = Some(jc.steps.len());
        driver_recover_wal_bytes = Some(wal_bytes);
        assert!(dr_identical, "crash-resume diverged — driver-recover record invalid");
        assert!(
            jc.steps.len() as u64 <= dr_budget,
            "journal replay exceeded the failover budget"
        );
        let _ = std::fs::remove_file(wal);
    }

    // ---------------- EKFAC stretched-refresh quality ----------------
    // The inter-refresh correction's payoff metric: on a deterministic
    // noisy quadratic, an 8x-stretched eigendecomposition cadence
    // (refresh_interval 32) with the EKFAC corrector live must hold the
    // final quality of the tight cadence (refresh_interval 4, no
    // corrector). Every trajectory is bitwise-deterministic (fixed
    // seeds, the engine's serial determinism), so the recorded
    // `ekfac_stretch_quality` ratio is machine-independent and the
    // baseline floors it (`ekfac_stretch_quality_min`). The per-step
    // timings record what the corrector's second-moment tracking costs
    // on the stretched cadence; they stay out of the baseline because
    // the corrector tax is small relative to run-to-run timer noise at
    // this tensor size.
    let mut ekfac_quality: Option<f64> = None;
    let mut ekfac_loss_tight: Option<f64> = None;
    let mut ekfac_loss_uncorrected: Option<f64> = None;
    let mut ekfac_loss_stretched: Option<f64> = None;
    let mut ekfac_on_ns: Option<u128> = None;
    let mut ekfac_off_ns: Option<u128> = None;
    if run("engine/ekfac_stretch") {
        use sketchy::optim::{ExecutorBuilder, UnitKind};
        let ek_shapes = [(48usize, 32usize)];
        let (ek_m, ek_n) = ek_shapes[0];
        // Fixed O(1)-spectrum curvature factors and target: the loss is
        // ½·tr((W−T)ᵀ H_l (W−T) H_r); a small deterministic noise
        // stream on the gradient keeps the converged loss bounded away
        // from zero, so the quality ratio is a stable number instead of
        // a quotient of vanishing tails.
        let h_l = at_a(&Matrix::randn(2 * ek_m, ek_m, &mut rng)).scale(1.0 / (2 * ek_m) as f64);
        let h_r = at_a(&Matrix::randn(2 * ek_n, ek_n, &mut rng)).scale(1.0 / (2 * ek_n) as f64);
        let target = Matrix::randn(ek_m, ek_n, &mut rng);
        let loss_of = |w: &Matrix| -> f64 {
            let d = w.sub(&target);
            0.5 * ops::dot(d.as_slice(), matmul(&matmul(&h_l, &d), &h_r).as_slice())
        };
        let ek_base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 1,
            stat_interval: 1,
            graft: GraftType::RmspropNormalized,
            ..Default::default()
        };
        let mk = |interval: usize, ekfac: bool| {
            ExecutorBuilder::local()
                .build(
                    &ek_shapes,
                    UnitKind::Sketched { rank: 8 },
                    ShampooConfig { ekfac, ..ek_base.clone() },
                    EngineConfig {
                        threads: 1,
                        block_size: 0,
                        refresh_interval: interval,
                        stagger: true,
                        ekfac,
                        ..Default::default()
                    },
                )
                .expect("launch ekfac-stretch engine")
        };
        // Average the loss over the last 16 of 96 steps — the noise
        // floor — rather than reading a single endpoint.
        let run_traj = |interval: usize, ekfac: bool| -> f64 {
            let mut eng = mk(interval, ekfac);
            let mut w = vec![Matrix::zeros(ek_m, ek_n)];
            let mut nrng = Pcg64::new(0xefac);
            let mut tail = 0.0;
            for step in 0..96 {
                let mut g = matmul(&matmul(&h_l, &w[0].sub(&target)), &h_r);
                g.axpy(0.05, &Matrix::randn(ek_m, ek_n, &mut nrng));
                eng.step(&mut w, &[g]);
                if step >= 80 {
                    tail += loss_of(&w[0]);
                }
            }
            tail / 16.0
        };
        let tight = run_traj(4, false);
        let uncorrected = run_traj(32, false);
        let stretched = run_traj(32, true);
        let quality = tight / stretched.max(f64::MIN_POSITIVE);
        ekfac_loss_tight = Some(tight);
        ekfac_loss_uncorrected = Some(uncorrected);
        ekfac_loss_stretched = Some(stretched);
        ekfac_quality = Some(quality);
        // Per-step cost of the corrector on the stretched cadence.
        let ek_grads: Vec<Matrix> = ek_shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
            .collect();
        let mut eng = mk(32, false);
        let mut ek_params = zeros_like(&ek_shapes);
        let mut bh = bench("engine/ekfac_stretch_step_off", fast);
        let st_off = bh.run(|| eng.step(&mut ek_params, &ek_grads));
        record(&bh, "refresh 32, corrector off".to_string());
        ekfac_off_ns = Some(st_off.median.as_nanos());
        let mut eng = mk(32, true);
        let mut ek_params = zeros_like(&ek_shapes);
        let mut bh = bench("engine/ekfac_stretch_step_on", fast);
        let st_on = bh.run(|| eng.step(&mut ek_params, &ek_grads));
        record(&bh, format!("refresh 32, corrector on, quality x{quality:.3} vs tight sync"));
        ekfac_on_ns = Some(st_on.median.as_nanos());
        println!(
            "engine/ekfac_stretch_96step  loss tight(4) {tight:.5}, stretched(32) sync \
             {uncorrected:.5}, stretched(32) ekfac {stretched:.5}, quality x{quality:.3}"
        );
    }

    // Assemble the gate-facing perf record from whichever engine
    // sections ran (CI runs `--filter engine/`, which runs them all; a
    // narrower filter yields a partial record the gate will reject —
    // deliberately, so metrics cannot silently vanish from CI).
    if let (Some(cal), Some(serial), Some(par)) = (cal_ns, serial_ns, par_ns) {
        std::fs::create_dir_all("bench_out").ok();
        let mut fields = vec![
            ("bench", "\"precond_engine\"".to_string()),
            ("shapes", "\"256x256+256x128\"".to_string()),
            ("block_size", mb_block.to_string()),
            ("blocks", mb_blocks.to_string()),
            ("refresh_interval", mb_refresh_interval.to_string()),
            ("serial_threads", "1".to_string()),
            ("parallel_threads", par_threads_used.to_string()),
            ("calibration_ns", cal.to_string()),
            ("serial_median_ns", serial.to_string()),
            ("parallel_median_ns", par.to_string()),
            ("serial_per_calibration", format!("{:.4}", serial as f64 / cal as f64)),
            ("parallel_per_calibration", format!("{:.4}", par as f64 / cal as f64)),
            ("speedup", format!("{mb_speedup:.4}")),
        ];
        if let Some(oh) = step_overhead_ns {
            let per_cal = format!("{:.4}", oh as f64 / cal as f64);
            fields.push(("step_overhead_ns", oh.to_string()));
            fields.push(("step_overhead_per_calibration", per_cal));
        }
        if let (Some(s), Some(o), Some(sp)) = (overlap_sync_ns, overlap_on_ns, overlap_speedup) {
            fields.push(("overlap_sync_ns", s.to_string()));
            fields.push(("overlap_on_ns", o.to_string()));
            fields.push(("overlap_speedup", format!("{sp:.4}")));
            // Emit the gate floor too, so refreshing the committed
            // baseline by copying this record over it preserves the
            // >=20%-win enforcement instead of silently dropping it.
            fields.push(("overlap_speedup_min", "1.2".to_string()));
        }
        if let (Some(s), Some(o), Some(sp)) =
            (shard_overlap_sync_ns, shard_overlap_on_ns, shard_overlap_speedup)
        {
            fields.push(("shard_overlap_sync_ns", s.to_string()));
            fields.push(("shard_overlap_on_ns", o.to_string()));
            fields.push(("shard_overlap_speedup", format!("{sp:.4}")));
            // The sharded win carries wire-serialization overhead in
            // both legs, so its floor sits below the in-process 1.2.
            fields.push(("shard_overlap_speedup_min", "1.1".to_string()));
        }
        if let (Some(v2), Some(v3), Some(r)) =
            (shard_wire_v2_bytes, shard_wire_v3_bytes, shard_wire_ratio)
        {
            // Byte counts, not timings: deterministic on any machine,
            // so the ratio floor is the binding (machine-independent)
            // check — emitted here so a baseline refresh keeps it.
            fields.push(("shard_wire_v2_bytes", v2.to_string()));
            fields.push(("shard_wire_v3_bytes", v3.to_string()));
            fields.push(("shard_wire_ratio", format!("{r:.4}")));
            fields.push(("shard_wire_ratio_min", "3.0".to_string()));
        }
        if let (Some(d), Some(s), Some(r)) =
            (sketch_state_dense_bytes, sketch_state_v4_bytes, sketch_wire_ratio)
        {
            // Also deterministic byte counts (no timings): the floor is
            // the binding machine-independent check for the sketch-
            // native state format.
            fields.push(("sketch_state_dense_bytes", d.to_string()));
            fields.push(("sketch_state_v4_bytes", s.to_string()));
            fields.push(("sketch_wire_ratio", format!("{r:.4}")));
            fields.push(("sketch_wire_ratio_min", "10.0".to_string()));
        }
        if let (Some(d), Some(s)) = (dense_ckpt_bytes, sketch_ckpt_bytes) {
            fields.push(("dense_state_ckpt_bytes", d.to_string()));
            fields.push(("sketch_state_ckpt_bytes", s.to_string()));
        }
        if let (Some(steps), Some(bytes)) = (shard_migrate_steps, shard_migrate_state_bytes) {
            // Deterministic counters (no timings). The ceiling is the
            // binding machine-independent check: a kill-and-replace
            // handoff must never replay more than one failover budget's
            // worth of journal — emitted here so a baseline refresh
            // keeps the bound.
            fields.push(("shard_migrate_steps", steps.to_string()));
            fields.push(("shard_migrate_state_bytes", bytes.to_string()));
            fields.push(("shard_migrate_steps_max", "8".to_string()));
        }
        if let (Some(q), Some(t), Some(u), Some(s)) =
            (ekfac_quality, ekfac_loss_tight, ekfac_loss_uncorrected, ekfac_loss_stretched)
        {
            // Deterministic trajectories (no timings): the quality
            // ratio is exact on any machine, so the floor is the
            // binding check for the stretched-cadence corrector —
            // emitted here so a baseline refresh keeps it. The raw
            // losses ride along for observability.
            fields.push(("ekfac_loss_tight4", format!("{t:.6}")));
            fields.push(("ekfac_loss_stretched32_sync", format!("{u:.6}")));
            fields.push(("ekfac_loss_stretched32_ekfac", format!("{s:.6}")));
            fields.push(("ekfac_stretch_quality", format!("{q:.4}")));
            fields.push(("ekfac_stretch_quality_min", "0.9".to_string()));
        }
        if let (Some(on), Some(off)) = (ekfac_on_ns, ekfac_off_ns) {
            fields.push(("ekfac_step_on_ns", on.to_string()));
            fields.push(("ekfac_step_off_ns", off.to_string()));
        }
        if let (Some(steps), Some(bytes)) = (driver_recover_steps, driver_recover_wal_bytes) {
            // Deterministic counters again: a crash-resumed driver must
            // never replay more than one failover budget's worth of
            // write-ahead journal, and the WAL holds typed sketch
            // factors so its size is an exact byte count — the ceiling
            // is emitted so a baseline refresh keeps the bound.
            fields.push(("driver_recover_steps", steps.to_string()));
            fields.push(("driver_recover_wal_bytes", bytes.to_string()));
            fields.push(("driver_recover_steps_max", "8".to_string()));
        }
        fields.push(("identical", identical.to_string()));
        let body = fields
            .iter()
            .map(|(k, v)| format!("  \"{k}\": {v}"))
            .collect::<Vec<_>>()
            .join(",\n");
        let json = format!("{{\n{body}\n}}\n");
        std::fs::write("bench_out/BENCH_precond_engine.json", &json).unwrap();
        println!("[engine perf record written to bench_out/BENCH_precond_engine.json]");
    }

    // ---------------- artifact + e2e (need artifacts) ----------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = std::sync::Arc::new(sketchy::runtime::Runtime::load("artifacts").unwrap());
        if run("artifact/cov_update_256_xla") {
            let c: Vec<f32> = (0..256 * 256).map(|_| rng.gaussian() as f32).collect();
            let g: Vec<f32> = (0..256 * 256).map(|_| rng.gaussian() as f32).collect();
            rt.executable("cov_update_256").unwrap();
            let mut bh = bench("artifact/cov_update_256_xla", fast);
            let st = bh.run(|| {
                let inputs = [
                    sketchy::runtime::literal::lit_f32(&c, &[256, 256]).unwrap(),
                    sketchy::runtime::literal::lit_f32(&g, &[256, 256]).unwrap(),
                ];
                std::hint::black_box(rt.execute("cov_update_256", &inputs).unwrap());
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((2 * 256 * 256 * 256) as f64, st.median)));
            // Native Rust equivalent for the same work.
            let cm = Matrix::randn(256, 256, &mut rng);
            let gm = Matrix::randn(256, 256, &mut rng);
            let mut bh = bench("artifact/cov_update_256_native", fast);
            let st = bh.run(|| {
                let mut c2 = cm.scale(0.999);
                c2.axpy(1.0, &at_a(&gm));
                std::hint::black_box(c2);
            });
            record(&bh, format!("{:.2} GFLOP/s", gflops((2 * 256 * 256 * 256) as f64, st.median)));
            let _ = a_at(&gm);
        }
        if run("e2e/lm_tiny_step") {
            use sketchy::data::MarkovCorpus;
            use sketchy::train::LmTrainer;
            let mut trainer = LmTrainer::new(rt.clone(), "tiny", 1).unwrap();
            let shapes = trainer.shapes.clone();
            let mut corpus = MarkovCorpus::new(trainer.vocab, 1);
            let mut opt = SShampoo::new(
                &shapes,
                SShampooConfig { base: cfg.clone(), rank: 8 },
            );
            // Warm up compile.
            trainer.step(&mut opt, &mut corpus, 2).unwrap();
            let mut bh = bench("e2e/lm_tiny_step_s_shampoo_2workers", fast);
            bh.run(|| {
                trainer.step(&mut opt, &mut corpus, 2).unwrap();
            });
            record(&bh, String::new());
        }
    } else {
        eprintln!("NOTE: artifact/e2e benches skipped (run `make artifacts`)");
    }

    // CSV dump.
    std::fs::create_dir_all("bench_out").ok();
    let csv = format!(
        "name,iters,median_ns,p10_ns,p90_ns,mean_ns,extra\n{}\n",
        rows.join("\n")
    );
    std::fs::write("bench_out/bench_main.csv", csv).unwrap();
    println!("\n[csv written to bench_out/bench_main.csv]");
}
