//! AB001 — allocation bounds in decode/load paths.
//!
//! A length field read off the wire (or out of a checkpoint) is
//! attacker-controlled until validated; passing it straight to
//! `Vec::with_capacity`/`vec![x; n]` turns a corrupt frame into an
//! allocation bomb. This rule flags sized allocations in functions that
//! look like decode/load paths unless the size expression is visibly
//! derived from the input actually present (`.min(...)` clamp,
//! `remaining`-style budget, `.len()` of a real buffer) or is a plain
//! literal. Audited exceptions go to the committed allowlist.

use super::lint::Violation;
use super::source::{contains_ident, SourceFile};

/// Function-name fragments that mark a decode/load path. Matched
/// against the `_`-separated segments of the function name (prefix
/// match, so `decodes`/`loader` count but `thread` does not hit
/// `read`, nor `preload` hit `load`).
const CTX_FRAGMENTS: &[&str] =
    &["decode", "read", "recv", "load", "restore", "decompress", "parse"];

fn decode_context(f: &SourceFile, idx: usize) -> Option<String> {
    let fn_name = &f.fn_ctx[idx];
    let lowered = fn_name.to_ascii_lowercase();
    if lowered.split('_').any(|seg| CTX_FRAGMENTS.iter().any(|k| seg.starts_with(k))) {
        return Some(format!("fn {fn_name}"));
    }
    // Methods of the wire decoder type itself (identifier match, so
    // `Decoder`/`Decay` impls elsewhere do not count).
    if contains_ident(&f.impl_ctx[idx], "Dec") {
        return Some("impl Dec".to_string());
    }
    None
}

/// Extract the text between a delimiter pair opening at
/// (`idx`, `open_at`), spanning at most a few lines.
fn delimited(f: &SourceFile, idx: usize, open_at: usize, open: char, close: char) -> Option<String> {
    let mut depth = 0i32;
    let mut text = String::new();
    for li in idx..f.code.len().min(idx + 5) {
        let chars: Vec<char> = f.code[li].chars().collect();
        let from = if li == idx { open_at } else { 0 };
        for &c in chars.get(from..)? {
            if c == open {
                depth += 1;
                if depth == 1 {
                    continue;
                }
            } else if c == close {
                depth -= 1;
                if depth == 0 {
                    return Some(text);
                }
            }
            text.push(c);
        }
        text.push(' ');
    }
    None
}

/// The size expression of a `vec![elem; size]` macro body, if the macro
/// has one (a plain list form has no top-level `;`).
fn vec_size(body: &str) -> Option<String> {
    let mut paren = 0i32;
    let mut brack = 0i32;
    for (i, c) in body.char_indices() {
        match c {
            '(' => paren += 1,
            ')' => paren -= 1,
            '[' => brack += 1,
            ']' => brack -= 1,
            ';' if paren == 0 && brack == 0 => return Some(body[i + 1..].to_string()),
            _ => {}
        }
    }
    None
}

/// A size expression passes when it is visibly tied to input that is
/// actually present, or is a compile-time literal.
fn is_bounded(size: &str) -> bool {
    if size.contains(".min(") || size.contains("remaining") || size.contains(".len(") {
        return true;
    }
    let mut stripped = size.to_string();
    for suffix in ["usize", "u64", "u32", "u16", "u8", "i64", "i32"] {
        stripped = stripped.replace(suffix, "");
    }
    !stripped.trim().is_empty()
        && stripped
            .chars()
            .all(|c| c.is_ascii_digit() || c.is_whitespace() || "_<()+*".contains(c))
}

pub fn check(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        for (idx, line) in f.code.iter().enumerate() {
            if f.is_test[idx] {
                continue;
            }
            let Some(ctx) = decode_context(f, idx) else { continue };
            if let Some(p) = line.find("with_capacity(") {
                let open_at = p + "with_capacity".len();
                if let Some(arg) = delimited(f, idx, open_at, '(', ')') {
                    if !is_bounded(&arg) {
                        out.push(Violation::at(
                            "AB001",
                            f,
                            idx,
                            format!(
                                "with_capacity({}) in {ctx} is not derived from remaining \
                                 input — clamp it or allowlist with a justification",
                                arg.trim()
                            ),
                        ));
                    }
                }
            }
            if let Some(p) = line.find("vec![") {
                let open_at = p + "vec!".len();
                if let Some(body) = delimited(f, idx, open_at, '[', ']') {
                    if let Some(size) = vec_size(&body) {
                        if !is_bounded(&size) {
                            out.push(Violation::at(
                                "AB001",
                                f,
                                idx,
                                format!(
                                    "vec![..; {}] in {ctx} is not derived from remaining \
                                     input — clamp it or allowlist with a justification",
                                    size.trim()
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
    out
}
