//! CK001/CK002 — config-key registry rules.
//!
//! Unknown-key validation only works if the registries and the lookups
//! agree. Registration sites (the `ensure_known_keys` calls, including
//! ones that pass a `KNOWN_KEYS` array) define, per `[section]`, the
//! set of legal keys; these rules then enforce:
//!
//! - **CK001** — every dotted `"section.key"` lookup string in
//!   production code names a registered key. A drifted lookup would
//!   read a key the validator rejects in config files — i.e. a knob
//!   that can never be set.
//! - **CK002** — every registered key is documented: the dotted
//!   `section.key` spelling must appear in the README knob tables.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use super::lint::Violation;
use super::source::{contains_ident, SourceFile};

/// Needles assembled from pieces so the linter's own source never
/// registers as a call site.
fn call_needle() -> &'static str {
    concat!("ensure_known_", "keys(")
}

fn array_needle() -> &'static str {
    concat!("KNOWN_", "KEYS")
}

struct Registry {
    keys: BTreeSet<String>,
    file: String,
    line: usize,
    text: String,
}

/// Find the end of a delimiter pair opening at (`idx`, `open_at`).
fn balance_end(
    f: &SourceFile,
    idx: usize,
    open_at: usize,
    open: char,
    close: char,
) -> Option<(usize, usize)> {
    let mut depth = 0i32;
    for li in idx..f.code.len().min(idx + 64) {
        let from = if li == idx { open_at } else { 0 };
        for (ci, c) in f.code[li].char_indices().filter(|(ci, _)| *ci >= from) {
            if c == open {
                depth += 1;
            } else if c == close {
                depth -= 1;
                if depth == 0 {
                    return Some((li, ci));
                }
            }
        }
    }
    None
}

/// String literals whose opening quote falls inside the given span.
fn span_lits(f: &SourceFile, start: (usize, usize), end: (usize, usize)) -> Vec<String> {
    f.lits
        .iter()
        .filter(|l| (l.line, l.col) >= start && (l.line, l.col) <= end)
        .map(|l| l.text.clone())
        .collect()
}

/// Resolve a `KNOWN_KEYS`-style array constant defined in `f`.
fn resolve_array(f: &SourceFile) -> BTreeSet<String> {
    for (idx, line) in f.code.iter().enumerate() {
        if f.is_test[idx] || !contains_ident(line, array_needle()) {
            continue;
        }
        let Some(eq) = line.find('=') else { continue };
        let Some(br) = line[eq..].find('[') else { continue };
        if let Some(end) = balance_end(f, idx, eq + br, '[', ']') {
            return span_lits(f, (idx, eq + br), end).into_iter().collect();
        }
    }
    BTreeSet::new()
}

fn is_key_ident(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

fn collect_registries(files: &[SourceFile]) -> BTreeMap<String, Registry> {
    let mut regs: BTreeMap<String, Registry> = BTreeMap::new();
    for f in files {
        for (idx, line) in f.code.iter().enumerate() {
            if f.is_test[idx] {
                continue;
            }
            let Some(p) = line.find(call_needle()) else { continue };
            let open_at = p + call_needle().len() - 1;
            let Some(end) = balance_end(f, idx, open_at, '(', ')') else { continue };
            let lits = span_lits(f, (idx, open_at), end);
            // The definition of the validator itself has no literal
            // section argument; only real call sites do.
            let Some((section, keys)) = lits.split_first() else { continue };
            let mut keys: BTreeSet<String> = keys.iter().cloned().collect();
            let references_array = (idx..=end.0).any(|li| contains_ident(&f.code[li], array_needle()));
            if references_array {
                keys.extend(resolve_array(f));
            }
            regs.entry(section.clone())
                .and_modify(|r| r.keys.extend(keys.iter().cloned()))
                .or_insert_with(|| Registry {
                    keys,
                    file: f.rel.clone(),
                    line: idx,
                    text: f.raw[idx].trim().to_string(),
                });
        }
    }
    regs
}

pub fn check(files: &[SourceFile], readme: Option<&str>) -> Vec<Violation> {
    let mut out = Vec::new();
    let regs = collect_registries(files);
    for f in files {
        for lit in &f.lits {
            if f.is_test[lit.line] {
                continue;
            }
            let Some((section, key)) = lit.text.split_once('.') else { continue };
            let Some(reg) = regs.get(section) else { continue };
            if is_key_ident(key) && !reg.keys.contains(key) {
                out.push(Violation::at(
                    "CK001",
                    f,
                    lit.line,
                    format!(
                        "config lookup `{section}.{key}` is not in the [{section}] \
                         known-keys registry ({})",
                        reg.file
                    ),
                ));
            }
        }
    }
    if let Some(readme) = readme {
        for (section, reg) in &regs {
            for key in &reg.keys {
                let dotted = format!("{section}.{key}");
                if !contains_ident(readme, &dotted) {
                    out.push(Violation {
                        rule: "CK002",
                        path: reg.file.clone(),
                        line: reg.line + 1,
                        msg: format!("config key `{dotted}` is not documented in README.md"),
                        text: reg.text.clone(),
                    });
                }
            }
        }
    }
    out
}
