//! DT001/DT002 — determinism rules.
//!
//! The repo-wide contract is bitwise identity across threads, shards,
//! overlap mode, and crash-resume. Two source-level habits break it:
//!
//! - **DT001** — raw wall-clock or entropy primitives. All timing must
//!   go through the injectable `Clock` in `coordinator/supervise.rs`
//!   (virtualizable in tests, anchored once in production); raw
//!   `Instant::now`/`SystemTime`/`thread::sleep`/thread-RNG calls make
//!   behavior depend on the machine of the day. The supervise module
//!   itself is the one blessed implementation site; benches measure
//!   wall time by design and ride the committed allowlist.
//! - **DT002** — `HashMap`/`HashSet` in the deterministic core
//!   (`optim/`, `coordinator/`, `sketch/`, `train/`). Their iteration
//!   order is seeded per process; any fold over it is a latent
//!   nondeterminism bug. BTree or index-keyed structures are required.

use super::lint::Violation;
use super::source::{contains_ident, SourceFile};

const WALL_CLOCK: &[&str] =
    &["Instant::now", "SystemTime", "thread::sleep", "from_entropy", "thread_rng"];

const HASH_TYPES: &[&str] = &["HashMap", "HashSet"];

/// Directories (path fragments) whose production code must stay
/// deterministically ordered.
const ORDERED_DIRS: &[&str] = &["optim/", "coordinator/", "sketch/", "train/"];

pub fn check(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        let blessed_clock = f.rel.ends_with("coordinator/supervise.rs");
        let ordered = ORDERED_DIRS.iter().any(|d| f.rel.contains(d));
        for (idx, line) in f.code.iter().enumerate() {
            if f.is_test[idx] {
                continue;
            }
            if !blessed_clock {
                for needle in WALL_CLOCK {
                    if line.contains(needle) {
                        out.push(Violation::at(
                            "DT001",
                            f,
                            idx,
                            format!(
                                "wall-clock/entropy primitive `{needle}` outside the \
                                 supervise.rs Clock abstraction"
                            ),
                        ));
                    }
                }
            }
            if ordered {
                for needle in HASH_TYPES {
                    if contains_ident(line, needle) {
                        out.push(Violation::at(
                            "DT002",
                            f,
                            idx,
                            format!(
                                "`{needle}` in deterministic core code — iteration order is \
                                 per-process; use BTree or indexed structures"
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}
