//! FL001 — float-comparison audit for the bench gate.
//!
//! The gate compares committed baselines against fresh bench records;
//! the hand-rolled JSON parser deliberately accepts `NaN`/`Infinity`
//! (python fixture compatibility), so any raw `as_f64` read inside
//! `util/gate.rs` can smuggle a non-finite or negative-zero value into
//! a `>`/`<` comparison that then silently passes. Gate code must use
//! the finite-checked accessor (`as_finite_f64`) or the named-error
//! helpers built on it. This rule is deliberately not allowlistable:
//! fix the site, don't suppress it.

use super::lint::Violation;
use super::source::SourceFile;

pub fn check(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| f.rel.ends_with("util/gate.rs")) {
        for (idx, line) in f.code.iter().enumerate() {
            if f.is_test[idx] {
                continue;
            }
            if line.contains(".as_f64(") {
                out.push(Violation::at(
                    "FL001",
                    f,
                    idx,
                    "raw `.as_f64()` read in gate code — use the finite-checked accessor \
                     so NaN/negative-zero baselines become named errors"
                        .to_string(),
                ));
            }
        }
    }
    out
}
