//! The `sketchy lint` rule engine.
//!
//! Rules are data: every rule has an id, a one-line summary, and an
//! allowlistability bit. The engine walks the repo's own Rust sources
//! (or any directory of `.rs` fixtures), builds comment/string-aware
//! [`SourceFile`] views, runs every rule module, applies the committed
//! allowlist (`rust/lint_allow.txt`), and renders `file:line` named
//! errors. Everything is deterministic: files are scanned in sorted
//! order and violations are reported in (path, line, rule) order.
//!
//! Two modes, decided by what the root contains:
//! - **repo mode** (`<root>/rust/src` exists): scan `rust/src` and
//!   `rust/tests`, skipping the committed `lint_fixtures`; the README
//!   and allowlist ride along. This is what CI runs on HEAD.
//! - **fixture mode** (anything else): scan every `.rs` under the root
//!   as-is — this is how the self-tests feed the engine intentionally
//!   bad files.

use std::path::{Path, PathBuf};

use anyhow::Context;

use super::source::SourceFile;
use super::{allocbound, configkey, determinism, floataudit, wiretag};

/// One rule's metadata. `allowlistable` rules accept audited
/// exceptions via `rust/lint_allow.txt`; the rest must be fixed.
#[derive(Debug)]
pub struct RuleMeta {
    pub id: &'static str,
    pub allowlistable: bool,
    pub summary: &'static str,
}

pub const RULES: &[RuleMeta] = &[
    RuleMeta {
        id: "DT001",
        allowlistable: true,
        summary: "no wall-clock/entropy primitives outside the supervise.rs Clock abstraction",
    },
    RuleMeta {
        id: "DT002",
        allowlistable: true,
        summary: "no HashMap/HashSet in optim/, coordinator/, sketch/, train/ production code",
    },
    RuleMeta {
        id: "WT001",
        allowlistable: false,
        summary: "every TAG_* wire tag value is unique",
    },
    RuleMeta {
        id: "WT002",
        allowlistable: false,
        summary: "every wire tag has both an encode_frame and a decode_payload arm",
    },
    RuleMeta {
        id: "WT003",
        allowlistable: false,
        summary: "every wire tag is named by at least one test",
    },
    RuleMeta {
        id: "WT004",
        allowlistable: false,
        summary: "PROTO_VERSION bumps must extend the marked degrade-matrix version list",
    },
    RuleMeta {
        id: "AB001",
        allowlistable: true,
        summary: "sized allocations in decode/load paths derive their bound from remaining input",
    },
    RuleMeta {
        id: "CK001",
        allowlistable: false,
        summary: "every dotted config lookup names a key in its section's known-keys registry",
    },
    RuleMeta {
        id: "CK002",
        allowlistable: false,
        summary: "every registered config key is documented in the README knob tables",
    },
    RuleMeta {
        id: "FL001",
        allowlistable: false,
        summary: "gate code reads numbers through the finite-checked accessor only",
    },
    RuleMeta {
        id: "AL001",
        allowlistable: false,
        summary: "every allowlist entry suppresses at least one current violation",
    },
];

pub fn rule_meta(id: &str) -> Option<&'static RuleMeta> {
    RULES.iter().find(|r| r.id == id)
}

/// One violation, anchored at a source line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub path: String,
    /// 1-based.
    pub line: usize,
    pub msg: String,
    /// Trimmed raw source line, for allowlist matching.
    pub text: String,
}

impl Violation {
    /// Anchor a violation at 0-based line `idx` of `f`.
    pub fn at(rule: &'static str, f: &SourceFile, idx: usize, msg: String) -> Violation {
        Violation {
            rule,
            path: f.rel.clone(),
            line: idx + 1,
            msg,
            text: f.raw.get(idx).map(|l| l.trim().to_string()).unwrap_or_default(),
        }
    }
}

/// One `rust/lint_allow.txt` entry:
/// `RULE | path-substring | line-substring | justification`.
#[derive(Debug)]
struct AllowEntry {
    rule: String,
    path_sub: String,
    line_sub: String,
    lineno: usize,
    raw: String,
}

fn allow_entries(text: &str) -> anyhow::Result<Vec<AllowEntry>> {
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = trimmed.splitn(4, '|').map(str::trim).collect();
        anyhow::ensure!(
            parts.len() == 4 && !parts[3].is_empty(),
            "lint_allow.txt:{}: expected `RULE | path | line-substring | justification`",
            idx + 1
        );
        out.push(AllowEntry {
            rule: parts[0].to_string(),
            path_sub: parts[1].to_string(),
            line_sub: parts[2].to_string(),
            lineno: idx + 1,
            raw: trimmed.to_string(),
        });
    }
    Ok(out)
}

/// Lint outcome: the surviving violations plus scan accounting.
#[derive(Debug)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
    pub allow_used: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!(
                "{}:{}: error[{}]: {}\n",
                v.path, v.line, v.rule, v.msg
            ));
        }
        if self.clean() {
            out.push_str(&format!(
                "sketchy lint: clean — {} files scanned, {} allowlisted exception(s)\n",
                self.files_scanned, self.allow_used
            ));
        } else {
            out.push_str(&format!(
                "sketchy lint: {} violation(s) — {} files scanned, {} allowlisted exception(s)\n",
                self.violations.len(),
                self.files_scanned,
                self.allow_used
            ));
        }
        out
    }
}

fn collect_rs(dir: &Path, skip_dir: Option<&str>, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("scan {}", dir.display()))? {
        entries.push(entry?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            let name = p.file_name().and_then(|n| n.to_str());
            if skip_dir.is_some() && name == skip_dir {
                continue;
            }
            collect_rs(&p, skip_dir, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_of(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

fn allow_path(root: &Path, repo_mode: bool) -> PathBuf {
    if repo_mode {
        root.join("rust").join("lint_allow.txt")
    } else {
        root.join("lint_allow.txt")
    }
}

/// Run every rule over the tree at `root` and apply the allowlist.
pub fn lint_root(root: &Path) -> anyhow::Result<LintReport> {
    let repo_mode = root.join("rust").join("src").is_dir();
    let mut paths = Vec::new();
    if repo_mode {
        collect_rs(&root.join("rust").join("src"), Some("lint_fixtures"), &mut paths)?;
        let tests = root.join("rust").join("tests");
        if tests.is_dir() {
            collect_rs(&tests, Some("lint_fixtures"), &mut paths)?;
        }
    } else {
        collect_rs(root, None, &mut paths)?;
    }
    anyhow::ensure!(!paths.is_empty(), "no .rs files found under {}", root.display());
    paths.sort();
    let mut files = Vec::new();
    for p in &paths {
        let text =
            std::fs::read_to_string(p).with_context(|| format!("read source {}", p.display()))?;
        let rel = rel_of(root, p);
        let wholly_test = rel.starts_with("rust/tests/") || rel.starts_with("tests/");
        files.push(SourceFile::build(rel, &text, wholly_test));
    }
    let readme = std::fs::read_to_string(root.join("README.md")).ok();

    let mut violations = Vec::new();
    violations.extend(determinism::check(&files));
    violations.extend(wiretag::check(&files));
    violations.extend(allocbound::check(&files));
    violations.extend(configkey::check(&files, readme.as_deref()));
    violations.extend(floataudit::check(&files));

    // Allowlist: suppress audited exceptions, then flag stale entries —
    // an entry that matches nothing is itself a violation, so the file
    // can only shrink as the code gets cleaned up.
    let allow_file = allow_path(root, repo_mode);
    let entries = match std::fs::read_to_string(&allow_file) {
        Ok(text) => allow_entries(&text)?,
        Err(_) => Vec::new(),
    };
    let mut used = vec![false; entries.len()];
    let mut kept = Vec::new();
    let mut allow_used = 0usize;
    for v in violations {
        let allowlistable = rule_meta(v.rule).is_some_and(|r| r.allowlistable);
        let hit = allowlistable
            && entries.iter().enumerate().any(|(i, e)| {
                let matches = e.rule == v.rule
                    && v.path.contains(&e.path_sub)
                    && v.text.contains(&e.line_sub);
                if matches {
                    used[i] = true;
                }
                matches
            });
        if hit {
            allow_used += 1;
        } else {
            kept.push(v);
        }
    }
    for (entry, was_used) in entries.iter().zip(&used) {
        let reason = if rule_meta(&entry.rule).is_none() {
            Some("names an unknown rule")
        } else if !rule_meta(&entry.rule).unwrap().allowlistable {
            Some("names a rule that is not allowlistable")
        } else if !*was_used {
            Some("matches no current violation (stale)")
        } else {
            None
        };
        if let Some(reason) = reason {
            kept.push(Violation {
                rule: "AL001",
                path: rel_of(root, &allow_file),
                line: entry.lineno,
                msg: format!("allowlist entry {reason}: `{}`", entry.raw),
                text: entry.raw.clone(),
            });
        }
    }
    kept.sort_by(|a, b| {
        (&a.path, a.line, a.rule, &a.msg).cmp(&(&b.path, b.line, b.rule, &b.msg))
    });
    kept.dedup_by(|a, b| a.path == b.path && a.line == b.line && a.rule == b.rule && a.msg == b.msg);
    Ok(LintReport { violations: kept, files_scanned: files.len(), allow_used })
}

/// CLI entry: lint `root`; with `fix_allowlist`, append TODO-justified
/// entries for any unsuppressed allowlistable violations and re-run.
pub fn run_lint(root: &str, fix_allowlist: bool) -> anyhow::Result<LintReport> {
    let root = Path::new(root);
    let report = lint_root(root)?;
    if !fix_allowlist {
        return Ok(report);
    }
    let fixable: Vec<&Violation> = report
        .violations
        .iter()
        .filter(|v| rule_meta(v.rule).is_some_and(|r| r.allowlistable))
        .collect();
    if fixable.is_empty() {
        return Ok(report);
    }
    let repo_mode = root.join("rust").join("src").is_dir();
    let path = allow_path(root, repo_mode);
    let mut text = std::fs::read_to_string(&path).unwrap_or_default();
    if !text.is_empty() && !text.ends_with('\n') {
        text.push('\n');
    }
    for v in &fixable {
        text.push_str(&format!("{} | {} | {} | TODO: justify\n", v.rule, v.path, v.text));
    }
    std::fs::write(&path, text).with_context(|| format!("write {}", path.display()))?;
    eprintln!(
        "sketchy lint: appended {} TODO-justified entr{} to {}",
        fixable.len(),
        if fixable.len() == 1 { "y" } else { "ies" },
        path.display()
    );
    lint_root(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_entries_parse_and_reject_garbage() {
        let text = "# comment\n\nDT001 | util/bench.rs | Instant::now( | benches measure wall time\n";
        let entries = allow_entries(text).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].rule, "DT001");
        assert_eq!(entries[0].lineno, 3);
        assert!(allow_entries("DT001 | a | b\n").is_err());
        assert!(allow_entries("DT001 | a | b | \n").is_err());
    }

    #[test]
    fn rule_table_is_consistent() {
        // Ids unique, summaries present, and the allowlistable set is
        // exactly the audited-exception rules.
        let mut seen = std::collections::BTreeSet::new();
        for r in RULES {
            assert!(seen.insert(r.id), "duplicate rule id {}", r.id);
            assert!(!r.summary.is_empty());
        }
        let allowlistable: Vec<&str> =
            RULES.iter().filter(|r| r.allowlistable).map(|r| r.id).collect();
        assert_eq!(allowlistable, vec!["DT001", "DT002", "AB001"]);
    }
}
