//! `sketchy lint` — a repo-invariant static analyzer.
//!
//! Every guarantee this reproduction makes (bitwise-deterministic
//! FD/Shampoo steps across threads, shards, overlap mode, and
//! crash-resume) rests on source-level conventions: timing goes through
//! the injectable `Clock`, the deterministic core iterates ordered
//! structures, the wire tag registry stays closed under encode/decode/
//! test coverage, decode-path allocations are bounded by real input,
//! and the config-key registries match both the lookups and the README.
//! This subsystem checks those conventions mechanically, with the same
//! no-deps line/token-scanning idiom as the vendored wire codec — no
//! external crates, no rustc internals.
//!
//! Entry points: [`run_lint`] (the `sketchy lint` subcommand),
//! [`lint_root`] (library/tests). Rules live one module per family and
//! are described by the [`RULES`] table; audited exceptions live in
//! `rust/lint_allow.txt`. The engine is self-tested against committed
//! failing fixtures in `rust/tests/lint_fixtures/` (excluded from repo
//! scans) by `rust/tests/lint_self.rs`, which also asserts HEAD is
//! clean.

pub mod allocbound;
pub mod configkey;
pub mod determinism;
pub mod floataudit;
pub mod lint;
pub mod source;
pub mod wiretag;

pub use lint::{lint_root, run_lint, LintReport, RuleMeta, Violation, RULES};
pub use source::SourceFile;
