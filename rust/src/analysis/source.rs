//! Comment/string-aware source model for `sketchy lint`.
//!
//! The linter does not parse Rust; it scans lines. To do that safely it
//! needs views of each file in which comments and string contents can
//! neither spoof nor hide a match:
//!
//! - `raw`: the file's lines verbatim (marker searches, allowlist
//!   matching, violation display).
//! - `code`: comments blanked entirely; string/char literal *contents*
//!   blanked to spaces with the delimiting quotes kept, so columns and
//!   brace structure survive. Every identifier-level rule reads this
//!   view — a needle inside a string or comment is not code.
//! - `lits`: every completed string literal (content plus the line and
//!   column of its opening quote), for the config-key rules that reason
//!   about quoted keys.
//!
//! On top of the `code` view a second pass tracks, per line: whether the
//! line sits inside a `#[cfg(test)]` region (or a `tests/` file), and
//! the innermost enclosing `fn` / `impl` headers — enough context to
//! scope rules like "allocation in a decode path" without a parser.

/// One string literal, anchored at its opening quote.
#[derive(Debug, Clone)]
pub struct StrLit {
    /// 0-based line of the opening quote.
    pub line: usize,
    /// 0-based column (in chars) of the opening quote.
    pub col: usize,
    /// Literal content, escapes unprocessed. Multi-line literals keep
    /// their newlines, which conveniently disqualifies them from the
    /// single-token matches the rules perform.
    pub text: String,
}

/// One scanned source file with the per-line views the rules consume.
#[derive(Debug)]
pub struct SourceFile {
    /// Forward-slash path relative to the lint root.
    pub rel: String,
    pub raw: Vec<String>,
    pub code: Vec<String>,
    pub lits: Vec<StrLit>,
    /// Line is inside `#[cfg(test)]` (or the whole file is a test).
    pub is_test: Vec<bool>,
    /// Name of the innermost enclosing `fn`, or empty at module level.
    pub fn_ctx: Vec<String>,
    /// Header of the innermost enclosing `impl`, or empty.
    pub impl_ctx: Vec<String>,
}

impl SourceFile {
    pub fn build(rel: String, text: &str, wholly_test: bool) -> SourceFile {
        let raw: Vec<String> = text.split('\n').map(str::to_string).collect();
        let (code_text, lits) = strip(text);
        let code: Vec<String> = code_text.split('\n').map(str::to_string).collect();
        debug_assert_eq!(raw.len(), code.len());
        let (is_test, fn_ctx, impl_ctx) = contexts(&code, wholly_test);
        SourceFile { rel, raw, code, lits, is_test, fn_ctx, impl_ctx }
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// True when `hay` contains `needle` at identifier boundaries (the
/// characters around the match, if any, are not identifier characters).
/// `needle` itself may contain `::` / `.` path separators.
pub fn contains_ident(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let end = at + needle.len();
        let pre_ok = hay[..at].chars().next_back().is_none_or(|c| !is_ident(c));
        let post_ok = hay[end..].chars().next().is_none_or(|c| !is_ident(c));
        if pre_ok && post_ok {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// Pass 1: blank comments and literal contents, collect string literals.
struct Emitter {
    out: String,
    line: usize,
    col: usize,
}

impl Emitter {
    fn emit(&mut self, c: char) {
        self.out.push(c);
        if c == '\n' {
            self.line += 1;
            self.col = 0;
        } else {
            self.col += 1;
        }
    }

    /// Blank a consumed source char: newlines survive, the rest
    /// becomes a space so columns stay aligned.
    fn blank(&mut self, c: char) {
        self.emit(if c == '\n' { '\n' } else { ' ' });
    }
}

fn strip(text: &str) -> (String, Vec<StrLit>) {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut em = Emitter { out: String::new(), line: 0, col: 0 };
    let mut lits = Vec::new();
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                em.emit(' ');
                i += 1;
            }
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1;
            em.emit(' ');
            em.emit(' ');
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    em.emit(' ');
                    em.emit(' ');
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    em.emit(' ');
                    em.emit(' ');
                    i += 2;
                } else {
                    em.blank(chars[i]);
                    i += 1;
                }
            }
            continue;
        }
        if c == '"' {
            // Raw string? Look back over `#`s for an `r`/`br` prefix
            // that is not the tail of a longer identifier.
            let mut j = i;
            let mut hashes = 0usize;
            while j > 0 && chars[j - 1] == '#' {
                hashes += 1;
                j -= 1;
            }
            let is_raw = j > 0
                && chars[j - 1] == 'r'
                && if j >= 2 && is_ident(chars[j - 2]) {
                    chars[j - 2] == 'b' && !(j >= 3 && is_ident(chars[j - 3]))
                } else {
                    true
                };
            let (lit_line, lit_col) = (em.line, em.col);
            em.emit('"');
            i += 1;
            let mut content = String::new();
            if is_raw {
                while i < n {
                    if chars[i] == '"' {
                        let mut k = 0;
                        while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            em.emit('"');
                            i += 1;
                            for _ in 0..hashes {
                                em.emit('#');
                                i += 1;
                            }
                            break;
                        }
                    }
                    content.push(chars[i]);
                    em.blank(chars[i]);
                    i += 1;
                }
            } else {
                while i < n {
                    if chars[i] == '\\' && i + 1 < n {
                        content.push(chars[i]);
                        content.push(chars[i + 1]);
                        em.emit(' ');
                        em.blank(chars[i + 1]);
                        i += 2;
                        continue;
                    }
                    if chars[i] == '"' {
                        em.emit('"');
                        i += 1;
                        break;
                    }
                    content.push(chars[i]);
                    em.blank(chars[i]);
                    i += 1;
                }
            }
            lits.push(StrLit { line: lit_line, col: lit_col, text: content });
            continue;
        }
        if c == '\'' {
            let escaped = i + 1 < n && chars[i + 1] == '\\';
            let closed =
                i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' && chars[i + 1] != '\\';
            if escaped {
                em.emit('\'');
                i += 1;
                while i < n && chars[i] != '\'' {
                    if chars[i] == '\\' && i + 1 < n {
                        em.emit(' ');
                        em.emit(' ');
                        i += 2;
                    } else {
                        em.emit(' ');
                        i += 1;
                    }
                }
                if i < n {
                    em.emit('\'');
                    i += 1;
                }
            } else if closed {
                em.emit('\'');
                em.emit(' ');
                em.emit('\'');
                i += 3;
            } else {
                // Lifetime or loop label.
                em.emit('\'');
                i += 1;
            }
            continue;
        }
        em.emit(c);
        i += 1;
    }
    (em.out, lits)
}

/// Pass 2: per-line test/fn/impl context over the `code` view.
fn contexts(code: &[String], wholly_test: bool) -> (Vec<bool>, Vec<String>, Vec<String>) {
    let mut depth: i64 = 0;
    let mut paren: i64 = 0;
    let mut brack: i64 = 0;
    let mut fn_stack: Vec<(i64, String)> = Vec::new();
    let mut impl_stack: Vec<(i64, String)> = Vec::new();
    let mut test_stack: Vec<i64> = Vec::new();
    let mut pending_fn: Option<String> = None;
    let mut impl_buf: Option<String> = None;
    let mut pending_test = false;
    let mut is_test = Vec::new();
    let mut fn_ctx = Vec::new();
    let mut impl_ctx = Vec::new();
    for line in code {
        is_test.push(wholly_test || !test_stack.is_empty());
        fn_ctx.push(fn_stack.last().map(|(_, s)| s.clone()).unwrap_or_default());
        impl_ctx.push(impl_stack.last().map(|(_, s)| s.clone()).unwrap_or_default());
        if line.contains("#[cfg(test)]") {
            pending_test = true;
        }
        let chars: Vec<char> = line.chars().collect();
        let mut k = 0;
        while k < chars.len() {
            let c = chars[k];
            if is_ident(c) && !(k > 0 && is_ident(chars[k - 1])) {
                let start = k;
                while k < chars.len() && is_ident(chars[k]) {
                    k += 1;
                }
                let word: String = chars[start..k].iter().collect();
                if word == "fn" {
                    let mut m = k;
                    while m < chars.len() && chars[m].is_whitespace() {
                        m += 1;
                    }
                    let name_start = m;
                    while m < chars.len() && is_ident(chars[m]) {
                        m += 1;
                    }
                    if m > name_start {
                        pending_fn = Some(chars[name_start..m].iter().collect());
                    }
                    k = m;
                } else if word == "impl" && impl_buf.is_none() && pending_fn.is_none() {
                    impl_buf = Some(String::new());
                } else if let Some(buf) = impl_buf.as_mut() {
                    buf.push_str(&word);
                }
                continue;
            }
            match c {
                '(' => paren += 1,
                ')' => paren -= 1,
                '[' => brack += 1,
                ']' => brack -= 1,
                '{' => {
                    depth += 1;
                    if let Some(buf) = impl_buf.take() {
                        impl_stack.push((depth, buf.trim().to_string()));
                    }
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((depth, name));
                    }
                    if pending_test {
                        test_stack.push(depth);
                        pending_test = false;
                    }
                }
                '}' => {
                    if fn_stack.last().is_some_and(|(d, _)| *d == depth) {
                        fn_stack.pop();
                    }
                    if impl_stack.last().is_some_and(|(d, _)| *d == depth) {
                        impl_stack.pop();
                    }
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                    depth -= 1;
                }
                ';' if paren == 0 && brack == 0 => {
                    pending_fn = None;
                    impl_buf = None;
                    pending_test = false;
                }
                _ => {}
            }
            if c != '{' {
                if let Some(buf) = impl_buf.as_mut() {
                    buf.push(c);
                }
            }
            k += 1;
        }
    }
    (is_test, fn_ctx, impl_ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(text: &str) -> SourceFile {
        SourceFile::build("x.rs".into(), text, false)
    }

    #[test]
    fn comments_and_strings_are_blanked_in_code_view() {
        let f = build(concat!(
            "let a = \"Instant::now\"; // Instant::now\n",
            "/* Instant::now */ let b = 1;\n",
            "let c = Instant::now();\n",
        ));
        assert!(!f.code[0].contains("Instant"));
        assert!(!f.code[1].contains("Instant"));
        assert!(f.code[2].contains("Instant::now"));
        assert_eq!(f.lits[0].text, "Instant::now");
    }

    #[test]
    fn multiline_and_raw_strings_keep_line_structure() {
        let f = build("let u = \"line one\nline {two}\";\nlet r = r#\"raw \"q\" body\"#;\nok();\n");
        assert_eq!(f.code.len(), f.raw.len());
        // The `{` inside the string must not look like a brace.
        assert!(!f.code[1].contains('{'));
        assert_eq!(f.lits[0].text, "line one\nline {two}");
        assert_eq!(f.lits[1].text, "raw \"q\" body");
        assert!(f.code[2].contains("ok()"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let f = build("fn f<'a>(x: &'a str) -> char {\n    if x.is_empty() { '{' } else { '\\n' }\n}\n");
        // The brace inside the char literal must not unbalance the walk.
        assert_eq!(f.fn_ctx[1], "f");
        assert!(f.code[0].contains("'a"));
    }

    #[test]
    fn cfg_test_regions_are_masked() {
        let f = build(concat!(
            "pub fn prod() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use super::*;\n",
            "    #[test]\n",
            "    fn t() { prod(); }\n",
            "}\n",
            "pub fn later() {}\n",
        ));
        assert!(!f.is_test[0]);
        assert!(f.is_test[3]);
        assert!(f.is_test[5]);
        assert!(!f.is_test[7]);
    }

    #[test]
    fn cfg_test_on_a_statement_does_not_leak() {
        let f = build("#[cfg(test)]\nuse std::fmt;\npub fn prod() {}\nfn g() { prod(); }\n");
        assert!(!f.is_test[2]);
        assert!(!f.is_test[3]);
    }

    #[test]
    fn fn_and_impl_context_track_nesting() {
        let f = build(concat!(
            "impl<'b> Dec<'b> {\n",
            "    fn matrix(&mut self) -> u32 {\n",
            "        let v = 1;\n",
            "        v\n",
            "    }\n",
            "}\n",
            "fn decode_payload(b: &[u8]) {\n",
            "    let x = b.len();\n",
            "}\n",
        ));
        assert!(f.impl_ctx[2].contains("Dec"));
        assert_eq!(f.fn_ctx[2], "matrix");
        assert_eq!(f.fn_ctx[7], "decode_payload");
        assert_eq!(f.fn_ctx[5], "");
    }

    #[test]
    fn trait_method_signatures_do_not_capture_context() {
        let f = build(concat!(
            "trait Clock {\n",
            "    fn now(&self) -> u64;\n",
            "    fn on_poll(&self) {}\n",
            "}\n",
            "fn free() { let x = 1; }\n",
            "static X: u32 = 0;\n",
        ));
        // The `;`-terminated signature must not leave `now` dangling.
        assert_eq!(f.fn_ctx[3], "");
        assert_eq!(f.impl_ctx[4], "");
    }

    #[test]
    fn contains_ident_respects_boundaries() {
        assert!(contains_ident("e.u8(TAG_INIT);", "TAG_INIT"));
        assert!(!contains_ident("e.u8(TAG_INIT_V7);", "TAG_INIT"));
        assert!(contains_ident("std::thread::sleep(d)", "thread::sleep"));
        assert!(!contains_ident("clock.sleep(d)", "thread::sleep"));
    }
}
