//! WT001–WT004 — wire-protocol registry rules for `coordinator/wire.rs`.
//!
//! The shard protocol's compatibility story rests on a tag registry and
//! a version constant; these rules keep both honest:
//!
//! - **WT001** — every `TAG_*` value is unique. A reused byte silently
//!   decodes one message kind as another on a version-skewed peer.
//! - **WT002** — every tag is referenced inside both `encode_frame` and
//!   `decode_payload`. A tag with one arm is a frame that can be sent
//!   but never understood (or vice versa).
//! - **WT003** — every tag is named by at least one test line
//!   (roundtrip/truncation coverage lives in `mod tests` and the
//!   integration suites).
//! - **WT004** — a `PROTO_VERSION` bump must extend the degrade-matrix
//!   test list: the marked version list (see the marker comment in
//!   `tests/shard_determinism.rs`) has to cover every protocol version
//!   `1..=PROTO_VERSION`, so old-peer interop is re-proven on each bump.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use super::lint::Violation;
use super::source::{contains_ident, SourceFile};

/// Marker comment that tags the degrade-matrix version list. Assembled
/// from pieces so the linter's own source never matches it.
fn marker() -> &'static str {
    concat!("lint:", "degrade-matrix")
}

fn tag_consts(f: &SourceFile) -> Vec<(String, u32, usize)> {
    let mut out = Vec::new();
    for (idx, line) in f.code.iter().enumerate() {
        if f.is_test[idx] {
            continue;
        }
        let Some(p) = line.find("const TAG_") else { continue };
        let rest = &line[p + "const ".len()..];
        let name_end = rest.find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'));
        let name = &rest[..name_end.unwrap_or(rest.len())];
        let Some(eq) = line.find('=') else { continue };
        let Ok(value) = line[eq + 1..].trim().trim_end_matches(';').trim().parse::<u32>() else {
            continue;
        };
        out.push((name.to_string(), value, idx));
    }
    out
}

fn parse_proto_version(f: &SourceFile) -> Option<(u32, usize)> {
    for (idx, line) in f.code.iter().enumerate() {
        if f.is_test[idx] || !line.contains("const ") || !contains_ident(line, "PROTO_VERSION") {
            continue;
        }
        let Some(eq) = line.find('=') else { continue };
        if let Ok(v) = line[eq + 1..].trim().trim_end_matches(';').trim().parse::<u32>() {
            return Some((v, idx));
        }
    }
    None
}

fn digit_runs(line: &str) -> Vec<u32> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in line.chars().chain(std::iter::once(' ')) {
        if c.is_ascii_digit() {
            cur.push(c);
        } else if !cur.is_empty() {
            if let Ok(v) = cur.parse::<u32>() {
                out.push(v);
            }
            cur.clear();
        }
    }
    out
}

pub fn check(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| f.rel.ends_with("coordinator/wire.rs")) {
        let tags = tag_consts(f);
        let mut seen: BTreeMap<u32, String> = BTreeMap::new();
        for (name, value, idx) in &tags {
            if let Some(prev) = seen.get(value) {
                out.push(Violation::at(
                    "WT001",
                    f,
                    *idx,
                    format!("`{name}` reuses wire tag value {value}, already taken by `{prev}`"),
                ));
            } else {
                seen.insert(*value, name.clone());
            }
        }
        for (name, _, idx) in &tags {
            let mut encoded = false;
            let mut decoded = false;
            let mut tested = false;
            for (j, line) in f.code.iter().enumerate() {
                if j == *idx || !contains_ident(line, name) {
                    continue;
                }
                match f.fn_ctx[j].as_str() {
                    "encode_frame" => encoded = true,
                    "decode_payload" => decoded = true,
                    _ => {}
                }
                if f.is_test[j] {
                    tested = true;
                }
            }
            if !tested {
                'files: for g in files {
                    for (j, line) in g.code.iter().enumerate() {
                        if g.is_test[j] && contains_ident(line, name) {
                            tested = true;
                            break 'files;
                        }
                    }
                }
            }
            if !encoded {
                out.push(Violation::at(
                    "WT002",
                    f,
                    *idx,
                    format!("wire tag `{name}` has no encode arm in `encode_frame`"),
                ));
            }
            if !decoded {
                out.push(Violation::at(
                    "WT002",
                    f,
                    *idx,
                    format!("wire tag `{name}` has no decode arm in `decode_payload`"),
                ));
            }
            if !tested {
                out.push(Violation::at(
                    "WT003",
                    f,
                    *idx,
                    format!("wire tag `{name}` is not named by any test"),
                ));
            }
        }
        if let Some((version, pidx)) = parse_proto_version(f) {
            let mut covered: BTreeSet<u32> = BTreeSet::new();
            let mut first_marker: Option<(&SourceFile, usize)> = None;
            for g in files {
                for (j, rawline) in g.raw.iter().enumerate() {
                    if !rawline.contains(marker()) {
                        continue;
                    }
                    if first_marker.is_none() {
                        first_marker = Some((g, j));
                    }
                    // The marked version list may wrap; read a few lines.
                    for k in j..(j + 4).min(g.raw.len()) {
                        covered.extend(digit_runs(&g.raw[k]));
                        if contains_ident(&g.code[k], "PROTO_VERSION") {
                            covered.insert(version);
                        }
                    }
                }
            }
            match first_marker {
                None => out.push(Violation::at(
                    "WT004",
                    f,
                    pidx,
                    format!(
                        "PROTO_VERSION = {version} but no degrade-matrix marker comment \
                         (`{}`) tags a version list in any test",
                        marker()
                    ),
                )),
                Some((g, j)) => {
                    let missing: Vec<u32> =
                        (1..=version).filter(|v| !covered.contains(v)).collect();
                    if !missing.is_empty() {
                        out.push(Violation::at(
                            "WT004",
                            g,
                            j,
                            format!(
                                "degrade-matrix version list does not cover protocol \
                                 version(s) {missing:?} (PROTO_VERSION = {version})"
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}
