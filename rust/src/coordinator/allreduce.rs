//! Tree allreduce over in-process gradient shards.
//!
//! Simulates the reduction structure of a data-parallel pod: ⌈log₂ W⌉
//! pairwise-combine rounds, each merging partner shards in parallel.
//! The result on "rank 0" is the element-wise mean across workers.

use crate::tensor::Matrix;

/// Statistics from one allreduce (observability for the E10 driver).
#[derive(Clone, Debug, Default)]
pub struct AllreduceStats {
    /// Number of pairwise-combine rounds (= ⌈log₂ workers⌉).
    pub rounds: usize,
    /// Total elements moved between shards.
    pub elements_moved: usize,
}

/// Reduce worker gradient shards to their mean with a binary tree.
/// Consumes the shards (rank 0's buffer becomes the output).
///
/// An empty shard list is a coordination bug (a step with zero workers);
/// it surfaces as an error rather than a panic so driver loops — the
/// data-parallel step and the cross-process shard engine alike — can
/// report which step failed and shut down cleanly.
pub fn tree_allreduce(
    mut shards: Vec<Vec<Matrix>>,
) -> anyhow::Result<(Vec<Matrix>, AllreduceStats)> {
    let w = shards.len();
    if w == 0 {
        anyhow::bail!("tree_allreduce requires at least one shard");
    }
    let mut stats = AllreduceStats::default();
    let mut stride = 1;
    while stride < w {
        stats.rounds += 1;
        // Pair (i, i+stride) for i ≡ 0 (mod 2·stride). Combines within a
        // round are independent — run them on scoped threads like a real
        // reduction tree's parallel links.
        let mut round_moved = 0usize;
        {
            // Split the shard vec into disjoint (dst, src) pairs.
            let mut pairs: Vec<(usize, usize)> = vec![];
            let mut i = 0;
            while i + stride < w {
                pairs.push((i, i + stride));
                i += 2 * stride;
            }
            for &(_dst, src) in &pairs {
                round_moved += shards[src].iter().map(|m| m.as_slice().len()).sum::<usize>();
            }
            // Take the source shards out, then add into destinations in
            // parallel.
            let mut taken: Vec<(usize, Vec<Matrix>)> = vec![];
            for &(_, src) in pairs.iter().rev() {
                taken.push((src, std::mem::take(&mut shards[src])));
            }
            taken.reverse();
            std::thread::scope(|scope| {
                let mut rest: &mut [Vec<Matrix>] = &mut shards;
                let mut base = 0usize;
                let mut handles = vec![];
                for (&(dst, _), (_, src_shard)) in pairs.iter().zip(taken) {
                    // Split off the destination shard mutably.
                    let offset = dst - base;
                    let (_, tail) = rest.split_at_mut(offset);
                    let (dst_slot, tail2) = tail.split_at_mut(1);
                    rest = tail2;
                    base = dst + 1;
                    let dst_ref = &mut dst_slot[0];
                    handles.push(scope.spawn(move || {
                        for (d, s) in dst_ref.iter_mut().zip(&src_shard) {
                            d.axpy(1.0, s);
                        }
                    }));
                }
                for h in handles {
                    h.join().unwrap();
                }
            });
        }
        stats.elements_moved += round_moved;
        stride *= 2;
    }
    let mut out = std::mem::take(&mut shards[0]);
    let scale = 1.0 / w as f64;
    for m in &mut out {
        m.scale_inplace(scale);
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all_msg;
    use crate::util::rng::Pcg64;

    fn serial_mean(shards: &[Vec<Matrix>]) -> Vec<Matrix> {
        let w = shards.len();
        let mut out = shards[0].clone();
        for s in &shards[1..] {
            for (o, m) in out.iter_mut().zip(s) {
                o.axpy(1.0, m);
            }
        }
        for m in &mut out {
            m.scale_inplace(1.0 / w as f64);
        }
        out
    }

    #[test]
    fn prop_allreduce_equals_serial_mean() {
        for_all_msg(
            400,
            15,
            |rng| {
                let workers = 1 + rng.below(9);
                let tensors = 1 + rng.below(4);
                let seed = rng.next_u64();
                (workers, tensors, seed)
            },
            |&(workers, tensors, seed)| {
                let mut rng = Pcg64::new(seed);
                let shapes: Vec<(usize, usize)> =
                    (0..tensors).map(|_| (1 + rng.below(6), 1 + rng.below(6))).collect();
                let shards: Vec<Vec<Matrix>> = (0..workers)
                    .map(|_| {
                        shapes
                            .iter()
                            .map(|&(r, c)| Matrix::randn(r, c, &mut rng))
                            .collect()
                    })
                    .collect();
                let want = serial_mean(&shards);
                let (got, stats) = tree_allreduce(shards).expect("non-empty shards");
                let expected_rounds = (workers as f64).log2().ceil() as usize;
                if stats.rounds != expected_rounds {
                    return Err(format!(
                        "rounds {} != ceil(log2({workers})) = {expected_rounds}",
                        stats.rounds
                    ));
                }
                for (g, w) in got.iter().zip(&want) {
                    if g.max_diff(w) > 1e-12 {
                        return Err(format!("mean mismatch: {}", g.max_diff(w)));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn single_worker_is_identity() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let (out, stats) = tree_allreduce(vec![vec![m.clone()]]).unwrap();
        assert_eq!(out[0], m);
        assert_eq!(stats.rounds, 0);
        assert_eq!(stats.elements_moved, 0);
    }

    #[test]
    fn single_worker_is_bitwise_identity() {
        // The single-shard path must not touch the payload at all: mean
        // over one shard divides by 1, which preserves every bit.
        let m = Matrix::from_vec(1, 3, vec![-0.0, f64::MIN_POSITIVE / 2.0, 1.0 / 3.0]);
        let (out, _) = tree_allreduce(vec![vec![m.clone()]]).unwrap();
        for (a, b) in out[0].as_slice().iter().zip(m.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_shard_list_is_an_error_not_a_panic() {
        let err = tree_allreduce(vec![]).unwrap_err();
        assert!(
            err.to_string().contains("at least one shard"),
            "unhelpful error: {err}"
        );
    }

    #[test]
    fn elements_moved_counts_comm_volume() {
        let shards: Vec<Vec<Matrix>> = (0..4).map(|_| vec![Matrix::zeros(2, 3)]).collect();
        let (_, stats) = tree_allreduce(shards).unwrap();
        // Round 1: 2 pairs × 6 elements; round 2: 1 pair × 6.
        assert_eq!(stats.elements_moved, 18);
    }
}
