//! Deterministic in-memory transport with scripted fault injection.
//!
//! The shard wire protocol now carries **two** in-flight request kinds
//! per connection (`Step`, and the parked `RefreshAhead` whose reply is
//! read one step later), which doubles the concurrent states a transport
//! failure can interrupt. Exercising those states over real sockets
//! means racing `kill(2)` against the kernel's buffers — inherently
//! flaky. This module replaces the socket with a pair of in-memory byte
//! pipes and a **fault script**: frames crossing the link are counted
//! per direction, and at scripted frame indices the harness drops,
//! delays, duplicates, or severs — exactly once, at exactly that frame,
//! every run.
//!
//! The pieces:
//!
//! - [`FaultScript`] — `(direction, frame index) → action` entries,
//!   counted across reconnects (a sever at request #3 means the 4th
//!   request frame of the *run*, not of the connection — indices are
//!   0-based and include handshake frames on the reply direction).
//! - [`FaultInjectingTransport`] — the listener: hands the driver a
//!   [`FaultConn`] per [`FaultInjectingTransport::dial`] and queues the
//!   matching worker-side end on an acceptor channel
//!   ([`FaultInjectingTransport::take_acceptor`]), with an optional
//!   connection budget so tests can model *permanent* link loss.
//! - [`FaultConn`] — one end of a connection. Writes are split into
//!   wire frames (length-prefix parsing, so multi-`write` callers are
//!   handled) and the script is consulted per frame; reads block with a
//!   capped timeout so a dropped frame surfaces as a timed-out read
//!   (the same failure shape a hung socket produces) instead of a hang.
//!
//! No sockets, no extra processes: a worker serve loop runs on a plain
//! thread (`ShardExecutor::launch_in_proc` wires this up), so
//! integration tests drive the full driver ↔ worker protocol — replay,
//! reconnect, idempotency — under exact, reproducible fault timing.

use super::wire::Conn;
use std::collections::VecDeque;
use std::io::{Error, ErrorKind, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

/// What happens to the frame at a scripted index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// The frame is discarded; the connection stays up. The waiting
    /// peer times out, which the driver treats as a transport failure
    /// (reconnect + replay).
    DropFrame,
    /// The frame is withheld and delivered immediately before the next
    /// frame sent in the same direction (a late packet). If the
    /// connection dies first, the frame dies with it.
    DelayFrame,
    /// The frame is delivered twice back to back (a replayed request
    /// arriving on top of the original — the worker's idempotency cache
    /// must absorb it). Request-direction only: a duplicated *reply*
    /// would be read as the answer to the next request, desyncing the
    /// strict request/reply channel in a way no real transport produces
    /// — [`FaultScript::on_reply`] rejects it.
    DuplicateFrame,
    /// The connection dies as this frame is sent: the frame is lost,
    /// both directions close, and the writer gets a connection error.
    Sever,
}

/// Direction of a frame, from the driver's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Driver → worker (requests).
    Request,
    /// Worker → driver (replies, including the handshake hello).
    Reply,
}

/// Scripted faults: each entry fires exactly once, at the given
/// per-direction frame index (0-based, counted across reconnects).
#[derive(Clone, Debug, Default)]
pub struct FaultScript {
    entries: Vec<(Dir, usize, FaultAction)>,
}

impl FaultScript {
    /// The empty script: a perfectly reliable link.
    pub fn none() -> FaultScript {
        FaultScript::default()
    }

    /// Add a fault on the `idx`-th driver → worker frame.
    pub fn on_request(mut self, idx: usize, action: FaultAction) -> FaultScript {
        self.entries.push((Dir::Request, idx, action));
        self
    }

    /// Add a fault on the `idx`-th worker → driver frame (index 0 is
    /// the first connection's hello). Panics on
    /// [`FaultAction::DuplicateFrame`]: a duplicated reply desyncs the
    /// strict request/reply channel in a way no real transport can
    /// (TCP never duplicates; real-world duplicates are request
    /// *replays*, which [`FaultScript::on_request`] models).
    pub fn on_reply(mut self, idx: usize, action: FaultAction) -> FaultScript {
        assert!(
            action != FaultAction::DuplicateFrame,
            "FaultScript::on_reply(DuplicateFrame) would desync the request/reply \
             protocol; script the duplicate on the request direction instead"
        );
        self.entries.push((Dir::Reply, idx, action));
        self
    }
}

// ---------------------------------------------------------------------------
// Driver-kill scripting.
// ---------------------------------------------------------------------------

/// Scripted **driver** crashes, the coordinator-side counterpart of
/// [`FaultScript`]: a sorted list of step indices after which the
/// driver process is to die abruptly (`SIGKILL`-equivalent — no
/// destructors, no final flush). The chaos harness and the
/// `--crash-at-step` flag consult this after each completed step; the
/// relaunched driver resumes from the durable journal
/// (`--resume-journal`) and must continue **bitwise identical** to an
/// uninterrupted run.
///
/// Each index fires at most once, so a resumed driver that replays
/// through a scripted step does not re-crash on it — the resumed
/// process builds its plan from the *remaining* indices (the CI leg
/// passes one index per launch, which is the simplest way to keep
/// that invariant).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DriverKillPlan {
    /// Remaining kill points, sorted ascending, deduplicated.
    steps: Vec<u64>,
}

impl DriverKillPlan {
    /// A plan that never kills.
    pub fn none() -> DriverKillPlan {
        DriverKillPlan::default()
    }

    /// Kill after each of the given (1-based optimizer) step indices.
    pub fn at(steps: &[u64]) -> DriverKillPlan {
        let mut steps = steps.to_vec();
        steps.sort_unstable();
        steps.dedup();
        DriverKillPlan { steps }
    }

    /// Parse a `--crash-at-step` style list: comma-separated step
    /// indices (`"3"` or `"3,7,11"`). Empty input is the empty plan.
    pub fn parse(spec: &str) -> Result<DriverKillPlan, String> {
        let mut steps = Vec::new();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let step: u64 = tok
                .parse()
                .map_err(|_| format!("crash-at-step: bad step index {tok:?} in {spec:?}"))?;
            if step == 0 {
                return Err(format!(
                    "crash-at-step: step indices are 1-based, got 0 in {spec:?}"
                ));
            }
            steps.push(step);
        }
        Ok(DriverKillPlan::at(&steps))
    }

    /// Whether the driver should die now, having just completed
    /// `step`. Consumes the matching kill point: asking again about
    /// the same step is `false`.
    pub fn should_kill(&mut self, step: u64) -> bool {
        match self.steps.iter().position(|&s| s == step) {
            Some(pos) => {
                self.steps.remove(pos);
                true
            }
            None => false,
        }
    }

    /// True if no kill points remain.
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Remaining kill points (sorted).
    pub fn remaining(&self) -> &[u64] {
        &self.steps
    }
}

// ---------------------------------------------------------------------------
// In-memory half-duplex byte pipe.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct PipeBuf {
    buf: VecDeque<u8>,
    closed: bool,
}

/// One direction of a connection: bytes in, bytes out, close flag.
struct Pipe {
    state: Mutex<PipeBuf>,
    cv: Condvar,
}

impl Pipe {
    fn new() -> Pipe {
        Pipe { state: Mutex::new(PipeBuf::default()), cv: Condvar::new() }
    }

    fn push(&self, bytes: &[u8]) -> std::io::Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(Error::new(ErrorKind::BrokenPipe, "fault pipe: peer closed"));
        }
        st.buf.extend(bytes.iter().copied());
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Blocking read with an optional bound. EOF (`Ok(0)`) once closed
    /// and drained; `TimedOut` if the bound expires with no data.
    fn read_into(&self, out: &mut [u8], timeout: Option<Duration>) -> std::io::Result<usize> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                // Bulk copy from the deque's (up to two) contiguous
                // runs — block payloads are hundreds of KB, so a
                // byte-at-a-time pop would dominate bench timings.
                let n = out.len().min(st.buf.len());
                let (a, b) = st.buf.as_slices();
                if n <= a.len() {
                    out[..n].copy_from_slice(&a[..n]);
                } else {
                    out[..a.len()].copy_from_slice(a);
                    out[a.len()..n].copy_from_slice(&b[..n - a.len()]);
                }
                st.buf.drain(..n);
                return Ok(n);
            }
            if st.closed {
                return Ok(0);
            }
            match timeout {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let (guard, res) = self.cv.wait_timeout(st, d).unwrap();
                    st = guard;
                    if res.timed_out() && st.buf.is_empty() && !st.closed {
                        return Err(Error::new(
                            ErrorKind::TimedOut,
                            "fault pipe: read timed out",
                        ));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared per-transport fault state.
// ---------------------------------------------------------------------------

struct FaultState {
    /// Remaining (unfired) script entries.
    script: Mutex<Vec<(Dir, usize, FaultAction)>>,
    req_frames: AtomicUsize,
    rep_frames: AtomicUsize,
    connections: AtomicUsize,
    max_connections: usize,
    /// Set by [`FaultInjectingTransport::kill`]: every future dial is
    /// refused, modeling a worker host that is gone for good.
    killed: AtomicBool,
    /// Frame bytes actually delivered across both directions (length
    /// prefixes included; dropped/severed frames excluded, duplicates
    /// counted twice) — the `shard_wire_bytes` bench's meter.
    bytes: AtomicU64,
}

impl FaultState {
    /// Claim the next frame index in `dir` and take its fault, if any.
    fn next_fault(&self, dir: Dir) -> Option<FaultAction> {
        let idx = match dir {
            Dir::Request => self.req_frames.fetch_add(1, Ordering::SeqCst),
            Dir::Reply => self.rep_frames.fetch_add(1, Ordering::SeqCst),
        };
        let mut script = self.script.lock().unwrap();
        let pos = script.iter().position(|&(d, i, _)| d == dir && i == idx)?;
        Some(script.swap_remove(pos).2)
    }
}

// ---------------------------------------------------------------------------
// Connection end.
// ---------------------------------------------------------------------------

/// One end of an in-memory connection. Writes pass through the fault
/// script (per complete wire frame); reads come straight off the
/// incoming pipe with a capped timeout.
pub struct FaultConn {
    dir: Dir,
    state: Arc<FaultState>,
    incoming: Arc<Pipe>,
    outgoing: Arc<Pipe>,
    /// Write-side frame assembly (writers may deliver a frame across
    /// several `write` calls).
    partial: Vec<u8>,
    /// A `DelayFrame` stash, delivered before the next delivered frame.
    delayed: Option<Vec<u8>>,
    severed: bool,
    timeout: Option<Duration>,
    /// Upper bound on any timeout a caller sets — keeps drop-fault
    /// tests fast regardless of the driver's production reply bound.
    timeout_cap: Option<Duration>,
}

/// Split one complete length-prefixed frame off the front of `partial`.
fn take_frame(partial: &mut Vec<u8>) -> Option<Vec<u8>> {
    if partial.len() < 4 {
        return None;
    }
    let len = u32::from_le_bytes([partial[0], partial[1], partial[2], partial[3]]) as usize;
    let total = 4usize.checked_add(len)?;
    if partial.len() < total {
        return None;
    }
    let rest = partial.split_off(total);
    Some(std::mem::replace(partial, rest))
}

impl FaultConn {
    fn deliver(&mut self, frame: &[u8]) -> std::io::Result<()> {
        if let Some(d) = self.delayed.take() {
            self.outgoing.push(&d)?;
            self.state.bytes.fetch_add(d.len() as u64, Ordering::Relaxed);
        }
        self.outgoing.push(frame)?;
        self.state.bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        Ok(())
    }
}

impl Read for FaultConn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.incoming.read_into(buf, self.timeout)
    }
}

impl Write for FaultConn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.severed {
            return Err(Error::new(
                ErrorKind::BrokenPipe,
                "fault transport: connection severed by script",
            ));
        }
        self.partial.extend_from_slice(buf);
        while let Some(frame) = take_frame(&mut self.partial) {
            match self.state.next_fault(self.dir) {
                None => self.deliver(&frame)?,
                Some(FaultAction::DropFrame) => {}
                Some(FaultAction::DelayFrame) => self.delayed = Some(frame),
                Some(FaultAction::DuplicateFrame) => {
                    self.deliver(&frame)?;
                    self.deliver(&frame)?;
                }
                Some(FaultAction::Sever) => {
                    self.severed = true;
                    self.incoming.close();
                    self.outgoing.close();
                    return Err(Error::new(
                        ErrorKind::ConnectionReset,
                        "fault transport: connection severed by script",
                    ));
                }
            }
        }
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Conn for FaultConn {
    fn set_timeout(&mut self, dur: Option<Duration>) -> std::io::Result<()> {
        self.timeout = match (dur, self.timeout_cap) {
            (Some(d), Some(cap)) => Some(d.min(cap)),
            (Some(d), None) => Some(d),
            (None, cap) => cap,
        };
        Ok(())
    }
}

impl Drop for FaultConn {
    /// Dropping either end closes both pipes, so the peer observes EOF
    /// — the same shape as a socket close.
    fn drop(&mut self) {
        self.incoming.close();
        self.outgoing.close();
    }
}

// ---------------------------------------------------------------------------
// The transport (listener + dialer).
// ---------------------------------------------------------------------------

/// In-memory fault-injecting replacement for a worker's socket listener.
/// Each [`FaultInjectingTransport::dial`] yields a fresh driver-side
/// [`FaultConn`] and queues the worker-side end on the acceptor; frame
/// counters and the fault script persist across those connections.
pub struct FaultInjectingTransport {
    state: Arc<FaultState>,
    accept_tx: Mutex<Sender<FaultConn>>,
    accept_rx: Mutex<Option<Receiver<FaultConn>>>,
    timeout_cap: Option<Duration>,
}

impl FaultInjectingTransport {
    /// Transport with the default read-timeout cap (200 ms — a dropped
    /// frame costs a test a fifth of a second, not two minutes) and no
    /// connection budget.
    pub fn new(script: FaultScript) -> Arc<FaultInjectingTransport> {
        FaultInjectingTransport::with_config(script, usize::MAX, Some(Duration::from_millis(200)))
    }

    /// Transport with an explicit connection budget (dials past it fail
    /// — models permanent link loss) and read-timeout cap.
    pub fn with_config(
        script: FaultScript,
        max_connections: usize,
        timeout_cap: Option<Duration>,
    ) -> Arc<FaultInjectingTransport> {
        let (tx, rx) = mpsc::channel();
        Arc::new(FaultInjectingTransport {
            state: Arc::new(FaultState {
                script: Mutex::new(script.entries),
                req_frames: AtomicUsize::new(0),
                rep_frames: AtomicUsize::new(0),
                connections: AtomicUsize::new(0),
                max_connections,
                killed: AtomicBool::new(false),
                bytes: AtomicU64::new(0),
            }),
            accept_tx: Mutex::new(tx),
            accept_rx: Mutex::new(Some(rx)),
            timeout_cap,
        })
    }

    /// Driver side: open a new connection. Fails once the connection
    /// budget is exhausted or the worker loop is gone.
    pub fn dial(&self) -> std::io::Result<FaultConn> {
        if self.state.killed.load(Ordering::SeqCst) {
            return Err(Error::new(
                ErrorKind::ConnectionRefused,
                "fault transport: worker killed",
            ));
        }
        let n = self.state.connections.fetch_add(1, Ordering::SeqCst);
        if n >= self.state.max_connections {
            return Err(Error::new(
                ErrorKind::ConnectionRefused,
                format!(
                    "fault transport: connection budget exhausted ({} allowed)",
                    self.state.max_connections
                ),
            ));
        }
        let requests = Arc::new(Pipe::new());
        let replies = Arc::new(Pipe::new());
        let worker_end = FaultConn {
            dir: Dir::Reply,
            state: Arc::clone(&self.state),
            incoming: Arc::clone(&requests),
            outgoing: Arc::clone(&replies),
            partial: Vec::new(),
            delayed: None,
            severed: false,
            timeout: None,
            timeout_cap: None,
        };
        let driver_end = FaultConn {
            dir: Dir::Request,
            state: Arc::clone(&self.state),
            incoming: replies,
            outgoing: requests,
            partial: Vec::new(),
            delayed: None,
            severed: false,
            timeout: self.timeout_cap,
            timeout_cap: self.timeout_cap,
        };
        self.accept_tx
            .lock()
            .unwrap()
            .send(worker_end)
            .map_err(|_| Error::new(ErrorKind::NotConnected, "fault transport: worker gone"))?;
        Ok(driver_end)
    }

    /// Kill the transport: every future dial is refused, modeling a
    /// worker host that is gone for good (a scripted [`FaultAction::Sever`]
    /// is survivable by reconnecting; this is not). Connections already
    /// open are untouched — the driver notices on its next reconnect.
    /// `FleetControl::kill_worker` calls this on in-proc seats so a
    /// dead seat can never be quietly revived through its old link.
    pub fn kill(&self) {
        self.state.killed.store(true, Ordering::SeqCst);
    }

    /// Worker side: the acceptor stream of incoming connections. Can be
    /// taken once; the worker serve loop recvs on it.
    pub fn take_acceptor(&self) -> Option<Receiver<FaultConn>> {
        self.accept_rx.lock().unwrap().take()
    }

    /// Connections dialed so far (successful or refused).
    pub fn connections(&self) -> usize {
        self.state.connections.load(Ordering::SeqCst)
    }

    /// Frame bytes delivered so far, both directions (length prefixes
    /// included) — the payoff meter for the delta-compressed payload
    /// layer. Deterministic: same run, same bytes, on any machine.
    pub fn bytes_delivered(&self) -> u64 {
        self.state.bytes.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wire::{self, WireMsg};

    /// Dial and return both ends of one connection.
    fn pair(t: &FaultInjectingTransport, acc: &Receiver<FaultConn>) -> (FaultConn, FaultConn) {
        let driver = t.dial().expect("dial");
        let worker = acc.recv().expect("accept");
        (driver, worker)
    }

    #[test]
    fn clean_link_roundtrips_messages() {
        let t = FaultInjectingTransport::new(FaultScript::none());
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap();
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
        wire::write_msg(&mut worker, &WireMsg::Ok).unwrap();
        assert_eq!(wire::read_msg(&mut driver).unwrap(), WireMsg::Ok);
        assert!(t.take_acceptor().is_none(), "acceptor can be taken once");
    }

    #[test]
    fn byte_meter_counts_delivered_frames_only() {
        let t = FaultInjectingTransport::new(
            FaultScript::none()
                .on_request(0, FaultAction::DropFrame)
                .on_request(2, FaultAction::DuplicateFrame),
        );
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        let frame = wire::encode_frame(&WireMsg::MemStats).unwrap();
        worker.set_timeout(Some(Duration::from_millis(50))).unwrap();
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap(); // dropped: 0 bytes
        assert!(wire::read_msg(&mut worker).is_err());
        assert_eq!(t.bytes_delivered(), 0, "dropped frames never cross the wire");
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap(); // delivered once
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap(); // duplicated: twice
        for _ in 0..3 {
            assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
        }
        assert_eq!(t.bytes_delivered(), 3 * frame.len() as u64);
    }

    #[test]
    fn dropped_frame_times_out_reader() {
        let t = FaultInjectingTransport::new(
            FaultScript::none().on_request(0, FaultAction::DropFrame),
        );
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap(); // dropped
        worker.set_timeout(Some(Duration::from_millis(50))).unwrap();
        let err = wire::read_msg(&mut worker).expect_err("dropped frame must not arrive");
        assert!(format!("{err:#}").contains("read"), "{err:#}");
        // The next frame (index 1) sails through.
        wire::write_msg(&mut driver, &WireMsg::Shutdown).unwrap();
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::Shutdown);
    }

    #[test]
    fn delayed_frame_arrives_before_the_next_one() {
        let t = FaultInjectingTransport::new(
            FaultScript::none().on_request(0, FaultAction::DelayFrame),
        );
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap(); // delayed
        wire::write_msg(&mut driver, &WireMsg::Shutdown).unwrap(); // releases it
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::Shutdown);
    }

    #[test]
    fn duplicated_frame_arrives_twice() {
        let t = FaultInjectingTransport::new(
            FaultScript::none().on_request(0, FaultAction::DuplicateFrame),
        );
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap();
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
    }

    #[test]
    fn sever_kills_both_directions_and_the_frame() {
        let t = FaultInjectingTransport::new(FaultScript::none().on_request(1, FaultAction::Sever));
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap();
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
        let err = wire::write_msg(&mut driver, &WireMsg::Shutdown)
            .expect_err("severed write must fail");
        assert!(format!("{err:#}").contains("severed"), "{err:#}");
        // The worker sees EOF, not the severed frame.
        assert_eq!(wire::read_msg_opt(&mut worker).unwrap(), None);
        // Reconnecting continues the frame count past the sever point.
        let (mut driver2, mut worker2) = pair(&t, &acc);
        wire::write_msg(&mut driver2, &WireMsg::Shutdown).unwrap(); // request #2
        assert_eq!(wire::read_msg(&mut worker2).unwrap(), WireMsg::Shutdown);
        assert_eq!(t.connections(), 2);
    }

    #[test]
    fn connection_budget_models_permanent_loss() {
        let t = FaultInjectingTransport::with_config(
            FaultScript::none(),
            1,
            Some(Duration::from_millis(50)),
        );
        let acc = t.take_acceptor().unwrap();
        let (_driver, _worker) = pair(&t, &acc);
        let err = t.dial().expect_err("second dial must be refused");
        assert!(format!("{err}").contains("budget"), "{err}");
    }

    #[test]
    fn killed_transport_refuses_new_dials() {
        let t = FaultInjectingTransport::new(FaultScript::none());
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        t.kill();
        // The live connection still works …
        wire::write_msg(&mut driver, &WireMsg::MemStats).unwrap();
        assert_eq!(wire::read_msg(&mut worker).unwrap(), WireMsg::MemStats);
        // … but no new one can be made, ever.
        let err = t.dial().expect_err("dial after kill must be refused");
        assert!(format!("{err}").contains("killed"), "{err}");
    }

    #[test]
    fn frames_split_across_writes_are_reassembled() {
        let t = FaultInjectingTransport::new(FaultScript::none());
        let acc = t.take_acceptor().unwrap();
        let (mut driver, mut worker) = pair(&t, &acc);
        let frame = wire::encode_frame(&WireMsg::Error { message: "boom".into() }).unwrap();
        for chunk in frame.chunks(3) {
            driver.write_all(chunk).unwrap();
        }
        assert_eq!(
            wire::read_msg(&mut worker).unwrap(),
            WireMsg::Error { message: "boom".into() }
        );
    }

    #[test]
    #[should_panic(expected = "desync")]
    fn reply_duplication_is_rejected_by_the_script_builder() {
        let _ = FaultScript::none().on_reply(0, FaultAction::DuplicateFrame);
    }

    #[test]
    fn driver_kill_plan_parses_fires_once_and_sorts() {
        let mut plan = DriverKillPlan::parse("7, 3,3").unwrap();
        assert_eq!(plan.remaining(), &[3, 7]);
        assert!(!plan.should_kill(2));
        assert!(plan.should_kill(3));
        assert!(!plan.should_kill(3), "each kill point fires at most once");
        assert!(plan.should_kill(7));
        assert!(plan.is_empty());
        assert_eq!(DriverKillPlan::parse("").unwrap(), DriverKillPlan::none());
        assert!(DriverKillPlan::parse("0").is_err(), "step indices are 1-based");
        assert!(DriverKillPlan::parse("3,x").is_err());
    }

    #[test]
    fn dropping_an_end_gives_the_peer_eof() {
        let t = FaultInjectingTransport::new(FaultScript::none());
        let acc = t.take_acceptor().unwrap();
        let (driver, mut worker) = pair(&t, &acc);
        drop(driver);
        assert_eq!(wire::read_msg_opt(&mut worker).unwrap(), None);
    }
}
