//! Driver-side shard membership: epoch-numbered fleet views, the block
//! assignment policy surface, and the latency-fed rebalancing
//! controller behind the elastic fleet.
//!
//! The shard executor (`coordinator/shard.rs`) owns the wire plumbing;
//! this module owns the *decisions*: which seat serves which blocks
//! ([`FleetView`]), when a seat change bumps the fleet epoch, and when
//! observed per-shard step latency justifies moving blocks
//! ([`MembershipController::maybe_rebalance`]). Keeping the transitions
//! here makes them unit-testable without a worker fleet — see the tests
//! at the bottom for the join/leave/replace/rebalance contract.
//!
//! ## Determinism
//!
//! Assignment policies are pure functions of `(n_blocks, seats,
//! weights)` and every transition is driver-initiated at a wire-quiescent
//! point, so two runs that make the same membership decisions at the
//! same steps produce bitwise-identical parameters. Block math is
//! placement-independent: a block's update depends only on its own
//! `(param, grad, ctx)` stream, never on which worker computes it.

use super::supervise::LinkTimeouts;
use anyhow::ensure;

/// Default bounded failover budget: the journal keeps at most this many
/// steps of replay history, so re-seating a replacement worker replays
/// at most this many steps past the last state sync point.
pub const DEFAULT_FAILOVER_BUDGET: u64 = 8;

/// EWMA smoothing factor for per-shard step latency observations.
const LATENCY_ALPHA: f64 = 0.3;

/// Rebalance trigger: slowest/fastest seat EWMA ratio must exceed this
/// before the controller proposes moving blocks.
const REBALANCE_IMBALANCE: f64 = 1.5;

// ---------------------------------------------------------------------------
// Assignment policy.
// ---------------------------------------------------------------------------

/// Block-to-shard assignment policy. The contiguous balanced policy
/// ([`ContiguousAssignment`]) is the default and is preserved bit-for-bit
/// from the original free function; the rebalancer and the tests share
/// this one surface.
pub trait BlockAssignment: Send + Sync {
    /// Partition `n_blocks` across `seats` shards. Every block index in
    /// `0..n_blocks` must appear exactly once; each seat's list must be
    /// an ascending contiguous run (the wire layer's reply validation
    /// depends on contiguity).
    fn assign(&self, n_blocks: usize, seats: usize) -> Vec<Vec<usize>>;

    /// Re-partition under per-seat weights (higher weight → more
    /// blocks; the controller feeds `1 / latency`). The default ignores
    /// the weights and falls back to [`BlockAssignment::assign`].
    fn rebalance(&self, n_blocks: usize, seats: usize, weights: &[f64]) -> Vec<Vec<usize>> {
        let _ = weights;
        self.assign(n_blocks, seats)
    }
}

/// Deterministic contiguous block partition: seat `s` owns a balanced
/// run of consecutive block indices (earlier seats take the remainder).
/// `assign` is bit-for-bit the historical `assign_blocks` policy.
#[derive(Clone, Copy, Debug, Default)]
pub struct ContiguousAssignment;

impl BlockAssignment for ContiguousAssignment {
    fn assign(&self, n_blocks: usize, seats: usize) -> Vec<Vec<usize>> {
        assert!(seats >= 1, "assign_blocks requires at least one shard");
        let base = n_blocks / seats;
        let extra = n_blocks % seats;
        let mut out = Vec::with_capacity(seats);
        let mut next = 0;
        for s in 0..seats {
            let take = base + usize::from(s < extra);
            out.push((next..next + take).collect());
            next += take;
        }
        out
    }

    /// Weighted contiguous partition via largest-remainder quotas:
    /// seat `s` gets `round(n * w_s / Σw)` blocks (floors first, the
    /// remainder goes to the largest fractional parts, ties to lower
    /// seat index), still as consecutive runs in seat order. Degenerate
    /// weights (non-finite, non-positive, or empty) fall back to the
    /// balanced partition.
    fn rebalance(&self, n_blocks: usize, seats: usize, weights: &[f64]) -> Vec<Vec<usize>> {
        assert!(seats >= 1, "rebalance requires at least one shard");
        let usable = weights.len() == seats
            && weights.iter().all(|w| w.is_finite() && *w > 0.0)
            && weights.iter().sum::<f64>() > 0.0;
        if !usable {
            return self.assign(n_blocks, seats);
        }
        let total: f64 = weights.iter().sum();
        let mut quota: Vec<usize> = Vec::with_capacity(seats);
        let mut frac: Vec<(usize, f64)> = Vec::with_capacity(seats);
        let mut assigned = 0usize;
        for (s, w) in weights.iter().enumerate() {
            let exact = n_blocks as f64 * w / total;
            let floor = exact.floor() as usize;
            quota.push(floor);
            frac.push((s, exact - floor as f64));
            assigned += floor;
        }
        // Largest fractional remainder first; ties go to the lower seat
        // index so the result is deterministic.
        frac.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        for (s, _) in frac.iter().take(n_blocks.saturating_sub(assigned)) {
            quota[*s] += 1;
        }
        let mut out = Vec::with_capacity(seats);
        let mut next = 0;
        for take in quota {
            out.push((next..next + take).collect());
            next += take;
        }
        debug_assert_eq!(next, n_blocks);
        out
    }
}

/// Validate an assignment for wire use: every block in `0..n_blocks`
/// exactly once, each seat an ascending contiguous run.
pub fn validate_assignment(assignment: &[Vec<usize>], n_blocks: usize) -> anyhow::Result<()> {
    let mut next = 0usize;
    for (s, owned) in assignment.iter().enumerate() {
        for &b in owned {
            ensure!(
                b == next,
                "assignment for seat {s} is not a contiguous in-order partition (block {b}, expected {next})"
            );
            next += 1;
        }
    }
    ensure!(next == n_blocks, "assignment covers {next} of {n_blocks} blocks");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fleet view.
// ---------------------------------------------------------------------------

/// Epoch-numbered view of the shard fleet: which seat serves which
/// blocks, and how many times each seat has been re-seated. Every
/// membership change (join, leave, replace, effective rebalance) bumps
/// `epoch`; a no-op rebalance does not. The epoch is carried on the
/// wire in the v5 `Adopt` handshake so a replacement worker is seated
/// into a specific view.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetView {
    /// Monotone view counter; 0 is the construction-time view.
    pub epoch: u64,
    /// Blocks served per seat (contiguous runs, in seat order).
    pub assignment: Vec<Vec<usize>>,
    /// Per-seat incarnation: bumped each time the seat's worker is
    /// replaced, so late frames from a dead incarnation are
    /// distinguishable in logs and tests.
    pub incarnations: Vec<u32>,
}

impl FleetView {
    /// Construction-time view (epoch 0, incarnation 0 everywhere).
    pub fn new(assignment: Vec<Vec<usize>>) -> FleetView {
        let seats = assignment.len();
        FleetView { epoch: 0, assignment, incarnations: vec![0; seats] }
    }

    /// Number of seats (including currently-empty ones).
    pub fn seats(&self) -> usize {
        self.assignment.len()
    }

    /// A new seat joins with no blocks (a later rebalance moves work
    /// onto it). Returns the new seat index.
    pub fn join(&mut self) -> usize {
        self.assignment.push(Vec::new());
        self.incarnations.push(0);
        self.epoch += 1;
        self.assignment.len() - 1
    }

    /// Seat `seat` leaves the fleet: its blocks are orphaned (returned
    /// to the caller for reassignment) and the seat is retired in
    /// place — seat indices are stable, a retired seat just serves
    /// nothing until a rebalance or replace re-seats it.
    pub fn leave(&mut self, seat: usize) -> Vec<usize> {
        let orphaned = std::mem::take(&mut self.assignment[seat]);
        self.epoch += 1;
        orphaned
    }

    /// Seat `seat`'s worker is replaced by a fresh one serving the same
    /// blocks: incarnation and epoch bump, assignment unchanged.
    pub fn replace(&mut self, seat: usize) -> u64 {
        self.incarnations[seat] += 1;
        self.epoch += 1;
        self.epoch
    }

    /// Install a new assignment. A no-op (identical assignment) leaves
    /// the epoch unchanged and returns `false`.
    pub fn rebalance(&mut self, assignment: Vec<Vec<usize>>) -> bool {
        assert_eq!(
            assignment.len(),
            self.assignment.len(),
            "rebalance cannot change the seat count"
        );
        if assignment == self.assignment {
            return false;
        }
        self.assignment = assignment;
        self.epoch += 1;
        true
    }
}

// ---------------------------------------------------------------------------
// Latency tracking + controller.
// ---------------------------------------------------------------------------

/// Per-seat step-latency EWMA, fed from the executor's step timing.
#[derive(Clone, Debug)]
pub struct LatencyTracker {
    ewma: Vec<Option<f64>>,
}

impl LatencyTracker {
    pub fn new(seats: usize) -> LatencyTracker {
        LatencyTracker { ewma: vec![None; seats] }
    }

    /// Fold one observed per-step latency (nanoseconds) for `seat`.
    pub fn observe(&mut self, seat: usize, nanos: f64) {
        if !nanos.is_finite() || nanos <= 0.0 {
            return;
        }
        let cell = &mut self.ewma[seat];
        *cell = Some(match *cell {
            Some(prev) => prev + LATENCY_ALPHA * (nanos - prev),
            None => nanos,
        });
    }

    /// Forget a seat's history (its worker was replaced).
    pub fn reset_seat(&mut self, seat: usize) {
        self.ewma[seat] = None;
    }

    /// Slowest/fastest EWMA ratio, once every seat has been observed.
    pub fn imbalance(&self) -> Option<f64> {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for cell in &self.ewma {
            let v = (*cell)?;
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo > 0.0 && hi.is_finite()).then(|| hi / lo)
    }

    /// Per-seat rebalance weights (`1 / latency`), once every seat has
    /// been observed.
    pub fn weights(&self) -> Option<Vec<f64>> {
        self.ewma.iter().map(|c| c.map(|v| 1.0 / v)).collect()
    }
}

/// Elastic-fleet knobs, resolved from `--shard-spares` / `--rebalance`
/// / `--journal` / the timeout flags and the `[shard]` config section.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipConfig {
    /// Warm spare workers kept idle for failover. 0 disables elastic
    /// failover: a dead worker is a named, terminal error (the
    /// historical behavior).
    pub spares: usize,
    /// Enable latency-fed block rebalancing at state sync points.
    pub rebalance: bool,
    /// Journal depth / maximum replay length for a migration (steps).
    pub failover_budget: u64,
    /// Durable write-ahead journal path (`--journal` /
    /// `--resume-journal`). `Some` turns the in-memory step journal
    /// into an on-disk WAL the driver can crash-resume from.
    pub journal: Option<String>,
    /// Worker listen addresses recovered from a resumed journal, one
    /// per seat (empty string = not re-adoptable, spawn fresh). The
    /// relaunched driver tries to re-adopt these before spawning.
    pub resume_addrs: Option<Vec<String>>,
    /// Per-link connect/reply/heartbeat/deadline budgets.
    pub timeouts: LinkTimeouts,
}

impl Default for MembershipConfig {
    fn default() -> Self {
        MembershipConfig {
            spares: 0,
            rebalance: false,
            failover_budget: DEFAULT_FAILOVER_BUDGET,
            journal: None,
            resume_addrs: None,
            timeouts: LinkTimeouts::default(),
        }
    }
}

impl MembershipConfig {
    /// Whether any elastic machinery (journaling, sync snapshots,
    /// migration) should be active at all. A durable journal rides on
    /// the same sync-point/journal machinery even with no spares.
    pub fn elastic(&self) -> bool {
        self.spares > 0 || self.rebalance || self.journal.is_some()
    }
}

/// Driver-side membership controller: owns the fleet view, the latency
/// tracker, and the rebalance policy, and answers the executor's
/// "should anything change?" questions at sync points.
pub struct MembershipController {
    pub cfg: MembershipConfig,
    pub view: FleetView,
    latency: LatencyTracker,
    policy: Box<dyn BlockAssignment>,
    /// Weights staged by an explicit `FleetControl::request_rebalance`,
    /// consumed at the next sync point.
    staged: Option<Vec<f64>>,
}

impl MembershipController {
    pub fn new(cfg: MembershipConfig, assignment: Vec<Vec<usize>>) -> MembershipController {
        let seats = assignment.len();
        MembershipController {
            cfg,
            view: FleetView::new(assignment),
            latency: LatencyTracker::new(seats),
            policy: Box::new(ContiguousAssignment),
            staged: None,
        }
    }

    /// Fold one per-seat step latency observation.
    pub fn observe_step_latency(&mut self, seat: usize, nanos: f64) {
        self.latency.observe(seat, nanos);
    }

    /// Stage an explicit rebalance (tests and operators): applied at
    /// the next sync point regardless of the imbalance trigger.
    pub fn stage_rebalance(&mut self, weights: Vec<f64>) {
        self.staged = Some(weights);
    }

    /// Record a seat replacement: bumps the epoch + incarnation and
    /// forgets the dead worker's latency history. Returns the new epoch.
    pub fn on_replace(&mut self, seat: usize) -> u64 {
        self.latency.reset_seat(seat);
        self.view.replace(seat)
    }

    /// Called at a wire-quiescent sync point: propose a new assignment
    /// if one is justified (an explicitly staged rebalance, or the
    /// latency imbalance trigger when `--rebalance` is on). Returns
    /// `None` when nothing should move; an accepted proposal must be
    /// installed with [`FleetView::rebalance`] by the caller *after*
    /// the state migration succeeds.
    pub fn maybe_rebalance(&mut self, n_blocks: usize) -> Option<Vec<Vec<usize>>> {
        let weights = match self.staged.take() {
            Some(w) => w,
            None => {
                if !self.cfg.rebalance {
                    return None;
                }
                if self.latency.imbalance()? < REBALANCE_IMBALANCE {
                    return None;
                }
                self.latency.weights()?
            }
        };
        let seats = self.view.seats();
        let proposal = self.policy.rebalance(n_blocks, seats, &weights);
        if validate_assignment(&proposal, n_blocks).is_err() || proposal == self.view.assignment {
            return None;
        }
        Some(proposal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_assignment_matches_historical_policy() {
        let p = ContiguousAssignment;
        assert_eq!(p.assign(10, 3), vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
        assert_eq!(p.assign(2, 4), vec![vec![0], vec![1], vec![], vec![]]);
        assert_eq!(p.assign(6, 2), vec![vec![0, 1, 2], vec![3, 4, 5]]);
        validate_assignment(&p.assign(10, 3), 10).unwrap();
    }

    #[test]
    fn weighted_rebalance_is_contiguous_and_weight_proportional() {
        let p = ContiguousAssignment;
        // Seat 0 twice as fast as seat 1 → twice the blocks.
        let a = p.rebalance(9, 2, &[2.0, 1.0]);
        assert_eq!(a, vec![(0..6).collect::<Vec<_>>(), (6..9).collect::<Vec<_>>()]);
        validate_assignment(&a, 9).unwrap();
        // Degenerate weights fall back to the balanced partition.
        assert_eq!(p.rebalance(10, 3, &[0.0, 1.0, 1.0]), p.assign(10, 3));
        assert_eq!(p.rebalance(10, 3, &[f64::NAN, 1.0, 1.0]), p.assign(10, 3));
        assert_eq!(p.rebalance(10, 2, &[1.0]), p.assign(10, 2));
        // Equal weights reproduce the balanced partition exactly.
        assert_eq!(p.rebalance(10, 3, &[1.0, 1.0, 1.0]), p.assign(10, 3));
    }

    #[test]
    fn fleet_view_join_transition_bumps_epoch() {
        let mut v = FleetView::new(vec![vec![0, 1], vec![2, 3]]);
        assert_eq!(v.epoch, 0);
        let seat = v.join();
        assert_eq!(seat, 2);
        assert_eq!(v.epoch, 1);
        assert_eq!(v.seats(), 3);
        assert!(v.assignment[2].is_empty());
        assert_eq!(v.incarnations, vec![0, 0, 0]);
    }

    #[test]
    fn fleet_view_leave_transition_orphans_blocks() {
        let mut v = FleetView::new(vec![vec![0, 1], vec![2, 3]]);
        let orphaned = v.leave(1);
        assert_eq!(orphaned, vec![2, 3]);
        assert_eq!(v.epoch, 1);
        // Seat indices are stable: the seat stays, empty.
        assert_eq!(v.seats(), 2);
        assert!(v.assignment[1].is_empty());
    }

    #[test]
    fn fleet_view_replace_transition_bumps_incarnation_not_assignment() {
        let mut v = FleetView::new(vec![vec![0, 1], vec![2, 3]]);
        let epoch = v.replace(0);
        assert_eq!(epoch, 1);
        assert_eq!(v.epoch, 1);
        assert_eq!(v.incarnations, vec![1, 0]);
        assert_eq!(v.assignment, vec![vec![0, 1], vec![2, 3]]);
        let epoch = v.replace(0);
        assert_eq!(epoch, 2);
        assert_eq!(v.incarnations, vec![2, 0]);
    }

    #[test]
    fn fleet_view_rebalance_noop_keeps_epoch() {
        let mut v = FleetView::new(vec![vec![0, 1], vec![2, 3]]);
        assert!(!v.rebalance(vec![vec![0, 1], vec![2, 3]]));
        assert_eq!(v.epoch, 0);
        assert!(v.rebalance(vec![vec![0, 1, 2], vec![3]]));
        assert_eq!(v.epoch, 1);
    }

    #[test]
    fn latency_tracker_feeds_rebalance_trigger() {
        let mut c = MembershipController::new(
            MembershipConfig { spares: 0, rebalance: true, failover_budget: 8, ..Default::default() },
            ContiguousAssignment.assign(8, 2),
        );
        // No observations yet → no proposal.
        assert!(c.maybe_rebalance(8).is_none());
        // Balanced latencies → imbalance below trigger → no proposal.
        for _ in 0..8 {
            c.observe_step_latency(0, 1_000.0);
            c.observe_step_latency(1, 1_100.0);
        }
        assert!(c.maybe_rebalance(8).is_none());
        // Seat 1 three times slower → proposal shifts blocks to seat 0.
        for _ in 0..32 {
            c.observe_step_latency(1, 3_000.0);
        }
        let proposal = c.maybe_rebalance(8).expect("imbalance above trigger");
        assert!(proposal[0].len() > proposal[1].len());
        validate_assignment(&proposal, 8).unwrap();
    }

    #[test]
    fn staged_rebalance_bypasses_trigger_and_rebalance_flag() {
        let mut c = MembershipController::new(
            MembershipConfig { spares: 1, rebalance: false, failover_budget: 8, ..Default::default() },
            ContiguousAssignment.assign(8, 2),
        );
        c.stage_rebalance(vec![3.0, 1.0]);
        let proposal = c.maybe_rebalance(8).expect("staged rebalance always proposes");
        assert_eq!(proposal, vec![(0..6).collect::<Vec<_>>(), (6..8).collect::<Vec<_>>()]);
        // Consumed: a second call with no staging and rebalance off → None.
        assert!(c.maybe_rebalance(8).is_none());
    }

    #[test]
    fn replace_resets_latency_history() {
        let mut c = MembershipController::new(
            MembershipConfig { spares: 1, rebalance: true, failover_budget: 8, ..Default::default() },
            ContiguousAssignment.assign(8, 2),
        );
        for _ in 0..16 {
            c.observe_step_latency(0, 1_000.0);
            c.observe_step_latency(1, 9_000.0);
        }
        let epoch = c.on_replace(1);
        assert_eq!(epoch, 1);
        assert_eq!(c.view.incarnations, vec![0, 1]);
        // Seat 1's history is gone → weights unavailable → no proposal.
        assert!(c.maybe_rebalance(8).is_none());
    }
}
