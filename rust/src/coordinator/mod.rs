//! Data-parallel training coordinator (system S8).
//!
//! The paper's headline setting is large-batch data-parallel training
//! (§1, §7: "we would frequently find that faster accelerators were
//! unavailable ... encouraging us to leverage data-parallel training").
//! This module reproduces that coordination structure at laptop scale:
//! a leader drives N worker threads, each computing gradients for its
//! microbatch through the PJRT artifact; gradients meet in a tree
//! allreduce; the leader applies the (Sketchy) optimizer once per step —
//! amortizing the batch-size-independent optimizer cost exactly as §7
//! argues.

//! A second coordination axis shards the *optimizer* itself:
//! [`shard`] partitions the block engine's preconditioner blocks across
//! worker processes over the [`wire`] protocol, so eigendecomposition
//! refreshes stop being bound by one process's cores.

pub mod allreduce;
pub mod fault;
pub mod membership;
pub mod pipeline;
pub mod shard;
pub mod supervise;
pub mod wire;
pub mod worker;

pub use allreduce::{tree_allreduce, AllreduceStats};
pub use fault::{DriverKillPlan, FaultAction, FaultInjectingTransport, FaultScript};
pub use membership::{
    BlockAssignment, ContiguousAssignment, FleetView, LatencyTracker, MembershipConfig,
    MembershipController,
};
pub use pipeline::BoundedQueue;
pub use shard::{FleetControl, ShardConfig, ShardExecutor, ShardLaunch, ShardTransport};
pub use supervise::{Backoff, Clock, LinkTimeouts, Supervisor, SystemClock, VirtualClock};
pub use worker::{data_parallel_step, GradientWorker, StepResult};
