//! Bounded producer/consumer queue with backpressure accounting — the
//! data-pipeline leg of the coordinator (batches are produced by the
//! generator thread and consumed by gradient workers; the bound keeps
//! the producer from racing ahead of training).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Bounded MPMC queue (condvar-based; std::sync::mpsc has no bounded
/// multi-consumer flavor). Tracks high-water mark and block counts.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    high_water: usize,
    producer_blocks: usize,
    consumer_blocks: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        BoundedQueue {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
                high_water: 0,
                producer_blocks: 0,
                consumer_blocks: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    /// Blocking push; returns false if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.q.len() >= self.cap {
            g.producer_blocks += 1;
        }
        while g.q.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.q.push_back(item);
        let depth = g.q.len();
        if depth > g.high_water {
            g.high_water = depth;
        }
        drop(g);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; None when closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        if g.q.is_empty() {
            g.consumer_blocks += 1;
        }
        while g.q.is_empty() && !g.closed {
            g = self.not_empty.wait(g).unwrap();
        }
        let item = g.q.pop_front();
        drop(g);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// A closed, pre-filled queue — the self-scheduling work-list idiom
    /// used by the block engine (`optim::engine`): the leader enqueues
    /// every task up front, worker threads drain until `None`, so task
    /// assignment follows worker availability (cheap work stealing).
    pub fn work_list(items: impl IntoIterator<Item = T>) -> Self {
        let items: Vec<T> = items.into_iter().collect();
        let q = BoundedQueue::new(items.len().max(1));
        for item in items {
            q.push(item);
        }
        q.close();
        q
    }

    /// Close the queue: producers fail, consumers drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// (high-water mark, producer blocks, consumer blocks).
    pub fn stats(&self) -> (usize, usize, usize) {
        let g = self.inner.lock().unwrap();
        (g.high_water, g.producer_blocks, g.consumer_blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = BoundedQueue::new(4);
        for i in 0..3 {
            assert!(q.push(i));
        }
        q.close();
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_bounds_depth() {
        let q = Arc::new(BoundedQueue::new(2));
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                qp.push(i);
            }
            qp.close();
        });
        // Slow consumer.
        let mut got = vec![];
        while let Some(v) = q.pop() {
            got.push(v);
            std::thread::yield_now();
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
        let (hw, pblocks, _) = q.stats();
        assert!(hw <= 2, "queue exceeded bound: {hw}");
        assert!(pblocks > 0, "producer never hit backpressure");
    }

    #[test]
    fn multi_consumer_drains_everything() {
        let q = Arc::new(BoundedQueue::new(8));
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..200 {
                qp.push(i);
            }
            qp.close();
        });
        let mut handles = vec![];
        for _ in 0..4 {
            let qc = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = vec![];
                while let Some(v) = qc.pop() {
                    local.push(v);
                }
                local
            }));
        }
        producer.join().unwrap();
        let mut all: Vec<i32> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn work_list_drains_in_order_then_none() {
        let q = BoundedQueue::work_list(0..5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
        // Pushing into a finished work list fails (it is closed).
        assert!(!q.push(99));
        // Empty work lists are legal and immediately drained.
        let empty: BoundedQueue<usize> = BoundedQueue::work_list(std::iter::empty());
        assert_eq!(empty.pop(), None);
    }

    #[test]
    fn close_unblocks_producer() {
        let q = Arc::new(BoundedQueue::new(1));
        assert!(q.push(1));
        let qp = q.clone();
        let t = std::thread::spawn(move || qp.push(2)); // blocks: full
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(!t.join().unwrap(), "push into closed queue must fail");
    }

    #[test]
    fn close_unblocks_blocked_consumer() {
        // A consumer parked on an empty queue must wake on close and see
        // the drained-and-closed signal (None), not hang forever.
        let q: Arc<BoundedQueue<i32>> = Arc::new(BoundedQueue::new(2));
        let qc = q.clone();
        let t = std::thread::spawn(move || qc.pop()); // blocks: empty
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None, "pop from closed empty queue must be None");
        // The blocked wait was accounted.
        let (_, _, cblocks) = q.stats();
        assert_eq!(cblocks, 1);
    }

    #[test]
    fn capacity_one_ping_pong() {
        // The tightest legal bound: every push except into an empty
        // queue must wait for the matching pop, forcing strict
        // alternation. Order, bound, and closure semantics must all
        // survive the ping-pong.
        let q = Arc::new(BoundedQueue::new(1));
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..64 {
                assert!(qp.push(i), "queue closed under producer");
            }
            qp.close();
        });
        let mut got = vec![];
        while let Some(v) = q.pop() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        let (hw, _, _) = q.stats();
        assert_eq!(hw, 1, "capacity-1 queue exceeded its bound");
        // Closed and drained: further pops return None immediately.
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn accounting_tracks_high_water_and_both_block_kinds() {
        // Deterministic accounting check with no cross-thread timing
        // races: both block counters increment on the *would-wait*
        // condition at call entry, which a closed queue lets us drive
        // single-threaded.
        let q = BoundedQueue::new(2);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.stats(), (2, 0, 0));
        q.close();
        // Push against a full (and closed) queue: one producer wait
        // accounted, push refused.
        assert!(!q.push(3));
        assert_eq!(q.stats(), (2, 1, 0));
        // Draining a closed queue still yields its contents...
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.stats(), (2, 1, 0));
        // ...and popping past the end accounts one consumer wait.
        assert_eq!(q.pop(), None);
        assert_eq!(q.stats(), (2, 1, 1));
        // High-water keeps the deepest point, not the (now zero) depth.
    }
}
