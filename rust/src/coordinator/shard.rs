//! Cross-process sharded block execution.
//!
//! The §3.4 blocked engine (`optim::engine`) parallelizes preconditioner
//! blocks within one process; this module shards them **across worker
//! processes**. The driver partitions the engine's block list over N
//! `sketchy shard-worker` processes (spawned from the same binary),
//! ships each shard its gathered block statistics, drives
//! `Preconditioner::ingest/refresh/apply` remotely, and scatters the
//! returned parameter blocks back — the engine's gather → drive →
//! scatter step *is* the RPC boundary.
//!
//! Transport is TCP (localhost by default, any host via the launcher
//! template) or a Unix domain socket, speaking the length-prefixed
//! codec of [`super::wire`]. Workers announce their
//! listen address on stdout (`SKETCHY-SHARD-LISTENING <transport>
//! <addr>`), keep all block state in-process across connections, and
//! cache their last step reply keyed by `t` — so the driver can
//! reconnect after a transport failure and replay the in-flight request
//! without double-applying it. Hard worker failures (a dead process)
//! surface as `anyhow` errors naming the shard.
//!
//! ## Sharded RefreshAhead (pipelined refresh overlap)
//!
//! With `--overlap-refresh`, the engine prefetches step `t + 1`'s
//! inverse-root refreshes. On the sharded executor that prefetch is a
//! **second in-flight request per shard**: at the end of step `t` the
//! driver ships each worker a [`WireMsg::RefreshAhead`] carrying the
//! worker's share of the `t + 1` due-set and does *not* read the reply —
//! the worker runs those eigendecompositions (on its own worker pool)
//! while the trainer computes gradients, and the driver joins the
//! [`WireMsg::RefreshAheadOk`] replies just before `t + 1`'s `Step`.
//! Prefetching only happens on steps that fold no statistics, so the
//! roots computed ahead are bit-for-bit the roots a synchronous refresh
//! would compute; a joined-but-unused prefetch (the cancel path) is also
//! harmless, because an in-step refresh from unchanged statistics
//! recomputes identical roots. Workers cache their last
//! `RefreshAheadOk` keyed by `t_next`, so a reconnect that replays the
//! request cannot double-count refreshes.
//!
//! Capability is negotiated at handshake: v2 workers greet with
//! [`WireMsg::HelloV2`] carrying an explicit overlap-capability report,
//! v1 workers greet with the legacy [`WireMsg::Hello`] and the driver
//! degrades that shard (and, for determinism of accounting, the whole
//! run) to synchronous refresh with a logged one-time notice.
//!
//! ## Multi-host launch + delta-compressed payloads (protocol v3)
//!
//! Worker spawning is pluggable: by default the driver exec's its own
//! binary on localhost, but a launcher command template
//! (`--shard-launch`, see [`ShardLaunch`]) renders an arbitrary argv
//! per shard — `ssh host{shard} /path/to/sketchy {worker_cmd} ...` —
//! and the worker's stdout announcement (with `--listen` /
//! `--advertise-host`) flows back through the launcher process. The
//! in-test launcher is [`ShardExecutor::launch_in_proc`], which mounts
//! the same worker state machine on threads over the scriptable fault
//! harness.
//!
//! Cross-host links make full dense frames the bottleneck, so protocol
//! v3 negotiates a delta-compressed payload layer per connection (the
//! [`WireMsg::HelloV3`] capability report + the `--shard-compress`
//! knob): each block matrix ships as the RLE/varint compression of its
//! bits XORed against the last mutually acked step ([`DeltaMat`]),
//! with tagged baselines, idempotent-replay safety, and a full-frame
//! resync after any reconnect. v2/v1 workers degrade to uncompressed
//! full frames exactly like the refresh-overlap degrade matrix.
//!
//! Determinism: every block's math runs in exactly one place, parameter
//! payloads travel as raw IEEE-754 bits (the delta codec is
//! bit-lossless), and the scatter writes each
//! disjoint block window directly — so an N-shard run is **bitwise
//! identical** to the in-process engine, with or without overlap or
//! compression
//! (`tests/shard_determinism.rs` and the CI `shard-smoke` job assert
//! this for N ∈ {2, 4}, including under scripted transport faults via
//! [`super::fault::FaultInjectingTransport`] and
//! [`ShardExecutor::launch_in_proc`]).
//!
//! ## Elastic membership (protocol v5)
//!
//! With `--shard-spares`/`--rebalance` the fleet becomes **elastic**: a
//! [`MembershipController`] (see [`super::membership`]) keeps an
//! epoch-numbered fleet view, the driver journals each step's block
//! payloads between bounded-budget sync points (driver-side
//! [`WireMsg::StateSnap`] snapshots every `failover_budget` steps), and
//! a dead worker is healed in place: a warm spare is adopted onto the
//! vacant seat ([`WireMsg::Adopt`] re-seats its identity under the new
//! epoch), re-initialized, restored from the last-acked snapshot, and
//! replayed through the journal — at most `failover_budget` steps — so
//! the fleet's math stays **bitwise identical** to an uninterrupted run
//! with exact refresh accounting. Delta-codec baselines resync on the
//! fresh link automatically (full-frame resync, as after any
//! reconnect). Optional latency-fed rebalancing re-cuts the contiguous
//! assignment at sync points only, migrating blocks over the same
//! snapshot/restore path. Elastic control (kill, stats, staged
//! rebalance) lives on the [`FleetControl`] handle.

use super::fault::FaultInjectingTransport;
use super::membership::{
    validate_assignment, BlockAssignment, ContiguousAssignment, MembershipConfig,
    MembershipController,
};
use super::supervise::{Backoff, Clock, LinkTimeouts, Supervisor, SystemClock};
use super::wire::{
    self, bits_matrix, mat_bits, BlockPayload, BlockSpec, BlockStateMsg, Conn, DeltaMat,
    FrameReader, InitMsg, RefreshAheadMsg, RefreshAheadOkMsg, RefreshAheadOkV4Msg, StateExpect,
    StateRestoreMsg, StateSnapMsg, StateSnapOkMsg, StepEntry, StepEntryV3, StepEntryV4, StepMsg,
    StepOkMsg, StepOkV3Msg, StepOkV4Msg, StepV3Msg, StepV4Msg, WireMsg, PROTO_VERSION,
};
use crate::optim::engine::{
    drive_all, effective_worker_threads, lock_state, BlockExecutor, RefreshAheadDone,
    RefreshAheadPlan, UnitKind,
};
use crate::optim::precond::{BlockState, BlockStateSnap, StepCtx};
use crate::optim::{Block, GraftType, ShampooConfig};
use crate::runtime::pool;
use crate::tensor::Matrix;
use crate::train::journal::JournalWriter;
use crate::util::cli::Args;
use crate::util::config::Config;
use anyhow::{anyhow, bail, ensure, Context};
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Stdout handshake prefix a worker prints once its listener is bound.
const LISTEN_PREFIX: &str = "SKETCHY-SHARD-LISTENING ";

/// Worker spawn/launch attempts before giving up with a shard-named
/// error (transient launcher failures — an ssh connection race, a PID
/// limit blip — retry with deterministic backoff).
const SPAWN_ATTEMPTS: usize = 3;

/// Backoff schedule for spawn retries and the shutdown drain (replaces
/// the old fixed 10 ms sleep-spin).
const SPAWN_BACKOFF_BASE: Duration = Duration::from_millis(50);
const SPAWN_BACKOFF_CAP: Duration = Duration::from_secs(1);
const DRAIN_BACKOFF_BASE: Duration = Duration::from_millis(10);
const DRAIN_BACKOFF_CAP: Duration = Duration::from_millis(500);

// ---------------------------------------------------------------------------
// Configuration.
// ---------------------------------------------------------------------------

/// Wire transport between driver and shard workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardTransport {
    /// Localhost TCP (portable default).
    Tcp,
    /// Unix domain socket (lower latency; unix targets only).
    #[cfg(unix)]
    Unix,
}

impl ShardTransport {
    /// Parse a `--shard-transport` / `shard.transport` value.
    pub fn parse(s: &str) -> anyhow::Result<ShardTransport> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Ok(ShardTransport::Tcp),
            #[cfg(unix)]
            "unix" => Ok(ShardTransport::Unix),
            #[cfg(not(unix))]
            "unix" => bail!("shard transport 'unix' is unavailable on this platform"),
            other => bail!("unknown shard transport {other:?} (expected tcp or unix)"),
        }
    }
}

impl std::fmt::Display for ShardTransport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardTransport::Tcp => f.write_str("tcp"),
            #[cfg(unix)]
            ShardTransport::Unix => f.write_str("unix"),
        }
    }
}

/// Sharding knobs, resolvable from CLI flags and `[shard]` config keys
/// (same precedence discipline as [`crate::optim::EngineConfig::resolve`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Worker process count (0 = sharding disabled, run in-process).
    pub shards: usize,
    /// Wire transport for the worker links.
    pub transport: ShardTransport,
    /// Wire protocol version workers are spawned to speak
    /// ([`PROTO_VERSION`] normally; 1 pins the pre-RefreshAhead
    /// protocol, degrading refresh overlap to synchronous; 2 pins the
    /// pre-compression protocol, degrading payloads to full frames).
    pub proto: u32,
    /// Use the v3 delta-compressed payload layer on links whose worker
    /// reports the capability at handshake (v2/v1 workers keep full
    /// frames regardless). Never changes the numbers — payloads are
    /// bit-lossless either way.
    pub compress: bool,
    /// Optional launcher command template for spawning workers on
    /// remote hosts (e.g. over ssh) instead of exec-ing the local
    /// binary; see [`ShardLaunch`] for the placeholder grammar.
    pub launch: Option<String>,
    /// Warm spare workers to keep on standby for elastic failover
    /// (`--shard-spares`). 0 disables elastic membership unless
    /// `rebalance` is set.
    pub spares: usize,
    /// Enable latency-fed block rebalancing at sync points
    /// (`--rebalance`).
    pub rebalance: bool,
    /// Elastic failover budget: the driver snapshots worker state every
    /// this many steps, bounding journal replay after a kill
    /// (`--shard-failover-budget`).
    pub failover_budget: u64,
    /// Bound on establishing a connection to a worker, in ms
    /// (`--shard-connect-timeout-ms`; default 10 000).
    pub connect_timeout_ms: u64,
    /// Bound on waiting for any single worker reply, in ms
    /// (`--shard-reply-timeout-ms`; default 120 000). A hung worker on
    /// an unsupervised link surfaces as a shard-named error after this
    /// long; generous enough for a stale-schedule eigendecomposition
    /// burst on paper-scale (1024) blocks.
    pub reply_timeout_ms: u64,
    /// Supervised-link poll quantum / staleness bound before a `Ping`
    /// probe, in ms (`--shard-heartbeat-ms`; default 500).
    pub heartbeat_ms: u64,
    /// Supervised-link liveness deadline, in ms
    /// (`--shard-deadline-ms`; default 10 000): a silent worker on an
    /// elastic v6 fleet is killed and replaced after this long instead
    /// of waiting out the reply timeout.
    pub deadline_ms: u64,
    /// Durable write-ahead journal path (`--journal`). The driver
    /// persists sync-point snapshots + per-step records here so a
    /// killed driver can resume bitwise with `--resume-journal`.
    pub journal: Option<String>,
    /// Journal path to resume from (`--resume-journal`; implies
    /// journaling to the same path).
    pub resume_journal: Option<String>,
}

impl Default for ShardConfig {
    fn default() -> Self {
        let m = MembershipConfig::default();
        ShardConfig {
            shards: 0,
            transport: ShardTransport::Tcp,
            proto: PROTO_VERSION,
            compress: true,
            launch: None,
            spares: m.spares,
            rebalance: m.rebalance,
            failover_budget: m.failover_budget,
            connect_timeout_ms: m.timeouts.connect.as_millis() as u64,
            reply_timeout_ms: m.timeouts.reply.as_millis() as u64,
            heartbeat_ms: m.timeouts.heartbeat.as_millis() as u64,
            deadline_ms: m.timeouts.deadline.as_millis() as u64,
            journal: None,
            resume_journal: None,
        }
    }
}

impl ShardConfig {
    /// Config keys the `[shard]` section understands; anything else is
    /// a named error from [`ShardConfig::resolve`] (so a typo'd knob —
    /// `shard.spare` for `shard.spares` — can't silently become a
    /// no-op).
    const KNOWN_KEYS: &'static [&'static str] = &[
        "count",
        "transport",
        "proto",
        "compress",
        "launch",
        "spares",
        "rebalance",
        "failover_budget",
        "connect_timeout_ms",
        "reply_timeout_ms",
        "heartbeat_ms",
        "deadline_ms",
        "journal",
    ];

    /// Resolve from `--shards` / `--shard-transport` / `--shard-proto` /
    /// `--shard-compress` / `--shard-launch` / `--shard-spares` /
    /// `--rebalance` / `--shard-failover-budget` CLI flags with
    /// `shard.count` / `shard.transport` / `shard.proto` /
    /// `shard.compress` / `shard.launch` / `shard.spares` /
    /// `shard.rebalance` / `shard.failover_budget` config keys as
    /// fallback. Unknown `[shard]` keys are a named error.
    pub fn resolve(args: &Args, cfg: &Config) -> anyhow::Result<ShardConfig> {
        cfg.ensure_known_keys("shard", Self::KNOWN_KEYS)?;
        let d = ShardConfig::default();
        let shards = args.get_usize("shards", cfg.usize_or("shard.count", d.shards));
        let transport = match args.get("shard-transport") {
            Some(s) => ShardTransport::parse(s)?,
            None => ShardTransport::parse(&cfg.str_or("shard.transport", "tcp"))?,
        };
        let proto =
            args.get_usize("shard-proto", cfg.usize_or("shard.proto", d.proto as usize)) as u32;
        ensure!(
            (1..=PROTO_VERSION).contains(&proto),
            "unsupported shard wire protocol v{proto} (this build speaks v1..=v{PROTO_VERSION})"
        );
        let compress = args.get_bool("shard-compress", cfg.bool_or("shard.compress", d.compress));
        let launch = match args.get("shard-launch") {
            // An explicit empty value (`--shard-launch ""`) disables a
            // config-file template — the only CLI spelling that can
            // restore plain local exec.
            Some(s) if !s.trim().is_empty() => Some(s.to_string()),
            Some(_) => None,
            None => {
                let s = cfg.str_or("shard.launch", "");
                (!s.trim().is_empty()).then_some(s)
            }
        };
        let spares = args.get_usize("shard-spares", cfg.usize_or("shard.spares", d.spares));
        let rebalance = args.get_bool("rebalance", cfg.bool_or("shard.rebalance", d.rebalance));
        let failover_budget = args.get_u64(
            "shard-failover-budget",
            cfg.usize_or("shard.failover_budget", d.failover_budget as usize) as u64,
        );
        ensure!(failover_budget >= 1, "--shard-failover-budget must be >= 1");
        let connect_timeout_ms = args.get_u64(
            "shard-connect-timeout-ms",
            cfg.usize_or("shard.connect_timeout_ms", d.connect_timeout_ms as usize) as u64,
        );
        let reply_timeout_ms = args.get_u64(
            "shard-reply-timeout-ms",
            cfg.usize_or("shard.reply_timeout_ms", d.reply_timeout_ms as usize) as u64,
        );
        let heartbeat_ms = args.get_u64(
            "shard-heartbeat-ms",
            cfg.usize_or("shard.heartbeat_ms", d.heartbeat_ms as usize) as u64,
        );
        let deadline_ms = args.get_u64(
            "shard-deadline-ms",
            cfg.usize_or("shard.deadline_ms", d.deadline_ms as usize) as u64,
        );
        ensure!(connect_timeout_ms >= 1, "--shard-connect-timeout-ms must be >= 1");
        ensure!(reply_timeout_ms >= 1, "--shard-reply-timeout-ms must be >= 1");
        ensure!(heartbeat_ms >= 1, "--shard-heartbeat-ms must be >= 1");
        ensure!(deadline_ms >= 1, "--shard-deadline-ms must be >= 1");
        ensure!(
            heartbeat_ms <= deadline_ms && deadline_ms <= reply_timeout_ms,
            "timeout knobs must satisfy heartbeat ({heartbeat_ms} ms) <= deadline \
             ({deadline_ms} ms) <= reply ({reply_timeout_ms} ms)"
        );
        let resume_journal = match args.get("resume-journal") {
            Some(s) if !s.trim().is_empty() => Some(s.to_string()),
            _ => None,
        };
        // `--resume-journal` implies continuing to journal to the same
        // path; an explicit `--journal` (or `shard.journal`) may also
        // set it directly.
        let journal = match args.get("journal") {
            Some(s) if !s.trim().is_empty() => Some(s.to_string()),
            Some(_) => None,
            None => match resume_journal.clone() {
                Some(p) => Some(p),
                None => {
                    let s = cfg.str_or("shard.journal", "");
                    (!s.trim().is_empty()).then_some(s)
                }
            },
        };
        if (spares > 0 || rebalance || journal.is_some()) && proto < 5 {
            bail!(
                "elastic membership (--shard-spares/--rebalance/--journal) needs wire \
                 protocol v5, but --shard-proto pins v{proto}"
            );
        }
        Ok(ShardConfig {
            shards,
            transport,
            proto,
            compress,
            launch,
            spares,
            rebalance,
            failover_budget,
            connect_timeout_ms,
            reply_timeout_ms,
            heartbeat_ms,
            deadline_ms,
            journal,
            resume_journal,
        })
    }

    /// Whether cross-process sharding is requested.
    pub fn enabled(&self) -> bool {
        self.shards >= 1
    }

    /// The per-link connect/reply/heartbeat/deadline budgets.
    pub fn timeouts(&self) -> LinkTimeouts {
        LinkTimeouts {
            connect: Duration::from_millis(self.connect_timeout_ms),
            reply: Duration::from_millis(self.reply_timeout_ms),
            heartbeat: Duration::from_millis(self.heartbeat_ms),
            deadline: Duration::from_millis(self.deadline_ms),
        }
    }

    /// The elastic-membership slice of these knobs.
    pub fn membership(&self) -> MembershipConfig {
        MembershipConfig {
            spares: self.spares,
            rebalance: self.rebalance,
            failover_budget: self.failover_budget,
            journal: self.journal.clone(),
            resume_addrs: None,
            timeouts: self.timeouts(),
        }
    }
}

/// How to start shard workers: which binary to exec (or which launcher
/// command to run), how many shards, which transport, which wire
/// protocol version, and whether to use the v3 compressed payloads.
///
/// ## Launcher templates (multi-host)
///
/// `launch` lifts worker spawning off localhost: instead of exec-ing
/// `program` directly, the driver renders the template per shard and
/// runs the result. Placeholders: `{shard}` → the shard index,
/// `{program}` → the local binary path, `{worker_cmd}` → the standard
/// `shard-worker --worker-id N --transport T --proto-version V`
/// invocation (appended at the end when the placeholder is absent).
/// Tokens split on whitespace — there is no shell quoting; point the
/// template at real argv words. The spawned command's stdout must
/// carry the worker's listen announcement back to the driver, which
/// `ssh` does natively:
///
/// ```text
/// --shard-launch "ssh worker-{shard}.cluster /opt/sketchy/sketchy
///     {worker_cmd} --listen 0.0.0.0:0 --advertise-host worker-{shard}.cluster"
/// ```
///
/// The worker binds `--listen`, announces `--advertise-host` plus the
/// bound port, and the driver dials that address — same handshake,
/// same reconnect/replay machinery, same bitwise contract as
/// localhost.
#[derive(Clone, Debug)]
pub struct ShardLaunch {
    /// Binary exposing the `shard-worker` subcommand (normally this
    /// process's own executable; tests pass `CARGO_BIN_EXE_sketchy`).
    pub program: PathBuf,
    pub shards: usize,
    pub transport: ShardTransport,
    /// Protocol version passed to workers as `--proto-version`.
    pub proto: u32,
    /// Use delta-compressed payloads on capable (v3) links.
    pub compress: bool,
    /// Optional launcher command template (see the type-level docs).
    pub launch: Option<String>,
    /// Elastic-membership / journal knobs resolved alongside the
    /// launch plan ([`ShardConfig::membership`]). Carried here so every
    /// construction path — `ExecutorBuilder::sharded` and the
    /// deprecated `PrecondEngine::sharded` shim — forwards them instead
    /// of silently substituting defaults (`ExecutorBuilder::membership`
    /// still overrides explicitly).
    pub membership: MembershipConfig,
}

impl ShardLaunch {
    /// Launch plan re-execing the current binary.
    pub fn current_exe(cfg: &ShardConfig) -> anyhow::Result<ShardLaunch> {
        ensure!(cfg.shards >= 1, "shard launch requires --shards >= 1");
        Ok(ShardLaunch {
            program: std::env::current_exe().context("resolve current executable")?,
            shards: cfg.shards,
            transport: cfg.transport,
            proto: cfg.proto,
            compress: cfg.compress,
            launch: cfg.launch.clone(),
            membership: cfg.membership(),
        })
    }
}

/// Render the launcher command line for one shard: substitute
/// `{shard}` / `{program}`, split on whitespace, and splice the worker
/// invocation at `{worker_cmd}` (appended when absent). Returns the
/// program to exec plus its arguments.
fn render_launch_command(
    template: &str,
    program: &std::path::Path,
    shard: usize,
    worker_args: &[String],
) -> anyhow::Result<(PathBuf, Vec<String>)> {
    let rendered = template
        .replace("{shard}", &shard.to_string())
        .replace("{program}", &program.display().to_string());
    let mut toks: Vec<String> = rendered.split_whitespace().map(str::to_string).collect();
    ensure!(!toks.is_empty(), "shard launch template rendered to an empty command");
    match toks.iter().position(|t| t == "{worker_cmd}") {
        Some(pos) => {
            toks.splice(pos..=pos, worker_args.iter().cloned());
        }
        None => toks.extend(worker_args.iter().cloned()),
    }
    // An embedded occurrence (`cmd={worker_cmd}` or a missing space)
    // would otherwise ship the literal placeholder to the remote argv —
    // fail fast instead of producing a confusing remote exec error.
    ensure!(
        toks.iter().all(|t| !t.contains("{worker_cmd}")),
        "shard launch template: {{worker_cmd}} must be a standalone whitespace-separated token"
    );
    ensure!(
        toks.first().map(String::as_str) != Some("shard-worker"),
        "shard launch template must name a program before the worker command"
    );
    let prog = PathBuf::from(toks.remove(0));
    Ok((prog, toks))
}

/// Deterministic contiguous block partition: shard `s` owns a balanced
/// run of consecutive block indices (earlier shards take the remainder).
#[deprecated(note = "use coordinator::membership::ContiguousAssignment (BlockAssignment trait)")]
pub fn assign_blocks(n_blocks: usize, shards: usize) -> Vec<Vec<usize>> {
    ContiguousAssignment.assign(n_blocks, shards)
}

// ---------------------------------------------------------------------------
// Transport plumbing shared by both sides.
// ---------------------------------------------------------------------------

impl Conn for TcpStream {
    fn set_timeout(&mut self, dur: Option<Duration>) -> std::io::Result<()> {
        TcpStream::set_read_timeout(self, dur)
    }
}

#[cfg(unix)]
impl Conn for UnixStream {
    fn set_timeout(&mut self, dur: Option<Duration>) -> std::io::Result<()> {
        UnixStream::set_read_timeout(self, dur)
    }
}

/// A worker's announced listen address.
#[derive(Clone, Debug, PartialEq, Eq)]
enum WorkerAddr {
    Tcp(String),
    #[cfg(unix)]
    Unix(PathBuf),
}

impl WorkerAddr {
    /// `<kind> <addr>` — the representation journaled at sync points so
    /// a relaunched driver can try to re-adopt the surviving fleet.
    fn journal_repr(&self) -> String {
        match self {
            WorkerAddr::Tcp(a) => format!("tcp {a}"),
            #[cfg(unix)]
            WorkerAddr::Unix(p) => format!("unix {}", p.display()),
        }
    }

    fn from_journal_repr(s: &str) -> Option<WorkerAddr> {
        let (kind, addr) = s.split_once(' ')?;
        match kind {
            "tcp" => Some(WorkerAddr::Tcp(addr.to_string())),
            #[cfg(unix)]
            "unix" => Some(WorkerAddr::Unix(PathBuf::from(addr))),
            _ => None,
        }
    }
}

/// Parse a worker's stdout handshake line.
fn parse_listen_line(line: &str) -> Option<WorkerAddr> {
    let rest = line.trim().strip_prefix(LISTEN_PREFIX)?;
    WorkerAddr::from_journal_repr(rest)
}

/// Open one connection to an announced worker address.
fn dial_addr(addr: &WorkerAddr, connect_timeout: Duration) -> anyhow::Result<Box<dyn Conn>> {
    match addr {
        WorkerAddr::Tcp(addr) => {
            let sock = addr
                .to_socket_addrs()
                .with_context(|| format!("resolve {addr}"))?
                .next()
                .ok_or_else(|| anyhow!("no socket addr in {addr}"))?;
            let stream = TcpStream::connect_timeout(&sock, connect_timeout)
                .with_context(|| format!("connect tcp {addr}"))?;
            // Step frames are small; don't let Nagle delay them.
            let _ = stream.set_nodelay(true);
            Ok(Box::new(stream))
        }
        #[cfg(unix)]
        WorkerAddr::Unix(path) => {
            let stream = UnixStream::connect(path)
                .with_context(|| format!("connect unix {}", path.display()))?;
            Ok(Box::new(stream))
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side: `sketchy shard-worker`.
// ---------------------------------------------------------------------------

/// Per-slot (param, grad) bit snapshots — a worker-side delta baseline.
type SlotBits = Vec<(Vec<u64>, Vec<u64>)>;

/// Per-block (param, grad) bit snapshots keyed by global block index —
/// a driver-side upload baseline.
type BlockBits = BTreeMap<u32, (Vec<u64>, Vec<u64>)>;

/// Per-block param bit snapshots keyed by global block index — a
/// driver-side download baseline.
type ParamBits = BTreeMap<u32, Vec<u64>>;

/// Lock-recovery for worker-side block states: a block panic surfaces
/// as a named error through [`drive_all`] (and the wire turns it into a
/// shard-named `Error` reply), but the panicking task leaves its state
/// mutex poisoned — every later touch through a bare `.unwrap()` would
/// die with an opaque `PoisonError` instead of the shard-error
/// contract. Recover the inner value, exactly like the engine's
/// [`lock_state`].
fn state_mut(m: &mut Mutex<BlockState>) -> &mut BlockState {
    m.get_mut().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Block states owned by one worker process. Persists across
/// connections so the driver can reconnect without losing statistics.
struct WorkerState {
    graft: GraftType,
    /// Unit kind + sidedness from Init — the worker's own copy of the
    /// block table, used to validate v4 state payloads (shape/rank/kind)
    /// *before* any payload resolution or allocation.
    kind: UnitKind,
    one_sided: bool,
    /// Thread knob for the worker's own block pool (0 = auto).
    threads: usize,
    states: Vec<Mutex<BlockState>>,
    /// Global block index → local slot.
    slot_of: BTreeMap<u32, usize>,
    /// Last step reply, keyed by `t` — replayed verbatim when the driver
    /// retries a step after a reconnect (idempotency).
    last_step: Option<(u64, WireMsg)>,
    /// Last RefreshAhead reply, keyed by `t_next` — same idempotent
    /// replay for the overlap request that raced a reconnect (re-running
    /// the eigendecompositions would be bitwise harmless but would skew
    /// the refresh accounting).
    last_refresh_ahead: Option<(u64, WireMsg)>,
    /// v3 delta-codec download baseline: per-slot (param, grad) bits of
    /// the last successfully processed `StepV3`, tagged with its `t`.
    /// Survives reconnects (like all worker state); advanced only after
    /// a step fully succeeds, so an errored or replayed frame can never
    /// corrupt it.
    delta_rx: Option<(u64, SlotBits)>,
    /// v3 upload baseline: per-slot returned-param bits of the last
    /// `StepOkV3` this worker encoded, tagged with its `t`. The
    /// lockstep protocol guarantees the driver decoded that reply
    /// (possibly via cache replay) before it could send the next step.
    delta_tx: Option<(u64, Vec<Vec<u64>>)>,
}

impl WorkerState {
    fn build(init: &InitMsg) -> anyhow::Result<WorkerState> {
        let kind = UnitKind::from_code(init.kind, init.rank as usize)
            .ok_or_else(|| anyhow!("unknown unit kind code {}", init.kind))?;
        let graft = GraftType::from_code(init.graft)
            .ok_or_else(|| anyhow!("unknown graft code {}", init.graft))?;
        // Only beta2 / eps / one_sided / graft reach unit construction;
        // per-step knobs (lr, momentum, decay, schedule) travel in every
        // Step message, so the worker needs no full driver config.
        let base = ShampooConfig {
            beta2: init.beta2,
            eps: init.eps,
            one_sided: init.one_sided,
            graft,
            ekfac: init.ekfac,
            ..Default::default()
        };
        let mut states = Vec::with_capacity(init.blocks.len());
        let mut slot_of = BTreeMap::new();
        for (slot, b) in init.blocks.iter().enumerate() {
            ensure!(b.rows > 0 && b.cols > 0, "block {} has empty shape", b.index);
            ensure!(
                slot_of.insert(b.index, slot).is_none(),
                "duplicate block index {} in init",
                b.index
            );
            let shape = (b.rows as usize, b.cols as usize);
            states.push(Mutex::new(BlockState::new(
                kind.make(shape, &base),
                graft,
                shape,
                init.beta2,
            )));
        }
        Ok(WorkerState {
            graft,
            kind,
            one_sided: init.one_sided,
            threads: init.threads as usize,
            states,
            slot_of,
            last_step: None,
            last_refresh_ahead: None,
            delta_rx: None,
            delta_tx: None,
        })
    }

    fn process_step(&mut self, msg: &StepMsg) -> anyhow::Result<StepOkMsg> {
        ensure!(
            msg.entries.len() == self.states.len(),
            "step carries {} blocks, shard owns {}",
            msg.entries.len(),
            self.states.len()
        );
        let mut ctxs: Vec<Option<StepCtx>> = vec![None; self.states.len()];
        for ent in &msg.entries {
            let slot = *self
                .slot_of
                .get(&ent.index)
                .ok_or_else(|| anyhow!("unknown block index {}", ent.index))?;
            ensure!(ctxs[slot].is_none(), "duplicate entry for block {}", ent.index);
            let st = state_mut(&mut self.states[slot]);
            ensure!(
                ent.param.shape() == st.param.shape() && ent.grad.shape() == st.grad.shape(),
                "block {} shape mismatch: got {:?}/{:?}, own {:?}",
                ent.index,
                ent.param.shape(),
                ent.grad.shape(),
                st.param.shape()
            );
            st.param.as_mut_slice().copy_from_slice(ent.param.as_slice());
            st.grad.as_mut_slice().copy_from_slice(ent.grad.as_slice());
            ctxs[slot] = Some(StepCtx {
                t: msg.t as usize,
                scale: msg.scale,
                preconditioning: msg.preconditioning,
                refresh_due: ent.refresh_due,
                lr: msg.lr,
                beta1: msg.beta1,
                weight_decay: msg.weight_decay,
                stat_due: msg.stat_due,
                graft: self.graft,
            });
        }
        let ctxs: Vec<StepCtx> = ctxs
            .into_iter()
            .map(|c| c.ok_or_else(|| anyhow!("step is missing an assigned block")))
            .collect::<anyhow::Result<_>>()?;
        let threads = effective_worker_threads(self.threads, self.states.len());
        let refreshes = drive_all(&self.states, &ctxs, threads)?;
        let mut entries = Vec::with_capacity(msg.entries.len());
        for ent in &msg.entries {
            let slot = self.slot_of[&ent.index];
            entries.push((ent.index, state_mut(&mut self.states[slot]).param.clone()));
        }
        Ok(StepOkMsg { t: msg.t, refreshes: refreshes as u32, entries })
    }

    /// The v3 counterpart of [`WorkerState::process_step`]: resolve the
    /// delta-encoded payloads against the download baseline, drive the
    /// identical per-block math, and reply with payloads delta-encoded
    /// against this worker's previous reply. Baselines advance only on
    /// full success; `resync` drops them first (the driver sets it
    /// after a reconnect), re-anchoring the stream on full frames.
    fn process_step_v3(&mut self, msg: &StepV3Msg) -> anyhow::Result<StepOkV3Msg> {
        if msg.resync {
            self.delta_rx = None;
            self.delta_tx = None;
        }
        ensure!(
            msg.entries.len() == self.states.len(),
            "step carries {} blocks, shard owns {}",
            msg.entries.len(),
            self.states.len()
        );
        let n = self.states.len();
        let mut ctxs: Vec<Option<StepCtx>> = vec![None; n];
        let mut resolved: Vec<Option<(Vec<u64>, Vec<u64>)>> = vec![None; n];
        for ent in &msg.entries {
            let slot = *self
                .slot_of
                .get(&ent.index)
                .ok_or_else(|| anyhow!("unknown block index {}", ent.index))?;
            ensure!(resolved[slot].is_none(), "duplicate entry for block {}", ent.index);
            let shape = state_mut(&mut self.states[slot]).param.shape();
            ensure!(
                ent.param.shape() == shape && ent.grad.shape() == shape,
                "block {} shape mismatch: got {:?}/{:?}, own {:?}",
                ent.index,
                ent.param.shape(),
                ent.grad.shape(),
                shape
            );
            // A Delta payload may only be applied against the baseline
            // it was encoded from — tagged by `base_t`, validated here.
            let needs_base = matches!(ent.param, DeltaMat::Delta { .. })
                || matches!(ent.grad, DeltaMat::Delta { .. });
            let base = if needs_base {
                match &self.delta_rx {
                    Some((bt, bases)) if *bt == msg.base_t && msg.base_t != 0 => {
                        Some(&bases[slot])
                    }
                    Some((bt, _)) => bail!(
                        "delta base mismatch: step t={} encoded against t={}, baseline \
                         holds t={bt} (full-frame resync required)",
                        msg.t,
                        msg.base_t
                    ),
                    None => bail!(
                        "delta step t={} without a baseline (full-frame resync required)",
                        msg.t
                    ),
                }
            } else {
                None
            };
            let pbits = ent.param.resolve(base.map(|(p, _)| p.as_slice()))?;
            let gbits = ent.grad.resolve(base.map(|(_, g)| g.as_slice()))?;
            resolved[slot] = Some((pbits, gbits));
            ctxs[slot] = Some(StepCtx {
                t: msg.t as usize,
                scale: msg.scale,
                preconditioning: msg.preconditioning,
                refresh_due: ent.refresh_due,
                lr: msg.lr,
                beta1: msg.beta1,
                weight_decay: msg.weight_decay,
                stat_due: msg.stat_due,
                graft: self.graft,
            });
        }
        let ctxs: Vec<StepCtx> = ctxs
            .into_iter()
            .map(|c| c.ok_or_else(|| anyhow!("step is missing an assigned block")))
            .collect::<anyhow::Result<_>>()?;
        let resolved: SlotBits = resolved
            .into_iter()
            .map(|r| r.ok_or_else(|| anyhow!("step is missing an assigned block")))
            .collect::<anyhow::Result<_>>()?;
        for (slot, (pbits, gbits)) in resolved.iter().enumerate() {
            let st = state_mut(&mut self.states[slot]);
            for (dst, &b) in st.param.as_mut_slice().iter_mut().zip(pbits) {
                *dst = f64::from_bits(b);
            }
            for (dst, &b) in st.grad.as_mut_slice().iter_mut().zip(gbits) {
                *dst = f64::from_bits(b);
            }
        }
        let threads = effective_worker_threads(self.threads, n);
        let refreshes = drive_all(&self.states, &ctxs, threads)?;
        // Encode the reply against the previous reply's bits — valid
        // only when that reply was for the immediately preceding step.
        let tx_base = self.delta_tx.take().filter(|(bt, _)| bt + 1 == msg.t);
        let base_t = tx_base.as_ref().map(|(bt, _)| *bt).unwrap_or(0);
        let mut out_bits: Vec<Vec<u64>> = Vec::with_capacity(n);
        let mut entries = Vec::with_capacity(msg.entries.len());
        for s in self.states.iter_mut() {
            out_bits.push(mat_bits(&state_mut(s).param));
        }
        for ent in &msg.entries {
            let slot = self.slot_of[&ent.index];
            let (rows, cols) = state_mut(&mut self.states[slot]).param.shape();
            let base = tx_base.as_ref().map(|(_, b)| b[slot].as_slice());
            entries.push((ent.index, DeltaMat::encode(rows, cols, &out_bits[slot], base)));
        }
        self.delta_rx = Some((msg.t, resolved));
        self.delta_tx = Some((msg.t, out_bits));
        Ok(StepOkV3Msg { t: msg.t, base_t, refreshes: refreshes as u32, entries })
    }

    /// Run the RefreshAhead stage against the owned block states: visit
    /// the due subset (every owned block when `all`) and recompute
    /// inverse roots where the slot fires or roots are still missing —
    /// exactly the in-process `LocalExecutor` job body, on this worker's
    /// share of the pool. The driver parks the reply, so this work
    /// overlaps the trainer's gradient computation.
    fn process_refresh_ahead(
        &mut self,
        msg: &RefreshAheadMsg,
    ) -> anyhow::Result<RefreshAheadOkMsg> {
        let due: BTreeSet<u32> = msg.due.iter().copied().collect();
        for &i in &due {
            ensure!(
                self.slot_of.contains_key(&i),
                "unknown block index {i} in refresh-ahead"
            );
        }
        // BTreeMap iteration is index-ordered, so the target list (and
        // the reply's refreshed list) is deterministic.
        let targets: Vec<(usize, u32, bool)> = self
            .slot_of
            .iter()
            .filter_map(|(&index, &slot)| {
                let d = due.contains(&index);
                (msg.all || d).then_some((slot, index, d))
            })
            .collect();
        let count = AtomicUsize::new(0);
        let flags: Vec<AtomicBool> = targets.iter().map(|_| AtomicBool::new(false)).collect();
        if !targets.is_empty() {
            let threads = effective_worker_threads(self.threads, targets.len());
            let states = &self.states;
            pool::global()
                .try_run(threads, targets.len(), |j| {
                    let (slot, _, d) = targets[j];
                    // Same per-task kernel pin and refresh condition as
                    // the in-process RefreshAhead job: the driver only
                    // prefetches on steps that fold no statistics, so
                    // these roots equal a synchronous refresh bitwise.
                    crate::tensor::ops::with_single_thread(|| {
                        let mut st = lock_state(&states[slot]);
                        if !st.unit.ready() || d {
                            if st.unit.refresh() {
                                count.fetch_add(1, Ordering::Relaxed);
                            }
                            flags[j].store(true, Ordering::Relaxed);
                        }
                    });
                })
                .map_err(|m| anyhow!("refresh-ahead phase: {m}"))?;
        }
        let refreshed = targets
            .iter()
            .zip(&flags)
            .filter(|(_, f)| f.load(Ordering::Relaxed))
            .map(|(&(_, index, _), _)| index)
            .collect();
        Ok(RefreshAheadOkMsg {
            t_next: msg.t_next,
            count: count.load(Ordering::Relaxed) as u32,
            refreshed,
        })
    }

    /// The v4 typed-payload step: `param`/`grad` must travel as `Dense`
    /// payloads (gradients have no factored form), so the step unwraps
    /// them to the v3 delta layer and shares its entire core — baseline
    /// discipline, resync, reply encoding — then re-wraps the reply.
    fn process_step_v4(&mut self, msg: &StepV4Msg) -> anyhow::Result<StepOkV4Msg> {
        let entries = msg
            .entries
            .iter()
            .map(|e| {
                let (param, grad) = match (&e.param, &e.grad) {
                    (BlockPayload::Dense(p), BlockPayload::Dense(g)) => (p.clone(), g.clone()),
                    _ => bail!(
                        "block {}: step payloads must be Dense (sketch/diag payloads \
                         only travel in state frames)",
                        e.index
                    ),
                };
                Ok(StepEntryV3 { index: e.index, refresh_due: e.refresh_due, param, grad })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let v3 = StepV3Msg {
            t: msg.t,
            base_t: msg.base_t,
            resync: msg.resync,
            scale: msg.scale,
            preconditioning: msg.preconditioning,
            stat_due: msg.stat_due,
            lr: msg.lr,
            beta1: msg.beta1,
            weight_decay: msg.weight_decay,
            entries,
        };
        let ok = self.process_step_v3(&v3)?;
        Ok(StepOkV4Msg {
            t: ok.t,
            base_t: ok.base_t,
            refreshes: ok.refreshes,
            entries: ok
                .entries
                .into_iter()
                .map(|(i, dm)| (i, BlockPayload::Dense(dm)))
                .collect(),
        })
    }

    /// The worker's own [`StateExpect`] row for one owned slot — the
    /// block table every v4 state payload is validated against before
    /// any resolve/allocation.
    fn expect_for(&mut self, slot: usize) -> StateExpect {
        let (rows, cols) = state_mut(&mut self.states[slot]).param.shape();
        StateExpect {
            rows,
            cols,
            kind: self.kind.code(),
            rank: self.kind.rank(),
            one_sided: self.one_sided,
        }
    }

    /// Serve a v4 `StateSnap`: export the typed state of the wanted
    /// blocks (all owned when `want` is empty), in index order. Pure
    /// read — naturally idempotent under reconnect replay.
    fn process_state_snap(&mut self, msg: &StateSnapMsg) -> anyhow::Result<StateSnapOkMsg> {
        let want: Vec<u32> = if msg.want.is_empty() {
            self.slot_of.keys().copied().collect()
        } else {
            for &i in &msg.want {
                ensure!(self.slot_of.contains_key(&i), "unknown block index {i} in state-snap");
            }
            msg.want.clone()
        };
        let mut entries = Vec::with_capacity(want.len());
        for index in want {
            let slot = self.slot_of[&index];
            let snap = state_mut(&mut self.states[slot]).snapshot();
            entries.push(BlockStateMsg::from_snap(index, &snap));
        }
        Ok(StateSnapOkMsg { entries })
    }

    /// Serve a v4 `StateRestore`: validate every payload against the
    /// worker's block table (shape/rank/kind, *before* resolving any
    /// compressed buffer), then restore. Idempotent: re-applying the
    /// same payloads lands on the same bitwise state.
    fn process_state_restore(&mut self, msg: &StateRestoreMsg) -> anyhow::Result<()> {
        // Validate all entries first so a bad batch cannot leave a
        // half-restored worker behind.
        let mut staged = Vec::with_capacity(msg.entries.len());
        for entry in &msg.entries {
            let index = entry.index;
            let slot = *self
                .slot_of
                .get(&index)
                .ok_or_else(|| anyhow!("unknown block index {index} in state-restore"))?;
            let exp = self.expect_for(slot);
            let snap = entry
                .clone()
                .into_snap(&exp)
                .with_context(|| format!("block {index} state payload"))?;
            staged.push((slot, index, snap));
        }
        for (slot, index, snap) in staged {
            state_mut(&mut self.states[slot])
                .restore(snap)
                .with_context(|| format!("restore block {index}"))?;
        }
        Ok(())
    }

    /// Per-block cumulative escaped mass ρ_{1:t} of every sketched
    /// block, in index order — the RFD diagnostic shipped in v4
    /// `RefreshAheadOk` replies so drivers can watch sketch-escape
    /// growth without a state RPC.
    fn escaped_masses(&mut self) -> Vec<(u32, f64)> {
        let mut out = Vec::new();
        let slots: Vec<(u32, usize)> = self.slot_of.iter().map(|(&i, &s)| (i, s)).collect();
        for (index, slot) in slots {
            let st = state_mut(&mut self.states[slot]);
            let sketches = st.unit.sketches();
            if !sketches.is_empty() {
                out.push((index, sketches.iter().map(|fd| fd.escaped_mass()).sum()));
            }
        }
        out
    }

    fn mem_stats(&mut self) -> (u64, u64) {
        let mut mem = 0u64;
        let mut second = 0u64;
        for s in &mut self.states {
            let st = state_mut(s);
            mem += st.mem_bytes() as u64;
            second += st.second_moment_bytes() as u64;
        }
        // The delta codec's baselines are real worker memory (full bit
        // snapshots of params + grads) — keep them visible to operators
        // sizing hosts from the MemStats report.
        if let Some((_, slots)) = &self.delta_rx {
            mem += slots.iter().map(|(p, g)| (p.len() + g.len()) as u64 * 8).sum::<u64>();
        }
        if let Some((_, slots)) = &self.delta_tx {
            mem += slots.iter().map(|p| p.len() as u64 * 8).sum::<u64>();
        }
        (mem, second)
    }
}

/// Serve one connection at wire protocol version `proto`. `Ok(true)`
/// keeps the worker alive for further connections (reconnect support);
/// `Ok(false)` means clean shutdown. `worker_id` is mutable because a
/// v5 [`WireMsg::Adopt`] re-seats the worker's identity: a spare that
/// adopts shard `s` greets future reconnects as `s`.
fn handle_conn<S: Read + Write>(
    stream: &mut S,
    state: &mut Option<WorkerState>,
    worker_id: &mut u32,
    proto: u32,
) -> anyhow::Result<bool> {
    let wid = *worker_id;
    if proto <= 1 {
        // Legacy greeting: no capability report — the driver keeps this
        // shard's refreshes synchronous and its payloads full-frame.
        wire::write_msg(stream, &WireMsg::Hello { worker_id: wid })?;
    } else if proto == 2 {
        wire::write_msg(stream, &WireMsg::HelloV2 { worker_id: wid, proto, overlap: true })?;
    } else if proto == 3 {
        wire::write_msg(
            stream,
            &WireMsg::HelloV3 { worker_id: wid, proto, overlap: true, compress: true },
        )?;
    } else if proto == 4 {
        wire::write_msg(
            stream,
            &WireMsg::HelloV4 {
                worker_id: wid,
                proto,
                overlap: true,
                compress: true,
                state: true,
            },
        )?;
    } else if proto == 5 {
        wire::write_msg(
            stream,
            &WireMsg::HelloV5 {
                worker_id: wid,
                proto,
                overlap: true,
                compress: true,
                state: true,
                member: true,
            },
        )?;
    } else {
        wire::write_msg(
            stream,
            &WireMsg::HelloV6 {
                worker_id: wid,
                proto,
                overlap: true,
                compress: true,
                state: true,
                member: true,
                heartbeat: true,
            },
        )?;
    }
    loop {
        let msg = match wire::read_msg_opt(stream)? {
            None => return Ok(true), // driver closed; await a reconnect
            Some(m) => m,
        };
        match msg {
            WireMsg::Init(init) => {
                let reply = match WorkerState::build(&init) {
                    Ok(ws) => {
                        *state = Some(ws);
                        WireMsg::Ok
                    }
                    Err(e) => WireMsg::Error { message: format!("init: {e:#}") },
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::Step(step) => {
                let reply = match state.as_mut() {
                    None => WireMsg::Error { message: "step before init".into() },
                    Some(ws) => match &ws.last_step {
                        Some((t, cached)) if *t == step.t => cached.clone(),
                        _ => match ws.process_step(&step) {
                            Ok(ok) => {
                                let reply = WireMsg::StepOk(ok);
                                ws.last_step = Some((step.t, reply.clone()));
                                reply
                            }
                            Err(e) => {
                                WireMsg::Error { message: format!("step t={}: {e:#}", step.t) }
                            }
                        },
                    },
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::StepV3(step) => {
                let reply = if proto < 3 {
                    // A v2/v1 worker emulation must behave like the old
                    // binary: it never advertised the payload layer.
                    WireMsg::Error {
                        message: format!(
                            "delta-compressed step unsupported at wire protocol v{proto}"
                        ),
                    }
                } else {
                    match state.as_mut() {
                        None => WireMsg::Error { message: "step before init".into() },
                        // Shared idempotency cache with plain Step: the
                        // replay of a delta frame must serve the cached
                        // bytes *before* any baseline logic runs, so a
                        // duplicate can never re-apply or re-tag.
                        Some(ws) => match &ws.last_step {
                            Some((t, cached)) if *t == step.t => cached.clone(),
                            _ => match ws.process_step_v3(&step) {
                                Ok(ok) => {
                                    let reply = WireMsg::StepOkV3(ok);
                                    ws.last_step = Some((step.t, reply.clone()));
                                    reply
                                }
                                Err(e) => WireMsg::Error {
                                    message: format!("step t={}: {e:#}", step.t),
                                },
                            },
                        },
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::RefreshAhead(ra) => {
                let reply = if proto <= 1 {
                    // A v1 worker emulation must behave like the old
                    // binary: it never advertised this capability.
                    WireMsg::Error {
                        message: "refresh-ahead unsupported at wire protocol v1".into(),
                    }
                } else {
                    match state.as_mut() {
                        None => WireMsg::Error { message: "refresh-ahead before init".into() },
                        Some(ws) => match &ws.last_refresh_ahead {
                            Some((t, cached)) if *t == ra.t_next => cached.clone(),
                            _ => match ws.process_refresh_ahead(&ra) {
                                // v4 links get the extended reply with
                                // the per-block escaped-mass diagnostics
                                // (the RFD accumulator); older links keep
                                // the v2 reply shape.
                                Ok(ok) => {
                                    let reply = if proto >= 4 {
                                        WireMsg::RefreshAheadOkV4(RefreshAheadOkV4Msg {
                                            t_next: ok.t_next,
                                            count: ok.count,
                                            refreshed: ok.refreshed,
                                            escaped: ws.escaped_masses(),
                                        })
                                    } else {
                                        WireMsg::RefreshAheadOk(ok)
                                    };
                                    ws.last_refresh_ahead = Some((ra.t_next, reply.clone()));
                                    reply
                                }
                                Err(e) => WireMsg::Error {
                                    message: format!("refresh-ahead t={}: {e:#}", ra.t_next),
                                },
                            },
                        },
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::StepV4(step) => {
                let reply = if proto < 4 {
                    // A v3/v2/v1 worker emulation must behave like the
                    // old binary: it never advertised the typed layer.
                    WireMsg::Error {
                        message: format!(
                            "typed-payload step unsupported at wire protocol v{proto}"
                        ),
                    }
                } else {
                    match state.as_mut() {
                        None => WireMsg::Error { message: "step before init".into() },
                        // Shared idempotency cache with Step/StepV3: a
                        // replayed frame is served the cached bytes
                        // before any baseline logic runs.
                        Some(ws) => match &ws.last_step {
                            Some((t, cached)) if *t == step.t => cached.clone(),
                            _ => match ws.process_step_v4(&step) {
                                Ok(ok) => {
                                    let reply = WireMsg::StepOkV4(ok);
                                    ws.last_step = Some((step.t, reply.clone()));
                                    reply
                                }
                                Err(e) => WireMsg::Error {
                                    message: format!("step t={}: {e:#}", step.t),
                                },
                            },
                        },
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::StateSnap(snap) => {
                let reply = if proto < 4 {
                    WireMsg::Error {
                        message: format!(
                            "state snapshots unsupported at wire protocol v{proto}"
                        ),
                    }
                } else {
                    match state.as_mut() {
                        None => WireMsg::Error { message: "state-snap before init".into() },
                        // Pure read: no cache needed — a replay re-reads
                        // the same (unchanged-by-this-RPC) state.
                        Some(ws) => match ws.process_state_snap(&snap) {
                            Ok(ok) => WireMsg::StateSnapOk(ok),
                            Err(e) => WireMsg::Error { message: format!("state-snap: {e:#}") },
                        },
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::StateRestore(restore) => {
                let reply = if proto < 4 {
                    WireMsg::Error {
                        message: format!(
                            "state restore unsupported at wire protocol v{proto}"
                        ),
                    }
                } else {
                    match state.as_mut() {
                        None => WireMsg::Error { message: "state-restore before init".into() },
                        // Idempotent: re-applying the same payloads lands
                        // on the same bitwise state, so replay is safe
                        // without a cache.
                        Some(ws) => match ws.process_state_restore(&restore) {
                            Ok(()) => WireMsg::Ok,
                            Err(e) => {
                                WireMsg::Error { message: format!("state-restore: {e:#}") }
                            }
                        },
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::Adopt { epoch, shard } => {
                let reply = if proto < 5 {
                    WireMsg::Error {
                        message: format!(
                            "membership adoption unsupported at wire protocol v{proto}"
                        ),
                    }
                } else {
                    // Re-seat this worker's identity: drop any block
                    // state from a previous seat (the driver re-inits
                    // and restores), and greet future reconnects with
                    // the adopted shard id.
                    *worker_id = shard;
                    *state = None;
                    WireMsg::AdoptOk { epoch, shard }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::Ping { seq } => {
                // Liveness probe: answerable before Init (the supervisor
                // may probe a seat that is still being restored).
                let reply = if proto < 6 {
                    WireMsg::Error {
                        message: format!(
                            "heartbeat ping unsupported at wire protocol v{proto}"
                        ),
                    }
                } else {
                    WireMsg::Pong { seq }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::MemStats => {
                let reply = match state.as_mut() {
                    None => WireMsg::MemStatsOk { mem_bytes: 0, second_moment_bytes: 0 },
                    Some(ws) => {
                        let (mem_bytes, second_moment_bytes) = ws.mem_stats();
                        WireMsg::MemStatsOk { mem_bytes, second_moment_bytes }
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::Shutdown => {
                wire::write_msg(stream, &WireMsg::Ok)?;
                return Ok(false);
            }
            other => {
                let reply =
                    WireMsg::Error { message: format!("unexpected driver message: {other:?}") };
                wire::write_msg(stream, &reply)?;
            }
        }
    }
}

fn announce(detail: &str) -> anyhow::Result<()> {
    let mut out = std::io::stdout();
    writeln!(out, "{LISTEN_PREFIX}{detail}").context("announce listen address")?;
    out.flush().context("flush listen address")?;
    Ok(())
}

/// Entry point for the `sketchy shard-worker` subcommand: bind a
/// listener, announce it on stdout, then serve driver connections until
/// a `Shutdown` message arrives. Block state persists across
/// connections; per-connection transport errors are logged and the
/// worker keeps listening. `--proto-version 1` pins the legacy
/// (pre-RefreshAhead) handshake so degraded-mode deployments stay
/// testable end to end.
pub fn serve_worker(args: &Args) -> anyhow::Result<()> {
    let mut worker_id = args.get_usize("worker-id", 0) as u32;
    let transport = ShardTransport::parse(&args.get_or("transport", "tcp"))?;
    let proto = args.get_usize("proto-version", PROTO_VERSION as usize) as u32;
    ensure!(
        (1..=PROTO_VERSION).contains(&proto),
        "unsupported --proto-version {proto} (this build speaks v1..=v{PROTO_VERSION})"
    );
    let mut state: Option<WorkerState> = None;
    match transport {
        ShardTransport::Tcp => {
            // Multi-host launches bind a reachable interface
            // (`--listen 0.0.0.0:0`) and announce a dialable name
            // (`--advertise-host`) with the bound port; the localhost
            // defaults preserve the single-host behavior exactly.
            let listen = args.get_or("listen", "127.0.0.1:0");
            let listener = TcpListener::bind(listen.as_str())
                .with_context(|| format!("shard worker: bind tcp {listen}"))?;
            let addr = listener.local_addr().context("shard worker: local addr")?;
            let announced = match args.get("advertise-host") {
                Some(host) => format!("{host}:{}", addr.port()),
                None => addr.to_string(),
            };
            announce(&format!("tcp {announced}"))?;
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: accept failed: {e}");
                        continue;
                    }
                };
                match handle_conn(&mut stream, &mut state, &mut worker_id, proto) {
                    Ok(true) => continue,
                    Ok(false) => break,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: connection error: {e:#}");
                        continue;
                    }
                }
            }
        }
        #[cfg(unix)]
        ShardTransport::Unix => {
            let dir = args
                .get("socket-dir")
                .map(PathBuf::from)
                .unwrap_or_else(std::env::temp_dir);
            let path = dir.join(format!(
                "sketchy-shard-{worker_id}-{}.sock",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .with_context(|| format!("shard worker: bind {}", path.display()))?;
            announce(&format!("unix {}", path.display()))?;
            loop {
                let mut stream = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: accept failed: {e}");
                        continue;
                    }
                };
                match handle_conn(&mut stream, &mut state, &mut worker_id, proto) {
                    Ok(true) => continue,
                    Ok(false) => break,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: connection error: {e:#}");
                        continue;
                    }
                }
            }
            let _ = std::fs::remove_file(&path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Driver side.
// ---------------------------------------------------------------------------

/// Factory for fresh connections to one worker (reconnects reuse it).
type Dialer = Box<dyn FnMut() -> anyhow::Result<Box<dyn Conn>> + Send>;

/// The driver's (reconnectable) request/reply channel to one worker,
/// over any [`Conn`] transport. Holds the per-shard **in-flight slot**:
/// besides the usual strict request/response traffic, at most one
/// RefreshAhead request may be parked with its reply unread.
struct ShardChannel {
    shard: usize,
    dial: Dialer,
    conn: Option<Box<dyn Conn>>,
    /// Encoded frame of the last request, replayed after a reconnect
    /// (safe: the worker deduplicates steps and refresh-aheads by `t`).
    last_req: Vec<u8>,
    /// Wire protocol version from the worker's greeting (0 = never
    /// connected).
    proto: u32,
    /// RefreshAhead capability from the worker's greeting.
    overlap: bool,
    /// Delta-compression capability from the worker's greeting
    /// (v3+ greetings only; v2/v1 greetings report none).
    compress: bool,
    /// Typed block-state capability (v4 `HelloV4` only): the worker
    /// serves `StepV4`/`StateSnap`/`StateRestore` frames.
    state: bool,
    /// Membership capability (v5 `HelloV5` only): the worker serves
    /// `Adopt` frames and can be re-seated as another shard.
    member: bool,
    /// Heartbeat capability (v6 `HelloV6` only): the worker answers
    /// `Ping` probes, so the link can run supervised.
    heartbeat: bool,
    /// Liveness supervision enabled for this link (elastic fleet, all
    /// links heartbeat-capable, nonzero deadline): reply waits poll in
    /// heartbeat quanta on the injected clock instead of one blocking
    /// read, and deadline silence escalates instead of reconnecting.
    supervised: bool,
    /// Resolved timing knobs for this link.
    timeouts: LinkTimeouts,
    /// Injectable time source for the supervised reply loop.
    clock: Arc<dyn Clock>,
    /// Bumped on every successful (re)connect — the delta codec
    /// compares it against the generation its baselines were taken on
    /// and resyncs with full frames after any reconnect.
    generation: u64,
    /// `t_next` of a sent-but-unjoined RefreshAhead request.
    pending_refresh: Option<u64>,
}

impl ShardChannel {
    fn new(
        shard: usize,
        dial: Dialer,
        timeouts: LinkTimeouts,
        clock: Arc<dyn Clock>,
    ) -> ShardChannel {
        ShardChannel {
            shard,
            dial,
            conn: None,
            last_req: Vec::new(),
            proto: 0,
            overlap: false,
            compress: false,
            state: false,
            member: false,
            heartbeat: false,
            supervised: false,
            timeouts,
            clock,
            generation: 0,
            pending_refresh: None,
        }
    }

    fn connect(&mut self) -> anyhow::Result<()> {
        let mut conn = (self.dial)()?;
        // Bound every reply wait: a wedged worker becomes a shard-named
        // error (after one reconnect attempt) instead of a frozen driver.
        let _ = conn.set_timeout(Some(self.timeouts.reply));
        match wire::read_msg(&mut conn).context("read worker hello")? {
            WireMsg::Hello { worker_id } if worker_id as usize == self.shard => {
                self.proto = 1;
                self.overlap = false;
                self.compress = false;
                self.state = false;
                self.member = false;
                self.heartbeat = false;
            }
            WireMsg::HelloV2 { worker_id, proto, overlap }
                if worker_id as usize == self.shard =>
            {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = false;
                self.state = false;
                self.member = false;
                self.heartbeat = false;
            }
            WireMsg::HelloV3 { worker_id, proto, overlap, compress }
                if worker_id as usize == self.shard =>
            {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = compress;
                self.state = false;
                self.member = false;
                self.heartbeat = false;
            }
            WireMsg::HelloV4 { worker_id, proto, overlap, compress, state }
                if worker_id as usize == self.shard =>
            {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = compress;
                self.state = state;
                self.member = false;
                self.heartbeat = false;
            }
            WireMsg::HelloV5 { worker_id, proto, overlap, compress, state, member }
                if worker_id as usize == self.shard =>
            {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = compress;
                self.state = state;
                self.member = member;
                self.heartbeat = false;
            }
            WireMsg::HelloV6 { worker_id, proto, overlap, compress, state, member, heartbeat }
                if worker_id as usize == self.shard =>
            {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = compress;
                self.state = state;
                self.member = member;
                self.heartbeat = heartbeat;
            }
            WireMsg::Hello { worker_id }
            | WireMsg::HelloV2 { worker_id, .. }
            | WireMsg::HelloV3 { worker_id, .. }
            | WireMsg::HelloV4 { worker_id, .. }
            | WireMsg::HelloV5 { worker_id, .. }
            | WireMsg::HelloV6 { worker_id, .. } => {
                bail!("worker identity mismatch: got {worker_id}, want {}", self.shard)
            }
            other => bail!("expected hello, got {other:?}"),
        }
        self.conn = Some(conn);
        self.generation += 1;
        Ok(())
    }

    /// Re-seat this channel onto `shard` by adopting the worker on the
    /// other end (a warm spare): dial, expect a v5 membership-capable
    /// greeting under *any* identity, and hand the worker its new seat
    /// via [`WireMsg::Adopt`]. On success the channel's identity checks,
    /// reply caches, and delta baselines all start fresh.
    fn adopt(&mut self, shard: usize, epoch: u64) -> anyhow::Result<()> {
        self.conn = None;
        self.last_req.clear();
        self.pending_refresh = None;
        let mut conn = (self.dial)()?;
        let _ = conn.set_timeout(Some(self.timeouts.reply));
        match wire::read_msg(&mut conn).context("read spare hello")? {
            WireMsg::HelloV5 { proto, overlap, compress, state, member: true, .. } => {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = compress;
                self.state = state;
                self.member = true;
                self.heartbeat = false;
            }
            WireMsg::HelloV6 {
                proto, overlap, compress, state, member: true, heartbeat, ..
            } => {
                self.proto = proto;
                self.overlap = overlap;
                self.compress = compress;
                self.state = state;
                self.member = true;
                self.heartbeat = heartbeat;
            }
            other => bail!(
                "elastic failover needs a wire protocol v5+ membership-capable spare, \
                 got {other:?}"
            ),
        }
        let msg = WireMsg::Adopt { epoch, shard: shard as u32 };
        wire::write_msg(&mut conn, &msg).context("send adopt")?;
        match wire::read_msg(&mut conn).context("adopt reply")? {
            WireMsg::AdoptOk { epoch: e, shard: s } if e == epoch && s == shard as u32 => {}
            other => bail!("adopt reply mismatch: {other:?}"),
        }
        self.shard = shard;
        self.conn = Some(conn);
        self.generation += 1;
        Ok(())
    }

    fn try_send(&mut self, frame: &[u8]) -> anyhow::Result<()> {
        if self.conn.is_none() {
            self.connect()?;
        }
        let conn = self.conn.as_mut().unwrap();
        conn.write_all(frame).context("write frame")?;
        conn.flush().context("flush frame")?;
        Ok(())
    }

    /// Send a request, reconnecting once on transport failure.
    fn send(&mut self, msg: &WireMsg) -> anyhow::Result<()> {
        let frame = wire::encode_frame(msg)?;
        if let Err(first) = self.try_send(&frame) {
            self.conn = None;
            self.try_send(&frame)
                .with_context(|| format!("resend after transport error ({first:#})"))?;
        }
        self.last_req = frame;
        Ok(())
    }

    /// Receive the pending reply. On transport failure, reconnect and
    /// replay the last request once — the worker's reply caches make the
    /// replay idempotent even if the original request already applied.
    fn recv(&mut self) -> anyhow::Result<WireMsg> {
        if self.supervised {
            return self.recv_supervised();
        }
        let first = match self.conn.as_mut() {
            Some(conn) => wire::read_msg(conn),
            None => Err(anyhow!("not connected")),
        };
        match first {
            Ok(msg) => Ok(msg),
            Err(first) => self.replay_after(first),
        }
    }

    /// Supervised reply wait (elastic v6 fleets): instead of one
    /// blocking read bounded by the reply timeout, poll the link in
    /// heartbeat-sized quanta through a [`FrameReader`] (partial frames
    /// survive across polls) and charge each silent quantum to the
    /// injected clock. A link silent past [`LinkTimeouts::deadline`] is
    /// a *hung worker*: the error surfaces without any reconnect-replay
    /// so the step loop's reactive-migration path replaces the seat
    /// long before the reply timeout would fire. Hard transport
    /// failures (EOF/reset) keep the reconnect-and-replay-once
    /// contract of the plain path.
    fn recv_supervised(&mut self) -> anyhow::Result<WireMsg> {
        let quantum = self.timeouts.heartbeat;
        let deadline = self.timeouts.deadline;
        let start = self.clock.now();
        let mut reader = FrameReader::new();
        let first = 'poll: {
            if self.conn.is_none() {
                break 'poll anyhow!("not connected");
            }
            let _ = self.conn.as_mut().unwrap().set_timeout(Some(quantum));
            loop {
                match reader.poll(self.conn.as_mut().unwrap()) {
                    Ok(Some(msg)) => {
                        let _ =
                            self.conn.as_mut().unwrap().set_timeout(Some(self.timeouts.reply));
                        return Ok(msg);
                    }
                    Ok(None) => {
                        self.clock.on_poll(quantum);
                        if self.clock.now().saturating_sub(start) >= deadline {
                            self.conn = None;
                            bail!(
                                "shard {}: worker silent past the {} ms liveness deadline \
                                 (hung link)",
                                self.shard,
                                deadline.as_millis()
                            );
                        }
                    }
                    Err(e) => break 'poll e,
                }
            }
        };
        self.replay_after(first)
    }

    /// Reconnect and replay the last request once after a transport
    /// failure — the worker's reply caches make the replay idempotent
    /// even if the original request already applied.
    fn replay_after(&mut self, first: anyhow::Error) -> anyhow::Result<WireMsg> {
        self.conn = None;
        let frame = self.last_req.clone();
        ensure!(!frame.is_empty(), "no request to replay after {first:#}");
        self.try_send(&frame)
            .with_context(|| format!("reconnect after transport error ({first:#})"))?;
        let conn = self.conn.as_mut().unwrap();
        wire::read_msg(conn).with_context(|| format!("reply after reconnect ({first:#})"))
    }

    /// Strict liveness probe: send `Ping{seq}` and require the matching
    /// `Pong`. Only issued on idle links (never with a RefreshAhead
    /// reply parked) so the strict request/reply ordering holds.
    fn ping(&mut self, seq: u64) -> anyhow::Result<()> {
        match self.request(&WireMsg::Ping { seq })? {
            WireMsg::Pong { seq: got } if got == seq => Ok(()),
            WireMsg::Pong { seq: got } => {
                bail!("shard {}: pong seq mismatch: got {got}, want {seq}", self.shard)
            }
            WireMsg::Error { message } => bail!("shard {}: ping: {message}", self.shard),
            other => bail!("shard {}: unexpected ping reply: {other:?}", self.shard),
        }
    }

    fn request(&mut self, msg: &WireMsg) -> anyhow::Result<WireMsg> {
        self.send(msg)?;
        self.recv()
    }

    /// Best-effort Shutdown over the live connection (no reconnect
    /// attempts — used on drop). Returns whether the worker acked.
    fn shutdown_quietly(&mut self) -> bool {
        let Some(conn) = self.conn.as_mut() else { return false };
        let _ = conn.set_timeout(Some(Duration::from_secs(2)));
        match wire::encode_frame(&WireMsg::Shutdown) {
            Ok(frame) => {
                conn.write_all(&frame).and_then(|_| conn.flush()).is_ok()
                    && wire::read_msg(conn).is_ok()
            }
            Err(_) => false,
        }
    }
}

/// What backs one shard: a spawned `sketchy shard-worker` process or an
/// in-process thread over the fault-injection transport.
enum WorkerBackend {
    Process {
        /// `None` for a worker the driver *re-adopted* after a crash
        /// resume (`--resume-journal`): the process belongs to a prior
        /// driver incarnation, so there is no handle to reap — shutdown
        /// is by wire `Shutdown` only.
        child: Option<Child>,
        addr: WorkerAddr,
        /// Held so late worker prints land in the pipe instead of EPIPE.
        _stdout: Option<BufReader<ChildStdout>>,
    },
    InProc {
        join: Option<JoinHandle<()>>,
        /// The seat's fault transport, kept so `kill_worker` can refuse
        /// future dials at the link layer — a killed in-proc seat must
        /// not be quietly revivable through its old link.
        transport: Arc<FaultInjectingTransport>,
    },
}

/// Driver-side per-shard delta-codec state (the v3 payload layer).
/// Baselines are tagged with the step they were taken at and advance
/// only on acked traffic, so a replayed frame always decodes against
/// bits both sides agree on; a reconnect (tracked by the channel
/// generation) drops everything and the next encoded step resyncs with
/// full frames.
#[derive(Default)]
struct DeltaCodec {
    /// Upload baseline: per-block (param, grad) bits of the last
    /// *acked* step, tagged with its `t`.
    tx: Option<(u64, BlockBits)>,
    /// Upload sent but not yet acked; promoted to `tx` on `StepOk`.
    tx_pending: Option<(u64, BlockBits)>,
    /// Download baseline: per-block param bits of the last decoded
    /// reply, tagged with its `t`.
    rx: Option<(u64, ParamBits)>,
    /// Channel generation the baselines belong to.
    generation: u64,
}

impl DeltaCodec {
    /// Heap bytes held by the baselines (driver-side memory accounting).
    fn mem_bytes(&self) -> usize {
        let pair_map = |m: &Option<(u64, BlockBits)>| {
            m.as_ref()
                .map(|(_, b)| b.values().map(|(p, g)| (p.len() + g.len()) * 8).sum::<usize>())
                .unwrap_or(0)
        };
        let rx = self
            .rx
            .as_ref()
            .map(|(_, b)| b.values().map(|p| p.len() * 8).sum::<usize>())
            .unwrap_or(0);
        pair_map(&self.tx) + pair_map(&self.tx_pending) + rx
    }
}

/// One shard: its channel plus whatever runs the worker.
struct WorkerHandle {
    channel: ShardChannel,
    backend: WorkerBackend,
    /// v3 payload-layer state (inert on full-frame links).
    delta: DeltaCodec,
}

impl WorkerHandle {
    /// Join-and-discard a parked RefreshAhead reply, if any — the
    /// cancel path, and the barrier keeping the strict request/response
    /// wire clear before any other request goes out. Discarding is
    /// bitwise-safe: the step's own refresh slot recomputes identical
    /// roots from unchanged statistics, and the accounting counts that
    /// in-step refresh exactly once.
    fn drain_pending_refresh(&mut self) {
        if self.channel.pending_refresh.take().is_some() {
            // A failed drain leaves conn = None; the next request dials
            // a fresh connection, which starts with no queued replies.
            let _ = self.channel.recv();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        // Clear the wire, then graceful stop: Shutdown over the live
        // connection, short grace period, then SIGKILL as the backstop.
        self.drain_pending_refresh();
        let graceful = self.channel.shutdown_quietly();
        match &mut self.backend {
            WorkerBackend::Process { child, addr, .. } => {
                if let Some(child) = child.as_mut() {
                    if graceful {
                        // Capped exponential backoff while draining: same
                        // 2 s grace window, far fewer wakeups than the old
                        // fixed 10 ms spin. Timed on the channel's
                        // injected clock, like every other deadline.
                        let clock = self.channel.clock.clone();
                        let mut backoff = Backoff::new(DRAIN_BACKOFF_BASE, DRAIN_BACKOFF_CAP);
                        let deadline = clock.now() + Duration::from_secs(2);
                        loop {
                            match child.try_wait() {
                                Ok(Some(_)) => break,
                                Ok(None) if clock.now() < deadline => {
                                    clock.sleep(backoff.next());
                                }
                                _ => {
                                    let _ = child.kill();
                                    let _ = child.wait();
                                    break;
                                }
                            }
                        }
                    } else {
                        let _ = child.kill();
                        let _ = child.wait();
                    }
                }
                #[cfg(unix)]
                if let WorkerAddr::Unix(path) = addr {
                    let _ = std::fs::remove_file(path);
                }
                #[cfg(not(unix))]
                let _ = addr;
            }
            WorkerBackend::InProc { join, .. } => {
                if graceful {
                    if let Some(j) = join.take() {
                        let _ = j.join();
                    }
                }
                // Not graceful: the thread parks on its acceptor until
                // the transport drops; detach instead of hanging here.
            }
        }
    }
}

/// Spawn one worker process — directly, or through the launcher
/// command template (ssh and friends) — and read its announced listen
/// address off the spawned command's stdout. Transient launch failures
/// (spawn errors, a worker dying before its announcement) are retried
/// up to [`SPAWN_ATTEMPTS`] times with capped deterministic backoff;
/// a template that cannot be rendered fails fast, and exhaustion
/// surfaces a shard-named error.
fn spawn_process_worker(
    launch: &ShardLaunch,
    shard: usize,
    timeouts: LinkTimeouts,
    clock: Arc<dyn Clock>,
) -> anyhow::Result<WorkerHandle> {
    let worker_args: Vec<String> = vec![
        "shard-worker".into(),
        "--worker-id".into(),
        shard.to_string(),
        "--transport".into(),
        launch.transport.to_string(),
        "--proto-version".into(),
        launch.proto.to_string(),
    ];
    let (program, args) = match &launch.launch {
        None => (launch.program.clone(), worker_args),
        Some(template) => render_launch_command(template, &launch.program, shard, &worker_args)
            .with_context(|| format!("shard {shard}: render launch template"))?,
    };
    let mut backoff = Backoff::new(SPAWN_BACKOFF_BASE, SPAWN_BACKOFF_CAP);
    let mut last_err = None;
    for attempt in 1..=SPAWN_ATTEMPTS {
        match try_spawn_worker(&program, &args, shard, timeouts, clock.clone()) {
            Ok(handle) => return Ok(handle),
            Err(e) => {
                last_err = Some(e);
                if attempt < SPAWN_ATTEMPTS {
                    clock.sleep(backoff.next());
                }
            }
        }
    }
    Err(last_err.unwrap()).with_context(|| {
        format!("shard {shard}: worker launch failed after {SPAWN_ATTEMPTS} attempts")
    })
}

/// One worker-launch attempt: spawn, await the announced listen
/// address, build the channel.
fn try_spawn_worker(
    program: &std::path::Path,
    args: &[String],
    shard: usize,
    timeouts: LinkTimeouts,
    clock: Arc<dyn Clock>,
) -> anyhow::Result<WorkerHandle> {
    let mut cmd = Command::new(program);
    cmd.args(args)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawn {} shard-worker", program.display()))?;
    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| anyhow!("worker stdout pipe missing"))?;
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).context("read worker handshake")?;
        if n == 0 {
            let _ = child.kill();
            let _ = child.wait();
            bail!("worker exited before announcing a listen address");
        }
        if let Some(addr) = parse_listen_line(&line) {
            break addr;
        }
        // Tolerate stray prints ahead of the announcement.
    };
    let dial_addr_copy = addr.clone();
    let connect = timeouts.connect;
    let channel = ShardChannel::new(
        shard,
        Box::new(move || dial_addr(&dial_addr_copy, connect)),
        timeouts,
        clock,
    );
    Ok(WorkerHandle {
        channel,
        backend: WorkerBackend::Process { child: Some(child), addr, _stdout: Some(reader) },
        delta: DeltaCodec::default(),
    })
}

/// Dial + handshake an *already running* worker at a journaled address
/// — the crash-resume re-adoption path. The worker keeps listening
/// across driver deaths, so a relaunched driver (`--resume-journal`)
/// re-seats the surviving fleet instead of spawning a fresh one. The
/// returned handle has no child process: shutdown is by wire only.
fn adopt_process_worker(
    repr: &str,
    shard: usize,
    epoch: u64,
    timeouts: LinkTimeouts,
    clock: Arc<dyn Clock>,
) -> anyhow::Result<WorkerHandle> {
    let addr = WorkerAddr::from_journal_repr(repr)
        .ok_or_else(|| anyhow!("shard {shard}: bad journaled worker address {repr:?}"))?;
    let dial_addr_copy = addr.clone();
    let connect = timeouts.connect;
    let mut channel = ShardChannel::new(
        shard,
        Box::new(move || dial_addr(&dial_addr_copy, connect)),
        timeouts,
        clock,
    );
    channel
        .adopt(shard, epoch)
        .with_context(|| format!("shard {shard}: re-adopt journaled worker at {repr}"))?;
    Ok(WorkerHandle {
        channel,
        backend: WorkerBackend::Process { child: None, addr, _stdout: None },
        delta: DeltaCodec::default(),
    })
}

/// Build the Init message for one shard's owned blocks.
fn init_msg_for(
    owned: &[usize],
    blocks: &[Block],
    kind: UnitKind,
    base: &ShampooConfig,
    worker_threads: usize,
) -> WireMsg {
    let specs: Vec<BlockSpec> = owned
        .iter()
        .map(|&i| {
            let (rows, cols) = blocks[i].shape();
            BlockSpec { index: i as u32, rows: rows as u32, cols: cols as u32 }
        })
        .collect();
    WireMsg::Init(InitMsg {
        kind: kind.code(),
        rank: kind.rank() as u32,
        beta2: base.beta2,
        eps: base.eps,
        one_sided: base.one_sided,
        graft: base.graft.code(),
        threads: worker_threads as u32,
        ekfac: base.ekfac,
        blocks: specs,
    })
}

/// Driver-side block table for validating returned v4 state payloads:
/// the same shape/kind/rank facts `init_msg_for` ships to the workers,
/// kept locally so a hostile or corrupt snapshot reply can be rejected
/// before any payload resolution allocates.
fn expects_for(blocks: &[Block], kind: UnitKind, base: &ShampooConfig) -> Vec<StateExpect> {
    blocks
        .iter()
        .map(|b| {
            let (rows, cols) = b.shape();
            StateExpect {
                rows,
                cols,
                kind: kind.code(),
                rank: kind.rank(),
                one_sided: base.one_sided,
            }
        })
        .collect()
}

/// Drive one shard's Init request/reply.
fn init_worker(w: &mut WorkerHandle, shard: usize, msg: &WireMsg) -> anyhow::Result<()> {
    match w.channel.request(msg).with_context(|| format!("shard {shard}: init"))? {
        WireMsg::Ok => Ok(()),
        WireMsg::Error { message } => bail!("shard {shard}: init failed: {message}"),
        other => bail!("shard {shard}: unexpected init reply {other:?}"),
    }
}

/// `threads = 0` (auto) means "all cores" — but N colocated workers
/// each doing that would oversubscribe the host N-fold. Split the auto
/// budget across shards; an explicit knob passes through untouched.
/// Thread counts never change the numbers.
fn split_thread_budget(threads: usize, shards: usize) -> usize {
    if threads == 0 {
        (crate::tensor::ops::num_threads() / shards).max(1)
    } else {
        threads
    }
}

// ---------------------------------------------------------------------------
// Elastic fleet bookkeeping.
// ---------------------------------------------------------------------------

/// Cumulative elastic-fleet event counters, readable through
/// [`FleetControl::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetStats {
    /// Seats migrated to a replacement worker.
    pub migrations: usize,
    /// Journal steps replayed across all migrations.
    pub migrated_steps: usize,
    /// Encoded bytes of `StateRestore` frames shipped during migrations.
    pub migrated_state_bytes: usize,
    /// Assignment re-cuts applied at sync points.
    pub rebalances: usize,
}

/// Shared driver-side fleet flags: which seats are known dead, the
/// current membership epoch, staged rebalance weights, and the event
/// counters. Shared (`Arc`) between the executor and any number of
/// [`FleetControl`] handles.
struct FleetFlags {
    dead: Mutex<Vec<bool>>,
    epoch: AtomicU64,
    staged: Mutex<Option<Vec<f64>>>,
    stats: Mutex<FleetStats>,
}

impl FleetFlags {
    fn new(seats: usize) -> FleetFlags {
        FleetFlags {
            dead: Mutex::new(vec![false; seats]),
            epoch: AtomicU64::new(0),
            staged: Mutex::new(None),
            stats: Mutex::new(FleetStats::default()),
        }
    }

    /// Flag reads/writes must survive a poisoned-by-panic lock: the
    /// flags are plain values with no invariants spanning the lock.
    fn is_dead(&self, seat: usize) -> bool {
        let dead = self.dead.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        dead.get(seat).copied().unwrap_or(false)
    }

    fn set_dead(&self, seat: usize, val: bool) {
        let mut dead = self.dead.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(d) = dead.get_mut(seat) {
            *d = val;
        }
    }

    fn dead_seats(&self) -> Vec<usize> {
        let dead = self.dead.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        dead.iter().enumerate().filter(|(_, d)| **d).map(|(s, _)| s).collect()
    }

    fn take_staged(&self) -> Option<Vec<f64>> {
        self.staged.lock().unwrap_or_else(std::sync::PoisonError::into_inner).take()
    }

    fn bump_stats(&self, f: impl FnOnce(&mut FleetStats)) {
        let mut stats = self.stats.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        f(&mut stats);
    }
}

/// Cloneable control handle over a [`ShardExecutor`]'s fleet: fault
/// injection (kill, drop connections), membership introspection
/// (epoch, stats), and operator-staged rebalancing — usable while the
/// executor itself is owned by an engine.
#[derive(Clone)]
pub struct FleetControl {
    workers: Arc<Mutex<Vec<WorkerHandle>>>,
    flags: Arc<FleetFlags>,
}

impl FleetControl {
    /// Kill one worker: process workers are SIGKILLed, in-proc harness
    /// workers have their link severed and their seat marked dead (the
    /// harness thread idles unadopted). Under elastic membership the
    /// next step migrates the seat to a spare; without it the next step
    /// surfaces an error naming the shard.
    pub fn kill_worker(&self, shard: usize) -> anyhow::Result<()> {
        let mut workers =
            self.workers.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let w = workers.get_mut(shard).ok_or_else(|| anyhow!("no shard {shard}"))?;
        self.flags.set_dead(shard, true);
        // A parked RefreshAhead on a dead seat can never be joined —
        // drop the slot so the blocks stay refresh-due in-step.
        w.channel.pending_refresh = None;
        w.channel.conn = None;
        match &mut w.backend {
            WorkerBackend::Process { child, .. } => match child.as_mut() {
                Some(child) => {
                    child.kill().context("kill worker")?;
                    let _ = child.wait();
                }
                None => bail!("shard {shard}: re-adopted worker has no process handle"),
            },
            WorkerBackend::InProc { transport, .. } => {
                // Refuse future dials at the link layer too: the dead
                // seat must not be revivable through its old transport.
                transport.kill();
            }
        }
        Ok(())
    }

    /// Fault injection for tests: drop every driver-side connection.
    /// The next request reconnects transparently (workers keep state).
    pub fn drop_connections(&self) {
        let mut workers =
            self.workers.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        for w in workers.iter_mut() {
            w.channel.conn = None;
        }
    }

    /// Current membership epoch (0 until the first replace/rebalance).
    pub fn epoch(&self) -> u64 {
        self.flags.epoch.load(Ordering::SeqCst)
    }

    /// Cumulative elastic-fleet event counters.
    pub fn stats(&self) -> FleetStats {
        *self.flags.stats.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Stage an explicit rebalance (per-seat weights; higher = more
    /// blocks), applied at the executor's next sync point.
    pub fn request_rebalance(&self, weights: Vec<f64>) {
        let mut staged =
            self.flags.staged.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        *staged = Some(weights);
    }
}

/// One journaled step: everything needed to replay the step to a
/// replacement worker as a plain (v1 full-frame) `Step` — per-block
/// pre-step payload slices plus the *effective* refresh flags
/// (`refresh_due` OR refreshed-ahead, so an in-step refresh on replay
/// reproduces the ahead-refreshed roots bitwise).
struct JournalStep {
    t: u64,
    scale: f64,
    preconditioning: bool,
    stat_due: bool,
    lr: f64,
    beta1: f64,
    weight_decay: f64,
    flags: Vec<bool>,
    params: Vec<Matrix>,
    grads: Vec<Matrix>,
}

/// Bounded migration journal: the driver's last-acked per-block state
/// snapshots (taken every `failover_budget` steps at a wire-quiescent
/// point) plus every step journaled since. A replacement worker is
/// restored from `snaps` and replayed through `steps` — at most
/// `failover_budget` of them.
struct StepJournal {
    /// Step whose post-step state `snaps` captures (0 = pre-training).
    sync_t: u64,
    /// Last-acked snapshot per global block (`None` until the first
    /// sync point: a fresh Init *is* the t=0 state).
    snaps: Option<Vec<BlockStateSnap>>,
    steps: Vec<JournalStep>,
}

/// Per-seat accounting of the refresh-ahead joined for step `t_next`:
/// which blocks were refreshed ahead, and how many each seat reported
/// (already counted by the engine — a migrated seat's in-step replay
/// refreshes must not be double-counted).
struct AheadRecord {
    t_next: u64,
    refreshed: Vec<bool>,
    counts: Vec<usize>,
}

/// Driver-side elastic runtime: membership controller, warm spares,
/// the migration journal, and the last joined refresh-ahead record.
struct ElasticRuntime {
    controller: MembershipController,
    spares: Vec<WorkerHandle>,
    /// Launch plan for spawning replacement workers once the warm
    /// spares run out (process fleets only; in-proc fleets are limited
    /// to the transports handed in at launch).
    launch: Option<ShardLaunch>,
    /// Next `--worker-id` for a cold-spawned replacement.
    next_spare_id: usize,
    journal: StepJournal,
    ahead: Option<AheadRecord>,
    /// Durable write-ahead journal path (`--journal`); `None` keeps the
    /// PR-7 in-memory-only journal.
    wal_path: Option<String>,
    /// Open write-ahead journal, created lazily at the first journaled
    /// step (so a `--resume-journal` load is never clobbered by the
    /// executor's construction).
    wal: Option<JournalWriter>,
    /// Link knobs + clock for channels built after launch (cold-spawned
    /// replacements), and whether their links run supervised.
    timeouts: LinkTimeouts,
    clock: Arc<dyn Clock>,
    supervised: bool,
}

/// [`BlockExecutor`] driving blocks across worker processes (or
/// in-process harness workers — see [`ShardExecutor::launch_in_proc_with`]).
pub struct ShardExecutor {
    /// Mutex for interior mutability (`mem_bytes` RPCs through `&self`);
    /// Arc so [`FleetControl`] handles stay valid while an engine owns
    /// the executor.
    workers: Arc<Mutex<Vec<WorkerHandle>>>,
    /// shard → owned global block indices.
    assignment: Vec<Vec<usize>>,
    /// Total engine block count (sizes RefreshAhead flag vectors).
    n_blocks: usize,
    /// Human transport label: `tcp`, `unix`, or `in-proc`.
    transport: String,
    /// Every worker reported RefreshAhead capability at handshake.
    overlap: bool,
    /// Delta-compressed payloads requested; applied per link to the
    /// workers that reported the capability (v2/v1 links keep full
    /// frames — the degrade matrix).
    compress: bool,
    /// Every worker reported the typed block-state capability (v4
    /// `HelloV4`); snapshot/restore refuses to run without it.
    state: bool,
    /// Driver's own copy of the block table, one [`StateExpect`] per
    /// global block — returned state payloads are validated against
    /// this *before* any payload resolution allocates.
    expects: Vec<StateExpect>,
    /// Construction facts needed to re-Init a migrated or rebalanced
    /// seat without the original `&[Block]` slice.
    kind: UnitKind,
    base: ShampooConfig,
    worker_threads: usize,
    flags: Arc<FleetFlags>,
    /// `Some` iff elastic membership was requested at launch.
    elastic: Option<ElasticRuntime>,
    /// Every worker reported the v6 heartbeat capability.
    heartbeat: bool,
    /// Per-seat liveness ledger; `Some` iff the fleet runs supervised
    /// (elastic + every link heartbeat-capable + nonzero deadline).
    supervisor: Option<Supervisor>,
    /// Injected time source shared with every channel's supervised
    /// reply loop.
    clock: Arc<dyn Clock>,
}

/// Map a poisoned driver-side worker-table lock into the shard-failure
/// error contract instead of an opaque `PoisonError` panic. The lock
/// only poisons when an earlier panic tore through a worker RPC, so
/// the table's consistency is unknown — step paths must refuse it.
fn workers_guard(
    workers: &Mutex<Vec<WorkerHandle>>,
) -> anyhow::Result<std::sync::MutexGuard<'_, Vec<WorkerHandle>>> {
    workers.lock().map_err(|_| {
        anyhow!(
            "shard executor: worker table lock poisoned by an earlier panic \
             (a failed step is terminal; rebuild the engine and its workers)"
        )
    })
}

/// Build the Init message for a seat's owned blocks from the driver's
/// own block table (shapes live in `expects`) — the migration/rebalance
/// equivalent of `init_msg_for`, usable without the engine's `&[Block]`.
fn init_msg_from_expects(
    owned: &[usize],
    expects: &[StateExpect],
    kind: UnitKind,
    base: &ShampooConfig,
    worker_threads: usize,
) -> WireMsg {
    let specs: Vec<BlockSpec> = owned
        .iter()
        .map(|&i| BlockSpec {
            index: i as u32,
            rows: expects[i].rows as u32,
            cols: expects[i].cols as u32,
        })
        .collect();
    WireMsg::Init(InitMsg {
        kind: kind.code(),
        rank: kind.rank() as u32,
        beta2: base.beta2,
        eps: base.eps,
        one_sided: base.one_sided,
        graft: base.graft.code(),
        threads: worker_threads as u32,
        ekfac: base.ekfac,
        blocks: specs,
    })
}

impl ShardExecutor {
    /// Spawn `launch.shards` workers (capped at the block count), assign
    /// contiguous block runs, and initialize each worker's states.
    /// `membership` turns on the elastic fleet: `membership.spares`
    /// extra workers are spawned warm (announced but uninitialized) and
    /// the driver journals steps between bounded sync points so a dead
    /// seat can be migrated deterministically.
    pub fn launch_with(
        launch: &ShardLaunch,
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        threads: usize,
        membership: &MembershipConfig,
    ) -> anyhow::Result<ShardExecutor> {
        ensure!(launch.shards >= 1, "shard launch requires at least one shard");
        ensure!(!blocks.is_empty(), "shard launch requires at least one block");
        let shards = launch.shards.min(blocks.len());
        let assignment = ContiguousAssignment.assign(blocks.len(), shards);
        let worker_threads = split_thread_budget(threads, shards);
        let timeouts = membership.timeouts;
        let clock: Arc<dyn Clock> = Arc::new(SystemClock::new());
        let mut workers = Vec::with_capacity(shards);
        for (shard, owned) in assignment.iter().enumerate() {
            // Crash resume: a journaled worker address means a previous
            // driver incarnation left a live worker listening there —
            // re-adopt it instead of spawning a duplicate. Any failure
            // (worker gone, address recycled) falls back to a fresh
            // spawn; either way the seat is re-Init'd from scratch, so
            // the two paths are bitwise identical.
            let journaled = membership
                .resume_addrs
                .as_ref()
                .and_then(|a| a.get(shard))
                .filter(|r| !r.is_empty());
            let mut w = match journaled {
                Some(repr) => {
                    match adopt_process_worker(repr, shard, 0, timeouts, clock.clone()) {
                        Ok(w) => w,
                        Err(e) => {
                            eprintln!(
                                "shard {shard}: journaled worker at {repr} not adoptable \
                                 ({e:#}); spawning fresh"
                            );
                            spawn_process_worker(launch, shard, timeouts, clock.clone())
                                .with_context(|| format!("shard {shard}: spawn worker"))?
                        }
                    }
                }
                None => spawn_process_worker(launch, shard, timeouts, clock.clone())
                    .with_context(|| format!("shard {shard}: spawn worker"))?,
            };
            init_worker(&mut w, shard, &init_msg_for(owned, blocks, kind, base, worker_threads))?;
            workers.push(w);
        }
        let mut spares = Vec::with_capacity(membership.spares);
        for k in 0..membership.spares {
            let id = shards + k;
            spares.push(
                spawn_process_worker(launch, id, timeouts, clock.clone())
                    .with_context(|| format!("spare worker {id}: spawn"))?,
            );
        }
        ShardExecutor::assemble(
            workers,
            assignment,
            blocks.len(),
            launch.transport.to_string(),
            launch.compress,
            expects_for(blocks, kind, base),
            kind,
            base.clone(),
            worker_threads,
            membership,
            spares,
            Some(launch.clone()),
            clock,
        )
    }

    /// Non-elastic [`ShardExecutor::launch_with`].
    #[deprecated(note = "use optim::ExecutorBuilder (or ShardExecutor::launch_with)")]
    pub fn launch(
        launch: &ShardLaunch,
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        threads: usize,
    ) -> anyhow::Result<ShardExecutor> {
        let membership = MembershipConfig::default();
        ShardExecutor::launch_with(launch, blocks, kind, base, threads, &membership)
    }

    /// Non-elastic [`ShardExecutor::launch_in_proc_with`].
    #[deprecated(note = "use optim::ExecutorBuilder (or ShardExecutor::launch_in_proc_with)")]
    #[allow(clippy::too_many_arguments)]
    pub fn launch_in_proc(
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        threads: usize,
        transports: &[Arc<FaultInjectingTransport>],
        proto: u32,
        compress: bool,
    ) -> anyhow::Result<ShardExecutor> {
        ShardExecutor::launch_in_proc_with(
            blocks,
            kind,
            base,
            threads,
            transports,
            proto,
            compress,
            &MembershipConfig::default(),
        )
    }

    /// Test/bench-facing variant of [`ShardExecutor::launch`]: shard
    /// "workers" are threads in this process, served over the in-memory
    /// [`FaultInjectingTransport`] — no sockets, no child processes — so
    /// integration tests can script transport faults at exact frame
    /// indices. One transport per shard (shard count = transport count,
    /// capped at the block count). `proto` pins the workers' wire
    /// protocol version ([`PROTO_VERSION`] normally; 1 emulates a
    /// pre-RefreshAhead worker for the degrade-to-sync matrix, 2 a
    /// pre-compression worker for the full-frame degrade matrix);
    /// `compress` requests the v3 delta payload layer (inert below v3).
    /// This doubles as the scriptable in-test *launcher*: the same
    /// worker state machine the process/ssh launchers run, mounted on
    /// threads over the fault harness. Under elastic membership the
    /// *last* `membership.spares` transports back warm spare workers
    /// (announced, never initialized) instead of seats.
    #[allow(clippy::too_many_arguments)]
    pub fn launch_in_proc_with(
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        threads: usize,
        transports: &[Arc<FaultInjectingTransport>],
        proto: u32,
        compress: bool,
        membership: &MembershipConfig,
    ) -> anyhow::Result<ShardExecutor> {
        ShardExecutor::launch_in_proc_clocked(
            blocks,
            kind,
            base,
            threads,
            transports,
            proto,
            compress,
            membership,
            Arc::new(SystemClock::new()),
        )
    }

    /// [`ShardExecutor::launch_in_proc_with`] with an injected [`Clock`]
    /// — the deterministic-supervision harness: a virtual clock makes
    /// heartbeat/deadline decisions advance only on observed polls, so
    /// hung-worker tests run without wall-clock sleeps.
    #[allow(clippy::too_many_arguments)]
    pub fn launch_in_proc_clocked(
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        threads: usize,
        transports: &[Arc<FaultInjectingTransport>],
        proto: u32,
        compress: bool,
        membership: &MembershipConfig,
        clock: Arc<dyn Clock>,
    ) -> anyhow::Result<ShardExecutor> {
        ensure!(!transports.is_empty(), "in-proc shard launch requires at least one transport");
        ensure!(!blocks.is_empty(), "shard launch requires at least one block");
        ensure!(
            (1..=PROTO_VERSION).contains(&proto),
            "unsupported wire protocol v{proto} (this build speaks v1..=v{PROTO_VERSION})"
        );
        ensure!(
            transports.len() > membership.spares,
            "in-proc shard launch: {} transports cannot cover {} spares plus at least one seat",
            transports.len(),
            membership.spares
        );
        let shards = (transports.len() - membership.spares).min(blocks.len());
        let assignment = ContiguousAssignment.assign(blocks.len(), shards);
        let worker_threads = split_thread_budget(threads, shards);
        let mount = |slot: usize| -> anyhow::Result<WorkerHandle> {
            let transport = &transports[slot];
            let acceptor = transport
                .take_acceptor()
                .ok_or_else(|| anyhow!("shard {slot}: transport acceptor already taken"))?;
            let wid = slot as u32;
            let join = std::thread::Builder::new()
                .name(format!("sketchy-inproc-shard-{slot}"))
                .spawn(move || {
                    // The serve loop of `serve_worker`, minus the socket:
                    // block state persists across connections, transport
                    // errors leave the worker awaiting a redial. The
                    // worker id is mutable — a v5 Adopt re-seats it.
                    let mut wid = wid;
                    let mut state: Option<WorkerState> = None;
                    while let Ok(mut conn) = acceptor.recv() {
                        match handle_conn(&mut conn, &mut state, &mut wid, proto) {
                            Ok(true) => continue,
                            Ok(false) => break,
                            Err(e) => {
                                // Same surfacing as serve_worker: scripted
                                // faults kill connections on purpose, but a
                                // genuine protocol error must leave a trace.
                                eprintln!(
                                    "in-proc shard worker {wid}: connection error: {e:#}"
                                );
                                continue;
                            }
                        }
                    }
                })
                .with_context(|| format!("shard {slot}: spawn in-proc worker"))?;
            let dial_t = Arc::clone(transport);
            let channel = ShardChannel::new(
                slot,
                Box::new(move || {
                    let conn = dial_t.dial().context("dial in-proc transport")?;
                    Ok(Box::new(conn) as Box<dyn Conn>)
                }),
                membership.timeouts,
                clock.clone(),
            );
            Ok(WorkerHandle {
                channel,
                backend: WorkerBackend::InProc {
                    join: Some(join),
                    transport: Arc::clone(transport),
                },
                delta: DeltaCodec::default(),
            })
        };
        let mut workers = Vec::with_capacity(shards);
        for (shard, owned) in assignment.iter().enumerate() {
            let mut w = mount(shard)?;
            init_worker(&mut w, shard, &init_msg_for(owned, blocks, kind, base, worker_threads))?;
            workers.push(w);
        }
        let mut spares = Vec::with_capacity(membership.spares);
        for k in 0..membership.spares {
            spares.push(mount(shards + k)?);
        }
        ShardExecutor::assemble(
            workers,
            assignment,
            blocks.len(),
            "in-proc".to_string(),
            compress,
            expects_for(blocks, kind, base),
            kind,
            base.clone(),
            worker_threads,
            membership,
            spares,
            None,
            clock,
        )
    }

    /// Shared tail of the launch paths: record the per-worker capability
    /// reports (with a one-time notice for degraded workers), stand up
    /// the elastic runtime when requested, and build the executor.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        mut workers: Vec<WorkerHandle>,
        assignment: Vec<Vec<usize>>,
        n_blocks: usize,
        transport: String,
        compress: bool,
        expects: Vec<StateExpect>,
        kind: UnitKind,
        base: ShampooConfig,
        worker_threads: usize,
        membership: &MembershipConfig,
        spares: Vec<WorkerHandle>,
        launch: Option<ShardLaunch>,
        clock: Arc<dyn Clock>,
    ) -> anyhow::Result<ShardExecutor> {
        let overlap = workers.iter().all(|w| w.channel.overlap);
        let state = workers.iter().all(|w| w.channel.state);
        let member = workers.iter().all(|w| w.channel.member);
        let heartbeat = workers.iter().all(|w| w.channel.heartbeat);
        for w in &workers {
            if !w.channel.overlap {
                // Neutral capability report: whether this *disables*
                // anything is the engine's call (`resolve_overlap`
                // prints the one-time knob notice when overlap was
                // actually requested).
                eprintln!(
                    "shard {}: worker greeted with wire protocol v{} (no RefreshAhead \
                     capability)",
                    w.channel.shard,
                    w.channel.proto.max(1)
                );
            }
        }
        // EKFAC correctors travel in Init and in every typed state
        // payload, so the whole fleet — seats and warm spares alike
        // (a spare can be promoted into any seat) — must speak wire
        // protocol v7. Refuse at construction rather than degrade
        // silently mid-run.
        if base.ekfac {
            ensure!(
                workers.iter().chain(spares.iter()).all(|w| w.channel.proto >= 7),
                "--ekfac requires every worker link at wire protocol v7 \
                 (a worker greeted below v7; drop --ekfac or unpin --shard-proto)"
            );
        }
        // Liveness supervision: elastic fleet, every link heartbeat-
        // capable, nonzero deadline. Non-elastic fleets keep the plain
        // blocking reply waits (there is no replacement path to
        // escalate into).
        let supervised = membership.elastic()
            && heartbeat
            && membership.timeouts.deadline > Duration::ZERO;
        let mut elastic = if membership.elastic() {
            ensure!(
                member && state,
                "elastic membership requires every worker link at wire protocol v5 \
                 (a worker greeted below v5; drop --shard-spares/--rebalance or unpin \
                 --shard-proto)"
            );
            let next_spare_id = workers.len() + spares.len();
            Some(ElasticRuntime {
                controller: MembershipController::new(membership.clone(), assignment.clone()),
                spares,
                launch,
                next_spare_id,
                journal: StepJournal { sync_t: 0, snaps: None, steps: Vec::new() },
                ahead: None,
                wal_path: membership.journal.clone(),
                wal: None,
                timeouts: membership.timeouts,
                clock: clock.clone(),
                supervised,
            })
        } else {
            None
        };
        for w in workers.iter_mut() {
            w.channel.supervised = supervised && w.channel.heartbeat;
        }
        if let Some(el) = elastic.as_mut() {
            for s in el.spares.iter_mut() {
                s.channel.supervised = supervised && s.channel.heartbeat;
            }
        }
        let seats = workers.len();
        let supervisor =
            supervised.then(|| Supervisor::new(seats, membership.timeouts, clock.now()));
        Ok(ShardExecutor {
            workers: Arc::new(Mutex::new(workers)),
            assignment,
            n_blocks,
            transport,
            overlap,
            compress,
            state,
            expects,
            kind,
            base,
            worker_threads,
            flags: Arc::new(FleetFlags::new(seats)),
            elastic,
            heartbeat,
            supervisor,
            clock,
        })
    }

    /// Worker process count actually launched.
    pub fn shards(&self) -> usize {
        self.assignment.len()
    }

    /// Control handle over this executor's fleet: kill/sever fault
    /// injection, membership epoch + stats, staged rebalancing. Clones
    /// stay valid while an engine owns the executor.
    pub fn control(&self) -> FleetControl {
        FleetControl { workers: Arc::clone(&self.workers), flags: Arc::clone(&self.flags) }
    }

    /// Fault injection for tests: kill one worker. The next step
    /// surfaces an error naming the shard (or, under elastic
    /// membership, migrates the seat to a spare).
    #[deprecated(note = "use ShardExecutor::control() and FleetControl::kill_worker")]
    pub fn kill_worker(&mut self, shard: usize) -> anyhow::Result<()> {
        self.control().kill_worker(shard)
    }

    /// Fault injection for tests: drop every driver-side connection.
    /// The next request reconnects transparently (workers keep state).
    #[deprecated(note = "use ShardExecutor::control() and FleetControl::drop_connections")]
    pub fn drop_connections(&mut self) {
        self.control().drop_connections()
    }

    fn mem_stats_total(&self) -> (usize, usize) {
        // Diagnostics must not die on a poisoned lock — recover the
        // inner table (the accounting reads are safe either way).
        let mut workers = self.workers.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut mem = 0usize;
        let mut second = 0usize;
        for (seat, w) in workers.iter_mut().enumerate() {
            // A killed seat awaiting migration has nothing to report
            // (and an in-proc "killed" worker must not be dialed).
            if self.flags.is_dead(seat) {
                continue;
            }
            // The wire is strict request/response outside the parked
            // RefreshAhead slot — join-and-discard it before any other
            // request.
            w.drain_pending_refresh();
            // Driver-side delta baselines are part of the engine's real
            // footprint too (the workers report their own).
            mem += w.delta.mem_bytes();
            let shard = w.channel.shard;
            match w.channel.request(&WireMsg::MemStats) {
                Ok(WireMsg::MemStatsOk { mem_bytes, second_moment_bytes }) => {
                    mem += mem_bytes as usize;
                    second += second_moment_bytes as usize;
                }
                Ok(other) => {
                    eprintln!("shard {shard}: unexpected memstats reply {other:?}");
                }
                Err(e) => eprintln!("shard {shard}: memstats failed: {e:#}"),
            }
        }
        (mem, second)
    }
}

/// Encode one seat's step frame (delta-compressed when the link and the
/// knob allow it), advancing the seat's delta-codec baselines. Factored
/// out of `step_blocks` so the elastic paths share it verbatim.
#[allow(clippy::too_many_arguments)]
fn encode_step_msg(
    w: &mut WorkerHandle,
    owned: &[usize],
    blocks: &[Block],
    params: &[Matrix],
    grads: &[Matrix],
    ctxs: &[StepCtx],
    common: &StepCtx,
    compress: bool,
) -> WireMsg {
    let t64 = common.t as u64;
    if compress && w.channel.proto >= 3 && w.channel.compress {
        // v3 payload layer. A reconnect since the last encode
        // invalidates nothing semantically (baselines are tagged), but
        // we drop them and resync with full frames anyway — the worker
        // is told to do the same.
        let resync = w.delta.generation != w.channel.generation;
        if resync {
            w.delta = DeltaCodec { generation: w.channel.generation, ..Default::default() };
        }
        let base = w.delta.tx.take().filter(|(bt, _)| bt + 1 == t64);
        let base_t = base.as_ref().map(|(bt, _)| *bt).unwrap_or(0);
        let mut sent: BlockBits = BTreeMap::new();
        let mut entries = Vec::with_capacity(owned.len());
        for &i in owned {
            let b = &blocks[i];
            let (rows, cols) = b.shape();
            let pbits = mat_bits(&params[b.tensor].slice(b.r0, b.r1, b.c0, b.c1));
            let gbits = mat_bits(&grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1));
            let bb = base.as_ref().and_then(|(_, m)| m.get(&(i as u32)));
            entries.push(StepEntryV3 {
                index: i as u32,
                refresh_due: ctxs[i].refresh_due,
                param: DeltaMat::encode(rows, cols, &pbits, bb.map(|(p, _)| p.as_slice())),
                grad: DeltaMat::encode(rows, cols, &gbits, bb.map(|(_, g)| g.as_slice())),
            });
            sent.insert(i as u32, (pbits, gbits));
        }
        w.delta.tx = base;
        w.delta.tx_pending = Some((t64, sent));
        if w.channel.proto >= 4 {
            // v4 typed payloads share the v3 delta/baseline core: the
            // same `DeltaMat` entries travel wrapped in
            // `BlockPayload::Dense` (param/grad are always dense on the
            // step path — sketch factors only travel on the state RPCs).
            WireMsg::StepV4(StepV4Msg {
                t: t64,
                base_t,
                resync,
                scale: common.scale,
                preconditioning: common.preconditioning,
                stat_due: common.stat_due,
                lr: common.lr,
                beta1: common.beta1,
                weight_decay: common.weight_decay,
                entries: entries
                    .into_iter()
                    .map(|e| StepEntryV4::new(e.index, e.refresh_due, e.param, e.grad))
                    .collect(),
            })
        } else {
            WireMsg::StepV3(StepV3Msg {
                t: t64,
                base_t,
                resync,
                scale: common.scale,
                preconditioning: common.preconditioning,
                stat_due: common.stat_due,
                lr: common.lr,
                beta1: common.beta1,
                weight_decay: common.weight_decay,
                entries,
            })
        }
    } else {
        let entries: Vec<StepEntry> = owned
            .iter()
            .map(|&i| {
                let b = &blocks[i];
                StepEntry {
                    index: i as u32,
                    refresh_due: ctxs[i].refresh_due,
                    param: params[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                    grad: grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                }
            })
            .collect();
        WireMsg::Step(StepMsg {
            t: t64,
            scale: common.scale,
            preconditioning: common.preconditioning,
            stat_due: common.stat_due,
            lr: common.lr,
            beta1: common.beta1,
            weight_decay: common.weight_decay,
            entries,
        })
    }
}

/// Validate and scatter one seat's step reply, advancing the seat's
/// delta-codec baselines; returns the reply's refresh count. Factored
/// out of `step_blocks` so the elastic replay path shares it verbatim.
#[allow(clippy::too_many_arguments)]
fn apply_step_reply(
    reply: WireMsg,
    w: &mut WorkerHandle,
    shard: usize,
    owned: &[usize],
    blocks: &[Block],
    params: &mut [Matrix],
    common: &StepCtx,
    compress: bool,
) -> anyhow::Result<usize> {
    let t64 = common.t as u64;
    // A v4 reply is the v3 reply with each entry wrapped in a typed
    // payload; unwrap the mandatory `Dense` layer up front so one arm
    // below handles both protocols.
    let reply = match reply {
        WireMsg::StepOkV4(ok) => {
            let mut entries = Vec::with_capacity(ok.entries.len().min(1 << 16));
            for (index, payload) in ok.entries {
                let BlockPayload::Dense(dm) = payload else {
                    bail!("shard {shard}: v4 step reply for block {index} is not a dense payload");
                };
                entries.push((index, dm));
            }
            WireMsg::StepOkV3(StepOkV3Msg {
                t: ok.t,
                base_t: ok.base_t,
                refreshes: ok.refreshes,
                entries,
            })
        }
        other => other,
    };
    // Ownership bounds: assignments are contiguous runs, so a range
    // check validates each returned index in O(1).
    let (own_lo, own_hi) = match (owned.first(), owned.last()) {
        (Some(&lo), Some(&hi)) => (lo, hi),
        _ => (1, 0), // empty shard: any index is foreign
    };
    // Both reply forms validate t / count / per-block ownership and
    // shape *before* any scatter or payload resolution — the shape
    // bound is what keeps a corrupt or hostile reply from turning a
    // few-byte compressed frame into a giant decompression (the same
    // contract the worker side enforces on uploads). The scatter writes
    // each disjoint block window directly (bitwise — payloads are raw
    // f64 bits, and the delta codec is bit-lossless).
    let refreshes = match reply {
        WireMsg::StepOk(ok) => {
            ensure!(
                ok.t == t64,
                "shard {shard}: reply for step {} while driving step {}",
                ok.t,
                common.t
            );
            ensure!(
                ok.entries.len() == owned.len(),
                "shard {shard}: returned {} blocks, owns {}",
                ok.entries.len(),
                owned.len()
            );
            for (index, m) in &ok.entries {
                let i = *index as usize;
                ensure!(
                    i >= own_lo && i <= own_hi && i < blocks.len(),
                    "shard {shard}: returned foreign block {i}"
                );
                let b = &blocks[i];
                ensure!(
                    m.shape() == b.shape(),
                    "shard {shard}: block {i} shape {:?}, want {:?}",
                    m.shape(),
                    b.shape()
                );
                params[b.tensor].set_slice(b.r0, b.c0, m);
            }
            ok.refreshes as usize
        }
        WireMsg::StepOkV3(ok) => {
            ensure!(
                ok.t == t64,
                "shard {shard}: reply for step {} while driving step {}",
                ok.t,
                common.t
            );
            ensure!(
                ok.entries.len() == owned.len(),
                "shard {shard}: returned {} blocks, owns {}",
                ok.entries.len(),
                owned.len()
            );
            let mut rx_new: ParamBits = BTreeMap::new();
            for (index, dm) in &ok.entries {
                let i = *index as usize;
                ensure!(
                    i >= own_lo && i <= own_hi && i < blocks.len(),
                    "shard {shard}: returned foreign block {i}"
                );
                let b = &blocks[i];
                let (rows, cols) = b.shape();
                ensure!(
                    dm.shape() == (rows, cols),
                    "shard {shard}: block {i} shape {:?}, want {:?}",
                    dm.shape(),
                    b.shape()
                );
                let base = match dm {
                    DeltaMat::Delta { .. } => match &w.delta.rx {
                        Some((bt, map)) if *bt == ok.base_t && ok.base_t != 0 => {
                            Some(map.get(index).ok_or_else(|| {
                                anyhow!(
                                    "shard {shard}: delta reply for block {index} with no \
                                     baseline entry"
                                )
                            })?)
                        }
                        _ => bail!(
                            "shard {shard}: delta reply base t={} does not match the held \
                             baseline",
                            ok.base_t
                        ),
                    },
                    _ => None,
                };
                let bits = dm
                    .resolve(base.map(|b| b.as_slice()))
                    .with_context(|| format!("shard {shard}: block {index} payload"))?;
                params[b.tensor].set_slice(b.r0, b.c0, &bits_matrix(rows, cols, &bits));
                rx_new.insert(*index, bits);
            }
            // Advance the codec baselines only after every entry
            // decoded: the upload is now acked and the download fully
            // resolved.
            if compress && w.channel.proto >= 3 && w.channel.compress {
                w.delta.rx = Some((t64, rx_new));
                if let Some((pt, m)) = w.delta.tx_pending.take() {
                    if pt == t64 {
                        w.delta.tx = Some((pt, m));
                    }
                }
            }
            ok.refreshes as usize
        }
        WireMsg::Error { message } => bail!("shard {shard}: worker error: {message}"),
        other => bail!("shard {shard}: unexpected step reply {other:?}"),
    };
    Ok(refreshes)
}

/// Append step `t` to the elastic journal (replacing a same-`t` entry,
/// so a re-driven step cannot double-journal). Returns the per-seat
/// ahead-refresh counts the last `finish_refresh_ahead` delivered for
/// this step, if any — the reactive migration path subtracts them from
/// a replayed reply's refresh count to keep engine accounting exact.
fn journal_push(
    el: &mut ElasticRuntime,
    blocks: &[Block],
    params: &[Matrix],
    grads: &[Matrix],
    ctxs: &[StepCtx],
    common: &StepCtx,
) -> Option<Vec<usize>> {
    let t64 = common.t as u64;
    let ahead = el.ahead.take().filter(|a| a.t_next == t64);
    // Journal the *effective* refresh flag: a block served by the joined
    // refresh-ahead arrives with refresh_due cleared, but its refresh
    // already happened — the replay must re-run it in-step so the
    // replacement's state matches the fleet's bitwise (ahead roots are
    // computed from the same frozen statistics as in-step roots).
    let flags: Vec<bool> = ctxs
        .iter()
        .enumerate()
        .map(|(i, c)| c.refresh_due || ahead.as_ref().is_some_and(|a| a.refreshed[i]))
        .collect();
    let mut ps = Vec::with_capacity(blocks.len());
    let mut gs = Vec::with_capacity(blocks.len());
    for b in blocks {
        ps.push(params[b.tensor].slice(b.r0, b.r1, b.c0, b.c1));
        gs.push(grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1));
    }
    if el.journal.steps.last().map(|s| s.t) == Some(t64) {
        el.journal.steps.pop();
    }
    el.journal.steps.push(JournalStep {
        t: t64,
        scale: common.scale,
        preconditioning: common.preconditioning,
        stat_due: common.stat_due,
        lr: common.lr,
        beta1: common.beta1,
        weight_decay: common.weight_decay,
        flags,
        params: ps,
        grads: gs,
    });
    ahead.map(|a| a.counts)
}

/// Per-seat dialable addresses for the durable journal: a relaunched
/// driver re-adopts workers at these. In-proc seats record an empty
/// string (their transports die with the process — never re-adoptable).
fn seat_addrs(workers: &[WorkerHandle]) -> Vec<String> {
    workers
        .iter()
        .map(|w| match &w.backend {
            WorkerBackend::Process { addr, .. } => addr.journal_repr(),
            WorkerBackend::InProc { .. } => String::new(),
        })
        .collect()
}

/// Durable write-ahead journaling (`--journal`): lazily create the
/// on-disk journal at the first journaled step (creation truncates, so
/// it must run *after* any `--resume-journal` load), then append this
/// step's record **before any worker sees the step** — a driver killed
/// at any later point finds the step on disk and replays it on resume.
fn wal_append(
    el: &mut ElasticRuntime,
    workers: &[WorkerHandle],
    params: &[Matrix],
    grads: &[Matrix],
    common: &StepCtx,
) -> anyhow::Result<()> {
    let Some(path) = el.wal_path.clone() else { return Ok(()) };
    let t64 = common.t as u64;
    if el.wal.is_none() {
        // The sync section captures the state the replay starts from:
        // post-step t64-1 params (= the pre-step params right now) and
        // the snapshot taken at that point (restored state on a resume
        // path, absent at a fresh t=0 start where Init *is* the state).
        let sync_t = t64.saturating_sub(1);
        let snaps = match (&el.journal.snaps, sync_t) {
            (_, 0) => None,
            (Some(s), _) => Some(
                s.iter()
                    .enumerate()
                    .map(|(i, snap)| BlockStateMsg::from_snap(i as u32, snap))
                    .collect::<Vec<_>>(),
            ),
            (None, _) => bail!(
                "durable journal {path}: first journaled step is t={t64} but the driver \
                 holds no state snapshot covering t={sync_t}"
            ),
        };
        let addrs = seat_addrs(workers);
        el.wal = Some(
            JournalWriter::create(&path, sync_t, params, snaps.as_deref(), &addrs)
                .with_context(|| format!("create durable journal {path}"))?,
        );
    }
    el.wal
        .as_mut()
        .unwrap()
        .append_step(t64, common.lr, grads)
        .with_context(|| format!("journal step t={t64} to {path}"))
}

/// Rewrite the durable journal at a successful sync point: the new
/// sync section (post-step params + fresh snapshot + current seat
/// addresses) replaces the whole file atomically, discarding every
/// covered step record. Failure is non-fatal — steps keep appending to
/// the previous sync section, which stays valid for resume.
fn wal_sync(el: &mut ElasticRuntime, workers: &[WorkerHandle], params: &[Matrix], t64: u64) {
    let Some(path) = el.wal_path.clone() else { return };
    let Some(snaps) = el.journal.snaps.as_ref() else { return };
    let msgs: Vec<BlockStateMsg> = snaps
        .iter()
        .enumerate()
        .map(|(i, snap)| BlockStateMsg::from_snap(i as u32, snap))
        .collect();
    let addrs = seat_addrs(workers);
    match JournalWriter::create(&path, t64, params, Some(&msgs), &addrs) {
        Ok(w) => el.wal = Some(w),
        Err(e) => eprintln!(
            "durable journal rewrite at t={t64} skipped ({e:#}); steps keep appending \
             to the previous sync section"
        ),
    }
}

/// Migrate a dead seat onto a replacement worker: adopt a warm spare
/// (or cold-spawn one on process fleets), re-`Init` the seat's blocks,
/// restore the driver's last-acked snapshot, and replay the journal
/// through `replay_through`. Returns the replayed reply for step
/// `replay_through` when the journal holds that step — the reactive
/// mid-step path scatters it as the seat's own step reply.
#[allow(clippy::too_many_arguments)]
fn migrate_and_replay(
    el: &mut ElasticRuntime,
    flags: &FleetFlags,
    workers: &mut [WorkerHandle],
    assignment: &[Vec<usize>],
    expects: &[StateExpect],
    kind: UnitKind,
    base: &ShampooConfig,
    worker_threads: usize,
    seat: usize,
    replay_through: u64,
) -> anyhow::Result<Option<WireMsg>> {
    let mut nw = match el.spares.pop() {
        Some(w) => w,
        None => match &el.launch {
            Some(launch) => {
                let id = el.next_spare_id;
                el.next_spare_id += 1;
                spawn_process_worker(launch, id, el.timeouts, el.clock.clone())
                    .with_context(|| format!("spare worker {id}: spawn"))?
            }
            None => {
                bail!("shard {seat}: worker died and no spare remains (raise --shard-spares)")
            }
        },
    };
    let epoch = el.controller.on_replace(seat);
    flags.epoch.store(epoch, Ordering::SeqCst);
    nw.channel
        .adopt(seat, epoch)
        .with_context(|| format!("shard {seat}: adopt replacement worker"))?;
    nw.channel.supervised = el.supervised && nw.channel.heartbeat;
    // Fresh link, fresh codec: generation 0 never matches an adopted
    // channel's generation, so the first compressed step resyncs with
    // full frames on both directions.
    nw.delta = DeltaCodec::default();
    let init = init_msg_from_expects(&assignment[seat], expects, kind, base, worker_threads);
    init_worker(&mut nw, seat, &init)?;
    let mut state_bytes = 0usize;
    if let Some(snaps) = &el.journal.snaps {
        let entries: Vec<BlockStateMsg> = assignment[seat]
            .iter()
            .map(|&i| BlockStateMsg::from_snap(i as u32, &snaps[i]))
            .collect();
        if !entries.is_empty() {
            let msg = WireMsg::StateRestore(StateRestoreMsg { entries });
            state_bytes = wire::encode_frame(&msg)?.len();
            let reply = nw
                .channel
                .request(&msg)
                .with_context(|| format!("shard {seat}: migrate state restore"))?;
            match reply {
                WireMsg::Ok => {}
                WireMsg::Error { message } => {
                    bail!("shard {seat}: migrate restore failed: {message}")
                }
                other => bail!("shard {seat}: unexpected migrate restore reply {other:?}"),
            }
        }
    }
    // Replay the journal from the snapshot point through the target
    // step, as plain full-frame Step messages (every v5 worker accepts
    // them regardless of the fleet's compression setting).
    let mut final_reply = None;
    let mut replayed = 0usize;
    for js in &el.journal.steps {
        if js.t > replay_through {
            break;
        }
        let entries: Vec<StepEntry> = assignment[seat]
            .iter()
            .map(|&i| StepEntry {
                index: i as u32,
                refresh_due: js.flags[i],
                param: js.params[i].clone(),
                grad: js.grads[i].clone(),
            })
            .collect();
        let msg = WireMsg::Step(StepMsg {
            t: js.t,
            scale: js.scale,
            preconditioning: js.preconditioning,
            stat_due: js.stat_due,
            lr: js.lr,
            beta1: js.beta1,
            weight_decay: js.weight_decay,
            entries,
        });
        let reply = nw
            .channel
            .request(&msg)
            .with_context(|| format!("shard {seat}: replay step t={}", js.t))?;
        match &reply {
            WireMsg::StepOk(ok) if ok.t == js.t => {}
            WireMsg::Error { message } => {
                bail!("shard {seat}: replay step t={} failed: {message}", js.t)
            }
            other => bail!("shard {seat}: unexpected replay reply {other:?}"),
        }
        replayed += 1;
        if js.t == replay_through {
            final_reply = Some(reply);
        }
    }
    // Seat the replacement. The old handle's connection is already torn
    // down (or torn down here) so its Drop never talks on a dead link;
    // the process backend still reaps its child.
    let mut old = std::mem::replace(&mut workers[seat], nw);
    old.channel.pending_refresh = None;
    old.channel.conn = None;
    drop(old);
    flags.set_dead(seat, false);
    flags.bump_stats(|s| {
        s.migrations += 1;
        s.migrated_steps += replayed;
        s.migrated_state_bytes += state_bytes;
    });
    eprintln!(
        "shard {seat}: migrated to replacement worker (epoch {epoch}, {replayed} steps \
         replayed, {state_bytes} state bytes)"
    );
    Ok(final_reply)
}

/// Restore `owned`'s blocks onto seat `seat` from driver-held snaps.
fn restore_seat(
    w: &mut WorkerHandle,
    seat: usize,
    owned: &[usize],
    snaps: &[BlockStateSnap],
) -> anyhow::Result<()> {
    let entries: Vec<BlockStateMsg> =
        owned.iter().map(|&i| BlockStateMsg::from_snap(i as u32, &snaps[i])).collect();
    if entries.is_empty() {
        return Ok(());
    }
    let reply = w
        .channel
        .request(&WireMsg::StateRestore(StateRestoreMsg { entries }))
        .with_context(|| format!("shard {seat}: state restore"))?;
    match reply {
        WireMsg::Ok => Ok(()),
        WireMsg::Error { message } => bail!("shard {seat}: worker error: {message}"),
        other => bail!("shard {seat}: unexpected state-restore reply {other:?}"),
    }
}

/// Snapshot every block's typed state from the fleet (the elastic sync
/// point and the checkpoint path share this validation exactly).
fn snapshot_all(
    workers: &mut [WorkerHandle],
    assignment: &[Vec<usize>],
    n_blocks: usize,
    expects: &[StateExpect],
) -> anyhow::Result<Vec<BlockStateSnap>> {
    let mut out: Vec<Option<BlockStateSnap>> = Vec::new();
    out.resize_with(n_blocks, || None);
    for (shard, w) in workers.iter_mut().enumerate() {
        // The wire is strict request/response outside the parked
        // RefreshAhead slot — join-and-discard it first.
        w.drain_pending_refresh();
        let reply = w
            .channel
            .request(&WireMsg::StateSnap(StateSnapMsg { want: vec![] }))
            .with_context(|| format!("shard {shard}: state snapshot"))?;
        let entries = match reply {
            WireMsg::StateSnapOk(ok) => ok.entries,
            WireMsg::Error { message } => bail!("shard {shard}: worker error: {message}"),
            other => bail!("shard {shard}: unexpected state-snapshot reply {other:?}"),
        };
        ensure!(
            entries.len() == assignment[shard].len(),
            "shard {shard}: returned {} block states, owns {}",
            entries.len(),
            assignment[shard].len()
        );
        let (own_lo, own_hi) = match (assignment[shard].first(), assignment[shard].last()) {
            (Some(&lo), Some(&hi)) => (lo, hi),
            _ => (1, 0), // empty shard: any index is foreign
        };
        for msg in entries {
            let i = msg.index as usize;
            ensure!(
                i >= own_lo && i <= own_hi && i < n_blocks,
                "shard {shard}: returned foreign block state {i}"
            );
            ensure!(out[i].is_none(), "shard {shard}: duplicate block state {i}");
            // `into_snap` validates every declared shape/rank against
            // the driver's own block table before any payload
            // resolution allocates.
            let snap = msg
                .into_snap(&expects[i])
                .with_context(|| format!("shard {shard}: block {i} state"))?;
            out[i] = Some(snap);
        }
    }
    out.into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow!("no shard returned state for block {i}")))
        .collect()
}

/// Elastic sync point (every `failover_budget` steps, after the step's
/// replies are in): snapshot the fleet, truncate the journal, then
/// apply any staged or latency-triggered rebalance by re-`Init`ing and
/// restoring the seats whose ownership changed. A failed snapshot skips
/// the sync (the journal keeps growing until the next sync point
/// succeeds); a failure while applying a rebalance is a hard error —
/// the fleet would otherwise be left half re-cut.
#[allow(clippy::too_many_arguments)]
fn sync_and_rebalance(
    el: &mut ElasticRuntime,
    flags: &FleetFlags,
    workers: &mut [WorkerHandle],
    assignment: &mut Vec<Vec<usize>>,
    n_blocks: usize,
    expects: &[StateExpect],
    kind: UnitKind,
    base: &ShampooConfig,
    worker_threads: usize,
    t64: u64,
    params: &[Matrix],
) -> anyhow::Result<()> {
    let snaps = match snapshot_all(workers, assignment, n_blocks, expects) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "elastic sync at t={t64} skipped ({e:#}); the journal keeps growing until \
                 the next sync point"
            );
            return Ok(());
        }
    };
    el.journal.snaps = Some(snaps);
    el.journal.sync_t = t64;
    el.journal.steps.clear();
    wal_sync(el, workers, params, t64);
    if let Some(weights) = flags.take_staged() {
        el.controller.stage_rebalance(weights);
    }
    let Some(next) = el.controller.maybe_rebalance(n_blocks) else {
        return Ok(());
    };
    ensure!(
        next.len() == workers.len(),
        "rebalance proposal has {} seats, fleet has {}",
        next.len(),
        workers.len()
    );
    validate_assignment(&next, n_blocks).context("rebalance proposal rejected")?;
    let snaps = el.journal.snaps.as_ref().expect("journal synced above");
    for (seat, w) in workers.iter_mut().enumerate() {
        if next[seat] == assignment[seat] {
            continue;
        }
        w.drain_pending_refresh();
        let init = init_msg_from_expects(&next[seat], expects, kind, base, worker_threads);
        init_worker(w, seat, &init)?;
        restore_seat(w, seat, &next[seat], snaps)?;
        // Ownership moved: the held baselines may describe blocks this
        // seat no longer owns — resync from full frames.
        w.delta = DeltaCodec::default();
    }
    el.controller.view.rebalance(next.clone());
    flags.epoch.store(el.controller.view.epoch, Ordering::SeqCst);
    flags.bump_stats(|s| s.rebalances += 1);
    eprintln!(
        "elastic fleet: rebalanced block assignment at t={t64} (epoch {})",
        el.controller.view.epoch
    );
    *assignment = next;
    Ok(())
}

impl BlockExecutor for ShardExecutor {
    fn step_blocks(
        &mut self,
        blocks: &[Block],
        params: &mut [Matrix],
        grads: &[Matrix],
        ctxs: &[StepCtx],
    ) -> anyhow::Result<usize> {
        if blocks.is_empty() {
            return Ok(0);
        }
        debug_assert_eq!(blocks.len(), ctxs.len());
        // The wire ships the step-wide ctx fields once per shard (only
        // refresh_due varies across blocks in the engine's schedule).
        // Reject heterogeneous batches loudly instead of silently
        // applying ctxs[0] to every block.
        let common = &ctxs[0];
        for (i, c) in ctxs.iter().enumerate() {
            ensure!(
                c.t == common.t
                    && c.scale.to_bits() == common.scale.to_bits()
                    && c.preconditioning == common.preconditioning
                    && c.stat_due == common.stat_due
                    && c.lr.to_bits() == common.lr.to_bits()
                    && c.beta1.to_bits() == common.beta1.to_bits()
                    && c.weight_decay.to_bits() == common.weight_decay.to_bits()
                    && c.graft == common.graft,
                "block {i}: ctx differs from block 0 in a step-wide field \
                 (only refresh_due may vary across blocks on the shard wire)"
            );
        }
        let ShardExecutor {
            workers,
            assignment,
            compress,
            elastic,
            flags,
            expects,
            kind,
            base,
            worker_threads,
            supervisor,
            clock,
            ..
        } = self;
        let compress = *compress;
        let mut guard = workers_guard(workers)?;
        let workers = &mut *guard;
        let t64 = common.t as u64;
        // Supervised fleets: probe idle-too-long seats *before* the step
        // commits to the wire. A hung worker caught here is marked dead
        // and healed by the proactive migration pass below, within the
        // liveness deadline on the injected clock — never by waiting out
        // the blocking reply timeout. Seats with a parked RefreshAhead
        // are skipped: the wire is strict request/reply, and joining
        // that reply proves liveness anyway.
        if let Some(sup) = supervisor.as_mut() {
            let now = clock.now();
            for (seat, w) in workers.iter_mut().enumerate() {
                if flags.is_dead(seat)
                    || w.channel.pending_refresh.is_some()
                    || !sup.ping_due(seat, now)
                {
                    continue;
                }
                let seq = sup.next_ping_seq();
                match w.channel.ping(seq) {
                    Ok(()) => sup.note_alive(seat, clock.now()),
                    Err(e) => {
                        eprintln!("shard {seat}: liveness probe failed ({e:#}); migrating");
                        flags.set_dead(seat, true);
                    }
                }
            }
        }
        // Elastic bookkeeping first: journal this step's payloads (in
        // memory and — write-ahead — on disk), then proactively heal any
        // seat already known dead: its replacement replays the journal
        // through t-1 and then takes step t with the rest of the fleet.
        let mut ahead_counts: Option<Vec<usize>> = None;
        if let Some(el) = elastic.as_mut() {
            ahead_counts = journal_push(el, blocks, params, grads, ctxs, common);
            wal_append(el, workers, params, grads, common)?;
            for seat in flags.dead_seats() {
                migrate_and_replay(
                    el,
                    flags,
                    workers,
                    assignment,
                    expects,
                    *kind,
                    base,
                    *worker_threads,
                    seat,
                    t64.saturating_sub(1),
                )
                .with_context(|| format!("shard {seat}: elastic failover"))?;
                if let Some(sup) = supervisor.as_mut() {
                    sup.reset_seat(seat, clock.now());
                }
            }
        } else if let Some(seat) = flags.dead_seats().first().copied() {
            bail!(
                "shard {seat}: worker was killed and no elastic membership is configured \
                 (launch with --shard-spares to enable failover)"
            );
        }
        // Ship every shard its gathered block statistics first, then
        // collect replies in shard order — workers compute concurrently.
        // Under elastic membership a send/recv failure defers the seat
        // to the reactive migration pass instead of failing the step.
        let mut failed: Vec<usize> = Vec::new();
        let mut sent = vec![false; workers.len()];
        for (shard, w) in workers.iter_mut().enumerate() {
            // Cancel path: a RefreshAhead parked by a caller that never
            // joined it is drained and discarded before the Step goes
            // out (the engine normally joins first; direct executor
            // drivers may not).
            w.drain_pending_refresh();
            let msg = encode_step_msg(
                w,
                &assignment[shard],
                blocks,
                params,
                grads,
                ctxs,
                common,
                compress,
            );
            match w
                .channel
                .send(&msg)
                .with_context(|| format!("shard {shard}: send step t={}", common.t))
            {
                Ok(()) => sent[shard] = true,
                Err(e) => {
                    if elastic.is_none() {
                        return Err(e);
                    }
                    eprintln!("shard {shard}: send failed mid-step ({e:#}); migrating");
                    failed.push(shard);
                }
            }
        }
        let mut refreshes = 0usize;
        for (shard, w) in workers.iter_mut().enumerate() {
            if !sent[shard] {
                continue;
            }
            let started = clock.now();
            let reply = match w
                .channel
                .recv()
                .with_context(|| format!("shard {shard}: step t={} reply", common.t))
            {
                Ok(r) => r,
                Err(e) => {
                    if elastic.is_none() {
                        return Err(e);
                    }
                    eprintln!("shard {shard}: reply failed mid-step ({e:#}); migrating");
                    failed.push(shard);
                    continue;
                }
            };
            if let Some(sup) = supervisor.as_mut() {
                sup.note_alive(shard, clock.now());
            }
            if let Some(el) = elastic.as_mut() {
                // Feed the rebalancer the observed per-seat step wall
                // time (EWMA-smoothed inside the controller).
                let nanos = clock.now().saturating_sub(started).as_secs_f64() * 1e9;
                el.controller.observe_step_latency(shard, nanos);
            }
            refreshes += apply_step_reply(
                reply,
                w,
                shard,
                &assignment[shard],
                blocks,
                params,
                common,
                compress,
            )?;
        }
        if let Some(el) = elastic.as_mut() {
            // Reactive pass: a seat died mid-step. Replay it through
            // step t itself — the final replayed reply *is* this seat's
            // step reply, minus the ahead-refresh count the engine
            // already booked for it.
            for seat in failed {
                flags.set_dead(seat, true);
                let reply = migrate_and_replay(
                    el,
                    flags,
                    workers,
                    assignment,
                    expects,
                    *kind,
                    base,
                    *worker_threads,
                    seat,
                    t64,
                )
                .with_context(|| format!("shard {seat}: elastic failover"))?
                .ok_or_else(|| {
                    anyhow!("shard {seat}: migration replay produced no reply for step t={t64}")
                })?;
                if let Some(sup) = supervisor.as_mut() {
                    sup.reset_seat(seat, clock.now());
                }
                let n = apply_step_reply(
                    reply,
                    &mut workers[seat],
                    seat,
                    &assignment[seat],
                    blocks,
                    params,
                    common,
                    compress,
                )?;
                let over = ahead_counts.as_ref().map_or(0, |c| c[seat]);
                refreshes += n.saturating_sub(over);
            }
            // Bounded-budget sync point: snapshot the fleet, truncate
            // the journal, and apply any staged/triggered rebalance.
            if t64 % el.controller.cfg.failover_budget == 0 {
                sync_and_rebalance(
                    el,
                    flags,
                    workers,
                    assignment,
                    blocks.len(),
                    expects,
                    *kind,
                    base,
                    *worker_threads,
                    t64,
                    params,
                )?;
            }
        }
        Ok(refreshes)
    }

    fn mem_bytes(&self) -> usize {
        self.mem_stats_total().0
    }
    fn second_moment_bytes(&self) -> usize {
        self.mem_stats_total().1
    }

    fn overlap_capable(&self) -> bool {
        self.overlap
    }

    fn begin_refresh_ahead(&mut self, plan: RefreshAheadPlan) -> bool {
        if !self.overlap {
            return false;
        }
        let ShardExecutor { workers, assignment, n_blocks, flags, .. } = self;
        debug_assert_eq!(plan.due.len(), *n_blocks);
        let mut guard = match workers_guard(workers) {
            Ok(g) => g,
            Err(e) => {
                // Declining is always bitwise-safe (the step refreshes
                // synchronously); the poisoned table will fail the next
                // step with the shard-error contract.
                eprintln!("refresh-ahead declined: {e:#}");
                return false;
            }
        };
        let workers = &mut *guard;
        let mut any = false;
        for (shard, w) in workers.iter_mut().enumerate() {
            if flags.is_dead(shard) {
                // A dead seat keeps its blocks refresh-due; the elastic
                // migration replay refreshes them in-step instead.
                continue;
            }
            debug_assert!(
                w.channel.pending_refresh.is_none(),
                "refresh-ahead already in flight on shard {shard}"
            );
            let due: Vec<u32> = assignment[shard]
                .iter()
                .copied()
                .filter(|&i| plan.due[i])
                .map(|i| i as u32)
                .collect();
            if assignment[shard].is_empty() || (!plan.all && due.is_empty()) {
                continue; // nothing for this shard to prefetch
            }
            let t_next = plan.t_next as u64;
            let msg = WireMsg::RefreshAhead(RefreshAheadMsg { t_next, all: plan.all, due });
            match w.channel.send(&msg) {
                Ok(()) => {
                    // The reply stays parked until finish_refresh_ahead:
                    // this is the second in-flight request per shard.
                    w.channel.pending_refresh = Some(t_next);
                    any = true;
                }
                Err(e) => {
                    // Degrade just this step to a synchronous refresh on
                    // this shard — its blocks keep refresh_due in-step,
                    // so the numbers cannot change.
                    eprintln!(
                        "shard {shard}: refresh-ahead send failed ({e:#}); \
                         refreshing synchronously this step"
                    );
                }
            }
        }
        any
    }

    fn finish_refresh_ahead(&mut self) -> anyhow::Result<Option<RefreshAheadDone>> {
        let ShardExecutor { workers, assignment, n_blocks, elastic, flags, supervisor, clock, .. } =
            self;
        let mut guard = workers_guard(workers)?;
        let workers = &mut *guard;
        let mut refreshed = vec![false; *n_blocks];
        let mut counts = vec![0usize; workers.len()];
        let mut count = 0usize;
        let mut any = false;
        let mut t_seen: Option<u64> = None;
        for (shard, w) in workers.iter_mut().enumerate() {
            let Some(t_next) = w.channel.pending_refresh.take() else {
                continue;
            };
            any = true;
            t_seen = Some(t_next);
            if flags.is_dead(shard) {
                // Killed with a request parked: its blocks stay
                // refresh-due and the migration replay refreshes them
                // in-step, so the count here must remain zero.
                continue;
            }
            let reply = match w
                .channel
                .recv()
                .with_context(|| format!("shard {shard}: refresh-ahead t={t_next} reply"))
            {
                Ok(r) => r,
                Err(e) => {
                    if elastic.is_none() {
                        return Err(e);
                    }
                    eprintln!(
                        "shard {shard}: refresh-ahead join failed ({e:#}); scheduling failover"
                    );
                    flags.set_dead(shard, true);
                    continue;
                }
            };
            if let Some(sup) = supervisor.as_mut() {
                sup.note_alive(shard, clock.now());
            }
            let ok = match reply {
                WireMsg::RefreshAheadOk(ok) => ok,
                WireMsg::RefreshAheadOkV4(ok) => {
                    // v4 adds per-block escaped-mass diagnostics. They
                    // are informational (nothing numeric consumes them),
                    // but a non-finite ρ from a worker is still a bug
                    // worth surfacing at the protocol boundary.
                    for (idx, rho) in &ok.escaped {
                        ensure!(
                            rho.is_finite(),
                            "shard {shard}: refresh-ahead reported non-finite escaped \
                             mass {rho} for block {idx}"
                        );
                    }
                    RefreshAheadOkMsg { t_next: ok.t_next, count: ok.count, refreshed: ok.refreshed }
                }
                WireMsg::Error { message } => {
                    bail!("shard {shard}: worker error: {message}")
                }
                other => bail!("shard {shard}: unexpected refresh-ahead reply {other:?}"),
            };
            ensure!(
                ok.t_next == t_next,
                "shard {shard}: refresh-ahead reply for t={} while awaiting t={t_next}",
                ok.t_next
            );
            count += ok.count as usize;
            counts[shard] = ok.count as usize;
            let (own_lo, own_hi) = match (assignment[shard].first(), assignment[shard].last()) {
                (Some(&lo), Some(&hi)) => (lo, hi),
                _ => (1, 0),
            };
            for idx in ok.refreshed {
                let i = idx as usize;
                ensure!(
                    i >= own_lo && i <= own_hi && i < *n_blocks,
                    "shard {shard}: refresh-ahead reported foreign block {i}"
                );
                refreshed[i] = true;
            }
        }
        if let Some(el) = elastic.as_mut() {
            // Remember what the join delivered for the step about to be
            // driven: a reactive migration of that step subtracts these
            // per-seat counts from its replayed reply so the engine's
            // refresh accounting stays exact.
            el.ahead = t_seen.map(|t_next| AheadRecord {
                t_next,
                refreshed: refreshed.clone(),
                counts,
            });
        }
        Ok(any.then_some(RefreshAheadDone { refreshed, count }))
    }

    fn state_snapshot(&mut self) -> anyhow::Result<Vec<BlockStateSnap>> {
        ensure!(
            self.state,
            "shard executor: a worker greeted below wire protocol v4 (no typed \
             block-state capability); checkpoint snapshots need every link at v4"
        );
        let ShardExecutor {
            workers,
            assignment,
            n_blocks,
            expects,
            elastic,
            flags,
            kind,
            base,
            worker_threads,
            supervisor,
            clock,
            ..
        } = self;
        let mut guard = workers_guard(workers)?;
        let workers = &mut *guard;
        if let Some(el) = elastic.as_mut() {
            // Heal first so every seat can answer the snapshot RPC.
            let through = el.journal.steps.last().map(|s| s.t).unwrap_or(el.journal.sync_t);
            for seat in flags.dead_seats() {
                migrate_and_replay(
                    el,
                    flags,
                    workers,
                    assignment,
                    expects,
                    *kind,
                    base,
                    *worker_threads,
                    seat,
                    through,
                )
                .with_context(|| format!("shard {seat}: elastic failover"))?;
                if let Some(sup) = supervisor.as_mut() {
                    sup.reset_seat(seat, clock.now());
                }
            }
        }
        snapshot_all(workers, assignment, *n_blocks, expects)
    }

    fn state_restore(&mut self, snaps: Vec<BlockStateSnap>) -> anyhow::Result<()> {
        ensure!(
            self.state,
            "shard executor: a worker greeted below wire protocol v4 (no typed \
             block-state capability); checkpoint restore needs every link at v4"
        );
        let ShardExecutor {
            workers,
            assignment,
            n_blocks,
            expects,
            elastic,
            flags,
            kind,
            base,
            worker_threads,
            supervisor,
            clock,
            ..
        } = self;
        ensure!(
            snaps.len() == *n_blocks,
            "shard executor: restoring {} block states into {} blocks",
            snaps.len(),
            *n_blocks
        );
        let mut guard = workers_guard(workers)?;
        let workers = &mut *guard;
        if let Some(el) = elastic.as_mut() {
            // Heal first: a restore must land on a live, adopted fleet.
            let through = el.journal.steps.last().map(|s| s.t).unwrap_or(el.journal.sync_t);
            for seat in flags.dead_seats() {
                migrate_and_replay(
                    el,
                    flags,
                    workers,
                    assignment,
                    expects,
                    *kind,
                    base,
                    *worker_threads,
                    seat,
                    through,
                )
                .with_context(|| format!("shard {seat}: elastic failover"))?;
                if let Some(sup) = supervisor.as_mut() {
                    sup.reset_seat(seat, clock.now());
                }
            }
        }
        for (shard, w) in workers.iter_mut().enumerate() {
            w.drain_pending_refresh();
            restore_seat(w, shard, &assignment[shard], &snaps)?;
        }
        if let Some(el) = elastic.as_mut() {
            // The restored state is the fleet's new ground truth; the
            // journal re-bases on it so later migrations replay from
            // the restored snapshot rather than a pre-restore one.
            el.journal = StepJournal { sync_t: 0, snaps: Some(snaps), steps: Vec::new() };
        }
        Ok(())
    }

    fn label(&self) -> String {
        format!(
            "shards={}/{}{}{}",
            self.assignment.len(),
            self.transport,
            if self.compress { "+delta" } else { "" },
            if self.elastic.is_some() { "+elastic" } else { "" }
        )
    }

    fn fleet_control(&self) -> Option<FleetControl> {
        Some(self.control())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::FaultScript;
    use crate::optim::engine::{EngineConfig, PrecondEngine};
    use crate::optim::matrix_opt::Optimizer;
    use crate::optim::partition;
    use crate::util::rng::Pcg64;

    /// Non-elastic in-proc fleet over the given transports (the
    /// builder-era spelling of the old `launch_in_proc`).
    fn in_proc(
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        transports: &[Arc<FaultInjectingTransport>],
        proto: u32,
        compress: bool,
    ) -> ShardExecutor {
        ShardExecutor::launch_in_proc_with(
            blocks,
            kind,
            base,
            1,
            transports,
            proto,
            compress,
            &MembershipConfig::default(),
        )
        .expect("launch in-proc executor")
    }

    #[test]
    fn assignment_is_balanced_contiguous_and_total() {
        let a = ContiguousAssignment.assign(10, 3);
        assert_eq!(a, vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
        let b = ContiguousAssignment.assign(2, 4);
        assert_eq!(b, vec![vec![0], vec![1], vec![], vec![]]);
        let c = ContiguousAssignment.assign(0, 2);
        assert_eq!(c, vec![Vec::<usize>::new(), vec![]]);
        // Determinism: same inputs, same partition.
        assert_eq!(ContiguousAssignment.assign(10, 3), a);
    }

    #[test]
    #[allow(deprecated)]
    fn assign_blocks_shim_matches_the_trait_policy() {
        for (n, s) in [(10usize, 3usize), (2, 4), (0, 2), (7, 7), (13, 5)] {
            assert_eq!(assign_blocks(n, s), ContiguousAssignment.assign(n, s));
        }
    }

    #[test]
    fn transport_parse_and_display() {
        assert_eq!(ShardTransport::parse("tcp").unwrap(), ShardTransport::Tcp);
        assert_eq!(ShardTransport::parse("TCP").unwrap(), ShardTransport::Tcp);
        assert!(ShardTransport::parse("carrier-pigeon").is_err());
        assert_eq!(ShardTransport::Tcp.to_string(), "tcp");
        #[cfg(unix)]
        {
            assert_eq!(ShardTransport::parse("unix").unwrap(), ShardTransport::Unix);
            assert_eq!(ShardTransport::Unix.to_string(), "unix");
        }
    }

    #[test]
    fn shard_config_resolution_precedence() {
        let cfg = Config::parse("[shard]\ncount = 3\ntransport = \"tcp\"\nproto = 1").unwrap();
        let args = Args::parse(["train", "--shards", "2"].iter().map(|s| s.to_string()));
        let sc = ShardConfig::resolve(&args, &cfg).unwrap();
        assert_eq!(sc.shards, 2); // CLI beats config
        assert_eq!(sc.transport, ShardTransport::Tcp);
        assert_eq!(sc.proto, 1); // config beats default
        assert!(sc.enabled());
        let defaults = ShardConfig::resolve(&Args::default(), &Config::default()).unwrap();
        assert_eq!(defaults.shards, 0);
        assert_eq!(defaults.proto, PROTO_VERSION);
        assert!(defaults.compress, "delta compression defaults on");
        assert_eq!(defaults.launch, None);
        assert!(!defaults.enabled());
        // Compression + launcher knobs resolve with the same precedence.
        let cfg2 = Config::parse(
            "[shard]\ncompress = false\nlaunch = \"ssh host{shard} /opt/sk {worker_cmd}\"",
        )
        .unwrap();
        let sc2 = ShardConfig::resolve(&Args::default(), &cfg2).unwrap();
        assert!(!sc2.compress);
        assert_eq!(sc2.launch.as_deref(), Some("ssh host{shard} /opt/sk {worker_cmd}"));
        let args2 = Args::parse(
            ["train", "--shard-compress", "true", "--shard-launch", "env {program} {worker_cmd}"]
                .iter()
                .map(|s| s.to_string()),
        );
        let sc3 = ShardConfig::resolve(&args2, &cfg2).unwrap();
        assert!(sc3.compress, "CLI beats config");
        assert_eq!(sc3.launch.as_deref(), Some("env {program} {worker_cmd}"));
        // An explicit empty CLI template clears a config-file one
        // (back to plain local exec).
        let clear = Args::parse(["train", "--shard-launch", ""].iter().map(|s| s.to_string()));
        let sc4 = ShardConfig::resolve(&clear, &cfg2).unwrap();
        assert_eq!(sc4.launch, None, "empty CLI template disables the config template");
        let bad = Args::parse(
            ["train", "--shard-transport", "smoke-signals"].iter().map(|s| s.to_string()),
        );
        assert!(ShardConfig::resolve(&bad, &Config::default()).is_err());
        // Unknown future protocol versions are refused, not guessed at.
        let future = Args::parse(["train", "--shard-proto", "99"].iter().map(|s| s.to_string()));
        assert!(ShardConfig::resolve(&future, &Config::default()).is_err());
    }

    #[test]
    fn unknown_shard_config_keys_are_a_named_error() {
        // A typo'd knob (`spare` for `spares`) must fail resolution by
        // name instead of silently becoming a no-op.
        let cfg = Config::parse("[shard]\nspare = 2").unwrap();
        let err = ShardConfig::resolve(&Args::default(), &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown [shard] config key \"shard.spare\""), "got: {msg}");
        assert!(msg.contains("spares"), "error must list the known keys: {msg}");
        // Other sections are not the shard resolver's business.
        let other = Config::parse("[engine]\nbogus = 1").unwrap();
        assert!(ShardConfig::resolve(&Args::default(), &other).is_ok());
    }

    #[test]
    fn elastic_knobs_resolve_with_cli_over_config_precedence() {
        let cfg =
            Config::parse("[shard]\nspares = 1\nrebalance = true\nfailover_budget = 4").unwrap();
        let sc = ShardConfig::resolve(&Args::default(), &cfg).unwrap();
        assert_eq!(sc.spares, 1);
        assert!(sc.rebalance);
        assert_eq!(sc.failover_budget, 4);
        assert!(sc.membership().elastic());
        let args = Args::parse(
            ["train", "--shard-spares", "2", "--rebalance", "false", "--shard-failover-budget", "6"]
                .iter()
                .map(|s| s.to_string()),
        );
        let sc2 = ShardConfig::resolve(&args, &cfg).unwrap();
        assert_eq!(sc2.spares, 2, "CLI beats config");
        assert!(!sc2.rebalance, "CLI beats config");
        assert_eq!(sc2.failover_budget, 6, "CLI beats config");
        // Elastic membership needs the v5 links: a pinned older
        // protocol is refused at resolution, not at launch.
        let pinned = Args::parse(
            ["train", "--shard-spares", "1", "--shard-proto", "4"].iter().map(|s| s.to_string()),
        );
        assert!(ShardConfig::resolve(&pinned, &Config::default()).is_err());
        // And a zero failover budget is refused.
        let zero =
            Args::parse(["train", "--shard-failover-budget", "0"].iter().map(|s| s.to_string()));
        assert!(ShardConfig::resolve(&zero, &Config::default()).is_err());
        // Defaults stay non-elastic.
        assert!(!ShardConfig::default().membership().elastic());
    }

    #[test]
    fn timeout_knobs_resolve_with_cli_over_config_precedence() {
        // Documented defaults: connect 10 s, reply 120 s, heartbeat
        // 500 ms, deadline 10 s — matching LinkTimeouts::default().
        let d = ShardConfig::resolve(&Args::default(), &Config::default()).unwrap();
        assert_eq!(d.timeouts(), LinkTimeouts::default());
        assert_eq!(d.connect_timeout_ms, 10_000);
        assert_eq!(d.reply_timeout_ms, 120_000);
        assert_eq!(d.heartbeat_ms, 500);
        assert_eq!(d.deadline_ms, 10_000);
        // Config keys override defaults; CLI flags override config.
        let cfg = Config::parse(
            "[shard]\nconnect_timeout_ms = 2000\nreply_timeout_ms = 30000\n\
             heartbeat_ms = 100\ndeadline_ms = 1000",
        )
        .unwrap();
        let sc = ShardConfig::resolve(&Args::default(), &cfg).unwrap();
        assert_eq!(sc.connect_timeout_ms, 2000);
        assert_eq!(sc.reply_timeout_ms, 30_000);
        assert_eq!(sc.heartbeat_ms, 100);
        assert_eq!(sc.deadline_ms, 1000);
        let args = Args::parse(
            [
                "train",
                "--shard-connect-timeout-ms",
                "500",
                "--shard-reply-timeout-ms",
                "20000",
                "--shard-heartbeat-ms",
                "50",
                "--shard-deadline-ms",
                "200",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let sc2 = ShardConfig::resolve(&args, &cfg).unwrap();
        assert_eq!(sc2.connect_timeout_ms, 500, "CLI beats config");
        assert_eq!(sc2.heartbeat_ms, 50, "CLI beats config");
        assert_eq!(
            sc2.timeouts(),
            LinkTimeouts {
                connect: Duration::from_millis(500),
                reply: Duration::from_millis(20_000),
                heartbeat: Duration::from_millis(50),
                deadline: Duration::from_millis(200),
            }
        );
        // The ordering invariant heartbeat <= deadline <= reply is
        // enforced at resolution, by name.
        let inverted = Args::parse(
            ["train", "--shard-heartbeat-ms", "5000", "--shard-deadline-ms", "100"]
                .iter()
                .map(|s| s.to_string()),
        );
        let err = ShardConfig::resolve(&inverted, &Config::default()).unwrap_err();
        assert!(format!("{err:#}").contains("heartbeat"), "{err:#}");
        let past_reply = Args::parse(
            ["train", "--shard-deadline-ms", "300000"].iter().map(|s| s.to_string()),
        );
        assert!(ShardConfig::resolve(&past_reply, &Config::default()).is_err());
        // Zero timeouts are refused.
        let zero = Args::parse(
            ["train", "--shard-connect-timeout-ms", "0"].iter().map(|s| s.to_string()),
        );
        assert!(ShardConfig::resolve(&zero, &Config::default()).is_err());
    }

    #[test]
    fn journal_knobs_resolve_and_gate_on_protocol() {
        // --journal / shard.journal / --resume-journal all land in the
        // config; --resume-journal implies journaling to the same path.
        let cfg = Config::parse("[shard]\njournal = \"out/wal.skjl\"").unwrap();
        let sc = ShardConfig::resolve(&Args::default(), &cfg).unwrap();
        assert_eq!(sc.journal.as_deref(), Some("out/wal.skjl"));
        assert!(sc.membership().elastic(), "journaling turns the fleet elastic");
        let args =
            Args::parse(["train", "--journal", "a.skjl"].iter().map(|s| s.to_string()));
        let sc2 = ShardConfig::resolve(&args, &cfg).unwrap();
        assert_eq!(sc2.journal.as_deref(), Some("a.skjl"), "CLI beats config");
        let resume =
            Args::parse(["train", "--resume-journal", "b.skjl"].iter().map(|s| s.to_string()));
        let sc3 = ShardConfig::resolve(&resume, &Config::default()).unwrap();
        assert_eq!(sc3.resume_journal.as_deref(), Some("b.skjl"));
        assert_eq!(sc3.journal.as_deref(), Some("b.skjl"), "resume implies journal");
        // Journaling needs the v5+ typed-state links.
        let pinned = Args::parse(
            ["train", "--journal", "a.skjl", "--shard-proto", "4"]
                .iter()
                .map(|s| s.to_string()),
        );
        let err = ShardConfig::resolve(&pinned, &Config::default()).unwrap_err();
        assert!(format!("{err:#}").contains("v5"), "{err:#}");
        // An explicit empty --journal clears a config-file path.
        let clear = Args::parse(["train", "--journal", ""].iter().map(|s| s.to_string()));
        let sc4 = ShardConfig::resolve(&clear, &cfg).unwrap();
        assert_eq!(sc4.journal, None);
    }

    #[test]
    fn listen_line_parses() {
        assert_eq!(
            parse_listen_line("SKETCHY-SHARD-LISTENING tcp 127.0.0.1:4091\n"),
            Some(WorkerAddr::Tcp("127.0.0.1:4091".into()))
        );
        assert_eq!(parse_listen_line("unrelated noise"), None);
        assert_eq!(parse_listen_line("SKETCHY-SHARD-LISTENING warp 9"), None);
        #[cfg(unix)]
        assert_eq!(
            parse_listen_line("SKETCHY-SHARD-LISTENING unix /tmp/w0.sock"),
            Some(WorkerAddr::Unix(PathBuf::from("/tmp/w0.sock")))
        );
    }

    #[test]
    fn worker_state_matches_in_process_engine_bitwise() {
        // Drive the same gradient stream through (a) the in-process
        // engine and (b) the worker-side state machine fed by hand-built
        // Step messages — the math on both sides of the wire must agree
        // bitwise. This pins the worker implementation without sockets.
        let shapes = [(6usize, 4usize)];
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let ecfg = EngineConfig {
            threads: 1,
            block_size: 3,
            refresh_interval: 2,
            stagger: false,
            ..Default::default()
        };
        let mut engine = PrecondEngine::shampoo(&shapes, base.clone(), ecfg);
        let blocks = partition(&shapes, 3);
        let specs: Vec<BlockSpec> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let (rows, cols) = b.shape();
                BlockSpec { index: i as u32, rows: rows as u32, cols: cols as u32 }
            })
            .collect();
        let init = InitMsg {
            kind: UnitKind::Shampoo.code(),
            rank: 0,
            beta2: base.beta2,
            eps: base.eps,
            one_sided: base.one_sided,
            graft: base.graft.code(),
            threads: 1,
            ekfac: false,
            blocks: specs,
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mut p_eng = vec![crate::tensor::Matrix::zeros(6, 4)];
        let mut p_ws = p_eng.clone();
        let mut rng = Pcg64::new(99);
        for t in 1..=6u64 {
            let grads = vec![crate::tensor::Matrix::randn(6, 4, &mut rng)];
            engine.step(&mut p_eng, &grads);
            let entries: Vec<StepEntry> = blocks
                .iter()
                .enumerate()
                .map(|(i, b)| StepEntry {
                    index: i as u32,
                    refresh_due: t % 2 == 0, // stagger off, interval 2
                    param: p_ws[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                    grad: grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                })
                .collect();
            let msg = StepMsg {
                t,
                scale: 1.0, // clip disabled in base
                preconditioning: t as usize >= base.start_preconditioning_step,
                stat_due: true,
                lr: base.lr,
                beta1: base.beta1,
                weight_decay: base.weight_decay,
                entries,
            };
            let ok = ws.process_step(&msg).unwrap();
            for (index, block_param) in &ok.entries {
                let b = &blocks[*index as usize];
                p_ws[b.tensor].set_slice(b.r0, b.c0, block_param);
            }
            assert_eq!(
                p_eng[0].max_diff(&p_ws[0]),
                0.0,
                "worker path diverged from engine at step {t}"
            );
        }
        // The idempotency cache replays the last step verbatim.
        let cached = ws.last_step.clone().unwrap();
        assert_eq!(cached.0, 6);
    }

    #[test]
    fn worker_refresh_ahead_runs_due_blocks_only() {
        // Two 3x3 blocks; feed one step of statistics, then refresh
        // ahead block 0 only — its roots must exist afterwards and the
        // skipped block's must not, and the reply must name exactly the
        // refreshed block.
        let init = InitMsg {
            kind: UnitKind::Shampoo.code(),
            rank: 0,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::Rmsprop.code(),
            threads: 1,
            ekfac: false,
            blocks: vec![
                BlockSpec { index: 0, rows: 3, cols: 3 },
                BlockSpec { index: 1, rows: 3, cols: 3 },
            ],
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mut rng = Pcg64::new(515);
        let step = StepMsg {
            t: 1,
            scale: 1.0,
            preconditioning: false, // ingest only; no refresh yet
            stat_due: true,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 0.0,
            entries: (0..2)
                .map(|i| StepEntry {
                    index: i,
                    refresh_due: false,
                    param: Matrix::zeros(3, 3),
                    grad: Matrix::randn(3, 3, &mut rng),
                })
                .collect(),
        };
        ws.process_step(&step).unwrap();
        let ra = RefreshAheadMsg { t_next: 2, all: false, due: vec![0] };
        let ok = ws.process_refresh_ahead(&ra).unwrap();
        assert_eq!(ok.t_next, 2);
        assert_eq!(ok.refreshed, vec![0]);
        assert!(ok.count >= 1, "a Kronecker refresh runs an eigendecomposition");
        assert!(ws.states[0].get_mut().unwrap().unit.ready());
        assert!(!ws.states[1].get_mut().unwrap().unit.ready());
        // `all` visits the not-yet-ready block regardless of its slot.
        let ra_all = RefreshAheadMsg { t_next: 3, all: true, due: vec![] };
        let ok_all = ws.process_refresh_ahead(&ra_all).unwrap();
        assert_eq!(ok_all.refreshed, vec![1], "only the unready block needs work");
        assert!(ws.states[1].get_mut().unwrap().unit.ready());
        // Unknown indices are rejected loudly.
        let bad = RefreshAheadMsg { t_next: 4, all: false, due: vec![9] };
        assert!(ws.process_refresh_ahead(&bad).is_err());
    }

    #[test]
    fn duplicated_requests_are_absorbed_by_the_reply_caches() {
        // Drive a worker serve loop over the fault transport and
        // duplicate the Step request frame (a replayed request landing
        // on top of the original). The worker must answer both with the
        // *same bytes* — the cached reply. Re-processing would fold the
        // gradient statistics twice and change the parameters.
        use crate::coordinator::fault::FaultAction;
        let t = FaultInjectingTransport::with_config(
            // Request frames: 0 = Init, 1 = Step (duplicated).
            FaultScript::none().on_request(1, FaultAction::DuplicateFrame),
            usize::MAX,
            // Generous cap: this test reads replies by hand, with no
            // reconnect logic to absorb a scheduling-stall timeout.
            Some(Duration::from_secs(30)),
        );
        let acceptor = t.take_acceptor().unwrap();
        let worker = std::thread::spawn(move || {
            let mut state: Option<WorkerState> = None;
            let mut wid = 0u32;
            while let Ok(mut conn) = acceptor.recv() {
                match handle_conn(&mut conn, &mut state, &mut wid, PROTO_VERSION) {
                    Ok(true) => continue,
                    _ => break,
                }
            }
        });
        let mut conn = t.dial().unwrap();
        let _ = conn.set_timeout(Some(Duration::from_secs(10)));
        match wire::read_msg(&mut conn).unwrap() {
            WireMsg::HelloV6 {
                worker_id: 0,
                overlap: true,
                compress: true,
                state: true,
                member: true,
                heartbeat: true,
                ..
            } => {}
            other => panic!("unexpected hello: {other:?}"),
        }
        let init = WireMsg::Init(InitMsg {
            kind: UnitKind::Shampoo.code(),
            rank: 0,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::Rmsprop.code(),
            threads: 1,
            ekfac: false,
            blocks: vec![BlockSpec { index: 0, rows: 3, cols: 3 }],
        });
        wire::write_msg(&mut conn, &init).unwrap();
        assert_eq!(wire::read_msg(&mut conn).unwrap(), WireMsg::Ok);
        let mut rng = Pcg64::new(517);
        let step = WireMsg::Step(StepMsg {
            t: 1,
            scale: 1.0,
            preconditioning: true,
            stat_due: true,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 0.0,
            entries: vec![StepEntry {
                index: 0,
                refresh_due: true,
                param: Matrix::zeros(3, 3),
                grad: Matrix::randn(3, 3, &mut rng),
            }],
        });
        wire::write_msg(&mut conn, &step).unwrap(); // arrives twice
        let r1 = wire::read_msg(&mut conn).unwrap();
        let r2 = wire::read_msg(&mut conn).unwrap();
        assert!(matches!(r1, WireMsg::StepOk(_)), "got {r1:?}");
        assert_eq!(
            wire::encode_frame(&r1).unwrap(),
            wire::encode_frame(&r2).unwrap(),
            "duplicate step must be served from the reply cache"
        );
        wire::write_msg(&mut conn, &WireMsg::Shutdown).unwrap();
        assert_eq!(wire::read_msg(&mut conn).unwrap(), WireMsg::Ok);
        drop(conn);
        worker.join().unwrap();
    }

    #[test]
    fn in_proc_executor_matches_local_executor_bitwise() {
        // The full driver ↔ worker protocol over the in-memory
        // transport (no faults): bitwise identity with the local
        // executor, including the second-in-flight RefreshAhead slot.
        let shapes = [(6usize, 6usize)];
        let blocks = partition(&shapes, 3);
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut local = crate::optim::LocalExecutor::new(&blocks, UnitKind::Shampoo, &base, 1);
        let transports: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec = ShardExecutor::launch_in_proc_with(
            &blocks,
            UnitKind::Shampoo,
            &base,
            1,
            &transports,
            PROTO_VERSION,
            false,
            &MembershipConfig::default(),
        )
        .expect("launch in-proc executor");
        assert!(exec.overlap_capable());
        assert_eq!(exec.label(), "shards=2/in-proc");
        let mut p1 = vec![Matrix::zeros(6, 6)];
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(516);
        for t in 1..=6usize {
            let grads = vec![Matrix::randn(6, 6, &mut rng)];
            let ctxs: Vec<StepCtx> = (0..blocks.len())
                .map(|i| StepCtx {
                    t,
                    scale: 1.0,
                    preconditioning: t >= 2,
                    refresh_due: (t + i) % 2 == 0,
                    lr: 0.05,
                    beta1: 0.9,
                    weight_decay: 1e-3,
                    stat_due: true,
                    graft: GraftType::Rmsprop,
                })
                .collect();
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).expect("in-proc step");
            assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "diverged at step {t}");
        }
    }

    #[test]
    fn legacy_proto_worker_reports_no_overlap_capability() {
        let shapes = [(4usize, 4usize)];
        let blocks = partition(&shapes, 2);
        let base = ShampooConfig::default();
        let transports: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec = ShardExecutor::launch_in_proc_with(
            &blocks,
            UnitKind::Shampoo,
            &base,
            1,
            &transports,
            1,
            true,
            &MembershipConfig::default(),
        )
        .expect("launch v1 in-proc executor");
        assert!(!exec.overlap_capable(), "v1 workers must not report overlap capability");
        // And begin_refresh_ahead declines instead of wedging the wire.
        let declined = exec.begin_refresh_ahead(RefreshAheadPlan {
            due: vec![true; blocks.len()],
            all: false,
            t_next: 2,
        });
        assert!(!declined);
        assert!(exec.finish_refresh_ahead().unwrap().is_none());
    }

    #[test]
    fn compressed_in_proc_executor_matches_local_executor_bitwise() {
        // Full driver ↔ worker protocol with the v3 delta payload layer
        // on: the codec is bit-lossless, so the run must stay bitwise
        // identical to the local executor while shipping fewer bytes.
        let shapes = [(6usize, 6usize)];
        let blocks = partition(&shapes, 3);
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut local = crate::optim::LocalExecutor::new(&blocks, UnitKind::Shampoo, &base, 1);
        let transports: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec = ShardExecutor::launch_in_proc_with(
            &blocks,
            UnitKind::Shampoo,
            &base,
            1,
            &transports,
            PROTO_VERSION,
            true,
            &MembershipConfig::default(),
        )
        .expect("launch compressed in-proc executor");
        assert_eq!(exec.label(), "shards=2/in-proc+delta");
        let mut p1 = vec![Matrix::zeros(6, 6)];
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(530);
        for t in 1..=8usize {
            let grads = vec![Matrix::randn(6, 6, &mut rng)];
            let ctxs: Vec<StepCtx> = (0..blocks.len())
                .map(|i| StepCtx {
                    t,
                    scale: 1.0,
                    preconditioning: t >= 2,
                    refresh_due: (t + i) % 2 == 0,
                    lr: 0.05,
                    beta1: 0.9,
                    weight_decay: 1e-3,
                    stat_due: true,
                    graft: GraftType::Rmsprop,
                })
                .collect();
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).expect("compressed step");
            assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "diverged at step {t}");
            if t == 4 {
                // Mid-run reconnect: the next encoded step must resync
                // with full frames and keep the numbers identical.
                exec.control().drop_connections();
            }
        }
        let v2_bytes: u64 = transports.iter().map(|t| t.bytes_delivered()).sum();
        assert!(v2_bytes > 0);
    }

    #[test]
    fn duplicated_delta_steps_are_served_from_the_reply_cache() {
        // A replayed StepV3 landing on top of the original (frame
        // duplication inside a delta stream) must be answered with the
        // *same bytes* — before any baseline logic runs. Re-processing
        // would re-fold statistics and re-tag the baselines.
        use crate::coordinator::fault::FaultAction;
        let t = FaultInjectingTransport::with_config(
            // Request frames: 0 = Init, 1 = StepV3 #1, 2 = StepV3 #2
            // (duplicated — it carries Delta payloads).
            FaultScript::none().on_request(2, FaultAction::DuplicateFrame),
            usize::MAX,
            Some(Duration::from_secs(30)),
        );
        let acceptor = t.take_acceptor().unwrap();
        let worker = std::thread::spawn(move || {
            let mut state: Option<WorkerState> = None;
            let mut wid = 0u32;
            while let Ok(mut conn) = acceptor.recv() {
                match handle_conn(&mut conn, &mut state, &mut wid, PROTO_VERSION) {
                    Ok(true) => continue,
                    _ => break,
                }
            }
        });
        let mut conn = t.dial().unwrap();
        let _ = conn.set_timeout(Some(Duration::from_secs(10)));
        match wire::read_msg(&mut conn).unwrap() {
            WireMsg::HelloV6 { compress: true, member: true, heartbeat: true, .. } => {}
            other => panic!("unexpected hello: {other:?}"),
        }
        let init = WireMsg::Init(InitMsg {
            kind: UnitKind::Shampoo.code(),
            rank: 0,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::Rmsprop.code(),
            threads: 1,
            ekfac: false,
            blocks: vec![BlockSpec { index: 0, rows: 3, cols: 3 }],
        });
        wire::write_msg(&mut conn, &init).unwrap();
        assert_eq!(wire::read_msg(&mut conn).unwrap(), WireMsg::Ok);
        let mut rng = Pcg64::new(531);
        let mk_step =
            |t: u64, base_t: u64, pbits: &[u64], gbits: &[u64], base: Option<(&[u64], &[u64])>| {
                WireMsg::StepV3(StepV3Msg {
                    t,
                    base_t,
                    resync: false,
                    scale: 1.0,
                    preconditioning: true,
                    stat_due: true,
                    lr: 0.05,
                    beta1: 0.9,
                    weight_decay: 0.0,
                    entries: vec![StepEntryV3 {
                        index: 0,
                        refresh_due: true,
                        param: DeltaMat::encode(3, 3, pbits, base.map(|(p, _)| p)),
                        grad: DeltaMat::encode(3, 3, gbits, base.map(|(_, g)| g)),
                    }],
                })
            };
        let p1 = mat_bits(&Matrix::zeros(3, 3));
        let g1 = mat_bits(&Matrix::randn(3, 3, &mut rng));
        wire::write_msg(&mut conn, &mk_step(1, 0, &p1, &g1, None)).unwrap();
        let r1 = wire::read_msg(&mut conn).unwrap();
        let p2 = match &r1 {
            WireMsg::StepOkV3(ok) => ok.entries[0].1.resolve(None).unwrap(),
            other => panic!("unexpected reply: {other:?}"),
        };
        // Step 2: delta-encoded against step 1 — this frame duplicates.
        let g2 = mat_bits(&Matrix::randn(3, 3, &mut rng));
        wire::write_msg(&mut conn, &mk_step(2, 1, &p2, &g2, Some((&p1, &g1)))).unwrap();
        let r2 = wire::read_msg(&mut conn).unwrap();
        let r2_dup = wire::read_msg(&mut conn).unwrap();
        assert!(matches!(r2, WireMsg::StepOkV3(_)), "got {r2:?}");
        assert_eq!(
            wire::encode_frame(&r2).unwrap(),
            wire::encode_frame(&r2_dup).unwrap(),
            "duplicate delta step must be served from the reply cache"
        );
        wire::write_msg(&mut conn, &WireMsg::Shutdown).unwrap();
        assert_eq!(wire::read_msg(&mut conn).unwrap(), WireMsg::Ok);
        drop(conn);
        worker.join().unwrap();
    }

    #[test]
    fn delta_base_mismatch_is_rejected_and_resync_recovers() {
        let init = InitMsg {
            kind: UnitKind::Adam.code(),
            rank: 0,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::None.code(),
            threads: 1,
            ekfac: false,
            blocks: vec![BlockSpec { index: 0, rows: 2, cols: 2 }],
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mut rng = Pcg64::new(532);
        let bits = |m: &Matrix| mat_bits(m);
        let p = bits(&Matrix::zeros(2, 2));
        let g = bits(&Matrix::randn(2, 2, &mut rng));
        let mk = |t: u64, base_t: u64, resync: bool, param: DeltaMat, grad: DeltaMat| StepV3Msg {
            t,
            base_t,
            resync,
            scale: 1.0,
            preconditioning: true,
            stat_due: true,
            lr: 0.05,
            beta1: 0.0,
            weight_decay: 0.0,
            entries: vec![StepEntryV3 { index: 0, refresh_due: false, param, grad }],
        };
        // A Delta payload claiming a baseline the worker never saw must
        // be rejected loudly, not XORed against garbage.
        let orphan = mk(
            1,
            7,
            false,
            DeltaMat::Delta { rows: 2, cols: 2, comp: wire::rle_compress(&[0u8; 32]) },
            DeltaMat::encode(2, 2, &g, None),
        );
        let err = ws.process_step_v3(&orphan).unwrap_err();
        assert!(format!("{err:#}").contains("baseline"), "{err:#}");
        // Full frames (the resync path) recover the stream.
        let full =
            mk(1, 0, true, DeltaMat::encode(2, 2, &p, None), DeltaMat::encode(2, 2, &g, None));
        let ok1 = ws.process_step_v3(&full).unwrap();
        assert_eq!(ok1.t, 1);
        assert_eq!(ok1.base_t, 0, "first reply has no baseline to delta against");
        // Steady state: deltas against t=1 decode and the reply deltas
        // against the previous reply.
        let p2 = ok1.entries[0].1.resolve(None).unwrap();
        let g2 = bits(&Matrix::randn(2, 2, &mut rng));
        let step2 = mk(
            2,
            1,
            false,
            DeltaMat::encode(2, 2, &p2, Some(&p)),
            DeltaMat::encode(2, 2, &g2, Some(&g)),
        );
        let ok2 = ws.process_step_v3(&step2).unwrap();
        assert_eq!(ok2.base_t, 1, "steady-state replies delta against the previous reply");
        // A stale tag (t=1 again after t=2 advanced the baseline) is a
        // mismatch, not a silent mis-application.
        let stale = mk(
            3,
            1,
            false,
            DeltaMat::Delta { rows: 2, cols: 2, comp: wire::rle_compress(&[0u8; 32]) },
            DeltaMat::encode(2, 2, &g2, None),
        );
        let err = ws.process_step_v3(&stale).unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"), "{err:#}");
    }

    #[test]
    fn launch_template_renders_argv() {
        let worker_args: Vec<String> =
            ["shard-worker", "--worker-id", "1"].iter().map(|s| s.to_string()).collect();
        let prog = PathBuf::from("/opt/sketchy/sketchy");
        // Placeholder splice in the middle, {shard}/{program} substitution.
        let (p, args) = render_launch_command(
            "ssh worker-{shard}.cluster {program} {worker_cmd} --advertise-host worker-{shard}.cluster",
            &prog,
            1,
            &worker_args,
        )
        .unwrap();
        assert_eq!(p, PathBuf::from("ssh"));
        assert_eq!(
            args,
            vec![
                "worker-1.cluster",
                "/opt/sketchy/sketchy",
                "shard-worker",
                "--worker-id",
                "1",
                "--advertise-host",
                "worker-1.cluster",
            ]
        );
        // No placeholder: worker command appended.
        let (p, args) = render_launch_command("env {program}", &prog, 0, &worker_args).unwrap();
        assert_eq!(p, PathBuf::from("env"));
        assert_eq!(
            args,
            vec!["/opt/sketchy/sketchy", "shard-worker", "--worker-id", "1"]
        );
        // Degenerate templates are refused.
        assert!(render_launch_command("   ", &prog, 0, &worker_args).is_err());
        assert!(render_launch_command("{worker_cmd}", &prog, 0, &worker_args).is_err());
        // An embedded placeholder (missing space) fails fast instead of
        // shipping the literal to the remote argv.
        let glued = "ssh h {program} {worker_cmd}--listen 0.0.0.0:0";
        assert!(render_launch_command(glued, &prog, 0, &worker_args).is_err());
    }

    #[test]
    fn poisoned_worker_table_surfaces_shard_error_not_poison_panic() {
        let shapes = [(4usize, 4usize)];
        let blocks = partition(&shapes, 2);
        let base = ShampooConfig::default();
        let transports: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec = ShardExecutor::launch_in_proc_with(
            &blocks,
            UnitKind::Shampoo,
            &base,
            1,
            &transports,
            PROTO_VERSION,
            false,
            &MembershipConfig::default(),
        )
        .expect("launch executor");
        // Poison the worker-table lock the way a real failure would: a
        // panic while a shared-ref path holds it.
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = exec.workers.lock().unwrap();
            panic!("boom while holding the worker table");
        }));
        assert!(poison.is_err());
        let mut params = vec![Matrix::zeros(4, 4)];
        let grads = vec![Matrix::zeros(4, 4)];
        let ctxs: Vec<StepCtx> = (0..blocks.len())
            .map(|_| StepCtx {
                t: 1,
                scale: 1.0,
                preconditioning: false,
                refresh_due: false,
                lr: 0.05,
                beta1: 0.9,
                weight_decay: 0.0,
                stat_due: true,
                graft: GraftType::Rmsprop,
            })
            .collect();
        let err = exec
            .step_blocks(&blocks, &mut params, &grads, &ctxs)
            .expect_err("a poisoned table must fail the step, not panic");
        assert!(format!("{err:#}").contains("poisoned"), "{err:#}");
        // RefreshAhead declines instead of panicking…
        assert!(!exec.begin_refresh_ahead(RefreshAheadPlan {
            due: vec![false; blocks.len()],
            all: true,
            t_next: 2,
        }));
        assert!(exec.finish_refresh_ahead().is_err());
        // …and diagnostics recover rather than dying on the poison.
        let _ = exec.mem_bytes();
    }

    #[test]
    fn worker_state_rejects_malformed_steps() {
        let init = InitMsg {
            kind: UnitKind::Adam.code(),
            rank: 0,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::None.code(),
            threads: 1,
            ekfac: false,
            blocks: vec![BlockSpec { index: 4, rows: 2, cols: 2 }],
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mk_step = |entries| StepMsg {
            t: 1,
            scale: 1.0,
            preconditioning: true,
            stat_due: true,
            lr: 0.1,
            beta1: 0.0,
            weight_decay: 0.0,
            entries,
        };
        // Unknown block index.
        let bad = mk_step(vec![StepEntry {
            index: 9,
            refresh_due: false,
            param: Matrix::zeros(2, 2),
            grad: Matrix::zeros(2, 2),
        }]);
        assert!(ws.process_step(&bad).is_err());
        // Shape mismatch.
        let bad = mk_step(vec![StepEntry {
            index: 4,
            refresh_due: false,
            param: Matrix::zeros(3, 2),
            grad: Matrix::zeros(3, 2),
        }]);
        assert!(ws.process_step(&bad).is_err());
        // Wrong block count.
        assert!(ws.process_step(&mk_step(vec![])).is_err());
        // Init rejects garbage codes and duplicate blocks.
        assert!(WorkerState::build(&InitMsg { kind: 9, ..init.clone() }).is_err());
        assert!(WorkerState::build(&InitMsg { graft: 77, ..init.clone() }).is_err());
        let dup = InitMsg {
            blocks: vec![
                BlockSpec { index: 4, rows: 2, cols: 2 },
                BlockSpec { index: 4, rows: 2, cols: 2 },
            ],
            ..init
        };
        assert!(WorkerState::build(&dup).is_err());
    }

    /// Step-wide ctx fields shared by the v4 state tests below.
    fn sketch_ctxs(blocks: &[Block], t: usize) -> Vec<StepCtx> {
        (0..blocks.len())
            .map(|i| StepCtx {
                t,
                scale: 1.0,
                preconditioning: t >= 2,
                refresh_due: (t + i) % 2 == 0,
                lr: 0.05,
                beta1: 0.9,
                weight_decay: 1e-3,
                stat_due: true,
                graft: GraftType::Rmsprop,
            })
            .collect()
    }

    #[test]
    fn v4_state_snapshot_restore_over_wire_is_bitwise() {
        // Sketched blocks, so the O(dk) factored payloads actually
        // travel: snapshot a sharded run, check it equals the local
        // executor's snapshot payload for payload, restore it into a
        // *fresh* worker fleet, and the continued run must stay bitwise
        // identical to the local executor — the sketch payloads are
        // lossless factor transports, not approximations.
        let shapes = [(9usize, 6usize)];
        let blocks = partition(&shapes, 5);
        let kind = UnitKind::Sketched { rank: 3 };
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut local = crate::optim::LocalExecutor::new(&blocks, kind, &base, 1);
        let transports: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec = in_proc(&blocks, kind, &base, &transports, PROTO_VERSION, true);
        assert!(exec.state, "v4 workers must report the typed block-state capability");
        let mut p1 = vec![Matrix::zeros(9, 6)];
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(612);
        for t in 1..=5usize {
            let grads = vec![Matrix::randn(9, 6, &mut rng)];
            let ctxs = sketch_ctxs(&blocks, t);
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).unwrap();
            assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "diverged at step {t}");
        }
        // The wire snapshot equals the local snapshot, payload for
        // payload (compare through the canonical codec encoding).
        let local_snaps = local.state_snapshot().unwrap();
        let wire_snaps = exec.state_snapshot().unwrap();
        assert_eq!(local_snaps.len(), wire_snaps.len());
        for (i, (a, b)) in local_snaps.iter().zip(&wire_snaps).enumerate() {
            assert_eq!(
                BlockStateMsg::from_snap(i as u32, a),
                BlockStateMsg::from_snap(i as u32, b),
                "block {i} state differs between local and wire snapshots"
            );
        }
        // Restore into a fresh fleet (blank worker states) and keep
        // stepping: still bitwise against the uninterrupted local run.
        let transports2: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec2 = in_proc(&blocks, kind, &base, &transports2, PROTO_VERSION, true);
        exec2.state_restore(wire_snaps).unwrap();
        let mut p3 = p2.clone();
        for t in 6..=9usize {
            let grads = vec![Matrix::randn(9, 6, &mut rng)];
            let ctxs = sketch_ctxs(&blocks, t);
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec2.step_blocks(&blocks, &mut p3, &grads, &ctxs).unwrap();
            assert_eq!(p1[0].max_diff(&p3[0]), 0.0, "diverged at step {t} after restore");
        }
        // Restore rejects a wrong-length snapshot vector outright.
        assert!(exec2.state_restore(Vec::new()).is_err());
    }

    #[test]
    fn v4_severed_state_rpc_streams_recover_bitwise() {
        // Chaos leg: sever the connection inside the sketch-payload
        // state RPCs — once as the StateSnap request goes out (shard 0),
        // once as the StateSnapOk reply comes back (shard 1), and once
        // as the restore target's StateRestore goes out. The channel's
        // reconnect + replay must absorb all three (StateSnap is a pure
        // read, StateRestore idempotent), and the restored fleet must
        // continue bitwise identical to the local executor.
        use crate::coordinator::fault::FaultAction;
        let shapes = [(9usize, 6usize)];
        let blocks = partition(&shapes, 5);
        let kind = UnitKind::Sketched { rank: 3 };
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut local = crate::optim::LocalExecutor::new(&blocks, kind, &base, 1);
        // Request frames per shard: 0 = Init, 1..=5 = StepV4, 6 = the
        // StateSnap. Reply frames: 0 = hello, 1 = init Ok, 2..=6 =
        // StepOkV4, 7 = the StateSnapOk.
        let transports = vec![
            FaultInjectingTransport::new(
                FaultScript::none().on_request(6, FaultAction::Sever),
            ),
            FaultInjectingTransport::new(FaultScript::none().on_reply(7, FaultAction::Sever)),
        ];
        let mut exec = in_proc(&blocks, kind, &base, &transports, PROTO_VERSION, true);
        let mut p1 = vec![Matrix::zeros(9, 6)];
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(613);
        for t in 1..=5usize {
            let grads = vec![Matrix::randn(9, 6, &mut rng)];
            let ctxs = sketch_ctxs(&blocks, t);
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).unwrap();
        }
        let local_snaps = local.state_snapshot().unwrap();
        let wire_snaps = exec.state_snapshot().expect("snapshot must survive both severs");
        assert_eq!(transports[0].connections(), 2, "shard 0 reconnected mid-snap");
        assert_eq!(transports[1].connections(), 2, "shard 1 reconnected mid-snap");
        for (i, (a, b)) in local_snaps.iter().zip(&wire_snaps).enumerate() {
            assert_eq!(
                BlockStateMsg::from_snap(i as u32, a),
                BlockStateMsg::from_snap(i as u32, b),
                "block {i} state differs after severed snapshot RPCs"
            );
        }
        // Restore target: sever the StateRestore request itself
        // (request frames: 0 = Init, 1 = StateRestore).
        let transports2 = vec![
            FaultInjectingTransport::new(
                FaultScript::none().on_request(1, FaultAction::Sever),
            ),
            FaultInjectingTransport::new(FaultScript::none()),
        ];
        let mut exec2 = in_proc(&blocks, kind, &base, &transports2, PROTO_VERSION, true);
        exec2.state_restore(wire_snaps).expect("restore must survive the sever");
        assert_eq!(transports2[0].connections(), 2, "restore target reconnected mid-restore");
        let mut p3 = p2.clone();
        for t in 6..=9usize {
            let grads = vec![Matrix::randn(9, 6, &mut rng)];
            let ctxs = sketch_ctxs(&blocks, t);
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec2.step_blocks(&blocks, &mut p3, &grads, &ctxs).unwrap();
            assert_eq!(p1[0].max_diff(&p3[0]), 0.0, "diverged at step {t} after chaos restore");
        }
    }

    #[test]
    fn v4_driver_degrades_to_v3_worker_without_state_capability() {
        // Mixed-version deployment: v4 driver, workers pinned at v3.
        // Steps keep the delta payload layer and stay bitwise; the
        // state RPCs fail loudly with the capability message instead of
        // wedging the wire or half-restoring anything.
        let shapes = [(6usize, 6usize)];
        let blocks = partition(&shapes, 3);
        let kind = UnitKind::Sketched { rank: 2 };
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut local = crate::optim::LocalExecutor::new(&blocks, kind, &base, 1);
        let transports: Vec<_> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut exec = in_proc(&blocks, kind, &base, &transports, 3, true);
        assert!(!exec.state, "v3 greetings must not report the typed-state capability");
        assert!(exec.overlap_capable(), "v3 keeps the overlap capability");
        let mut p1 = vec![Matrix::zeros(6, 6)];
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(614);
        for t in 1..=5usize {
            let grads = vec![Matrix::randn(6, 6, &mut rng)];
            let ctxs = sketch_ctxs(&blocks, t);
            local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
            exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).unwrap();
            assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "mixed-version run diverged at step {t}");
        }
        let err = exec.state_snapshot().expect_err("v3 links must refuse state snapshots");
        assert!(format!("{err:#}").contains("below wire protocol v4"), "{err:#}");
        let snaps = local.state_snapshot().unwrap();
        let err = exec.state_restore(snaps).expect_err("v3 links must refuse state restore");
        assert!(format!("{err:#}").contains("below wire protocol v4"), "{err:#}");
        // The refusal is clean: the wire still steps bitwise afterwards.
        let grads = vec![Matrix::randn(6, 6, &mut rng)];
        let ctxs = sketch_ctxs(&blocks, 6);
        local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
        exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).unwrap();
        assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "diverged after refused state RPCs");
    }

    #[test]
    fn worker_state_restore_validates_batch_before_applying() {
        // One good + one bad entry: the worker must reject the batch
        // and leave even the *good* block untouched — no half-restored
        // worker — and a fully valid self-restore must be bitwise
        // idempotent.
        let init = InitMsg {
            kind: UnitKind::Sketched { rank: 2 }.code(),
            rank: 2,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::Rmsprop.code(),
            threads: 1,
            ekfac: false,
            blocks: vec![
                BlockSpec { index: 0, rows: 4, cols: 3 },
                BlockSpec { index: 1, rows: 4, cols: 3 },
            ],
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mut rng = Pcg64::new(640);
        let step = StepMsg {
            t: 1,
            scale: 1.0,
            preconditioning: true,
            stat_due: true,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 0.0,
            entries: (0..2)
                .map(|i| StepEntry {
                    index: i,
                    refresh_due: true,
                    param: Matrix::zeros(4, 3),
                    grad: Matrix::randn(4, 3, &mut rng),
                })
                .collect(),
        };
        ws.process_step(&step).unwrap();
        let before = ws.process_state_snap(&StateSnapMsg { want: vec![] }).unwrap();
        assert_eq!(before.entries.len(), 2);
        // Block 0 keeps its own valid payload; block 1 smuggles a
        // foreign-shaped momentum.
        let good = before.entries[0].clone();
        let mut bad = before.entries[1].clone();
        bad.mu = BlockPayload::dense(&Matrix::zeros(9, 9));
        let err = ws
            .process_state_restore(&StateRestoreMsg { entries: vec![good, bad] })
            .unwrap_err();
        assert!(format!("{err:#}").contains("block 1"), "{err:#}");
        // Unknown indices are rejected before anything resolves.
        let mut foreign = before.entries[0].clone();
        foreign.index = 7;
        assert!(ws
            .process_state_restore(&StateRestoreMsg { entries: vec![foreign] })
            .is_err());
        // The worker is bitwise untouched by the rejected batches.
        let after = ws.process_state_snap(&StateSnapMsg { want: vec![] }).unwrap();
        assert_eq!(before, after, "a rejected batch must not half-restore");
        // A fully valid self-restore lands and is bitwise idempotent.
        ws.process_state_restore(&StateRestoreMsg { entries: before.entries.clone() }).unwrap();
        let again = ws.process_state_snap(&StateSnapMsg { want: vec![] }).unwrap();
        assert_eq!(before, again, "self-restore must be bitwise idempotent");
        // Narrow snapshots honor the want-list and reject unknowns.
        let one = ws.process_state_snap(&StateSnapMsg { want: vec![1] }).unwrap();
        assert_eq!(one.entries.len(), 1);
        assert_eq!(one.entries[0].index, 1);
        assert!(ws.process_state_snap(&StateSnapMsg { want: vec![9] }).is_err());
    }
}
