//! Cross-process sharded block execution.
//!
//! The §3.4 blocked engine (`optim::engine`) parallelizes preconditioner
//! blocks within one process; this module shards them **across worker
//! processes**. The driver partitions the engine's block list over N
//! `sketchy shard-worker` processes (spawned from the same binary),
//! ships each shard its gathered block statistics, drives
//! `Preconditioner::ingest/refresh/apply` remotely, and scatters the
//! returned parameter blocks back — the engine's gather → drive →
//! scatter step *is* the RPC boundary.
//!
//! Transport is localhost TCP or a Unix domain socket, speaking the
//! length-prefixed codec of [`super::wire`]. Workers announce their
//! listen address on stdout (`SKETCHY-SHARD-LISTENING <transport>
//! <addr>`), keep all block state in-process across connections, and
//! cache their last step reply keyed by `t` — so the driver can
//! reconnect after a transport failure and replay the in-flight request
//! without double-applying it. Hard worker failures (a dead process)
//! surface as `anyhow` errors naming the shard.
//!
//! Determinism: every block's math runs in exactly one place, parameter
//! payloads travel as raw IEEE-754 bits, and the scatter writes each
//! disjoint block window directly — so an N-shard run is **bitwise
//! identical** to the in-process engine (`tests/shard_determinism.rs`
//! and the CI `shard-smoke` job assert this for N ∈ {2, 4}).

use super::wire::{self, BlockSpec, InitMsg, StepEntry, StepMsg, StepOkMsg, WireMsg};
use crate::optim::engine::{drive_all, effective_worker_threads, BlockExecutor, UnitKind};
use crate::optim::precond::{BlockState, StepCtx};
use crate::optim::{Block, GraftType, ShampooConfig};
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::config::Config;
use anyhow::{anyhow, bail, ensure, Context};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Stdout handshake prefix a worker prints once its listener is bound.
const LISTEN_PREFIX: &str = "SKETCHY-SHARD-LISTENING ";

/// Bound on establishing a TCP connection to a worker.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Bound on waiting for any single worker reply. A hung (not dead)
/// worker then surfaces as a shard-named error instead of freezing the
/// driver; generous enough for a stale-schedule eigendecomposition burst
/// on paper-scale (1024) blocks.
const REPLY_TIMEOUT: Duration = Duration::from_secs(120);

// ---------------------------------------------------------------------------
// Configuration.
// ---------------------------------------------------------------------------

/// Wire transport between driver and shard workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardTransport {
    /// Localhost TCP (portable default).
    Tcp,
    /// Unix domain socket (lower latency; unix targets only).
    #[cfg(unix)]
    Unix,
}

impl ShardTransport {
    /// Parse a `--shard-transport` / `shard.transport` value.
    pub fn parse(s: &str) -> anyhow::Result<ShardTransport> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Ok(ShardTransport::Tcp),
            #[cfg(unix)]
            "unix" => Ok(ShardTransport::Unix),
            #[cfg(not(unix))]
            "unix" => bail!("shard transport 'unix' is unavailable on this platform"),
            other => bail!("unknown shard transport {other:?} (expected tcp or unix)"),
        }
    }
}

impl std::fmt::Display for ShardTransport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardTransport::Tcp => f.write_str("tcp"),
            #[cfg(unix)]
            ShardTransport::Unix => f.write_str("unix"),
        }
    }
}

/// Sharding knobs, resolvable from CLI flags and `[shard]` config keys
/// (same precedence discipline as [`crate::optim::EngineConfig::resolve`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Worker process count (0 = sharding disabled, run in-process).
    pub shards: usize,
    /// Wire transport for the worker links.
    pub transport: ShardTransport,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { shards: 0, transport: ShardTransport::Tcp }
    }
}

impl ShardConfig {
    /// Resolve from `--shards` / `--shard-transport` CLI flags with
    /// `shard.count` / `shard.transport` config keys as fallback.
    pub fn resolve(args: &Args, cfg: &Config) -> anyhow::Result<ShardConfig> {
        let d = ShardConfig::default();
        let shards = args.get_usize("shards", cfg.usize_or("shard.count", d.shards));
        let transport = match args.get("shard-transport") {
            Some(s) => ShardTransport::parse(s)?,
            None => ShardTransport::parse(&cfg.str_or("shard.transport", "tcp"))?,
        };
        Ok(ShardConfig { shards, transport })
    }

    /// Whether cross-process sharding is requested.
    pub fn enabled(&self) -> bool {
        self.shards >= 1
    }
}

/// How to start shard workers: which binary to exec, how many shards,
/// which transport.
#[derive(Clone, Debug)]
pub struct ShardLaunch {
    /// Binary exposing the `shard-worker` subcommand (normally this
    /// process's own executable; tests pass `CARGO_BIN_EXE_sketchy`).
    pub program: PathBuf,
    pub shards: usize,
    pub transport: ShardTransport,
}

impl ShardLaunch {
    /// Launch plan re-execing the current binary.
    pub fn current_exe(cfg: &ShardConfig) -> anyhow::Result<ShardLaunch> {
        ensure!(cfg.shards >= 1, "shard launch requires --shards >= 1");
        Ok(ShardLaunch {
            program: std::env::current_exe().context("resolve current executable")?,
            shards: cfg.shards,
            transport: cfg.transport,
        })
    }
}

/// Deterministic contiguous block partition: shard `s` owns a balanced
/// run of consecutive block indices (earlier shards take the remainder).
pub fn assign_blocks(n_blocks: usize, shards: usize) -> Vec<Vec<usize>> {
    assert!(shards >= 1, "assign_blocks requires at least one shard");
    let base = n_blocks / shards;
    let extra = n_blocks % shards;
    let mut out = Vec::with_capacity(shards);
    let mut next = 0;
    for s in 0..shards {
        let take = base + usize::from(s < extra);
        out.push((next..next + take).collect());
        next += take;
    }
    out
}

// ---------------------------------------------------------------------------
// Transport plumbing shared by both sides.
// ---------------------------------------------------------------------------

/// A connected driver↔worker byte stream.
enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn set_read_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(dur),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A worker's announced listen address.
#[derive(Clone, Debug, PartialEq, Eq)]
enum WorkerAddr {
    Tcp(String),
    #[cfg(unix)]
    Unix(PathBuf),
}

/// Parse a worker's stdout handshake line.
fn parse_listen_line(line: &str) -> Option<WorkerAddr> {
    let rest = line.trim().strip_prefix(LISTEN_PREFIX)?;
    let (kind, addr) = rest.split_once(' ')?;
    match kind {
        "tcp" => Some(WorkerAddr::Tcp(addr.to_string())),
        #[cfg(unix)]
        "unix" => Some(WorkerAddr::Unix(PathBuf::from(addr))),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Worker side: `sketchy shard-worker`.
// ---------------------------------------------------------------------------

/// Block states owned by one worker process. Persists across
/// connections so the driver can reconnect without losing statistics.
struct WorkerState {
    graft: GraftType,
    /// Thread knob for the worker's own block pool (0 = auto).
    threads: usize,
    states: Vec<Mutex<BlockState>>,
    /// Global block index → local slot.
    slot_of: BTreeMap<u32, usize>,
    /// Last step reply, keyed by `t` — replayed verbatim when the driver
    /// retries a step after a reconnect (idempotency).
    last_step: Option<(u64, WireMsg)>,
}

impl WorkerState {
    fn build(init: &InitMsg) -> anyhow::Result<WorkerState> {
        let kind = UnitKind::from_code(init.kind, init.rank as usize)
            .ok_or_else(|| anyhow!("unknown unit kind code {}", init.kind))?;
        let graft = GraftType::from_code(init.graft)
            .ok_or_else(|| anyhow!("unknown graft code {}", init.graft))?;
        // Only beta2 / eps / one_sided / graft reach unit construction;
        // per-step knobs (lr, momentum, decay, schedule) travel in every
        // Step message, so the worker needs no full driver config.
        let base = ShampooConfig {
            beta2: init.beta2,
            eps: init.eps,
            one_sided: init.one_sided,
            graft,
            ..Default::default()
        };
        let mut states = Vec::with_capacity(init.blocks.len());
        let mut slot_of = BTreeMap::new();
        for (slot, b) in init.blocks.iter().enumerate() {
            ensure!(b.rows > 0 && b.cols > 0, "block {} has empty shape", b.index);
            ensure!(
                slot_of.insert(b.index, slot).is_none(),
                "duplicate block index {} in init",
                b.index
            );
            let shape = (b.rows as usize, b.cols as usize);
            states.push(Mutex::new(BlockState::new(
                kind.make(shape, &base),
                graft,
                shape,
                init.beta2,
            )));
        }
        Ok(WorkerState {
            graft,
            threads: init.threads as usize,
            states,
            slot_of,
            last_step: None,
        })
    }

    fn process_step(&mut self, msg: &StepMsg) -> anyhow::Result<StepOkMsg> {
        ensure!(
            msg.entries.len() == self.states.len(),
            "step carries {} blocks, shard owns {}",
            msg.entries.len(),
            self.states.len()
        );
        let mut ctxs: Vec<Option<StepCtx>> = vec![None; self.states.len()];
        for ent in &msg.entries {
            let slot = *self
                .slot_of
                .get(&ent.index)
                .ok_or_else(|| anyhow!("unknown block index {}", ent.index))?;
            ensure!(ctxs[slot].is_none(), "duplicate entry for block {}", ent.index);
            let st = self.states[slot].get_mut().unwrap();
            ensure!(
                ent.param.shape() == st.param.shape() && ent.grad.shape() == st.grad.shape(),
                "block {} shape mismatch: got {:?}/{:?}, own {:?}",
                ent.index,
                ent.param.shape(),
                ent.grad.shape(),
                st.param.shape()
            );
            st.param.as_mut_slice().copy_from_slice(ent.param.as_slice());
            st.grad.as_mut_slice().copy_from_slice(ent.grad.as_slice());
            ctxs[slot] = Some(StepCtx {
                t: msg.t as usize,
                scale: msg.scale,
                preconditioning: msg.preconditioning,
                refresh_due: ent.refresh_due,
                lr: msg.lr,
                beta1: msg.beta1,
                weight_decay: msg.weight_decay,
                stat_due: msg.stat_due,
                graft: self.graft,
            });
        }
        let ctxs: Vec<StepCtx> = ctxs
            .into_iter()
            .map(|c| c.ok_or_else(|| anyhow!("step is missing an assigned block")))
            .collect::<anyhow::Result<_>>()?;
        let threads = effective_worker_threads(self.threads, self.states.len());
        let refreshes = drive_all(&self.states, &ctxs, threads)?;
        let mut entries = Vec::with_capacity(msg.entries.len());
        for ent in &msg.entries {
            let slot = self.slot_of[&ent.index];
            entries.push((ent.index, self.states[slot].get_mut().unwrap().param.clone()));
        }
        Ok(StepOkMsg { t: msg.t, refreshes: refreshes as u32, entries })
    }

    fn mem_stats(&mut self) -> (u64, u64) {
        let mut mem = 0u64;
        let mut second = 0u64;
        for s in &mut self.states {
            let st = s.get_mut().unwrap();
            mem += st.mem_bytes() as u64;
            second += st.second_moment_bytes() as u64;
        }
        (mem, second)
    }
}

/// Serve one connection. `Ok(true)` keeps the worker alive for further
/// connections (reconnect support); `Ok(false)` means clean shutdown.
fn handle_conn<S: Read + Write>(
    stream: &mut S,
    state: &mut Option<WorkerState>,
    worker_id: u32,
) -> anyhow::Result<bool> {
    wire::write_msg(stream, &WireMsg::Hello { worker_id })?;
    loop {
        let msg = match wire::read_msg_opt(stream)? {
            None => return Ok(true), // driver closed; await a reconnect
            Some(m) => m,
        };
        match msg {
            WireMsg::Init(init) => {
                let reply = match WorkerState::build(&init) {
                    Ok(ws) => {
                        *state = Some(ws);
                        WireMsg::Ok
                    }
                    Err(e) => WireMsg::Error { message: format!("init: {e:#}") },
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::Step(step) => {
                let reply = match state.as_mut() {
                    None => WireMsg::Error { message: "step before init".into() },
                    Some(ws) => match &ws.last_step {
                        Some((t, cached)) if *t == step.t => cached.clone(),
                        _ => match ws.process_step(&step) {
                            Ok(ok) => {
                                let reply = WireMsg::StepOk(ok);
                                ws.last_step = Some((step.t, reply.clone()));
                                reply
                            }
                            Err(e) => {
                                WireMsg::Error { message: format!("step t={}: {e:#}", step.t) }
                            }
                        },
                    },
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::MemStats => {
                let reply = match state.as_mut() {
                    None => WireMsg::MemStatsOk { mem_bytes: 0, second_moment_bytes: 0 },
                    Some(ws) => {
                        let (mem_bytes, second_moment_bytes) = ws.mem_stats();
                        WireMsg::MemStatsOk { mem_bytes, second_moment_bytes }
                    }
                };
                wire::write_msg(stream, &reply)?;
            }
            WireMsg::Shutdown => {
                wire::write_msg(stream, &WireMsg::Ok)?;
                return Ok(false);
            }
            other => {
                let reply =
                    WireMsg::Error { message: format!("unexpected driver message: {other:?}") };
                wire::write_msg(stream, &reply)?;
            }
        }
    }
}

fn announce(detail: &str) -> anyhow::Result<()> {
    let mut out = std::io::stdout();
    writeln!(out, "{LISTEN_PREFIX}{detail}").context("announce listen address")?;
    out.flush().context("flush listen address")?;
    Ok(())
}

/// Entry point for the `sketchy shard-worker` subcommand: bind a
/// listener, announce it on stdout, then serve driver connections until
/// a `Shutdown` message arrives. Block state persists across
/// connections; per-connection transport errors are logged and the
/// worker keeps listening.
pub fn serve_worker(args: &Args) -> anyhow::Result<()> {
    let worker_id = args.get_usize("worker-id", 0) as u32;
    let transport = ShardTransport::parse(&args.get_or("transport", "tcp"))?;
    let mut state: Option<WorkerState> = None;
    match transport {
        ShardTransport::Tcp => {
            let listener = TcpListener::bind("127.0.0.1:0").context("shard worker: bind tcp")?;
            let addr = listener.local_addr().context("shard worker: local addr")?;
            announce(&format!("tcp {addr}"))?;
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: accept failed: {e}");
                        continue;
                    }
                };
                match handle_conn(&mut stream, &mut state, worker_id) {
                    Ok(true) => continue,
                    Ok(false) => break,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: connection error: {e:#}");
                        continue;
                    }
                }
            }
        }
        #[cfg(unix)]
        ShardTransport::Unix => {
            let dir = args
                .get("socket-dir")
                .map(PathBuf::from)
                .unwrap_or_else(std::env::temp_dir);
            let path = dir.join(format!(
                "sketchy-shard-{worker_id}-{}.sock",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .with_context(|| format!("shard worker: bind {}", path.display()))?;
            announce(&format!("unix {}", path.display()))?;
            loop {
                let mut stream = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: accept failed: {e}");
                        continue;
                    }
                };
                match handle_conn(&mut stream, &mut state, worker_id) {
                    Ok(true) => continue,
                    Ok(false) => break,
                    Err(e) => {
                        eprintln!("shard worker {worker_id}: connection error: {e:#}");
                        continue;
                    }
                }
            }
            let _ = std::fs::remove_file(&path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Driver side.
// ---------------------------------------------------------------------------

/// One spawned worker process plus its (reconnectable) connection.
struct WorkerProc {
    shard: usize,
    child: Child,
    addr: WorkerAddr,
    conn: Option<Stream>,
    /// Encoded frame of the last request, replayed after a reconnect
    /// (safe: the worker deduplicates steps by `t`).
    last_req: Vec<u8>,
    /// Held so late worker prints land in the pipe instead of EPIPE.
    _stdout: BufReader<ChildStdout>,
}

impl WorkerProc {
    fn spawn(launch: &ShardLaunch, shard: usize) -> anyhow::Result<WorkerProc> {
        let mut cmd = Command::new(&launch.program);
        cmd.arg("shard-worker")
            .arg("--worker-id")
            .arg(shard.to_string())
            .arg("--transport")
            .arg(launch.transport.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit());
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawn {} shard-worker", launch.program.display()))?;
        let stdout = child
            .stdout
            .take()
            .ok_or_else(|| anyhow!("worker stdout pipe missing"))?;
        let mut reader = BufReader::new(stdout);
        let addr = loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).context("read worker handshake")?;
            if n == 0 {
                let _ = child.kill();
                let _ = child.wait();
                bail!("worker exited before announcing a listen address");
            }
            if let Some(addr) = parse_listen_line(&line) {
                break addr;
            }
            // Tolerate stray prints ahead of the announcement.
        };
        Ok(WorkerProc { shard, child, addr, conn: None, last_req: Vec::new(), _stdout: reader })
    }

    fn connect(&mut self) -> anyhow::Result<()> {
        let mut stream = match &self.addr {
            WorkerAddr::Tcp(addr) => {
                let sock = addr
                    .to_socket_addrs()
                    .with_context(|| format!("resolve {addr}"))?
                    .next()
                    .ok_or_else(|| anyhow!("no socket addr in {addr}"))?;
                Stream::Tcp(
                    TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)
                        .with_context(|| format!("connect tcp {addr}"))?,
                )
            }
            #[cfg(unix)]
            WorkerAddr::Unix(path) => Stream::Unix(
                UnixStream::connect(path)
                    .with_context(|| format!("connect unix {}", path.display()))?,
            ),
        };
        // Bound every reply wait: a wedged worker becomes a shard-named
        // error (after one reconnect attempt) instead of a frozen driver.
        let _ = stream.set_read_timeout(Some(REPLY_TIMEOUT));
        match wire::read_msg(&mut stream).context("read worker hello")? {
            WireMsg::Hello { worker_id } if worker_id as usize == self.shard => {}
            WireMsg::Hello { worker_id } => {
                bail!("worker identity mismatch: got {worker_id}, want {}", self.shard)
            }
            other => bail!("expected hello, got {other:?}"),
        }
        if let Stream::Tcp(t) = &stream {
            // Step frames are small; don't let Nagle delay them.
            let _ = t.set_nodelay(true);
        }
        self.conn = Some(stream);
        Ok(())
    }

    fn try_send(&mut self, frame: &[u8]) -> anyhow::Result<()> {
        if self.conn.is_none() {
            self.connect()?;
        }
        let conn = self.conn.as_mut().unwrap();
        conn.write_all(frame).context("write frame")?;
        conn.flush().context("flush frame")?;
        Ok(())
    }

    /// Send a request, reconnecting once on transport failure.
    fn send(&mut self, msg: &WireMsg) -> anyhow::Result<()> {
        let frame = wire::encode_frame(msg)?;
        if let Err(first) = self.try_send(&frame) {
            self.conn = None;
            self.try_send(&frame)
                .with_context(|| format!("resend after transport error ({first:#})"))?;
        }
        self.last_req = frame;
        Ok(())
    }

    /// Receive the pending reply. On transport failure, reconnect and
    /// replay the last request once — the worker's step cache makes the
    /// replay idempotent even if the original request already applied.
    fn recv(&mut self) -> anyhow::Result<WireMsg> {
        let first = match self.conn.as_mut() {
            Some(conn) => wire::read_msg(conn),
            None => Err(anyhow!("not connected")),
        };
        match first {
            Ok(msg) => Ok(msg),
            Err(first) => {
                self.conn = None;
                let frame = self.last_req.clone();
                ensure!(!frame.is_empty(), "no request to replay after {first:#}");
                self.try_send(&frame)
                    .with_context(|| format!("reconnect after transport error ({first:#})"))?;
                let conn = self.conn.as_mut().unwrap();
                wire::read_msg(conn)
                    .with_context(|| format!("reply after reconnect ({first:#})"))
            }
        }
    }

    fn request(&mut self, msg: &WireMsg) -> anyhow::Result<WireMsg> {
        self.send(msg)?;
        self.recv()
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        // Graceful stop: Shutdown over the live connection, short grace
        // period, then SIGKILL as the backstop.
        let graceful = match self.conn.as_mut() {
            Some(conn) => {
                let _ = conn.set_read_timeout(Some(Duration::from_secs(2)));
                match wire::encode_frame(&WireMsg::Shutdown) {
                    Ok(frame) => {
                        conn.write_all(&frame).and_then(|_| conn.flush()).is_ok()
                            && wire::read_msg(conn).is_ok()
                    }
                    Err(_) => false,
                }
            }
            None => false,
        };
        if graceful {
            let deadline = Instant::now() + Duration::from_secs(2);
            loop {
                match self.child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    _ => {
                        let _ = self.child.kill();
                        let _ = self.child.wait();
                        break;
                    }
                }
            }
        } else {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
        #[cfg(unix)]
        if let WorkerAddr::Unix(path) = &self.addr {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// [`BlockExecutor`] driving blocks across worker processes.
pub struct ShardExecutor {
    /// Mutex for interior mutability: `mem_bytes` RPCs through `&self`.
    workers: Mutex<Vec<WorkerProc>>,
    /// shard → owned global block indices.
    assignment: Vec<Vec<usize>>,
    transport: ShardTransport,
}

impl ShardExecutor {
    /// Spawn `launch.shards` workers (capped at the block count), assign
    /// contiguous block runs, and initialize each worker's states.
    pub fn launch(
        launch: &ShardLaunch,
        blocks: &[Block],
        kind: UnitKind,
        base: &ShampooConfig,
        threads: usize,
    ) -> anyhow::Result<ShardExecutor> {
        ensure!(launch.shards >= 1, "shard launch requires at least one shard");
        ensure!(!blocks.is_empty(), "shard launch requires at least one block");
        let shards = launch.shards.min(blocks.len());
        let assignment = assign_blocks(blocks.len(), shards);
        // threads = 0 (auto) means "all cores" — but N colocated workers
        // each doing that would oversubscribe the host N-fold. Split the
        // auto budget across shards; an explicit knob passes through
        // untouched. Thread counts never change the numbers.
        let worker_threads = if threads == 0 {
            (crate::tensor::ops::num_threads() / shards).max(1)
        } else {
            threads
        };
        let mut workers = Vec::with_capacity(shards);
        for (shard, owned) in assignment.iter().enumerate() {
            let mut w = WorkerProc::spawn(launch, shard)
                .with_context(|| format!("shard {shard}: spawn worker"))?;
            let specs: Vec<BlockSpec> = owned
                .iter()
                .map(|&i| {
                    let (rows, cols) = blocks[i].shape();
                    BlockSpec { index: i as u32, rows: rows as u32, cols: cols as u32 }
                })
                .collect();
            let init = WireMsg::Init(InitMsg {
                kind: kind.code(),
                rank: kind.rank() as u32,
                beta2: base.beta2,
                eps: base.eps,
                one_sided: base.one_sided,
                graft: base.graft.code(),
                threads: worker_threads as u32,
                blocks: specs,
            });
            match w.request(&init).with_context(|| format!("shard {shard}: init"))? {
                WireMsg::Ok => {}
                WireMsg::Error { message } => bail!("shard {shard}: init failed: {message}"),
                other => bail!("shard {shard}: unexpected init reply {other:?}"),
            }
            workers.push(w);
        }
        Ok(ShardExecutor {
            workers: Mutex::new(workers),
            assignment,
            transport: launch.transport,
        })
    }

    /// Worker process count actually launched.
    pub fn shards(&self) -> usize {
        self.assignment.len()
    }

    /// Fault injection for tests: SIGKILL one worker process. The next
    /// step surfaces an error naming the shard.
    pub fn kill_worker(&mut self, shard: usize) -> anyhow::Result<()> {
        let workers = self.workers.get_mut().unwrap();
        let w = workers
            .get_mut(shard)
            .ok_or_else(|| anyhow!("no shard {shard}"))?;
        w.child.kill().context("kill worker")?;
        let _ = w.child.wait();
        Ok(())
    }

    /// Fault injection for tests: drop every driver-side connection.
    /// The next request reconnects transparently (workers keep state).
    pub fn drop_connections(&mut self) {
        for w in self.workers.get_mut().unwrap().iter_mut() {
            w.conn = None;
        }
    }

    fn mem_stats_total(&self) -> (usize, usize) {
        let mut workers = self.workers.lock().unwrap();
        let mut mem = 0usize;
        let mut second = 0usize;
        for w in workers.iter_mut() {
            match w.request(&WireMsg::MemStats) {
                Ok(WireMsg::MemStatsOk { mem_bytes, second_moment_bytes }) => {
                    mem += mem_bytes as usize;
                    second += second_moment_bytes as usize;
                }
                Ok(other) => {
                    eprintln!("shard {}: unexpected memstats reply {other:?}", w.shard);
                }
                Err(e) => eprintln!("shard {}: memstats failed: {e:#}", w.shard),
            }
        }
        (mem, second)
    }
}

impl BlockExecutor for ShardExecutor {
    fn step_blocks(
        &mut self,
        blocks: &[Block],
        params: &mut [Matrix],
        grads: &[Matrix],
        ctxs: &[StepCtx],
    ) -> anyhow::Result<usize> {
        if blocks.is_empty() {
            return Ok(0);
        }
        debug_assert_eq!(blocks.len(), ctxs.len());
        // The wire ships the step-wide ctx fields once per shard (only
        // refresh_due varies across blocks in the engine's schedule).
        // Reject heterogeneous batches loudly instead of silently
        // applying ctxs[0] to every block.
        let common = &ctxs[0];
        for (i, c) in ctxs.iter().enumerate() {
            ensure!(
                c.t == common.t
                    && c.scale.to_bits() == common.scale.to_bits()
                    && c.preconditioning == common.preconditioning
                    && c.stat_due == common.stat_due
                    && c.lr.to_bits() == common.lr.to_bits()
                    && c.beta1.to_bits() == common.beta1.to_bits()
                    && c.weight_decay.to_bits() == common.weight_decay.to_bits()
                    && c.graft == common.graft,
                "block {i}: ctx differs from block 0 in a step-wide field \
                 (only refresh_due may vary across blocks on the shard wire)"
            );
        }
        let ShardExecutor { workers, assignment, .. } = self;
        let workers = workers.get_mut().unwrap();
        // Ship every shard its gathered block statistics first, then
        // collect replies in shard order — workers compute concurrently.
        for (shard, w) in workers.iter_mut().enumerate() {
            let entries: Vec<StepEntry> = assignment[shard]
                .iter()
                .map(|&i| {
                    let b = &blocks[i];
                    StepEntry {
                        index: i as u32,
                        refresh_due: ctxs[i].refresh_due,
                        param: params[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                        grad: grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                    }
                })
                .collect();
            let msg = WireMsg::Step(StepMsg {
                t: common.t as u64,
                scale: common.scale,
                preconditioning: common.preconditioning,
                stat_due: common.stat_due,
                lr: common.lr,
                beta1: common.beta1,
                weight_decay: common.weight_decay,
                entries,
            });
            w.send(&msg)
                .with_context(|| format!("shard {shard}: send step t={}", common.t))?;
        }
        let mut refreshes = 0usize;
        for (shard, w) in workers.iter_mut().enumerate() {
            let reply = w
                .recv()
                .with_context(|| format!("shard {shard}: step t={} reply", common.t))?;
            let ok = match reply {
                WireMsg::StepOk(ok) => ok,
                WireMsg::Error { message } => bail!("shard {shard}: worker error: {message}"),
                other => bail!("shard {shard}: unexpected step reply {other:?}"),
            };
            ensure!(
                ok.t == common.t as u64,
                "shard {shard}: reply for step {} while driving step {}",
                ok.t,
                common.t
            );
            ensure!(
                ok.entries.len() == assignment[shard].len(),
                "shard {shard}: returned {} blocks, owns {}",
                ok.entries.len(),
                assignment[shard].len()
            );
            refreshes += ok.refreshes as usize;
            // Ownership bounds: assignments are contiguous runs, so a
            // range check validates each returned index in O(1).
            let (own_lo, own_hi) = match (assignment[shard].first(), assignment[shard].last()) {
                (Some(&lo), Some(&hi)) => (lo, hi),
                _ => (1, 0), // empty shard: any index is foreign
            };
            // Scatter: write each returned block into its disjoint
            // parameter window (bitwise — payloads are raw f64 bits).
            for (index, block_param) in &ok.entries {
                let i = *index as usize;
                ensure!(
                    i >= own_lo && i <= own_hi && i < blocks.len(),
                    "shard {shard}: returned foreign block {i}"
                );
                let b = &blocks[i];
                ensure!(
                    block_param.shape() == b.shape(),
                    "shard {shard}: block {i} shape {:?}, want {:?}",
                    block_param.shape(),
                    b.shape()
                );
                params[b.tensor].set_slice(b.r0, b.c0, block_param);
            }
        }
        Ok(refreshes)
    }

    fn mem_bytes(&self) -> usize {
        self.mem_stats_total().0
    }

    fn second_moment_bytes(&self) -> usize {
        self.mem_stats_total().1
    }

    fn label(&self) -> String {
        format!("shards={}/{}", self.assignment.len(), self.transport)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::engine::{EngineConfig, PrecondEngine};
    use crate::optim::matrix_opt::Optimizer;
    use crate::optim::partition;
    use crate::util::rng::Pcg64;

    #[test]
    fn assignment_is_balanced_contiguous_and_total() {
        let a = assign_blocks(10, 3);
        assert_eq!(a, vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
        let b = assign_blocks(2, 4);
        assert_eq!(b, vec![vec![0], vec![1], vec![], vec![]]);
        let c = assign_blocks(0, 2);
        assert_eq!(c, vec![Vec::<usize>::new(), vec![]]);
        // Determinism: same inputs, same partition.
        assert_eq!(assign_blocks(10, 3), a);
    }

    #[test]
    fn transport_parse_and_display() {
        assert_eq!(ShardTransport::parse("tcp").unwrap(), ShardTransport::Tcp);
        assert_eq!(ShardTransport::parse("TCP").unwrap(), ShardTransport::Tcp);
        assert!(ShardTransport::parse("carrier-pigeon").is_err());
        assert_eq!(ShardTransport::Tcp.to_string(), "tcp");
        #[cfg(unix)]
        {
            assert_eq!(ShardTransport::parse("unix").unwrap(), ShardTransport::Unix);
            assert_eq!(ShardTransport::Unix.to_string(), "unix");
        }
    }

    #[test]
    fn shard_config_resolution_precedence() {
        let cfg = Config::parse("[shard]\ncount = 3\ntransport = \"tcp\"").unwrap();
        let args = Args::parse(["train", "--shards", "2"].iter().map(|s| s.to_string()));
        let sc = ShardConfig::resolve(&args, &cfg).unwrap();
        assert_eq!(sc.shards, 2); // CLI beats config
        assert_eq!(sc.transport, ShardTransport::Tcp);
        assert!(sc.enabled());
        let defaults = ShardConfig::resolve(&Args::default(), &Config::default()).unwrap();
        assert_eq!(defaults.shards, 0);
        assert!(!defaults.enabled());
        let bad = Args::parse(
            ["train", "--shard-transport", "smoke-signals"].iter().map(|s| s.to_string()),
        );
        assert!(ShardConfig::resolve(&bad, &Config::default()).is_err());
    }

    #[test]
    fn listen_line_parses() {
        assert_eq!(
            parse_listen_line("SKETCHY-SHARD-LISTENING tcp 127.0.0.1:4091\n"),
            Some(WorkerAddr::Tcp("127.0.0.1:4091".into()))
        );
        assert_eq!(parse_listen_line("unrelated noise"), None);
        assert_eq!(parse_listen_line("SKETCHY-SHARD-LISTENING warp 9"), None);
        #[cfg(unix)]
        assert_eq!(
            parse_listen_line("SKETCHY-SHARD-LISTENING unix /tmp/w0.sock"),
            Some(WorkerAddr::Unix(PathBuf::from("/tmp/w0.sock")))
        );
    }

    #[test]
    fn worker_state_matches_in_process_engine_bitwise() {
        // Drive the same gradient stream through (a) the in-process
        // engine and (b) the worker-side state machine fed by hand-built
        // Step messages — the math on both sides of the wire must agree
        // bitwise. This pins the worker implementation without sockets.
        let shapes = [(6usize, 4usize)];
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let ecfg = EngineConfig {
            threads: 1,
            block_size: 3,
            refresh_interval: 2,
            stagger: false,
            ..Default::default()
        };
        let mut engine = PrecondEngine::shampoo(&shapes, base.clone(), ecfg);
        let blocks = partition(&shapes, 3);
        let specs: Vec<BlockSpec> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let (rows, cols) = b.shape();
                BlockSpec { index: i as u32, rows: rows as u32, cols: cols as u32 }
            })
            .collect();
        let init = InitMsg {
            kind: UnitKind::Shampoo.code(),
            rank: 0,
            beta2: base.beta2,
            eps: base.eps,
            one_sided: base.one_sided,
            graft: base.graft.code(),
            threads: 1,
            blocks: specs,
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mut p_eng = vec![crate::tensor::Matrix::zeros(6, 4)];
        let mut p_ws = p_eng.clone();
        let mut rng = Pcg64::new(99);
        for t in 1..=6u64 {
            let grads = vec![crate::tensor::Matrix::randn(6, 4, &mut rng)];
            engine.step(&mut p_eng, &grads);
            let entries: Vec<StepEntry> = blocks
                .iter()
                .enumerate()
                .map(|(i, b)| StepEntry {
                    index: i as u32,
                    refresh_due: t % 2 == 0, // stagger off, interval 2
                    param: p_ws[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                    grad: grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1),
                })
                .collect();
            let msg = StepMsg {
                t,
                scale: 1.0, // clip disabled in base
                preconditioning: t as usize >= base.start_preconditioning_step,
                stat_due: true,
                lr: base.lr,
                beta1: base.beta1,
                weight_decay: base.weight_decay,
                entries,
            };
            let ok = ws.process_step(&msg).unwrap();
            for (index, block_param) in &ok.entries {
                let b = &blocks[*index as usize];
                p_ws[b.tensor].set_slice(b.r0, b.c0, block_param);
            }
            assert_eq!(
                p_eng[0].max_diff(&p_ws[0]),
                0.0,
                "worker path diverged from engine at step {t}"
            );
        }
        // The idempotency cache replays the last step verbatim.
        let cached = ws.last_step.clone().unwrap();
        assert_eq!(cached.0, 6);
    }

    #[test]
    fn worker_state_rejects_malformed_steps() {
        let init = InitMsg {
            kind: UnitKind::Adam.code(),
            rank: 0,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: false,
            graft: GraftType::None.code(),
            threads: 1,
            blocks: vec![BlockSpec { index: 4, rows: 2, cols: 2 }],
        };
        let mut ws = WorkerState::build(&init).unwrap();
        let mk_step = |entries| StepMsg {
            t: 1,
            scale: 1.0,
            preconditioning: true,
            stat_due: true,
            lr: 0.1,
            beta1: 0.0,
            weight_decay: 0.0,
            entries,
        };
        // Unknown block index.
        let bad = mk_step(vec![StepEntry {
            index: 9,
            refresh_due: false,
            param: Matrix::zeros(2, 2),
            grad: Matrix::zeros(2, 2),
        }]);
        assert!(ws.process_step(&bad).is_err());
        // Shape mismatch.
        let bad = mk_step(vec![StepEntry {
            index: 4,
            refresh_due: false,
            param: Matrix::zeros(3, 2),
            grad: Matrix::zeros(3, 2),
        }]);
        assert!(ws.process_step(&bad).is_err());
        // Wrong block count.
        assert!(ws.process_step(&mk_step(vec![])).is_err());
        // Init rejects garbage codes and duplicate blocks.
        assert!(WorkerState::build(&InitMsg { kind: 9, ..init.clone() }).is_err());
        assert!(WorkerState::build(&InitMsg { graft: 77, ..init.clone() }).is_err());
        let dup = InitMsg {
            blocks: vec![
                BlockSpec { index: 4, rows: 2, cols: 2 },
                BlockSpec { index: 4, rows: 2, cols: 2 },
            ],
            ..init
        };
        assert!(WorkerState::build(&dup).is_err());
    }
}
