//! Driver-side liveness supervision for the shard fleet (wire v6).
//!
//! PR 7 made worker *death* recoverable, but detection stayed passive: a
//! hung worker — one whose connection is up but whose replies never
//! arrive — surfaced only after the blocking reply timeout (120 s by
//! default). This module makes detection proactive and deterministic:
//!
//! - [`Clock`] abstracts time so every deadline/backoff decision can be
//!   driven by a [`VirtualClock`] in tests — no wall-clock sleeps, no
//!   flaky timing. The production [`SystemClock`] is a thin monotonic
//!   wrapper over [`std::time::Instant`].
//! - [`Backoff`] is capped, deterministic exponential backoff (no
//!   jitter: determinism is the repo-wide contract, and the driver is a
//!   single client per link, so synchronized retries are not a risk).
//! - [`Supervisor`] tracks per-seat liveness against the
//!   [`LinkTimeouts`] knobs: a seat that has not proven itself alive
//!   within `heartbeat` is due a `Ping` probe, and one silent past
//!   `deadline` is escalated into the membership kill-and-replace path
//!   long before the reply timeout would fire.
//!
//! The wire side (v6 `Ping`/`Pong` frames behind the `HelloV6`
//! heartbeat capability) lives in [`crate::coordinator::wire`]; the
//! escalation plumbing lives in [`crate::coordinator::shard`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Clocks.
// ---------------------------------------------------------------------------

/// Injectable time source. `now` is monotone elapsed time since an
/// arbitrary per-clock origin; `on_poll` is the hook the supervised
/// reply loop calls once per poll quantum that elapsed without a frame,
/// which lets a virtual clock advance deterministically exactly when
/// the code under test observed time passing.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Monotone elapsed time since this clock's origin.
    fn now(&self) -> Duration;

    /// One poll quantum elapsed without progress (a read timed out).
    /// The system clock ignores this — wall time already advanced; the
    /// virtual clock advances by exactly the quantum.
    fn on_poll(&self, _quantum: Duration) {}

    /// Block the caller for `d`. The system clock really sleeps; the
    /// virtual clock advances instantly, so deterministic tests never
    /// wait out wall time. This is the one sanctioned sleep in the
    /// codebase — everything else goes through a `Clock`.
    fn sleep(&self, d: Duration);
}

/// Wall-clock time, anchored at construction.
#[derive(Debug)]
pub struct SystemClock {
    origin: std::time::Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { origin: std::time::Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Duration {
        self.origin.elapsed()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Deterministic test clock: time moves only when the code under test
/// reports it ([`Clock::on_poll`]) or the test advances it explicitly.
#[derive(Debug, Default)]
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Advance virtual time by `by`.
    pub fn advance(&self, by: Duration) {
        self.nanos.fetch_add(by.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::SeqCst))
    }

    fn on_poll(&self, quantum: Duration) {
        self.advance(quantum);
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

// ---------------------------------------------------------------------------
// Backoff.
// ---------------------------------------------------------------------------

/// Capped deterministic exponential backoff: `base`, `2·base`,
/// `4·base`, … clamped at `cap`. Replaces the raw fixed-interval
/// sleep-spins of the reconnect and shutdown paths.
#[derive(Clone, Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    next: Duration,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration) -> Backoff {
        let base = base.max(Duration::from_millis(1));
        Backoff { base, cap: cap.max(base), next: base }
    }

    /// The next delay to wait; doubles (up to the cap) for the call
    /// after.
    pub fn next(&mut self) -> Duration {
        let cur = self.next;
        self.next = (cur * 2).min(self.cap);
        cur
    }

    /// Back to the base delay (call after a success).
    pub fn reset(&mut self) {
        self.next = self.base;
    }
}

// ---------------------------------------------------------------------------
// Link timeout knobs.
// ---------------------------------------------------------------------------

/// Per-link timing knobs, resolved from `--shard-connect-timeout-ms` /
/// `--shard-reply-timeout-ms` / `--shard-heartbeat-ms` /
/// `--shard-deadline-ms` and the `[shard]` config section. The
/// invariant `heartbeat <= deadline <= reply` is enforced at
/// resolution ([`crate::coordinator::ShardConfig::resolve`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkTimeouts {
    /// Bound on establishing a connection to a worker.
    pub connect: Duration,
    /// Bound on a blocking reply wait (unsupervised links, and the
    /// hard upper bound everywhere).
    pub reply: Duration,
    /// Supervised poll quantum: how often a silent link is re-polled,
    /// and how stale a seat may go before a `Ping` probe is due.
    pub heartbeat: Duration,
    /// Supervised liveness deadline: a seat silent this long is
    /// escalated to the membership kill-and-replace path.
    pub deadline: Duration,
}

impl Default for LinkTimeouts {
    fn default() -> Self {
        LinkTimeouts {
            connect: Duration::from_secs(10),
            reply: Duration::from_secs(120),
            heartbeat: Duration::from_millis(500),
            deadline: Duration::from_secs(10),
        }
    }
}

// ---------------------------------------------------------------------------
// Supervisor.
// ---------------------------------------------------------------------------

/// Per-seat liveness ledger. A seat proves itself alive whenever any
/// reply arrives on its link ([`Supervisor::note_alive`]); the
/// executor consults [`Supervisor::ping_due`] before each step to
/// decide which idle seats to probe, and the supervised reply loop
/// escalates any seat silent past [`LinkTimeouts::deadline`].
#[derive(Debug)]
pub struct Supervisor {
    timeouts: LinkTimeouts,
    last_alive: Vec<Duration>,
    pings_sent: u64,
}

impl Supervisor {
    pub fn new(seats: usize, timeouts: LinkTimeouts, now: Duration) -> Supervisor {
        Supervisor { timeouts, last_alive: vec![now; seats], pings_sent: 0 }
    }

    pub fn timeouts(&self) -> LinkTimeouts {
        self.timeouts
    }

    /// Record proof of life for `seat` (any reply counts, not only
    /// `Pong`).
    pub fn note_alive(&mut self, seat: usize, now: Duration) {
        if let Some(cell) = self.last_alive.get_mut(seat) {
            *cell = now.max(*cell);
        }
    }

    /// A replacement worker took the seat: its liveness history starts
    /// fresh.
    pub fn reset_seat(&mut self, seat: usize, now: Duration) {
        if let Some(cell) = self.last_alive.get_mut(seat) {
            *cell = now;
        }
    }

    /// Whether `seat` has been silent for at least one heartbeat
    /// interval and should be probed with a `Ping`.
    pub fn ping_due(&self, seat: usize, now: Duration) -> bool {
        now.saturating_sub(self.last_alive[seat]) >= self.timeouts.heartbeat
    }

    /// Whether `seat` has been silent past the liveness deadline.
    pub fn overdue(&self, seat: usize, now: Duration) -> bool {
        now.saturating_sub(self.last_alive[seat]) >= self.timeouts.deadline
    }

    /// Monotone ping sequence numbers (echoed back in `Pong`).
    pub fn next_ping_seq(&mut self) -> u64 {
        self.pings_sent += 1;
        self.pings_sent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps_deterministically() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_millis(100));
        let waits: Vec<u64> = (0..6).map(|_| b.next().as_millis() as u64).collect();
        assert_eq!(waits, vec![10, 20, 40, 80, 100, 100]);
        b.reset();
        assert_eq!(b.next(), Duration::from_millis(10));
        // A second instance produces the identical schedule — no jitter.
        let mut b2 = Backoff::new(Duration::from_millis(10), Duration::from_millis(100));
        let waits2: Vec<u64> = (0..6).map(|_| b2.next().as_millis() as u64).collect();
        assert_eq!(waits, waits2);
        // Degenerate knobs are clamped, never a zero-spin.
        let mut z = Backoff::new(Duration::ZERO, Duration::ZERO);
        assert!(z.next() >= Duration::from_millis(1));
    }

    #[test]
    fn virtual_clock_advances_only_on_observed_polls() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.on_poll(Duration::from_millis(50));
        c.on_poll(Duration::from_millis(50));
        assert_eq!(c.now(), Duration::from_millis(100));
        c.advance(Duration::from_secs(1));
        assert_eq!(c.now(), Duration::from_millis(1100));
    }

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock::new();
        let a = c.now();
        c.on_poll(Duration::from_secs(999)); // no-op for wall time
        let b = c.now();
        assert!(b >= a);
        assert!(b < Duration::from_secs(999));
    }

    #[test]
    fn supervisor_ping_and_deadline_trip_on_the_virtual_clock() {
        let clock = VirtualClock::new();
        let t = LinkTimeouts {
            heartbeat: Duration::from_millis(50),
            deadline: Duration::from_millis(200),
            ..LinkTimeouts::default()
        };
        let mut sup = Supervisor::new(2, t, clock.now());
        assert!(!sup.ping_due(0, clock.now()));
        clock.advance(Duration::from_millis(50));
        assert!(sup.ping_due(0, clock.now()), "one heartbeat of silence is ping-due");
        assert!(!sup.overdue(0, clock.now()));
        // Seat 1 proves itself alive; seat 0 stays silent to the deadline.
        clock.advance(Duration::from_millis(100));
        sup.note_alive(1, clock.now());
        clock.advance(Duration::from_millis(50));
        assert!(sup.overdue(0, clock.now()), "200ms of silence trips the deadline");
        assert!(!sup.overdue(1, clock.now()));
        assert!(!sup.ping_due(1, clock.now()));
        // A replacement resets the ledger.
        sup.reset_seat(0, clock.now());
        assert!(!sup.overdue(0, clock.now()));
        // Sequence numbers are monotone from 1.
        assert_eq!(sup.next_ping_seq(), 1);
        assert_eq!(sup.next_ping_seq(), 2);
    }

    #[test]
    fn note_alive_never_moves_time_backwards() {
        let t = LinkTimeouts::default();
        let mut sup = Supervisor::new(1, t, Duration::from_millis(100));
        sup.note_alive(0, Duration::from_millis(40)); // stale observation
        assert!(!sup.ping_due(0, Duration::from_millis(120)));
    }
}
