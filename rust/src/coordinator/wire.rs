//! Length-prefixed wire codec for the cross-process shard engine.
//!
//! The driver and its `sketchy shard-worker` processes exchange frames
//! over localhost TCP or Unix domain sockets (see [`super::shard`]). A
//! frame is a little-endian `u32` payload length followed by the payload:
//! a one-byte message tag plus fixed-width fields. Every `f64` travels as
//! its IEEE-754 bit pattern (`to_bits`/`from_bits`), so a parameter block
//! round-trips **bitwise exactly** — the property the shard determinism
//! tests pin down. No serde, no external deps.
//!
//! Protocol (driver ⇄ worker, strict request/response):
//!
//! | driver sends      | worker replies      |
//! |-------------------|---------------------|
//! | [`WireMsg::Init`] | [`WireMsg::Ok`]     |
//! | [`WireMsg::Step`] | [`WireMsg::StepOk`] |
//! | [`WireMsg::StepV3`] | [`WireMsg::StepOkV3`] |
//! | [`WireMsg::RefreshAhead`] | [`WireMsg::RefreshAheadOk`] |
//! | [`WireMsg::MemStats`] | [`WireMsg::MemStatsOk`] |
//! | [`WireMsg::Shutdown`] | [`WireMsg::Ok`], then exits |
//!
//! plus the handshake ([`WireMsg::Hello`] at protocol v1,
//! [`WireMsg::HelloV2`] at v2, [`WireMsg::HelloV3`] from v3 — worker →
//! driver, once per connection) and [`WireMsg::Error`] (worker →
//! driver, in place of any reply).
//!
//! ## Wire protocol v3: delta-compressed block payloads
//!
//! Full frames ship every block's dense factors as raw `f64` bits —
//! fine on localhost, prohibitive on cross-host links. Protocol v3 adds
//! a payload layer ([`WireMsg::StepV3`] / [`WireMsg::StepOkV3`]) that
//! exploits what the Sketchy argument implies about the state worth
//! moving: between consecutive steps most parameter bits either do not
//! change at all (the driver re-uploads exactly the block the worker
//! returned; inactive embedding columns are bit-frozen) or change by a
//! small update. Each matrix travels as a [`DeltaMat`]: raw, or the
//! RLE/varint compression of its `f64` bit patterns XORed against the
//! receiver's baseline — the payload of the last mutually acked step,
//! tagged by `base_t` so a replayed frame can never be applied against
//! the wrong baseline. A `resync` flag (set by the driver after any
//! reconnect) drops all baselines and forces full frames in both
//! directions. The codec is **lossless on bit patterns**, so the shard
//! determinism contract (bitwise identity with the in-process engine)
//! is untouched; v2/v1 peers simply keep receiving uncompressed full
//! frames, exactly like the refresh-overlap degrade matrix.
//!
//! `RefreshAhead` is the only request the driver parks: it is sent at the
//! end of step `t` and its reply is not read until the top of step
//! `t + 1`, so the worker's eigendecompositions overlap the trainer's
//! gradient computation (a second in-flight request per shard). Workers
//! that greet with the v1 `Hello` never receive it — the driver degrades
//! that shard to synchronous refresh.

use crate::tensor::Matrix;
use anyhow::{anyhow, bail, ensure, Context};
use std::io::{Read, Write};
use std::time::Duration;

/// Current wire protocol version, carried in [`WireMsg::HelloV3`].
/// Version 1 (the plain [`WireMsg::Hello`] greeting) predates the
/// `RefreshAhead` messages; drivers treat v1 workers as refresh-overlap
/// incapable and keep their refreshes synchronous. Version 2 added the
/// capability handshake + RefreshAhead; version 3 adds the
/// delta-compressed block payload layer ([`DeltaMat`]). Drivers treat
/// v2/v1 workers as compression-incapable and ship full frames.
pub const PROTO_VERSION: u32 = 3;

/// A connected driver↔worker byte stream: any transport the shard
/// channel can speak — TCP, Unix sockets, or the in-memory
/// fault-injection harness ([`super::fault`]).
pub trait Conn: Read + Write + Send {
    /// Bound blocking reads (`None` = block forever). Transports that
    /// cannot honor a bound may clamp it; the driver treats a timed-out
    /// read as a transport failure (reconnect + replay).
    fn set_timeout(&mut self, dur: Option<Duration>) -> std::io::Result<()>;
}

/// Upper bound on a single frame (guards against corrupt length
/// prefixes allocating unbounded memory).
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Shard of one tensor block assigned to a worker: the engine's global
/// block index plus the block shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpec {
    pub index: u32,
    pub rows: u32,
    pub cols: u32,
}

/// Driver → worker: build per-block preconditioner states.
#[derive(Clone, Debug, PartialEq)]
pub struct InitMsg {
    /// Unit family: 0 = Shampoo, 1 = Sketched (`rank` applies), 2 = Adam.
    pub kind: u8,
    /// FD sketch size ℓ (sketched units only).
    pub rank: u32,
    pub beta2: f64,
    pub eps: f64,
    pub one_sided: bool,
    /// Grafting method code ([`crate::optim::GraftType::code`]).
    pub graft: u8,
    /// Worker-side thread knob (0 = auto); never changes the numbers.
    pub threads: u32,
    pub blocks: Vec<BlockSpec>,
}

/// One block's inputs for a driven step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepEntry {
    pub index: u32,
    /// Whether this block's staggered refresh slot lands on this step.
    pub refresh_due: bool,
    pub param: Matrix,
    pub grad: Matrix,
}

/// Driver → worker: drive every assigned block one step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepMsg {
    pub t: u64,
    pub scale: f64,
    pub preconditioning: bool,
    pub stat_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub entries: Vec<StepEntry>,
}

/// Worker → driver: updated parameter blocks + refresh accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct StepOkMsg {
    /// Echo of the driving step's `t` (idempotent-retry key).
    pub t: u64,
    /// Eigendecompositions run on this shard during the step.
    pub refreshes: u32,
    pub entries: Vec<(u32, Matrix)>,
}

/// Driver → worker: recompute inverse roots *now*, ahead of the step
/// that will use them. Sent at the end of step `t_next - 1`; the reply
/// is read just before `t_next`'s [`WireMsg::Step`], so the work hides
/// behind the trainer's gradient computation.
#[derive(Clone, Debug, PartialEq)]
pub struct RefreshAheadMsg {
    /// The step whose refresh slots are being prefetched (idempotency
    /// key for replay after a reconnect).
    pub t_next: u64,
    /// Visit every owned block, not just the due subset (first
    /// preconditioning step, where not-yet-ready blocks refresh
    /// regardless of their slot).
    pub all: bool,
    /// Global indices of the owned blocks whose refresh slot fires at
    /// `t_next`.
    pub due: Vec<u32>,
}

/// Worker → driver: which blocks were refreshed ahead, plus the
/// eigendecomposition count (refresh accounting).
#[derive(Clone, Debug, PartialEq)]
pub struct RefreshAheadOkMsg {
    /// Echo of the request's `t_next`.
    pub t_next: u64,
    /// Eigendecompositions that ran ahead.
    pub count: u32,
    /// Global indices of blocks whose roots are now fresh — the step at
    /// `t_next` must not refresh them again.
    pub refreshed: Vec<u32>,
}

/// One matrix payload in a v3 delta stream. The codec is stateless:
/// decoding yields the mode + compressed bytes, and XOR application
/// against the receiver's baseline happens in the message handler —
/// after the step-replay cache and shape validation have run, so a
/// replayed or malformed frame can never corrupt baseline state.
#[derive(Clone, Debug, PartialEq)]
pub enum DeltaMat {
    /// Uncompressed full frame — bit-for-bit the v2 matrix encoding
    /// (chosen when compression would not shrink the payload).
    Raw(Matrix),
    /// RLE/varint-compressed full frame (no baseline needed).
    Full { rows: u32, cols: u32, comp: Vec<u8> },
    /// RLE/varint-compressed XOR of the matrix's `f64` bit patterns
    /// against the receiver's baseline bits for this block, which must
    /// be tagged with the enclosing message's `base_t`.
    Delta { rows: u32, cols: u32, comp: Vec<u8> },
}

impl DeltaMat {
    /// Declared shape (validated against the plausibility bound at
    /// decode; the receiver still checks it against the block it owns
    /// before resolving).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            DeltaMat::Raw(m) => m.shape(),
            DeltaMat::Full { rows, cols, .. } | DeltaMat::Delta { rows, cols, .. } => {
                (*rows as usize, *cols as usize)
            }
        }
    }

    /// Encode a `rows`×`cols` matrix given as bit patterns, choosing
    /// the smallest of raw / compressed-full / compressed-delta (delta
    /// requires `base`, the receiver's baseline bits). Deterministic:
    /// same inputs, same choice, same bytes.
    pub fn encode(rows: usize, cols: usize, cur: &[u64], base: Option<&[u64]>) -> DeltaMat {
        debug_assert_eq!(rows * cols, cur.len());
        let raw_len = cur.len() * 8;
        // Prefer a winning delta outright — compressing the full frame
        // as well, just to compare, would double the per-step encode
        // cost for payloads whose delta already crushes (the unchanged
        // upload / frozen-parameter hot cases).
        if let Some(base) = base {
            debug_assert_eq!(base.len(), cur.len());
            let mut xored = Vec::with_capacity(raw_len);
            for (c, b) in cur.iter().zip(base) {
                xored.extend_from_slice(&(c ^ b).to_le_bytes());
            }
            let d = rle_compress(&xored);
            if d.len() < raw_len {
                return DeltaMat::Delta { rows: rows as u32, cols: cols as u32, comp: d };
            }
        }
        let mut plain = Vec::with_capacity(raw_len);
        for c in cur {
            plain.extend_from_slice(&c.to_le_bytes());
        }
        let full = rle_compress(&plain);
        if full.len() < raw_len {
            DeltaMat::Full { rows: rows as u32, cols: cols as u32, comp: full }
        } else {
            DeltaMat::Raw(bits_matrix(rows, cols, cur))
        }
    }

    /// Resolve to full bit patterns, XORing `Delta` payloads against
    /// `base`. The caller must have validated the shape against the
    /// block it owns first — `expected` output length derives from it,
    /// which is what bounds the decompressor's allocation.
    pub fn resolve(&self, base: Option<&[u64]>) -> anyhow::Result<Vec<u64>> {
        let (rows, cols) = self.shape();
        let n = rows * cols;
        match self {
            DeltaMat::Raw(m) => Ok(mat_bits(m)),
            DeltaMat::Full { comp, .. } => {
                let bytes = rle_decompress(comp, n * 8)?;
                Ok(le_bytes_to_bits(&bytes))
            }
            DeltaMat::Delta { comp, .. } => {
                let base = base
                    .ok_or_else(|| anyhow!("shard wire: delta payload without a baseline"))?;
                ensure!(
                    base.len() == n,
                    "shard wire: delta baseline holds {} values, payload claims {n}",
                    base.len()
                );
                let bytes = rle_decompress(comp, n * 8)?;
                let mut bits = le_bytes_to_bits(&bytes);
                for (x, b) in bits.iter_mut().zip(base) {
                    *x ^= b;
                }
                Ok(bits)
            }
        }
    }
}

/// Bit-pattern vector of a matrix — the delta codec's working form.
pub fn mat_bits(m: &Matrix) -> Vec<u64> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

/// Rebuild a matrix from bit patterns (bitwise inverse of [`mat_bits`]).
pub fn bits_matrix(rows: usize, cols: usize, bits: &[u64]) -> Matrix {
    debug_assert_eq!(rows * cols, bits.len());
    Matrix::from_vec(rows, cols, bits.iter().map(|&b| f64::from_bits(b)).collect())
}

fn le_bytes_to_bits(bytes: &[u8]) -> Vec<u64> {
    debug_assert_eq!(bytes.len() % 8, 0);
    bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

fn push_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            break;
        }
        buf.push(b | 0x80);
    }
}

fn read_varint(b: &[u8], i: &mut usize) -> anyhow::Result<u64> {
    let mut out = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *b
            .get(*i)
            .ok_or_else(|| anyhow!("shard wire: truncated varint"))?;
        *i += 1;
        ensure!(shift < 64, "shard wire: varint overflows u64");
        out |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(out);
        }
        shift += 7;
    }
}

/// Byte-level RLE over zero runs: a token is a varint `v` where
/// `v & 1 == 0` means a run of `v >> 1` zero bytes and `v & 1 == 1`
/// means `v >> 1` literal bytes follow. Lone zeros ride inside
/// literals (a run token would cost more than the byte it replaces).
/// XORed f64 bit patterns are mostly zero wherever entries did not
/// change, which is exactly what this crushes — no deps, deterministic,
/// lossless.
pub fn rle_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4 + 16);
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            let start = i;
            while i < data.len() && data[i] == 0 {
                i += 1;
            }
            if i - start >= 2 {
                push_varint(&mut out, ((i - start) as u64) << 1);
                continue;
            }
            i = start; // lone zero: cheaper inside the literal below
        }
        let start = i;
        while i < data.len() {
            if data[i] == 0 {
                let mut j = i;
                while j < data.len() && data[j] == 0 {
                    j += 1;
                }
                if j - i >= 2 {
                    break; // a real zero run ends the literal
                }
                i = j; // lone zero joins the literal
            } else {
                i += 1;
            }
        }
        push_varint(&mut out, (((i - start) as u64) << 1) | 1);
        out.extend_from_slice(&data[start..i]);
    }
    out
}

/// Inverse of [`rle_compress`]. `expected` is the exact output length
/// the caller derived from a validated block shape — every token is
/// checked against it before any byte materializes, so a corrupt
/// stream can neither over-allocate nor silently under-fill.
pub fn rle_decompress(comp: &[u8], expected: usize) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expected.min(1 << 20));
    let mut i = 0;
    while i < comp.len() {
        let v = read_varint(comp, &mut i)?;
        let len = usize::try_from(v >> 1).map_err(|_| anyhow!("shard wire: rle run too long"))?;
        ensure!(len > 0, "shard wire: zero-length rle token");
        ensure!(
            out.len().checked_add(len).is_some_and(|t| t <= expected),
            "shard wire: rle output overruns expected {expected} bytes"
        );
        if v & 1 == 1 {
            ensure!(
                i.checked_add(len).is_some_and(|t| t <= comp.len()),
                "shard wire: rle literal overruns input"
            );
            out.extend_from_slice(&comp[i..i + len]);
            i += len;
        } else {
            out.resize(out.len() + len, 0);
        }
    }
    ensure!(
        out.len() == expected,
        "shard wire: rle output {} bytes, expected {expected}",
        out.len()
    );
    Ok(out)
}

/// One block's inputs for a v3 delta-compressed step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepEntryV3 {
    pub index: u32,
    pub refresh_due: bool,
    pub param: DeltaMat,
    pub grad: DeltaMat,
}

/// Driver → worker: drive every assigned block one step, with the
/// block payloads delta-encoded against the last mutually acked step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepV3Msg {
    pub t: u64,
    /// Step whose decoded payload the [`DeltaMat::Delta`] entries XOR
    /// against (0 = no baseline: every entry is `Raw`/`Full`). The
    /// receiver rejects a mismatch against its own baseline tag instead
    /// of applying a delta to the wrong bits.
    pub base_t: u64,
    /// Receiver must drop every delta baseline (both directions) before
    /// processing and reply with full frames. The driver sets this on
    /// the first step encoded after any reconnect — the full-frame
    /// resync that re-anchors the stream.
    pub resync: bool,
    pub scale: f64,
    pub preconditioning: bool,
    pub stat_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub entries: Vec<StepEntryV3>,
}

/// Worker → driver: updated parameter blocks, delta-encoded against the
/// worker's previous reply (which the lockstep protocol guarantees the
/// driver has decoded before it could send this step).
#[derive(Clone, Debug, PartialEq)]
pub struct StepOkV3Msg {
    pub t: u64,
    /// Baseline tag for `Delta` entries (0 = none).
    pub base_t: u64,
    pub refreshes: u32,
    pub entries: Vec<(u32, DeltaMat)>,
}

/// Every message that can cross the shard wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Worker → driver greeting carrying the identity it was spawned
    /// with (protocol v1 — no capability report).
    Hello { worker_id: u32 },
    Init(InitMsg),
    Step(StepMsg),
    StepOk(StepOkMsg),
    MemStats,
    MemStatsOk { mem_bytes: u64, second_moment_bytes: u64 },
    Shutdown,
    Ok,
    Error { message: String },
    /// Worker → driver greeting from protocol v2 on: identity plus an
    /// explicit capability report. `overlap` means the worker accepts
    /// [`WireMsg::RefreshAhead`]; a false report (or a v1 `Hello`)
    /// degrades that shard to synchronous refresh.
    HelloV2 { worker_id: u32, proto: u32, overlap: bool },
    RefreshAhead(RefreshAheadMsg),
    RefreshAheadOk(RefreshAheadOkMsg),
    /// Worker → driver greeting from protocol v3 on: identity,
    /// capability report, and whether the worker accepts the
    /// delta-compressed payload layer ([`WireMsg::StepV3`]). A false
    /// report (or a v2/v1 greeting) keeps that link on full frames.
    HelloV3 { worker_id: u32, proto: u32, overlap: bool, compress: bool },
    StepV3(StepV3Msg),
    StepOkV3(StepOkV3Msg),
}

const TAG_HELLO: u8 = 1;
const TAG_INIT: u8 = 2;
const TAG_STEP: u8 = 3;
const TAG_STEP_OK: u8 = 4;
const TAG_MEM_STATS: u8 = 5;
const TAG_MEM_STATS_OK: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_OK: u8 = 8;
const TAG_ERROR: u8 = 9;
const TAG_HELLO_V2: u8 = 10;
const TAG_REFRESH_AHEAD: u8 = 11;
const TAG_REFRESH_AHEAD_OK: u8 = 12;
const TAG_HELLO_V3: u8 = 13;
const TAG_STEP_V3: u8 = 14;
const TAG_STEP_OK_V3: u8 = 15;

/// [`DeltaMat`] mode bytes.
const DM_RAW: u8 = 0;
const DM_FULL: u8 = 1;
const DM_DELTA: u8 = 2;

// ---------------------------------------------------------------------------
// Encoding.
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn boolean(&mut self, v: bool) {
        self.u8(v as u8);
    }
    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn matrix(&mut self, m: &Matrix) {
        self.u32(m.rows() as u32);
        self.u32(m.cols() as u32);
        for &x in m.as_slice() {
            self.f64(x);
        }
    }
    fn delta_mat(&mut self, m: &DeltaMat) {
        match m {
            DeltaMat::Raw(mat) => {
                self.u8(DM_RAW);
                self.matrix(mat);
            }
            DeltaMat::Full { rows, cols, comp } => {
                self.u8(DM_FULL);
                self.u32(*rows);
                self.u32(*cols);
                self.u32(comp.len() as u32);
                self.buf.extend_from_slice(comp);
            }
            DeltaMat::Delta { rows, cols, comp } => {
                self.u8(DM_DELTA);
                self.u32(*rows);
                self.u32(*cols);
                self.u32(comp.len() as u32);
                self.buf.extend_from_slice(comp);
            }
        }
    }
}

/// Encode a message as a complete length-prefixed frame, ready to write.
///
/// Fails (rather than truncating the `u32` length prefix or tripping the
/// receiver's cap mid-run) when the payload exceeds [`MAX_FRAME_BYTES`]
/// — both sides enforce the same bound.
pub fn encode_frame(msg: &WireMsg) -> anyhow::Result<Vec<u8>> {
    let mut e = Enc { buf: Vec::with_capacity(64) };
    match msg {
        WireMsg::Hello { worker_id } => {
            e.u8(TAG_HELLO);
            e.u32(*worker_id);
        }
        WireMsg::Init(init) => {
            e.u8(TAG_INIT);
            e.u8(init.kind);
            e.u32(init.rank);
            e.f64(init.beta2);
            e.f64(init.eps);
            e.boolean(init.one_sided);
            e.u8(init.graft);
            e.u32(init.threads);
            e.u32(init.blocks.len() as u32);
            for b in &init.blocks {
                e.u32(b.index);
                e.u32(b.rows);
                e.u32(b.cols);
            }
        }
        WireMsg::Step(step) => {
            e.u8(TAG_STEP);
            e.u64(step.t);
            e.f64(step.scale);
            e.boolean(step.preconditioning);
            e.boolean(step.stat_due);
            e.f64(step.lr);
            e.f64(step.beta1);
            e.f64(step.weight_decay);
            e.u32(step.entries.len() as u32);
            for ent in &step.entries {
                e.u32(ent.index);
                e.boolean(ent.refresh_due);
                e.matrix(&ent.param);
                e.matrix(&ent.grad);
            }
        }
        WireMsg::StepOk(ok) => {
            e.u8(TAG_STEP_OK);
            e.u64(ok.t);
            e.u32(ok.refreshes);
            e.u32(ok.entries.len() as u32);
            for (index, param) in &ok.entries {
                e.u32(*index);
                e.matrix(param);
            }
        }
        WireMsg::MemStats => e.u8(TAG_MEM_STATS),
        WireMsg::MemStatsOk { mem_bytes, second_moment_bytes } => {
            e.u8(TAG_MEM_STATS_OK);
            e.u64(*mem_bytes);
            e.u64(*second_moment_bytes);
        }
        WireMsg::Shutdown => e.u8(TAG_SHUTDOWN),
        WireMsg::Ok => e.u8(TAG_OK),
        WireMsg::Error { message } => {
            e.u8(TAG_ERROR);
            e.string(message);
        }
        WireMsg::HelloV2 { worker_id, proto, overlap } => {
            e.u8(TAG_HELLO_V2);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
        }
        WireMsg::RefreshAhead(ra) => {
            e.u8(TAG_REFRESH_AHEAD);
            e.u64(ra.t_next);
            e.boolean(ra.all);
            e.u32(ra.due.len() as u32);
            for &i in &ra.due {
                e.u32(i);
            }
        }
        WireMsg::RefreshAheadOk(ok) => {
            e.u8(TAG_REFRESH_AHEAD_OK);
            e.u64(ok.t_next);
            e.u32(ok.count);
            e.u32(ok.refreshed.len() as u32);
            for &i in &ok.refreshed {
                e.u32(i);
            }
        }
        WireMsg::HelloV3 { worker_id, proto, overlap, compress } => {
            e.u8(TAG_HELLO_V3);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
            e.boolean(*compress);
        }
        WireMsg::StepV3(step) => {
            e.u8(TAG_STEP_V3);
            e.u64(step.t);
            e.u64(step.base_t);
            e.boolean(step.resync);
            e.f64(step.scale);
            e.boolean(step.preconditioning);
            e.boolean(step.stat_due);
            e.f64(step.lr);
            e.f64(step.beta1);
            e.f64(step.weight_decay);
            e.u32(step.entries.len() as u32);
            for ent in &step.entries {
                e.u32(ent.index);
                e.boolean(ent.refresh_due);
                e.delta_mat(&ent.param);
                e.delta_mat(&ent.grad);
            }
        }
        WireMsg::StepOkV3(ok) => {
            e.u8(TAG_STEP_OK_V3);
            e.u64(ok.t);
            e.u64(ok.base_t);
            e.u32(ok.refreshes);
            e.u32(ok.entries.len() as u32);
            for (index, dm) in &ok.entries {
                e.u32(*index);
                e.delta_mat(dm);
            }
        }
    }
    if e.buf.len() > MAX_FRAME_BYTES {
        bail!(
            "shard wire: frame payload {} bytes exceeds cap {MAX_FRAME_BYTES}; \
             use more shards or a smaller --block-size so per-shard steps fit a frame",
            e.buf.len()
        );
    }
    let mut frame = Vec::with_capacity(4 + e.buf.len());
    frame.extend_from_slice(&(e.buf.len() as u32).to_le_bytes());
    frame.extend_from_slice(&e.buf);
    Ok(frame)
}

/// Write one message as a frame and flush.
pub fn write_msg<W: Write>(w: &mut W, msg: &WireMsg) -> anyhow::Result<()> {
    let frame = encode_frame(msg)?;
    w.write_all(&frame).context("shard wire: write frame")?;
    w.flush().context("shard wire: flush")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Decoding.
// ---------------------------------------------------------------------------

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("shard wire: truncated frame (need {n} bytes at offset {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn boolean(&mut self) -> anyhow::Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("shard wire: bad bool byte {other}"),
        }
    }
    fn string(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).context("shard wire: non-utf8 string")
    }
    fn matrix(&mut self) -> anyhow::Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        if rows > 1 << 20 || cols > 1 << 20 || rows.saturating_mul(cols) > 1 << 27 {
            bail!("shard wire: implausible matrix shape {rows}x{cols}");
        }
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(self.f64()?);
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }
    fn delta_mat(&mut self) -> anyhow::Result<DeltaMat> {
        match self.u8()? {
            DM_RAW => Ok(DeltaMat::Raw(self.matrix()?)),
            mode @ (DM_FULL | DM_DELTA) => {
                let rows = self.u32()?;
                let cols = self.u32()?;
                let (r, c) = (rows as usize, cols as usize);
                if r > 1 << 20 || c > 1 << 20 || r.saturating_mul(c) > 1 << 27 {
                    bail!("shard wire: implausible matrix shape {r}x{c}");
                }
                // The compressed body is bounded by the frame itself
                // (`take` fails on a lying length); decompression is
                // deferred to the handler, after shape validation.
                let n = self.u32()? as usize;
                let comp = self.take(n)?.to_vec();
                Ok(if mode == DM_FULL {
                    DeltaMat::Full { rows, cols, comp }
                } else {
                    DeltaMat::Delta { rows, cols, comp }
                })
            }
            other => bail!("shard wire: unknown delta-matrix mode {other}"),
        }
    }
    fn done(&self) -> anyhow::Result<()> {
        if self.i != self.b.len() {
            bail!("shard wire: {} trailing bytes in frame", self.b.len() - self.i);
        }
        Ok(())
    }
}

/// Decode one frame payload (without the length prefix).
pub fn decode_payload(payload: &[u8]) -> anyhow::Result<WireMsg> {
    let mut d = Dec { b: payload, i: 0 };
    let msg = match d.u8()? {
        TAG_HELLO => WireMsg::Hello { worker_id: d.u32()? },
        TAG_INIT => {
            let kind = d.u8()?;
            let rank = d.u32()?;
            let beta2 = d.f64()?;
            let eps = d.f64()?;
            let one_sided = d.boolean()?;
            let graft = d.u8()?;
            let threads = d.u32()?;
            let n = d.u32()? as usize;
            let mut blocks = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                blocks.push(BlockSpec { index: d.u32()?, rows: d.u32()?, cols: d.u32()? });
            }
            WireMsg::Init(InitMsg { kind, rank, beta2, eps, one_sided, graft, threads, blocks })
        }
        TAG_STEP => {
            let t = d.u64()?;
            let scale = d.f64()?;
            let preconditioning = d.boolean()?;
            let stat_due = d.boolean()?;
            let lr = d.f64()?;
            let beta1 = d.f64()?;
            let weight_decay = d.f64()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let refresh_due = d.boolean()?;
                let param = d.matrix()?;
                let grad = d.matrix()?;
                entries.push(StepEntry { index, refresh_due, param, grad });
            }
            WireMsg::Step(StepMsg {
                t,
                scale,
                preconditioning,
                stat_due,
                lr,
                beta1,
                weight_decay,
                entries,
            })
        }
        TAG_STEP_OK => {
            let t = d.u64()?;
            let refreshes = d.u32()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let param = d.matrix()?;
                entries.push((index, param));
            }
            WireMsg::StepOk(StepOkMsg { t, refreshes, entries })
        }
        TAG_MEM_STATS => WireMsg::MemStats,
        TAG_MEM_STATS_OK => {
            WireMsg::MemStatsOk { mem_bytes: d.u64()?, second_moment_bytes: d.u64()? }
        }
        TAG_SHUTDOWN => WireMsg::Shutdown,
        TAG_OK => WireMsg::Ok,
        TAG_ERROR => WireMsg::Error { message: d.string()? },
        TAG_HELLO_V2 => WireMsg::HelloV2 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
        },
        TAG_REFRESH_AHEAD => {
            let t_next = d.u64()?;
            let all = d.boolean()?;
            let n = d.u32()? as usize;
            let mut due = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                due.push(d.u32()?);
            }
            WireMsg::RefreshAhead(RefreshAheadMsg { t_next, all, due })
        }
        TAG_REFRESH_AHEAD_OK => {
            let t_next = d.u64()?;
            let count = d.u32()?;
            let n = d.u32()? as usize;
            let mut refreshed = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                refreshed.push(d.u32()?);
            }
            WireMsg::RefreshAheadOk(RefreshAheadOkMsg { t_next, count, refreshed })
        }
        TAG_HELLO_V3 => WireMsg::HelloV3 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
            compress: d.boolean()?,
        },
        TAG_STEP_V3 => {
            let t = d.u64()?;
            let base_t = d.u64()?;
            let resync = d.boolean()?;
            let scale = d.f64()?;
            let preconditioning = d.boolean()?;
            let stat_due = d.boolean()?;
            let lr = d.f64()?;
            let beta1 = d.f64()?;
            let weight_decay = d.f64()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let refresh_due = d.boolean()?;
                let param = d.delta_mat()?;
                let grad = d.delta_mat()?;
                entries.push(StepEntryV3 { index, refresh_due, param, grad });
            }
            WireMsg::StepV3(StepV3Msg {
                t,
                base_t,
                resync,
                scale,
                preconditioning,
                stat_due,
                lr,
                beta1,
                weight_decay,
                entries,
            })
        }
        TAG_STEP_OK_V3 => {
            let t = d.u64()?;
            let base_t = d.u64()?;
            let refreshes = d.u32()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let dm = d.delta_mat()?;
                entries.push((index, dm));
            }
            WireMsg::StepOkV3(StepOkV3Msg { t, base_t, refreshes, entries })
        }
        other => bail!("shard wire: unknown message tag {other}"),
    };
    d.done()?;
    Ok(msg)
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly (EOF before any length byte).
pub fn read_msg_opt<R: Read>(r: &mut R) -> anyhow::Result<Option<WireMsg>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = r.read(&mut len_buf[got..]).context("shard wire: read frame length")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("shard wire: connection closed mid-length ({got}/4 bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("shard wire: frame length {len} exceeds cap {MAX_FRAME_BYTES}");
    }
    // Grow the payload buffer as bytes actually arrive instead of
    // trusting the prefix with one up-front `vec![0; len]`: four corrupt
    // bytes under the cap would otherwise trigger a transient ~1 GB
    // allocation before the read even fails.
    let mut payload = Vec::with_capacity(len.min(1 << 16));
    let got = Read::by_ref(r)
        .take(len as u64)
        .read_to_end(&mut payload)
        .context("shard wire: read frame payload")?;
    if got < len {
        bail!("shard wire: connection closed mid-payload ({got}/{len} bytes)");
    }
    decode_payload(&payload).map(Some)
}

/// Read one frame, treating EOF as an error (driver side: a reply is
/// always expected).
pub fn read_msg<R: Read>(r: &mut R) -> anyhow::Result<WireMsg> {
    match read_msg_opt(r)? {
        Some(msg) => Ok(msg),
        None => bail!("shard wire: connection closed while awaiting reply"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn roundtrip(msg: WireMsg) {
        let frame = encode_frame(&msg).unwrap();
        let mut cursor = &frame[..];
        let got = read_msg(&mut cursor).unwrap();
        assert_eq!(got, msg);
        assert!(cursor.is_empty(), "frame not fully consumed");
    }

    #[test]
    fn all_messages_roundtrip() {
        let mut rng = Pcg64::new(77);
        roundtrip(WireMsg::Hello { worker_id: 3 });
        roundtrip(WireMsg::HelloV2 { worker_id: 5, proto: 2, overlap: true });
        roundtrip(WireMsg::HelloV2 { worker_id: 0, proto: 7, overlap: false });
        roundtrip(WireMsg::HelloV3 {
            worker_id: 2,
            proto: PROTO_VERSION,
            overlap: true,
            compress: true,
        });
        roundtrip(WireMsg::HelloV3 { worker_id: 9, proto: 4, overlap: false, compress: false });
        roundtrip(WireMsg::StepV3(StepV3Msg {
            t: 7,
            base_t: 6,
            resync: false,
            scale: 1.0,
            preconditioning: true,
            stat_due: false,
            lr: 1e-3,
            beta1: 0.9,
            weight_decay: 0.0,
            entries: vec![StepEntryV3 {
                index: 3,
                refresh_due: true,
                param: DeltaMat::Delta { rows: 2, cols: 3, comp: vec![1, 2, 3] },
                grad: DeltaMat::Raw(Matrix::randn(2, 3, &mut rng)),
            }],
        }));
        roundtrip(WireMsg::StepOkV3(StepOkV3Msg {
            t: 7,
            base_t: 0,
            refreshes: 1,
            entries: vec![
                (3, DeltaMat::Full { rows: 2, cols: 3, comp: vec![9] }),
                (4, DeltaMat::Raw(Matrix::randn(1, 2, &mut rng))),
            ],
        }));
        roundtrip(WireMsg::RefreshAhead(RefreshAheadMsg {
            t_next: 9,
            all: true,
            due: vec![0, 3, u32::MAX],
        }));
        roundtrip(WireMsg::RefreshAhead(RefreshAheadMsg { t_next: 0, all: false, due: vec![] }));
        roundtrip(WireMsg::RefreshAheadOk(RefreshAheadOkMsg {
            t_next: 9,
            count: 4,
            refreshed: vec![1, 2],
        }));
        roundtrip(WireMsg::RefreshAheadOk(RefreshAheadOkMsg {
            t_next: u64::MAX,
            count: 0,
            refreshed: vec![],
        }));
        roundtrip(WireMsg::Init(InitMsg {
            kind: 1,
            rank: 16,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: true,
            graft: 4,
            threads: 0,
            blocks: vec![
                BlockSpec { index: 0, rows: 7, cols: 5 },
                BlockSpec { index: 3, rows: 4, cols: 4 },
            ],
        }));
        roundtrip(WireMsg::Step(StepMsg {
            t: 42,
            scale: 0.5,
            preconditioning: true,
            stat_due: false,
            lr: 1e-3,
            beta1: 0.9,
            weight_decay: 1e-4,
            entries: vec![StepEntry {
                index: 7,
                refresh_due: true,
                param: Matrix::randn(3, 4, &mut rng),
                grad: Matrix::randn(3, 4, &mut rng),
            }],
        }));
        roundtrip(WireMsg::StepOk(StepOkMsg {
            t: 42,
            refreshes: 2,
            entries: vec![(7, Matrix::randn(3, 4, &mut rng))],
        }));
        roundtrip(WireMsg::MemStats);
        roundtrip(WireMsg::MemStatsOk { mem_bytes: 1024, second_moment_bytes: 512 });
        roundtrip(WireMsg::Shutdown);
        roundtrip(WireMsg::Ok);
        roundtrip(WireMsg::Error { message: "shard 2: boom".into() });
    }

    #[test]
    fn f64_payloads_are_bitwise_exact() {
        // Values that decimal formatting would mangle: subnormals, -0.0,
        // NaN payloads, and an irrational-looking mantissa.
        let vals =
            [f64::MIN_POSITIVE / 2.0, -0.0, f64::from_bits(0x7ff8_0000_dead_beef), 1.0 / 3.0];
        let m = Matrix::from_vec(1, 4, vals.to_vec());
        let msg = WireMsg::StepOk(StepOkMsg { t: 1, refreshes: 0, entries: vec![(0, m.clone())] });
        let frame = encode_frame(&msg).unwrap();
        let got = read_msg(&mut &frame[..]).unwrap();
        match got {
            WireMsg::StepOk(ok) => {
                for (a, b) in ok.entries[0].1.as_slice().iter().zip(m.as_slice()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn clean_eof_is_none_truncation_is_error() {
        let frame = encode_frame(&WireMsg::Ok).unwrap();
        assert_eq!(read_msg_opt(&mut std::io::empty()).unwrap(), None);
        // Cut inside the length prefix.
        assert!(read_msg_opt(&mut &frame[..2]).is_err());
        // Cut inside the payload.
        assert!(read_msg_opt(&mut &frame[..frame.len() - 1]).is_err());
    }

    // -----------------------------------------------------------------
    // Property-style coverage: every message kind, adversarial payloads.
    // -----------------------------------------------------------------

    /// f64 bit patterns decimal formatting would mangle (and equality
    /// would lie about): NaNs with payloads, ±0, subnormals, infinities.
    fn adversarial_f64(rng: &mut Pcg64) -> f64 {
        match rng.below(8) {
            0 => f64::from_bits(0x7ff8_0000_dead_beef), // quiet NaN w/ payload
            1 => f64::from_bits(0xfff0_0000_0000_0001), // signaling-ish NaN
            2 => -0.0,
            3 => f64::MIN_POSITIVE / 4.0, // subnormal
            4 => f64::INFINITY,
            5 => f64::NEG_INFINITY,
            6 => f64::from_bits(rng.next_u64()), // arbitrary bits
            _ => rng.gaussian(),
        }
    }

    fn adversarial_matrix(rng: &mut Pcg64) -> Matrix {
        let rows = 1 + rng.below(4);
        let cols = 1 + rng.below(4);
        let data = (0..rows * cols).map(|_| adversarial_f64(rng)).collect();
        Matrix::from_vec(rows, cols, data)
    }

    fn arbitrary_delta_mat(rng: &mut Pcg64) -> DeltaMat {
        let rows = 1 + rng.below(4) as u32;
        let cols = 1 + rng.below(4) as u32;
        match rng.below(3) {
            0 => DeltaMat::Raw(adversarial_matrix(rng)),
            1 => {
                let n = rng.below(32);
                DeltaMat::Full {
                    rows,
                    cols,
                    comp: (0..n).map(|_| rng.next_u64() as u8).collect(),
                }
            }
            _ => {
                let n = rng.below(32);
                DeltaMat::Delta {
                    rows,
                    cols,
                    comp: (0..n).map(|_| rng.next_u64() as u8).collect(),
                }
            }
        }
    }

    fn arbitrary_msg(rng: &mut Pcg64) -> WireMsg {
        match rng.below(15) {
            0 => WireMsg::Hello { worker_id: rng.next_u64() as u32 },
            1 => WireMsg::HelloV2 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
            },
            2 => {
                // Block lists from empty up to a large (max-len-ish) run.
                let n = [0, 1, 7, 4096][rng.below(4)];
                let blocks = (0..n)
                    .map(|i| BlockSpec {
                        index: i as u32,
                        rows: 1 + rng.below(64) as u32,
                        cols: 1 + rng.below(64) as u32,
                    })
                    .collect();
                WireMsg::Init(InitMsg {
                    kind: rng.below(3) as u8,
                    rank: rng.below(512) as u32,
                    beta2: adversarial_f64(rng),
                    eps: adversarial_f64(rng),
                    one_sided: rng.bernoulli(0.5),
                    graft: rng.below(6) as u8,
                    threads: rng.below(64) as u32,
                    blocks,
                })
            }
            3 => {
                let n = rng.below(4);
                let entries = (0..n)
                    .map(|i| StepEntry {
                        index: i as u32,
                        refresh_due: rng.bernoulli(0.5),
                        param: adversarial_matrix(rng),
                        grad: adversarial_matrix(rng),
                    })
                    .collect();
                WireMsg::Step(StepMsg {
                    t: rng.next_u64(),
                    scale: adversarial_f64(rng),
                    preconditioning: rng.bernoulli(0.5),
                    stat_due: rng.bernoulli(0.5),
                    lr: adversarial_f64(rng),
                    beta1: adversarial_f64(rng),
                    weight_decay: adversarial_f64(rng),
                    entries,
                })
            }
            4 => {
                let n = rng.below(4);
                let entries =
                    (0..n).map(|i| (i as u32, adversarial_matrix(rng))).collect();
                WireMsg::StepOk(StepOkMsg {
                    t: rng.next_u64(),
                    refreshes: rng.next_u64() as u32,
                    entries,
                })
            }
            5 => WireMsg::MemStats,
            6 => WireMsg::MemStatsOk {
                mem_bytes: rng.next_u64(),
                second_moment_bytes: rng.next_u64(),
            },
            7 => WireMsg::Shutdown,
            8 => WireMsg::Ok,
            9 => {
                let len = [0, 1, 200][rng.below(3)];
                let message: String =
                    (0..len).map(|_| char::from(b'a' + rng.below(26) as u8)).collect();
                WireMsg::Error { message }
            }
            10 => {
                let n = [0, 3, 1000][rng.below(3)];
                WireMsg::RefreshAhead(RefreshAheadMsg {
                    t_next: rng.next_u64(),
                    all: rng.bernoulli(0.5),
                    due: (0..n).map(|_| rng.next_u64() as u32).collect(),
                })
            }
            11 => {
                let n = rng.below(16);
                WireMsg::RefreshAheadOk(RefreshAheadOkMsg {
                    t_next: rng.next_u64(),
                    count: rng.next_u64() as u32,
                    refreshed: (0..n).map(|_| rng.next_u64() as u32).collect(),
                })
            }
            12 => WireMsg::HelloV3 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
                compress: rng.bernoulli(0.5),
            },
            13 => {
                let n = rng.below(4);
                let entries = (0..n)
                    .map(|i| StepEntryV3 {
                        index: i as u32,
                        refresh_due: rng.bernoulli(0.5),
                        param: arbitrary_delta_mat(rng),
                        grad: arbitrary_delta_mat(rng),
                    })
                    .collect();
                WireMsg::StepV3(StepV3Msg {
                    t: rng.next_u64(),
                    base_t: rng.next_u64(),
                    resync: rng.bernoulli(0.5),
                    scale: adversarial_f64(rng),
                    preconditioning: rng.bernoulli(0.5),
                    stat_due: rng.bernoulli(0.5),
                    lr: adversarial_f64(rng),
                    beta1: adversarial_f64(rng),
                    weight_decay: adversarial_f64(rng),
                    entries,
                })
            }
            _ => {
                let n = rng.below(4);
                let entries =
                    (0..n).map(|i| (i as u32, arbitrary_delta_mat(rng))).collect();
                WireMsg::StepOkV3(StepOkV3Msg {
                    t: rng.next_u64(),
                    base_t: rng.next_u64(),
                    refreshes: rng.next_u64() as u32,
                    entries,
                })
            }
        }
    }

    #[test]
    fn every_message_kind_roundtrips_over_adversarial_payloads() {
        // encode → decode → re-encode identity, compared at the byte
        // level: `Matrix` equality uses f64 `==`, which would falsely
        // reject NaN payloads that in fact round-tripped bit-exactly.
        crate::util::proptest::for_all_msg(
            0x5117e,
            300,
            arbitrary_msg,
            |msg| {
                let frame = encode_frame(msg).map_err(|e| format!("encode: {e}"))?;
                let decoded = decode_payload(&frame[4..]).map_err(|e| format!("decode: {e}"))?;
                let reframe = encode_frame(&decoded).map_err(|e| format!("re-encode: {e}"))?;
                if frame == reframe {
                    Ok(())
                } else {
                    Err("re-encoded frame differs from original".to_string())
                }
            },
        );
    }

    #[test]
    fn every_truncation_of_every_kind_is_rejected() {
        // For one representative frame of each message kind, every
        // strict prefix must fail to read (no silent partial decode).
        let mut rng = Pcg64::new(0x7c);
        let mut kinds_seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let msg = arbitrary_msg(&mut rng);
            let tag = std::mem::discriminant(&msg);
            if !kinds_seen.insert(tag) {
                continue;
            }
            let frame = encode_frame(&msg).unwrap();
            for cut in 0..frame.len() {
                assert!(
                    read_msg(&mut &frame[..cut]).is_err(),
                    "prefix of {cut}/{} bytes decoded for {msg:?}",
                    frame.len()
                );
            }
        }
        assert!(kinds_seen.len() >= 15, "generator missed kinds: {}", kinds_seen.len());
    }

    #[test]
    fn bad_lengths_are_rejected_without_allocation_blowup() {
        // A list-count field claiming u32::MAX entries in a tiny frame
        // must fail on the missing bytes, not try to allocate for it.
        let mut payload = vec![TAG_REFRESH_AHEAD];
        payload.extend_from_slice(&7u64.to_le_bytes()); // t_next
        payload.push(0); // all = false
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // due count lie
        assert!(decode_payload(&payload).is_err());
        // Same lie on a matrix-bearing message.
        let mut payload = vec![TAG_STEP_OK];
        payload.extend_from_slice(&1u64.to_le_bytes()); // t
        payload.extend_from_slice(&0u32.to_le_bytes()); // refreshes
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // entry count lie
        assert!(decode_payload(&payload).is_err());
        // Implausible matrix shapes are rejected before the data reads.
        let mut payload = vec![TAG_STEP_OK];
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes()); // one entry
        payload.extend_from_slice(&0u32.to_le_bytes()); // index
        payload.extend_from_slice(&((1u32 << 21).to_le_bytes())); // rows too big
        payload.extend_from_slice(&1u32.to_le_bytes()); // cols
        assert!(decode_payload(&payload).is_err());
        // A frame length prefix longer than the stream is a read error.
        let frame = encode_frame(&WireMsg::Ok).unwrap();
        let mut lying = frame.clone();
        lying[0] = 200; // declares 200 payload bytes; only 1 follows
        assert!(read_msg_opt(&mut &lying[..]).is_err());
        // A corrupt prefix claiming a near-cap (512 MB) payload fails on
        // the missing bytes — the reader grows its buffer with arriving
        // data rather than allocating the full declared length up front.
        let mut huge = (1u32 << 29).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 16]);
        assert!(read_msg_opt(&mut &huge[..]).is_err());
        // Bad bool byte inside an otherwise valid frame.
        let mut payload = vec![TAG_REFRESH_AHEAD];
        payload.extend_from_slice(&7u64.to_le_bytes());
        payload.push(2); // bool must be 0 or 1
        payload.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode_payload(&payload).is_err());
    }

    // -----------------------------------------------------------------
    // v3 payload layer: RLE/varint compressor + DeltaMat codec.
    // -----------------------------------------------------------------

    #[test]
    fn rle_roundtrips_and_crushes_zero_runs() {
        // Hand-picked shapes: empty, all-zero, no zeros, lone zeros,
        // alternating runs, trailing run.
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0; 4096],
            (1..=200u8).collect(),
            vec![1, 0, 2, 0, 3],
            vec![0, 0, 0, 7, 7, 0, 0, 1, 0],
            vec![5, 5, 5, 0, 0, 0, 0],
        ];
        for data in &cases {
            let comp = rle_compress(data);
            let back = rle_decompress(&comp, data.len()).unwrap();
            assert_eq!(&back, data);
        }
        // The all-zero case must actually compress.
        assert!(rle_compress(&[0u8; 4096]).len() < 8);
        // Random property sweep (zero-biased bytes so both token kinds
        // fire).
        crate::util::proptest::for_all_msg(
            0x41e,
            200,
            |rng| {
                let n = rng.below(600);
                (0..n)
                    .map(|_| if rng.bernoulli(0.6) { 0u8 } else { rng.next_u64() as u8 })
                    .collect::<Vec<u8>>()
            },
            |data| {
                let comp = rle_compress(data);
                let back =
                    rle_decompress(&comp, data.len()).map_err(|e| format!("decompress: {e}"))?;
                if &back == data {
                    Ok(())
                } else {
                    Err("rle roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn rle_decompress_rejects_corrupt_streams() {
        let comp = rle_compress(&[1, 2, 0, 0, 0, 3]);
        // Wrong expected length (both directions).
        assert!(rle_decompress(&comp, 5).is_err());
        assert!(rle_decompress(&comp, 7).is_err());
        // Truncated literal.
        let mut lit = Vec::new();
        super::push_varint(&mut lit, (8 << 1) | 1);
        lit.extend_from_slice(&[1, 2, 3]); // claims 8 literal bytes, has 3
        assert!(rle_decompress(&lit, 8).is_err());
        // A zero-run token claiming far more than `expected` must fail
        // before allocating for it.
        let mut bomb = Vec::new();
        super::push_varint(&mut bomb, u64::MAX & !1);
        assert!(rle_decompress(&bomb, 64).is_err());
        // Zero-length tokens cannot loop forever.
        let zero_tok = vec![0u8];
        assert!(rle_decompress(&zero_tok, 0).is_err());
        // Truncated varint.
        assert!(rle_decompress(&[0x80], 4).is_err());
        // Varint longer than u64.
        assert!(rle_decompress(&[0xff; 11], 4).is_err());
    }

    #[test]
    fn delta_mat_encodes_losslessly_in_every_mode() {
        let mut rng = Pcg64::new(0xd31a);
        for _ in 0..50 {
            let rows = 1 + rng.below(5);
            let cols = 1 + rng.below(5);
            let cur: Vec<u64> = (0..rows * cols)
                .map(|_| adversarial_f64(&mut rng).to_bits())
                .collect();
            // Baseline close to `cur` (sparse delta), far, and absent.
            let mut near = cur.clone();
            if !near.is_empty() {
                let k = rng.below(near.len());
                near[k] ^= 1;
            }
            let far: Vec<u64> = (0..cur.len()).map(|_| rng.next_u64()).collect();
            for base in [Some(&near), Some(&far), None] {
                let dm = DeltaMat::encode(rows, cols, &cur, base.map(|b| b.as_slice()));
                assert_eq!(dm.shape(), (rows, cols));
                let back = dm.resolve(base.map(|b| b.as_slice())).unwrap();
                assert_eq!(back, cur, "delta codec must be bit-lossless");
            }
        }
        // An unchanged payload deltas down to almost nothing.
        let cur = vec![0x3ff0_0000_0000_0001u64; 256];
        let dm = DeltaMat::encode(16, 16, &cur, Some(&cur));
        match &dm {
            DeltaMat::Delta { comp, .. } => assert!(comp.len() < 8, "got {} bytes", comp.len()),
            other => panic!("unchanged payload should pick Delta, got {other:?}"),
        }
        // Incompressible data without a baseline falls back to Raw.
        let mut rng = Pcg64::new(0xd31b);
        let noise: Vec<u64> = (0..64).map(|_| rng.next_u64() | 0x0101_0101_0101_0101).collect();
        assert!(matches!(DeltaMat::encode(8, 8, &noise, None), DeltaMat::Raw(_)));
    }

    #[test]
    fn delta_mat_resolve_rejects_bad_baselines() {
        let cur = vec![1u64, 2, 3, 4];
        let base = vec![9u64, 9, 9, 9];
        let dm = DeltaMat::encode(2, 2, &cur, Some(&base));
        assert!(matches!(dm, DeltaMat::Delta { .. }));
        // Delta without a baseline is an error, not garbage bits.
        assert!(dm.resolve(None).is_err());
        // Wrong-length baseline is rejected.
        assert!(dm.resolve(Some(&base[..2])).is_err());
        // Corrupt compressed body cannot satisfy the expected length.
        let bad = DeltaMat::Delta { rows: 2, cols: 2, comp: vec![0x03, 0xff] };
        assert!(bad.resolve(Some(&base)).is_err());
    }

    #[test]
    fn corrupt_frames_fail_loudly() {
        // Oversized length prefix.
        let mut bad = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        bad.extend_from_slice(&[0; 8]);
        assert!(read_msg_opt(&mut &bad[..]).is_err());
        // Unknown tag.
        let mut frame = encode_frame(&WireMsg::Ok).unwrap();
        frame[4] = 0xEE;
        assert!(read_msg_opt(&mut &frame[..]).is_err());
        // Trailing garbage inside a valid-length frame.
        let mut frame = encode_frame(&WireMsg::Shutdown).unwrap();
        frame[0] = 2; // payload length 2: tag + 1 junk byte
        frame.push(0);
        assert!(read_msg_opt(&mut &frame[..]).is_err());
    }
}
