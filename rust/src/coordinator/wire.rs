//! Length-prefixed wire codec for the cross-process shard engine.
//!
//! The driver and its `sketchy shard-worker` processes exchange frames
//! over localhost TCP or Unix domain sockets (see [`super::shard`]). A
//! frame is a little-endian `u32` payload length followed by the payload:
//! a one-byte message tag plus fixed-width fields. Every `f64` travels as
//! its IEEE-754 bit pattern (`to_bits`/`from_bits`), so a parameter block
//! round-trips **bitwise exactly** — the property the shard determinism
//! tests pin down. No serde, no external deps.
//!
//! Protocol (driver ⇄ worker, strict request/response):
//!
//! | driver sends      | worker replies      |
//! |-------------------|---------------------|
//! | [`WireMsg::Init`] | [`WireMsg::Ok`]     |
//! | [`WireMsg::Step`] | [`WireMsg::StepOk`] |
//! | [`WireMsg::StepV3`] | [`WireMsg::StepOkV3`] |
//! | [`WireMsg::RefreshAhead`] | [`WireMsg::RefreshAheadOk`] |
//! | [`WireMsg::MemStats`] | [`WireMsg::MemStatsOk`] |
//! | [`WireMsg::Shutdown`] | [`WireMsg::Ok`], then exits |
//!
//! plus the handshake ([`WireMsg::Hello`] at protocol v1,
//! [`WireMsg::HelloV2`] at v2, [`WireMsg::HelloV3`] from v3 — worker →
//! driver, once per connection) and [`WireMsg::Error`] (worker →
//! driver, in place of any reply).
//!
//! ## Wire protocol v3: delta-compressed block payloads
//!
//! Full frames ship every block's dense factors as raw `f64` bits —
//! fine on localhost, prohibitive on cross-host links. Protocol v3 adds
//! a payload layer ([`WireMsg::StepV3`] / [`WireMsg::StepOkV3`]) that
//! exploits what the Sketchy argument implies about the state worth
//! moving: between consecutive steps most parameter bits either do not
//! change at all (the driver re-uploads exactly the block the worker
//! returned; inactive embedding columns are bit-frozen) or change by a
//! small update. Each matrix travels as a [`DeltaMat`]: raw, or the
//! RLE/varint compression of its `f64` bit patterns XORed against the
//! receiver's baseline — the payload of the last mutually acked step,
//! tagged by `base_t` so a replayed frame can never be applied against
//! the wrong baseline. A `resync` flag (set by the driver after any
//! reconnect) drops all baselines and forces full frames in both
//! directions. The codec is **lossless on bit patterns**, so the shard
//! determinism contract (bitwise identity with the in-process engine)
//! is untouched; v2/v1 peers simply keep receiving uncompressed full
//! frames, exactly like the refresh-overlap degrade matrix.
//!
//! `RefreshAhead` is the only request the driver parks: it is sent at the
//! end of step `t` and its reply is not read until the top of step
//! `t + 1`, so the worker's eigendecompositions overlap the trainer's
//! gradient computation (a second in-flight request per shard). Workers
//! that greet with the v1 `Hello` never receive it — the driver degrades
//! that shard to synchronous refresh.
//!
//! ## Wire protocol v4: sketch-native typed block payloads
//!
//! Protocol v4 replaces the untyped matrix round-trips with the
//! [`BlockPayload`] codec: every matrix-shaped object crosses the wire as
//! a typed payload — `Dense` (composing with the [`DeltaMat`] delta
//! layer), `Sketch` (rank-ℓ FD factors + the escaped-mass scalar, O(dℓ)
//! bytes instead of a materialized O(d²) covariance), or `Diag`. On top
//! of it ride the typed step frames ([`WireMsg::StepV4`] /
//! [`WireMsg::StepOkV4`]), the escaped-mass-reporting
//! [`WireMsg::RefreshAheadOkV4`], and the block-state RPCs
//! ([`WireMsg::StateSnap`] / [`WireMsg::StateSnapOk`] /
//! [`WireMsg::StateRestore`]) that let a driver pull or push entire
//! optimizer states ([`StatePayload`]) — sketched `SketchUnit` sides
//! travel as their factors, never densified. The same payload types are
//! the checkpoint v2 block format ([`crate::train::checkpoint`]). v3/v2/
//! v1 peers keep working exactly as before (typed frames and state RPCs
//! simply never flow on those links), following the established degrade
//! matrix.
//!
//! ## Wire protocol v5: dynamic membership
//!
//! Protocol v5 adds the membership handshake behind the `member`
//! capability bit of [`WireMsg::HelloV5`]: the driver can send
//! [`WireMsg::Adopt`] to re-seat a connected worker — typically a warm
//! spare — as a specific shard under an epoch-numbered fleet view
//! (reply: [`WireMsg::AdoptOk`]). The adopted identity sticks for the
//! rest of the worker's life, so reconnect + replay keep working after
//! a migration. Everything else about a migration reuses existing
//! layers: state moves via the v4 `StateSnap`/`StateRestore` typed
//! payloads, and the replacement link's delta baselines resync exactly
//! like any fresh connection. v4-and-below peers step bitwise as
//! before; elastic failover just refuses cleanly on fleets containing
//! any non-`member` link.

use crate::optim::precond::{
    BlockStateSnap, EigCorrState, PrecondState, SideState, SketchCorrState, SketchState,
};
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, ensure, Context};
use std::io::{Read, Write};
use std::time::Duration;

/// Current wire protocol version, carried in [`WireMsg::HelloV5`].
/// Version 1 (the plain [`WireMsg::Hello`] greeting) predates the
/// `RefreshAhead` messages; drivers treat v1 workers as refresh-overlap
/// incapable and keep their refreshes synchronous. Version 2 added the
/// capability handshake + RefreshAhead; version 3 adds the
/// delta-compressed block payload layer ([`DeltaMat`]); version 4 adds
/// the typed [`BlockPayload`] codec and the block-state RPCs; version 5
/// adds the membership frames ([`WireMsg::Adopt`] /
/// [`WireMsg::AdoptOk`]) behind the `member` capability, so a warm
/// spare can be re-seated as a dead shard mid-run; version 6 adds the
/// liveness frames ([`WireMsg::Ping`] / [`WireMsg::Pong`]) behind the
/// `heartbeat` capability, so the driver's supervisor can probe a
/// silent worker instead of waiting out the blocking reply timeout;
/// version 7 adds the EKFAC corrector layer: the `--ekfac` knob travels
/// in the extended init frame (`TAG_INIT_V7`), and corrector diagonals
/// ride in the typed state payloads under new mode bytes. Ekfac-off
/// runs encode byte-identically to v6, so pre-v7 peers step bitwise as
/// before; ekfac-on fleets require every link at v7+ (the driver
/// refuses mixed fleets at construction instead of silently dropping
/// the correction on some shards).
/// Drivers treat lower-version workers as lacking the newer layers and
/// degrade per link.
pub const PROTO_VERSION: u32 = 7;

/// A connected driver↔worker byte stream: any transport the shard
/// channel can speak — TCP, Unix sockets, or the in-memory
/// fault-injection harness ([`super::fault`]).
pub trait Conn: Read + Write + Send {
    /// Bound blocking reads (`None` = block forever). Transports that
    /// cannot honor a bound may clamp it; the driver treats a timed-out
    /// read as a transport failure (reconnect + replay).
    fn set_timeout(&mut self, dur: Option<Duration>) -> std::io::Result<()>;
}

/// Upper bound on a single frame (guards against corrupt length
/// prefixes allocating unbounded memory).
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Shard of one tensor block assigned to a worker: the engine's global
/// block index plus the block shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpec {
    pub index: u32,
    pub rows: u32,
    pub cols: u32,
}

/// Driver → worker: build per-block preconditioner states.
#[derive(Clone, Debug, PartialEq)]
pub struct InitMsg {
    /// Unit family: 0 = Shampoo, 1 = Sketched (`rank` applies), 2 = Adam.
    pub kind: u8,
    /// FD sketch size ℓ (sketched units only).
    pub rank: u32,
    pub beta2: f64,
    pub eps: f64,
    pub one_sided: bool,
    /// Grafting method code ([`crate::optim::GraftType::code`]).
    pub graft: u8,
    /// Worker-side thread knob (0 = auto); never changes the numbers.
    pub threads: u32,
    pub blocks: Vec<BlockSpec>,
    /// EKFAC inter-refresh corrections on every unit (wire v7). `false`
    /// encodes as the legacy init frame, byte-identical to v6; `true`
    /// ships as `TAG_INIT_V7`, which pre-v7 workers reject by tag — the
    /// driver never sends it to them (mixed fleets are refused at
    /// construction).
    pub ekfac: bool,
}

/// One block's inputs for a driven step.
///
/// Construct via [`StepEntry::new`] — entry assembly lives in this codec
/// module so the payload layers (v1 raw, v3 delta, v4 typed) stay in one
/// place; building the struct literally outside it is deprecated.
#[derive(Clone, Debug, PartialEq)]
pub struct StepEntry {
    pub index: u32,
    /// Whether this block's staggered refresh slot lands on this step.
    pub refresh_due: bool,
    pub param: Matrix,
    pub grad: Matrix,
}

impl StepEntry {
    /// Codec-owned constructor for v1/v2 full-frame step entries.
    pub fn new(index: u32, refresh_due: bool, param: Matrix, grad: Matrix) -> StepEntry {
        StepEntry { index, refresh_due, param, grad }
    }
}

/// Driver → worker: drive every assigned block one step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepMsg {
    pub t: u64,
    pub scale: f64,
    pub preconditioning: bool,
    pub stat_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub entries: Vec<StepEntry>,
}

/// Worker → driver: updated parameter blocks + refresh accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct StepOkMsg {
    /// Echo of the driving step's `t` (idempotent-retry key).
    pub t: u64,
    /// Eigendecompositions run on this shard during the step.
    pub refreshes: u32,
    pub entries: Vec<(u32, Matrix)>,
}

/// Driver → worker: recompute inverse roots *now*, ahead of the step
/// that will use them. Sent at the end of step `t_next - 1`; the reply
/// is read just before `t_next`'s [`WireMsg::Step`], so the work hides
/// behind the trainer's gradient computation.
#[derive(Clone, Debug, PartialEq)]
pub struct RefreshAheadMsg {
    /// The step whose refresh slots are being prefetched (idempotency
    /// key for replay after a reconnect).
    pub t_next: u64,
    /// Visit every owned block, not just the due subset (first
    /// preconditioning step, where not-yet-ready blocks refresh
    /// regardless of their slot).
    pub all: bool,
    /// Global indices of the owned blocks whose refresh slot fires at
    /// `t_next`.
    pub due: Vec<u32>,
}

/// Worker → driver: which blocks were refreshed ahead, plus the
/// eigendecomposition count (refresh accounting).
#[derive(Clone, Debug, PartialEq)]
pub struct RefreshAheadOkMsg {
    /// Echo of the request's `t_next`.
    pub t_next: u64,
    /// Eigendecompositions that ran ahead.
    pub count: u32,
    /// Global indices of blocks whose roots are now fresh — the step at
    /// `t_next` must not refresh them again.
    pub refreshed: Vec<u32>,
}

/// One matrix payload in a v3 delta stream. The codec is stateless:
/// decoding yields the mode + compressed bytes, and XOR application
/// against the receiver's baseline happens in the message handler —
/// after the step-replay cache and shape validation have run, so a
/// replayed or malformed frame can never corrupt baseline state.
#[derive(Clone, Debug, PartialEq)]
pub enum DeltaMat {
    /// Uncompressed full frame — bit-for-bit the v2 matrix encoding
    /// (chosen when compression would not shrink the payload).
    Raw(Matrix),
    /// RLE/varint-compressed full frame (no baseline needed).
    Full { rows: u32, cols: u32, comp: Vec<u8> },
    /// RLE/varint-compressed XOR of the matrix's `f64` bit patterns
    /// against the receiver's baseline bits for this block, which must
    /// be tagged with the enclosing message's `base_t`.
    Delta { rows: u32, cols: u32, comp: Vec<u8> },
}

impl DeltaMat {
    /// Declared shape (validated against the plausibility bound at
    /// decode; the receiver still checks it against the block it owns
    /// before resolving).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            DeltaMat::Raw(m) => m.shape(),
            DeltaMat::Full { rows, cols, .. } | DeltaMat::Delta { rows, cols, .. } => {
                (*rows as usize, *cols as usize)
            }
        }
    }

    /// Encode a `rows`×`cols` matrix given as bit patterns, choosing
    /// the smallest of raw / compressed-full / compressed-delta (delta
    /// requires `base`, the receiver's baseline bits). Deterministic:
    /// same inputs, same choice, same bytes.
    pub fn encode(rows: usize, cols: usize, cur: &[u64], base: Option<&[u64]>) -> DeltaMat {
        debug_assert_eq!(rows * cols, cur.len());
        let raw_len = cur.len() * 8;
        // Prefer a winning delta outright — compressing the full frame
        // as well, just to compare, would double the per-step encode
        // cost for payloads whose delta already crushes (the unchanged
        // upload / frozen-parameter hot cases).
        if let Some(base) = base {
            debug_assert_eq!(base.len(), cur.len());
            let mut xored = Vec::with_capacity(raw_len);
            for (c, b) in cur.iter().zip(base) {
                xored.extend_from_slice(&(c ^ b).to_le_bytes());
            }
            let d = rle_compress(&xored);
            if d.len() < raw_len {
                return DeltaMat::Delta { rows: rows as u32, cols: cols as u32, comp: d };
            }
        }
        let mut plain = Vec::with_capacity(raw_len);
        for c in cur {
            plain.extend_from_slice(&c.to_le_bytes());
        }
        let full = rle_compress(&plain);
        if full.len() < raw_len {
            DeltaMat::Full { rows: rows as u32, cols: cols as u32, comp: full }
        } else {
            DeltaMat::Raw(bits_matrix(rows, cols, cur))
        }
    }

    /// Standalone (baseline-free) encode of a matrix: compressed-full
    /// when that wins, raw otherwise. This is the codec entry point that
    /// replaced the scattered `mat_bits` call sites — state payloads and
    /// checkpoint tensors all come through here.
    pub fn from_matrix(m: &Matrix) -> DeltaMat {
        DeltaMat::encode(m.rows(), m.cols(), &mat_bits(m), None)
    }

    /// Resolve to a [`Matrix`] (bitwise inverse of the encode path; the
    /// matrix-side companion of [`DeltaMat::resolve`]). The caller must
    /// have validated [`DeltaMat::shape`] against the block it owns
    /// first.
    pub fn resolve_matrix(&self, base: Option<&[u64]>) -> anyhow::Result<Matrix> {
        let (rows, cols) = self.shape();
        Ok(bits_matrix(rows, cols, &self.resolve(base)?))
    }

    /// Resolve to full bit patterns, XORing `Delta` payloads against
    /// `base`. The caller must have validated the shape against the
    /// block it owns first — `expected` output length derives from it,
    /// which is what bounds the decompressor's allocation.
    pub fn resolve(&self, base: Option<&[u64]>) -> anyhow::Result<Vec<u64>> {
        let (rows, cols) = self.shape();
        let n = rows * cols;
        match self {
            DeltaMat::Raw(m) => Ok(mat_bits(m)),
            DeltaMat::Full { comp, .. } => {
                let bytes = rle_decompress(comp, n * 8)?;
                Ok(le_bytes_to_bits(&bytes))
            }
            DeltaMat::Delta { comp, .. } => {
                let base = base
                    .ok_or_else(|| anyhow!("shard wire: delta payload without a baseline"))?;
                ensure!(
                    base.len() == n,
                    "shard wire: delta baseline holds {} values, payload claims {n}",
                    base.len()
                );
                let bytes = rle_decompress(comp, n * 8)?;
                let mut bits = le_bytes_to_bits(&bytes);
                for (x, b) in bits.iter_mut().zip(base) {
                    *x ^= b;
                }
                Ok(bits)
            }
        }
    }
}

/// Bit-pattern vector of a matrix — the delta codec's working form.
pub fn mat_bits(m: &Matrix) -> Vec<u64> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

/// Rebuild a matrix from bit patterns (bitwise inverse of [`mat_bits`]).
pub fn bits_matrix(rows: usize, cols: usize, bits: &[u64]) -> Matrix {
    debug_assert_eq!(rows * cols, bits.len());
    Matrix::from_vec(rows, cols, bits.iter().map(|&b| f64::from_bits(b)).collect())
}

fn le_bytes_to_bits(bytes: &[u8]) -> Vec<u64> {
    debug_assert_eq!(bytes.len() % 8, 0);
    bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

fn push_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            break;
        }
        buf.push(b | 0x80);
    }
}

fn read_varint(b: &[u8], i: &mut usize) -> anyhow::Result<u64> {
    let mut out = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *b
            .get(*i)
            .ok_or_else(|| anyhow!("shard wire: truncated varint"))?;
        *i += 1;
        ensure!(shift < 64, "shard wire: varint overflows u64");
        out |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(out);
        }
        shift += 7;
    }
}

/// Byte-level RLE over zero runs: a token is a varint `v` where
/// `v & 1 == 0` means a run of `v >> 1` zero bytes and `v & 1 == 1`
/// means `v >> 1` literal bytes follow. Lone zeros ride inside
/// literals (a run token would cost more than the byte it replaces).
/// XORed f64 bit patterns are mostly zero wherever entries did not
/// change, which is exactly what this crushes — no deps, deterministic,
/// lossless.
pub fn rle_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4 + 16);
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            let start = i;
            while i < data.len() && data[i] == 0 {
                i += 1;
            }
            if i - start >= 2 {
                push_varint(&mut out, ((i - start) as u64) << 1);
                continue;
            }
            i = start; // lone zero: cheaper inside the literal below
        }
        let start = i;
        while i < data.len() {
            if data[i] == 0 {
                let mut j = i;
                while j < data.len() && data[j] == 0 {
                    j += 1;
                }
                if j - i >= 2 {
                    break; // a real zero run ends the literal
                }
                i = j; // lone zero joins the literal
            } else {
                i += 1;
            }
        }
        push_varint(&mut out, (((i - start) as u64) << 1) | 1);
        out.extend_from_slice(&data[start..i]);
    }
    out
}

/// Inverse of [`rle_compress`]. `expected` is the exact output length
/// the caller derived from a validated block shape — every token is
/// checked against it before any byte materializes, so a corrupt
/// stream can neither over-allocate nor silently under-fill.
pub fn rle_decompress(comp: &[u8], expected: usize) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expected.min(1 << 20));
    let mut i = 0;
    while i < comp.len() {
        let v = read_varint(comp, &mut i)?;
        let len = usize::try_from(v >> 1).map_err(|_| anyhow!("shard wire: rle run too long"))?;
        ensure!(len > 0, "shard wire: zero-length rle token");
        ensure!(
            out.len().checked_add(len).is_some_and(|t| t <= expected),
            "shard wire: rle output overruns expected {expected} bytes"
        );
        if v & 1 == 1 {
            ensure!(
                i.checked_add(len).is_some_and(|t| t <= comp.len()),
                "shard wire: rle literal overruns input"
            );
            out.extend_from_slice(&comp[i..i + len]);
            i += len;
        } else {
            out.resize(out.len() + len, 0);
        }
    }
    ensure!(
        out.len() == expected,
        "shard wire: rle output {} bytes, expected {expected}",
        out.len()
    );
    Ok(out)
}

/// One block's inputs for a v3 delta-compressed step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepEntryV3 {
    pub index: u32,
    pub refresh_due: bool,
    pub param: DeltaMat,
    pub grad: DeltaMat,
}

/// Driver → worker: drive every assigned block one step, with the
/// block payloads delta-encoded against the last mutually acked step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepV3Msg {
    pub t: u64,
    /// Step whose decoded payload the [`DeltaMat::Delta`] entries XOR
    /// against (0 = no baseline: every entry is `Raw`/`Full`). The
    /// receiver rejects a mismatch against its own baseline tag instead
    /// of applying a delta to the wrong bits.
    pub base_t: u64,
    /// Receiver must drop every delta baseline (both directions) before
    /// processing and reply with full frames. The driver sets this on
    /// the first step encoded after any reconnect — the full-frame
    /// resync that re-anchors the stream.
    pub resync: bool,
    pub scale: f64,
    pub preconditioning: bool,
    pub stat_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub entries: Vec<StepEntryV3>,
}

/// Worker → driver: updated parameter blocks, delta-encoded against the
/// worker's previous reply (which the lockstep protocol guarantees the
/// driver has decoded before it could send this step).
#[derive(Clone, Debug, PartialEq)]
pub struct StepOkV3Msg {
    pub t: u64,
    /// Baseline tag for `Delta` entries (0 = none).
    pub base_t: u64,
    pub refreshes: u32,
    pub entries: Vec<(u32, DeltaMat)>,
}

impl StepEntryV3 {
    /// Codec-owned constructor for v3 delta-compressed step entries.
    pub fn new(index: u32, refresh_due: bool, param: DeltaMat, grad: DeltaMat) -> StepEntryV3 {
        StepEntryV3 { index, refresh_due, param, grad }
    }
}

// ---------------------------------------------------------------------------
// v4 typed block payloads + state codec.
// ---------------------------------------------------------------------------

/// How one matrix-shaped object crosses a v4 wire (or lands in a v2
/// checkpoint): dense matrices keep composing with the [`DeltaMat`]
/// delta layer; FD-sketched factors travel in factored O(dℓ) form;
/// diagonal accumulators are tagged so a receiver can sanity-check the
/// payload kind against the unit it owns.
#[derive(Clone, Debug, PartialEq)]
pub enum BlockPayload {
    /// Dense matrix (raw / compressed-full / delta against a baseline).
    Dense(DeltaMat),
    /// Rank-ℓ FD sketch factors + escaped-mass scalar.
    Sketch(SketchPayload),
    /// Elementwise (diagonal-method) accumulator.
    Diag(DeltaMat),
}

/// Serialized FD sketch: the d×ℓ eigenbasis, ℓ eigenvalues, and the
/// RFD escaped-mass bookkeeping that makes the sketch self-contained.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchPayload {
    /// Eigenbasis, d×ℓ (standalone-encoded; never `Delta`).
    pub basis: DeltaMat,
    /// Eigenvalues (descending, length ℓ) as IEEE-754 bit-exact f64s.
    pub eigvals: Vec<f64>,
    /// Cumulative escaped mass ρ_{1:t}.
    pub escaped_mass: f64,
    /// Escaped mass of the most recent update.
    pub last_rho: f64,
    /// Update counter.
    pub steps: u64,
}

impl SketchPayload {
    /// Encode an FD sketch state ([`SketchState`]) for the wire.
    pub fn from_state(s: &SketchState) -> SketchPayload {
        SketchPayload {
            basis: DeltaMat::from_matrix(&s.basis),
            eigvals: s.eigvals.clone(),
            escaped_mass: s.escaped_mass,
            last_rho: s.last_rho,
            steps: s.steps,
        }
    }

    /// Validate this payload's declared geometry against the expected
    /// sketch dimensions **without resolving anything** — the alloc-bomb
    /// guard for adversarial rank fields.
    pub fn validate(&self, dim: usize, rank: usize) -> anyhow::Result<()> {
        let (r, c) = self.basis.shape();
        ensure!(
            r == dim && c == rank,
            "state payload: sketch basis {r}x{c} != expected {dim}x{rank}"
        );
        ensure!(
            self.eigvals.len() == rank,
            "state payload: {} eigenvalues for a rank-{rank} sketch",
            self.eigvals.len()
        );
        ensure!(
            !matches!(self.basis, DeltaMat::Delta { .. }),
            "state payload: sketch basis must be standalone, not delta-encoded"
        );
        Ok(())
    }

    /// Decode into a [`SketchState`], validating against the expected
    /// dimensions before any allocation-bearing resolve runs.
    pub fn into_state(self, dim: usize, rank: usize) -> anyhow::Result<SketchState> {
        self.validate(dim, rank)?;
        Ok(SketchState {
            basis: self.basis.resolve_matrix(None)?,
            eigvals: self.eigvals,
            escaped_mass: self.escaped_mass,
            last_rho: self.last_rho,
            steps: self.steps,
        })
    }
}

impl BlockPayload {
    /// Standalone dense payload for a matrix (codec entry point that
    /// replaced direct `mat_bits` construction at the call sites).
    pub fn dense(m: &Matrix) -> BlockPayload {
        BlockPayload::Dense(DeltaMat::from_matrix(m))
    }

    /// Standalone diagonal-accumulator payload.
    pub fn diag(m: &Matrix) -> BlockPayload {
        BlockPayload::Diag(DeltaMat::from_matrix(m))
    }

    /// Declared shape of the payload (sketches report their basis shape).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            BlockPayload::Dense(dm) | BlockPayload::Diag(dm) => dm.shape(),
            BlockPayload::Sketch(s) => s.basis.shape(),
        }
    }

    /// Resolve a `Dense` payload to a matrix, validating the declared
    /// shape against the expected block geometry *before* the resolve
    /// allocates. `Sketch`/`Diag` payloads in a dense position are a
    /// protocol error.
    pub fn resolve_dense(
        &self,
        rows: usize,
        cols: usize,
        base: Option<&[u64]>,
    ) -> anyhow::Result<Matrix> {
        let BlockPayload::Dense(dm) = self else {
            bail!("block payload: expected a dense payload, got {}", self.kind_label());
        };
        let (r, c) = dm.shape();
        ensure!(r == rows && c == cols, "block payload: shape {r}x{c} != expected {rows}x{cols}");
        dm.resolve_matrix(base)
    }

    /// Resolve a `Diag` payload (standalone; same pre-resolve shape
    /// validation as [`BlockPayload::resolve_dense`]).
    pub fn resolve_diag(&self, rows: usize, cols: usize) -> anyhow::Result<Matrix> {
        let BlockPayload::Diag(dm) = self else {
            bail!("block payload: expected a diagonal payload, got {}", self.kind_label());
        };
        let (r, c) = dm.shape();
        ensure!(r == rows && c == cols, "block payload: shape {r}x{c} != expected {rows}x{cols}");
        dm.resolve_matrix(None)
    }

    fn kind_label(&self) -> &'static str {
        match self {
            BlockPayload::Dense(_) => "dense",
            BlockPayload::Sketch(_) => "sketch",
            BlockPayload::Diag(_) => "diag",
        }
    }
}

/// Serialized EKFAC eigenbasis corrector (wire v7): the stale basis the
/// unit corrects in plus the tracked per-direction diagonal. Travels in
/// the typed state payloads so snapshot/checkpoint/journal resume stay
/// bitwise for ekfac runs.
#[derive(Clone, Debug, PartialEq)]
pub struct EigCorrPayload {
    /// Eigenbasis, dim×dim (dense, standalone).
    pub basis: BlockPayload,
    /// Corrected diagonal (length dim) as IEEE-754 bit-exact f64s.
    pub diag: Vec<f64>,
}

impl EigCorrPayload {
    fn from_state(s: &EigCorrState) -> EigCorrPayload {
        EigCorrPayload { basis: BlockPayload::dense(&s.basis), diag: s.diag.clone() }
    }

    /// Decode for a dim×dim eigenbasis, validating declared geometry
    /// before anything resolves (the alloc-bomb discipline).
    fn into_state(self, what: &str, dim: usize) -> anyhow::Result<EigCorrState> {
        ensure!(
            self.diag.len() == dim,
            "state payload: {what}: {} corrector diagonal entries for dim {dim}",
            self.diag.len()
        );
        Ok(EigCorrState {
            basis: self
                .basis
                .resolve_dense(dim, dim, None)
                .with_context(|| format!("{what}: corrector basis"))?,
            diag: self.diag,
        })
    }
}

/// Serialized EKFAC sketch corrector (wire v7): the corrected diagonal
/// over the unit's rank-ℓ FD basis plus the escaped-mass tail scale.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchCorrPayload {
    /// Corrected diagonal over the FD basis columns (length ℓ).
    pub diag: Vec<f64>,
    /// Tracked tail second moment (escaped-mass direction).
    pub tail: f64,
}

impl SketchCorrPayload {
    fn from_state(s: &SketchCorrState) -> SketchCorrPayload {
        SketchCorrPayload { diag: s.diag.clone(), tail: s.tail }
    }

    fn into_state(self, rank: usize) -> anyhow::Result<SketchCorrState> {
        ensure!(
            self.diag.len() == rank,
            "state payload: {} corrector diagonal entries for a rank-{rank} sketch",
            self.diag.len()
        );
        Ok(SketchCorrState { diag: self.diag, tail: self.tail })
    }
}

/// One side of a serialized [`StatePayload::Sketch`].
#[derive(Clone, Debug, PartialEq)]
pub enum SidePayload {
    /// dim ≤ ℓ: exact small factor + cached root (+ v7 corrector).
    Exact { c: BlockPayload, root: Option<BlockPayload>, corr: Option<EigCorrPayload> },
    /// dim > ℓ: factored FD sketch (+ v7 corrector).
    Sketch { sketch: SketchPayload, corr: Option<SketchCorrPayload> },
}

/// Full serialized preconditioner-unit state — the wire/checkpoint form
/// of [`PrecondState`]. Sketched sides stay factored end to end.
#[derive(Clone, Debug, PartialEq)]
pub enum StatePayload {
    /// Exact Kronecker factors + cached inverse roots (+ v7 correctors).
    Kron {
        l: BlockPayload,
        r: BlockPayload,
        l_root: Option<BlockPayload>,
        r_root: Option<BlockPayload>,
        l_corr: Option<EigCorrPayload>,
        r_corr: Option<EigCorrPayload>,
    },
    /// Per-side sketched (or small-exact) factors.
    Sketch { left: SidePayload, right: SidePayload },
    /// Diagonal Adam moments + step counter.
    Diag { m: BlockPayload, v: BlockPayload, t: u64 },
}

/// What the receiver knows a block's state must look like — the
/// pre-resolve validation context for state payloads. Every declared
/// shape/rank in an incoming [`BlockStateMsg`] is checked against this
/// (derived from the receiver's own block table) before any payload
/// resolves, so adversarial rank/shape fields can never drive
/// allocations.
#[derive(Clone, Copy, Debug)]
pub struct StateExpect {
    pub rows: usize,
    pub cols: usize,
    /// Unit family code (same codes as [`InitMsg::kind`]).
    pub kind: u8,
    /// FD sketch size ℓ (sketched units only).
    pub rank: usize,
    pub one_sided: bool,
}

impl StateExpect {
    /// Whether a sketch unit's side of dimension `dim` is exact
    /// (dim ≤ ℓ) or sketched — must mirror `Side::new`.
    fn side_is_exact(&self, dim: usize) -> bool {
        dim <= self.rank
    }
}

fn side_from_state(s: &SideState) -> SidePayload {
    match s {
        SideState::Exact { c, root, corr } => SidePayload::Exact {
            c: BlockPayload::dense(c),
            root: root.as_ref().map(BlockPayload::dense),
            corr: corr.as_ref().map(EigCorrPayload::from_state),
        },
        SideState::Sketch { sketch, corr } => SidePayload::Sketch {
            sketch: SketchPayload::from_state(sketch),
            corr: corr.as_ref().map(SketchCorrPayload::from_state),
        },
    }
}

fn side_into_state(p: SidePayload, dim: usize, exp: &StateExpect) -> anyhow::Result<SideState> {
    match p {
        SidePayload::Exact { c, root, corr } => {
            ensure!(
                exp.side_is_exact(dim),
                "state payload: exact side payload for a sketched dim-{dim} side"
            );
            Ok(SideState::Exact {
                c: c.resolve_dense(dim, dim, None)?,
                root: root.map(|r| r.resolve_dense(dim, dim, None)).transpose()?,
                corr: corr.map(|cr| cr.into_state("exact side", dim)).transpose()?,
            })
        }
        SidePayload::Sketch { sketch, corr } => {
            ensure!(
                !exp.side_is_exact(dim),
                "state payload: sketch payload for an exact dim-{dim} side"
            );
            Ok(SideState::Sketch {
                sketch: sketch.into_state(dim, exp.rank)?,
                corr: corr.map(|cr| cr.into_state(exp.rank)).transpose()?,
            })
        }
    }
}

impl StatePayload {
    /// Encode a unit's [`PrecondState`] for the wire / checkpoint.
    pub fn from_state(s: &PrecondState) -> StatePayload {
        match s {
            PrecondState::Kronecker { l, r, l_root, r_root, l_corr, r_corr } => {
                StatePayload::Kron {
                    l: BlockPayload::dense(l),
                    r: BlockPayload::dense(r),
                    l_root: l_root.as_ref().map(BlockPayload::dense),
                    r_root: r_root.as_ref().map(BlockPayload::dense),
                    l_corr: l_corr.as_ref().map(EigCorrPayload::from_state),
                    r_corr: r_corr.as_ref().map(EigCorrPayload::from_state),
                }
            }
            PrecondState::Sketch { left, right } => StatePayload::Sketch {
                left: side_from_state(left),
                right: side_from_state(right),
            },
            PrecondState::Diag { m, v, t } => {
                StatePayload::Diag { m: BlockPayload::diag(m), v: BlockPayload::diag(v), t: *t }
            }
        }
    }

    /// Decode into a [`PrecondState`], validating the payload kind and
    /// every declared shape against `exp` **before** resolving (the
    /// alloc-bomb discipline: nothing materializes until the geometry
    /// checks out against the receiver's block table).
    pub fn into_state(self, exp: &StateExpect) -> anyhow::Result<PrecondState> {
        let (rows, cols) = (exp.rows, exp.cols);
        match (self, exp.kind) {
            (StatePayload::Kron { l, r, l_root, r_root, l_corr, r_corr }, 0) => {
                Ok(PrecondState::Kronecker {
                    l: l.resolve_dense(rows, rows, None)?,
                    r: r.resolve_dense(cols, cols, None)?,
                    l_root: l_root.map(|m| m.resolve_dense(rows, rows, None)).transpose()?,
                    r_root: r_root.map(|m| m.resolve_dense(cols, cols, None)).transpose()?,
                    l_corr: l_corr.map(|c| c.into_state("L", rows)).transpose()?,
                    r_corr: r_corr.map(|c| c.into_state("R", cols)).transpose()?,
                })
            }
            (StatePayload::Sketch { left, right }, 1) => Ok(PrecondState::Sketch {
                left: side_into_state(left, rows, exp)?,
                right: side_into_state(right, cols, exp)?,
            }),
            (StatePayload::Diag { m, v, t }, 2) => Ok(PrecondState::Diag {
                m: m.resolve_diag(rows, cols)?,
                v: v.resolve_diag(rows, cols)?,
                t,
            }),
            (payload, kind) => bail!(
                "state payload: {} payload for unit-kind code {kind}",
                match payload {
                    StatePayload::Kron { .. } => "Kronecker",
                    StatePayload::Sketch { .. } => "sketch",
                    StatePayload::Diag { .. } => "diagonal",
                }
            ),
        }
    }
}

/// Full serialized optimizer state of one block: the unit's
/// [`StatePayload`] plus the first-order companions. The wire form of
/// [`BlockStateSnap`]; also the checkpoint v2 block-state record.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockStateMsg {
    /// Global block index.
    pub index: u32,
    pub state: StatePayload,
    /// Momentum (always dense, block-shaped).
    pub mu: BlockPayload,
    /// Grafting accumulator (kinds that keep one).
    pub graft_v: Option<BlockPayload>,
    /// Grafting step counter.
    pub graft_t: u64,
}

impl BlockStateMsg {
    /// Encode one block's [`BlockStateSnap`] for the wire / checkpoint.
    pub fn from_snap(index: u32, snap: &BlockStateSnap) -> BlockStateMsg {
        BlockStateMsg {
            index,
            state: StatePayload::from_state(&snap.unit),
            mu: BlockPayload::dense(&snap.mu),
            graft_v: snap.graft_v.as_ref().map(BlockPayload::dense),
            graft_t: snap.graft_t,
        }
    }

    /// Decode into a [`BlockStateSnap`], validating every declared
    /// shape/rank against `exp` before resolving any payload.
    pub fn into_snap(self, exp: &StateExpect) -> anyhow::Result<BlockStateSnap> {
        let index = self.index;
        let unit = self.state.into_state(exp).with_context(|| format!("block {index} state"))?;
        let mu = self.mu.resolve_dense(exp.rows, exp.cols, None)?;
        let graft_v =
            self.graft_v.map(|g| g.resolve_dense(exp.rows, exp.cols, None)).transpose()?;
        Ok(BlockStateSnap { unit, mu, graft_v, graft_t: self.graft_t })
    }
}

/// One block's inputs for a v4 typed step (the param/grad payloads must
/// be `Dense`; the worker rejects anything else before touching them).
#[derive(Clone, Debug, PartialEq)]
pub struct StepEntryV4 {
    pub index: u32,
    pub refresh_due: bool,
    pub param: BlockPayload,
    pub grad: BlockPayload,
}

impl StepEntryV4 {
    /// Codec-owned constructor for v4 typed step entries.
    pub fn new(index: u32, refresh_due: bool, param: DeltaMat, grad: DeltaMat) -> StepEntryV4 {
        StepEntryV4 {
            index,
            refresh_due,
            param: BlockPayload::Dense(param),
            grad: BlockPayload::Dense(grad),
        }
    }
}

/// Driver → worker: drive every assigned block one step (v4 typed
/// payloads; same delta/baseline/resync semantics as [`StepV3Msg`]).
#[derive(Clone, Debug, PartialEq)]
pub struct StepV4Msg {
    pub t: u64,
    pub base_t: u64,
    pub resync: bool,
    pub scale: f64,
    pub preconditioning: bool,
    pub stat_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub entries: Vec<StepEntryV4>,
}

/// Worker → driver: updated parameter blocks as typed payloads.
#[derive(Clone, Debug, PartialEq)]
pub struct StepOkV4Msg {
    pub t: u64,
    pub base_t: u64,
    pub refreshes: u32,
    pub entries: Vec<(u32, BlockPayload)>,
}

/// Worker → driver: v4 RefreshAhead reply — the v2 fields plus the
/// per-block cumulative escaped mass of every refreshed sketched block
/// (left + right sides), the ρ_{1:t} diagnostic the driver aggregates.
#[derive(Clone, Debug, PartialEq)]
pub struct RefreshAheadOkV4Msg {
    pub t_next: u64,
    pub count: u32,
    pub refreshed: Vec<u32>,
    /// `(block index, ρ_left + ρ_right)` for refreshed sketched blocks.
    pub escaped: Vec<(u32, f64)>,
}

/// Driver → worker: snapshot the full optimizer state of the listed
/// blocks (empty = every owned block). Read-only and idempotent — safe
/// to replay verbatim after a reconnect.
#[derive(Clone, Debug, PartialEq)]
pub struct StateSnapMsg {
    pub want: Vec<u32>,
}

/// Worker → driver: the requested block states, sketched sides factored.
#[derive(Clone, Debug, PartialEq)]
pub struct StateSnapOkMsg {
    pub entries: Vec<BlockStateMsg>,
}

/// Driver → worker: overwrite the listed blocks' optimizer state
/// (reply: [`WireMsg::Ok`]). Idempotent — replay-safe.
#[derive(Clone, Debug, PartialEq)]
pub struct StateRestoreMsg {
    pub entries: Vec<BlockStateMsg>,
}

/// Every message that can cross the shard wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Worker → driver greeting carrying the identity it was spawned
    /// with (protocol v1 — no capability report).
    Hello { worker_id: u32 },
    Init(InitMsg),
    Step(StepMsg),
    StepOk(StepOkMsg),
    MemStats,
    MemStatsOk { mem_bytes: u64, second_moment_bytes: u64 },
    Shutdown,
    Ok,
    Error { message: String },
    /// Worker → driver greeting from protocol v2 on: identity plus an
    /// explicit capability report. `overlap` means the worker accepts
    /// [`WireMsg::RefreshAhead`]; a false report (or a v1 `Hello`)
    /// degrades that shard to synchronous refresh.
    HelloV2 { worker_id: u32, proto: u32, overlap: bool },
    RefreshAhead(RefreshAheadMsg),
    RefreshAheadOk(RefreshAheadOkMsg),
    /// Worker → driver greeting from protocol v3 on: identity,
    /// capability report, and whether the worker accepts the
    /// delta-compressed payload layer ([`WireMsg::StepV3`]). A false
    /// report (or a v2/v1 greeting) keeps that link on full frames.
    HelloV3 { worker_id: u32, proto: u32, overlap: bool, compress: bool },
    StepV3(StepV3Msg),
    StepOkV3(StepOkV3Msg),
    /// Worker → driver greeting from protocol v4 on: the v3 capability
    /// report plus `state` — whether the worker accepts the typed
    /// payload layer and the block-state RPCs. A false report (or any
    /// older greeting) keeps that link on the v3-and-below frames.
    HelloV4 { worker_id: u32, proto: u32, overlap: bool, compress: bool, state: bool },
    StepV4(StepV4Msg),
    StepOkV4(StepOkV4Msg),
    RefreshAheadOkV4(RefreshAheadOkV4Msg),
    StateSnap(StateSnapMsg),
    StateSnapOk(StateSnapOkMsg),
    StateRestore(StateRestoreMsg),
    /// Worker → driver greeting from protocol v5 on: the v4 capability
    /// report plus `member` — whether the worker accepts the dynamic
    /// membership frames ([`WireMsg::Adopt`]). A false report (or any
    /// older greeting) keeps that link on a fixed seat.
    HelloV5 { worker_id: u32, proto: u32, overlap: bool, compress: bool, state: bool, member: bool },
    /// Driver → worker: re-seat this worker as shard `shard` under
    /// fleet-view `epoch` — sent to a warm spare (or a freshly spawned
    /// replacement) before `Init`, so its identity survives reconnects.
    /// Reply: [`WireMsg::AdoptOk`] echoing both fields. Idempotent —
    /// replay-safe.
    Adopt { epoch: u64, shard: u32 },
    /// Worker → driver: the adoption acknowledgement.
    AdoptOk { epoch: u64, shard: u32 },
    /// Worker → driver greeting from protocol v6 on: the v5 capability
    /// report plus `heartbeat` — whether the worker answers the
    /// liveness probes ([`WireMsg::Ping`]). A false report (or any
    /// older greeting) leaves that link unsupervised: silence is only
    /// detected by the blocking reply timeout.
    HelloV6 {
        worker_id: u32,
        proto: u32,
        overlap: bool,
        compress: bool,
        state: bool,
        member: bool,
        heartbeat: bool,
    },
    /// Driver → worker liveness probe (protocol v6, `heartbeat`
    /// capability). Carries a driver-chosen sequence number; the worker
    /// echoes it in [`WireMsg::Pong`]. Valid at any point in the
    /// session, including before `Init`. Idempotent — replay-safe.
    Ping { seq: u64 },
    /// Worker → driver: the liveness probe echo.
    Pong { seq: u64 },
}

const TAG_HELLO: u8 = 1;
const TAG_INIT: u8 = 2;
const TAG_STEP: u8 = 3;
const TAG_STEP_OK: u8 = 4;
const TAG_MEM_STATS: u8 = 5;
const TAG_MEM_STATS_OK: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_OK: u8 = 8;
const TAG_ERROR: u8 = 9;
const TAG_HELLO_V2: u8 = 10;
const TAG_REFRESH_AHEAD: u8 = 11;
const TAG_REFRESH_AHEAD_OK: u8 = 12;
const TAG_HELLO_V3: u8 = 13;
const TAG_STEP_V3: u8 = 14;
const TAG_STEP_OK_V3: u8 = 15;
const TAG_HELLO_V4: u8 = 16;
const TAG_STEP_V4: u8 = 17;
const TAG_STEP_OK_V4: u8 = 18;
const TAG_REFRESH_AHEAD_OK_V4: u8 = 19;
const TAG_STATE_SNAP: u8 = 20;
const TAG_STATE_SNAP_OK: u8 = 21;
const TAG_STATE_RESTORE: u8 = 22;
const TAG_HELLO_V5: u8 = 23;
const TAG_ADOPT: u8 = 24;
const TAG_ADOPT_OK: u8 = 25;
const TAG_HELLO_V6: u8 = 26;
const TAG_PING: u8 = 27;
const TAG_PONG: u8 = 28;
/// v7 init frame: the legacy [`TAG_INIT`] body plus the trailing ekfac
/// flag. The legacy decoder rejects trailing bytes, so the extension
/// needs its own tag; drivers only emit it when ekfac is on (ekfac-off
/// init frames stay byte-identical to v6).
const TAG_INIT_V7: u8 = 29;

/// [`DeltaMat`] mode bytes.
const DM_RAW: u8 = 0;
const DM_FULL: u8 = 1;
const DM_DELTA: u8 = 2;

/// [`BlockPayload`] mode bytes.
const BP_DENSE: u8 = 0;
const BP_SKETCH: u8 = 1;
const BP_DIAG: u8 = 2;

/// [`StatePayload`] mode bytes. `SP_KRON_EKFAC` (v7) is `SP_KRON` plus
/// the two trailing corrector options — corrector-free states encode
/// under the legacy byte so they stay bit-identical to v6 frames, and
/// pre-v7 decoders reject the new byte by name instead of misreading.
const SP_KRON: u8 = 0;
const SP_SKETCH: u8 = 1;
const SP_DIAG: u8 = 2;
const SP_KRON_EKFAC: u8 = 3;

/// [`SidePayload`] mode bytes (`*_EKFAC` are the v7 corrector-carrying
/// forms; same legacy-byte rule as the state modes).
const SIDE_EXACT: u8 = 0;
const SIDE_SKETCH: u8 = 1;
const SIDE_EXACT_EKFAC: u8 = 2;
const SIDE_SKETCH_EKFAC: u8 = 3;

// ---------------------------------------------------------------------------
// Encoding.
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn boolean(&mut self, v: bool) {
        self.u8(v as u8);
    }
    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn matrix(&mut self, m: &Matrix) {
        self.u32(m.rows() as u32);
        self.u32(m.cols() as u32);
        for &x in m.as_slice() {
            self.f64(x);
        }
    }
    fn delta_mat(&mut self, m: &DeltaMat) {
        match m {
            DeltaMat::Raw(mat) => {
                self.u8(DM_RAW);
                self.matrix(mat);
            }
            DeltaMat::Full { rows, cols, comp } => {
                self.u8(DM_FULL);
                self.u32(*rows);
                self.u32(*cols);
                self.u32(comp.len() as u32);
                self.buf.extend_from_slice(comp);
            }
            DeltaMat::Delta { rows, cols, comp } => {
                self.u8(DM_DELTA);
                self.u32(*rows);
                self.u32(*cols);
                self.u32(comp.len() as u32);
                self.buf.extend_from_slice(comp);
            }
        }
    }
    fn sketch_payload(&mut self, s: &SketchPayload) {
        self.delta_mat(&s.basis);
        self.u32(s.eigvals.len() as u32);
        for &v in &s.eigvals {
            self.f64(v);
        }
        self.f64(s.escaped_mass);
        self.f64(s.last_rho);
        self.u64(s.steps);
    }
    fn block_payload(&mut self, p: &BlockPayload) {
        match p {
            BlockPayload::Dense(dm) => {
                self.u8(BP_DENSE);
                self.delta_mat(dm);
            }
            BlockPayload::Sketch(s) => {
                self.u8(BP_SKETCH);
                self.sketch_payload(s);
            }
            BlockPayload::Diag(dm) => {
                self.u8(BP_DIAG);
                self.delta_mat(dm);
            }
        }
    }
    fn opt_block_payload(&mut self, p: &Option<BlockPayload>) {
        match p {
            Some(p) => {
                self.boolean(true);
                self.block_payload(p);
            }
            None => self.boolean(false),
        }
    }
    fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f64(v);
        }
    }
    fn eig_corr(&mut self, c: &EigCorrPayload) {
        self.block_payload(&c.basis);
        self.f64s(&c.diag);
    }
    fn opt_eig_corr(&mut self, c: &Option<EigCorrPayload>) {
        match c {
            Some(c) => {
                self.boolean(true);
                self.eig_corr(c);
            }
            None => self.boolean(false),
        }
    }
    fn sketch_corr(&mut self, c: &SketchCorrPayload) {
        self.f64s(&c.diag);
        self.f64(c.tail);
    }
    fn opt_sketch_corr(&mut self, c: &Option<SketchCorrPayload>) {
        match c {
            Some(c) => {
                self.boolean(true);
                self.sketch_corr(c);
            }
            None => self.boolean(false),
        }
    }
    fn side_payload(&mut self, s: &SidePayload) {
        match s {
            SidePayload::Exact { c, root, corr: None } => {
                self.u8(SIDE_EXACT);
                self.block_payload(c);
                self.opt_block_payload(root);
            }
            SidePayload::Exact { c, root, corr } => {
                self.u8(SIDE_EXACT_EKFAC);
                self.block_payload(c);
                self.opt_block_payload(root);
                self.opt_eig_corr(corr);
            }
            SidePayload::Sketch { sketch, corr: None } => {
                self.u8(SIDE_SKETCH);
                self.sketch_payload(sketch);
            }
            SidePayload::Sketch { sketch, corr } => {
                self.u8(SIDE_SKETCH_EKFAC);
                self.sketch_payload(sketch);
                self.opt_sketch_corr(corr);
            }
        }
    }
    fn state_payload(&mut self, s: &StatePayload) {
        match s {
            StatePayload::Kron { l, r, l_root, r_root, l_corr: None, r_corr: None } => {
                self.u8(SP_KRON);
                self.block_payload(l);
                self.block_payload(r);
                self.opt_block_payload(l_root);
                self.opt_block_payload(r_root);
            }
            StatePayload::Kron { l, r, l_root, r_root, l_corr, r_corr } => {
                self.u8(SP_KRON_EKFAC);
                self.block_payload(l);
                self.block_payload(r);
                self.opt_block_payload(l_root);
                self.opt_block_payload(r_root);
                self.opt_eig_corr(l_corr);
                self.opt_eig_corr(r_corr);
            }
            StatePayload::Sketch { left, right } => {
                self.u8(SP_SKETCH);
                self.side_payload(left);
                self.side_payload(right);
            }
            StatePayload::Diag { m, v, t } => {
                self.u8(SP_DIAG);
                self.block_payload(m);
                self.block_payload(v);
                self.u64(*t);
            }
        }
    }
    fn block_state(&mut self, b: &BlockStateMsg) {
        self.u32(b.index);
        self.state_payload(&b.state);
        self.block_payload(&b.mu);
        self.opt_block_payload(&b.graft_v);
        self.u64(b.graft_t);
    }
}

/// Encode a message as a complete length-prefixed frame, ready to write.
///
/// Fails (rather than truncating the `u32` length prefix or tripping the
/// receiver's cap mid-run) when the payload exceeds [`MAX_FRAME_BYTES`]
/// — both sides enforce the same bound.
pub fn encode_frame(msg: &WireMsg) -> anyhow::Result<Vec<u8>> {
    let mut e = Enc { buf: Vec::with_capacity(64) };
    match msg {
        WireMsg::Hello { worker_id } => {
            e.u8(TAG_HELLO);
            e.u32(*worker_id);
        }
        WireMsg::Init(init) => {
            // Dual-encode: ekfac-off frames use the legacy tag and stay
            // byte-identical to v6 (pre-v7 workers keep working); ekfac
            // rides only in the v7 tag, which old workers reject by tag
            // instead of misreading.
            e.u8(if init.ekfac { TAG_INIT_V7 } else { TAG_INIT });
            e.u8(init.kind);
            e.u32(init.rank);
            e.f64(init.beta2);
            e.f64(init.eps);
            e.boolean(init.one_sided);
            e.u8(init.graft);
            e.u32(init.threads);
            e.u32(init.blocks.len() as u32);
            for b in &init.blocks {
                e.u32(b.index);
                e.u32(b.rows);
                e.u32(b.cols);
            }
            if init.ekfac {
                e.boolean(init.ekfac);
            }
        }
        WireMsg::Step(step) => {
            e.u8(TAG_STEP);
            e.u64(step.t);
            e.f64(step.scale);
            e.boolean(step.preconditioning);
            e.boolean(step.stat_due);
            e.f64(step.lr);
            e.f64(step.beta1);
            e.f64(step.weight_decay);
            e.u32(step.entries.len() as u32);
            for ent in &step.entries {
                e.u32(ent.index);
                e.boolean(ent.refresh_due);
                e.matrix(&ent.param);
                e.matrix(&ent.grad);
            }
        }
        WireMsg::StepOk(ok) => {
            e.u8(TAG_STEP_OK);
            e.u64(ok.t);
            e.u32(ok.refreshes);
            e.u32(ok.entries.len() as u32);
            for (index, param) in &ok.entries {
                e.u32(*index);
                e.matrix(param);
            }
        }
        WireMsg::MemStats => e.u8(TAG_MEM_STATS),
        WireMsg::MemStatsOk { mem_bytes, second_moment_bytes } => {
            e.u8(TAG_MEM_STATS_OK);
            e.u64(*mem_bytes);
            e.u64(*second_moment_bytes);
        }
        WireMsg::Shutdown => e.u8(TAG_SHUTDOWN),
        WireMsg::Ok => e.u8(TAG_OK),
        WireMsg::Error { message } => {
            e.u8(TAG_ERROR);
            e.string(message);
        }
        WireMsg::HelloV2 { worker_id, proto, overlap } => {
            e.u8(TAG_HELLO_V2);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
        }
        WireMsg::RefreshAhead(ra) => {
            e.u8(TAG_REFRESH_AHEAD);
            e.u64(ra.t_next);
            e.boolean(ra.all);
            e.u32(ra.due.len() as u32);
            for &i in &ra.due {
                e.u32(i);
            }
        }
        WireMsg::RefreshAheadOk(ok) => {
            e.u8(TAG_REFRESH_AHEAD_OK);
            e.u64(ok.t_next);
            e.u32(ok.count);
            e.u32(ok.refreshed.len() as u32);
            for &i in &ok.refreshed {
                e.u32(i);
            }
        }
        WireMsg::HelloV3 { worker_id, proto, overlap, compress } => {
            e.u8(TAG_HELLO_V3);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
            e.boolean(*compress);
        }
        WireMsg::StepV3(step) => {
            e.u8(TAG_STEP_V3);
            e.u64(step.t);
            e.u64(step.base_t);
            e.boolean(step.resync);
            e.f64(step.scale);
            e.boolean(step.preconditioning);
            e.boolean(step.stat_due);
            e.f64(step.lr);
            e.f64(step.beta1);
            e.f64(step.weight_decay);
            e.u32(step.entries.len() as u32);
            for ent in &step.entries {
                e.u32(ent.index);
                e.boolean(ent.refresh_due);
                e.delta_mat(&ent.param);
                e.delta_mat(&ent.grad);
            }
        }
        WireMsg::StepOkV3(ok) => {
            e.u8(TAG_STEP_OK_V3);
            e.u64(ok.t);
            e.u64(ok.base_t);
            e.u32(ok.refreshes);
            e.u32(ok.entries.len() as u32);
            for (index, dm) in &ok.entries {
                e.u32(*index);
                e.delta_mat(dm);
            }
        }
        WireMsg::HelloV4 { worker_id, proto, overlap, compress, state } => {
            e.u8(TAG_HELLO_V4);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
            e.boolean(*compress);
            e.boolean(*state);
        }
        WireMsg::StepV4(step) => {
            e.u8(TAG_STEP_V4);
            e.u64(step.t);
            e.u64(step.base_t);
            e.boolean(step.resync);
            e.f64(step.scale);
            e.boolean(step.preconditioning);
            e.boolean(step.stat_due);
            e.f64(step.lr);
            e.f64(step.beta1);
            e.f64(step.weight_decay);
            e.u32(step.entries.len() as u32);
            for ent in &step.entries {
                e.u32(ent.index);
                e.boolean(ent.refresh_due);
                e.block_payload(&ent.param);
                e.block_payload(&ent.grad);
            }
        }
        WireMsg::StepOkV4(ok) => {
            e.u8(TAG_STEP_OK_V4);
            e.u64(ok.t);
            e.u64(ok.base_t);
            e.u32(ok.refreshes);
            e.u32(ok.entries.len() as u32);
            for (index, p) in &ok.entries {
                e.u32(*index);
                e.block_payload(p);
            }
        }
        WireMsg::RefreshAheadOkV4(ok) => {
            e.u8(TAG_REFRESH_AHEAD_OK_V4);
            e.u64(ok.t_next);
            e.u32(ok.count);
            e.u32(ok.refreshed.len() as u32);
            for &i in &ok.refreshed {
                e.u32(i);
            }
            e.u32(ok.escaped.len() as u32);
            for (i, rho) in &ok.escaped {
                e.u32(*i);
                e.f64(*rho);
            }
        }
        WireMsg::StateSnap(snap) => {
            e.u8(TAG_STATE_SNAP);
            e.u32(snap.want.len() as u32);
            for &i in &snap.want {
                e.u32(i);
            }
        }
        WireMsg::StateSnapOk(ok) => {
            e.u8(TAG_STATE_SNAP_OK);
            e.u32(ok.entries.len() as u32);
            for b in &ok.entries {
                e.block_state(b);
            }
        }
        WireMsg::StateRestore(restore) => {
            e.u8(TAG_STATE_RESTORE);
            e.u32(restore.entries.len() as u32);
            for b in &restore.entries {
                e.block_state(b);
            }
        }
        WireMsg::HelloV5 { worker_id, proto, overlap, compress, state, member } => {
            e.u8(TAG_HELLO_V5);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
            e.boolean(*compress);
            e.boolean(*state);
            e.boolean(*member);
        }
        WireMsg::Adopt { epoch, shard } => {
            e.u8(TAG_ADOPT);
            e.u64(*epoch);
            e.u32(*shard);
        }
        WireMsg::AdoptOk { epoch, shard } => {
            e.u8(TAG_ADOPT_OK);
            e.u64(*epoch);
            e.u32(*shard);
        }
        WireMsg::HelloV6 { worker_id, proto, overlap, compress, state, member, heartbeat } => {
            e.u8(TAG_HELLO_V6);
            e.u32(*worker_id);
            e.u32(*proto);
            e.boolean(*overlap);
            e.boolean(*compress);
            e.boolean(*state);
            e.boolean(*member);
            e.boolean(*heartbeat);
        }
        WireMsg::Ping { seq } => {
            e.u8(TAG_PING);
            e.u64(*seq);
        }
        WireMsg::Pong { seq } => {
            e.u8(TAG_PONG);
            e.u64(*seq);
        }
    }
    if e.buf.len() > MAX_FRAME_BYTES {
        bail!(
            "shard wire: frame payload {} bytes exceeds cap {MAX_FRAME_BYTES}; \
             use more shards or a smaller --block-size so per-shard steps fit a frame",
            e.buf.len()
        );
    }
    let mut frame = Vec::with_capacity(4 + e.buf.len());
    frame.extend_from_slice(&(e.buf.len() as u32).to_le_bytes());
    frame.extend_from_slice(&e.buf);
    Ok(frame)
}

/// Write one message as a frame and flush.
pub fn write_msg<W: Write>(w: &mut W, msg: &WireMsg) -> anyhow::Result<()> {
    let frame = encode_frame(msg)?;
    w.write_all(&frame).context("shard wire: write frame")?;
    w.flush().context("shard wire: flush")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Decoding.
// ---------------------------------------------------------------------------

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    /// Bytes of input left — the honest upper bound for preallocation.
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("shard wire: truncated frame (need {n} bytes at offset {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn boolean(&mut self) -> anyhow::Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("shard wire: bad bool byte {other}"),
        }
    }
    fn string(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).context("shard wire: non-utf8 string")
    }
    fn matrix(&mut self) -> anyhow::Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        if rows > 1 << 20 || cols > 1 << 20 || rows.saturating_mul(cols) > 1 << 27 {
            bail!("shard wire: implausible matrix shape {rows}x{cols}");
        }
        // Prealloc no more than the input can actually deliver: a lying
        // header still fails in `f64`, but it must not reserve first.
        let mut data = Vec::with_capacity((rows * cols).min(self.remaining() / 8));
        for _ in 0..rows * cols {
            data.push(self.f64()?);
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }
    fn delta_mat(&mut self) -> anyhow::Result<DeltaMat> {
        match self.u8()? {
            DM_RAW => Ok(DeltaMat::Raw(self.matrix()?)),
            mode @ (DM_FULL | DM_DELTA) => {
                let rows = self.u32()?;
                let cols = self.u32()?;
                let (r, c) = (rows as usize, cols as usize);
                if r > 1 << 20 || c > 1 << 20 || r.saturating_mul(c) > 1 << 27 {
                    bail!("shard wire: implausible matrix shape {r}x{c}");
                }
                // The compressed body is bounded by the frame itself
                // (`take` fails on a lying length); decompression is
                // deferred to the handler, after shape validation. The
                // lengths below come out of `take`, so no prealloc here
                // can exceed the bytes actually present.
                let n = self.u32()? as usize;
                let comp = self.take(n)?.to_vec();
                Ok(if mode == DM_FULL {
                    DeltaMat::Full { rows, cols, comp }
                } else {
                    DeltaMat::Delta { rows, cols, comp }
                })
            }
            other => bail!("shard wire: unknown delta-matrix mode {other}"),
        }
    }
    fn sketch_payload(&mut self) -> anyhow::Result<SketchPayload> {
        let basis = self.delta_mat()?;
        let n = self.u32()? as usize;
        // The basis shape bound (≤ 2^20 per dim) also bounds any honest
        // eigenvalue count; a bigger claim is rejected before the reads.
        if n > 1 << 20 {
            bail!("shard wire: implausible sketch rank {n}");
        }
        let mut eigvals = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            eigvals.push(self.f64()?);
        }
        let escaped_mass = self.f64()?;
        let last_rho = self.f64()?;
        let steps = self.u64()?;
        Ok(SketchPayload { basis, eigvals, escaped_mass, last_rho, steps })
    }
    fn block_payload(&mut self) -> anyhow::Result<BlockPayload> {
        match self.u8()? {
            BP_DENSE => Ok(BlockPayload::Dense(self.delta_mat()?)),
            BP_SKETCH => Ok(BlockPayload::Sketch(self.sketch_payload()?)),
            BP_DIAG => Ok(BlockPayload::Diag(self.delta_mat()?)),
            other => bail!("shard wire: unknown block-payload mode {other}"),
        }
    }
    fn opt_block_payload(&mut self) -> anyhow::Result<Option<BlockPayload>> {
        Ok(if self.boolean()? { Some(self.block_payload()?) } else { None })
    }
    fn f64s(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.u32()? as usize;
        // Same bound as honest eigenvalue counts: a corrector diagonal
        // never exceeds one basis dimension (≤ 2^20).
        if n > 1 << 20 {
            bail!("shard wire: implausible f64 vector length {n}");
        }
        let mut vs = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            vs.push(self.f64()?);
        }
        Ok(vs)
    }
    fn eig_corr(&mut self) -> anyhow::Result<EigCorrPayload> {
        let basis = self.block_payload()?;
        let diag = self.f64s()?;
        Ok(EigCorrPayload { basis, diag })
    }
    fn opt_eig_corr(&mut self) -> anyhow::Result<Option<EigCorrPayload>> {
        Ok(if self.boolean()? { Some(self.eig_corr()?) } else { None })
    }
    fn sketch_corr(&mut self) -> anyhow::Result<SketchCorrPayload> {
        let diag = self.f64s()?;
        let tail = self.f64()?;
        Ok(SketchCorrPayload { diag, tail })
    }
    fn opt_sketch_corr(&mut self) -> anyhow::Result<Option<SketchCorrPayload>> {
        Ok(if self.boolean()? { Some(self.sketch_corr()?) } else { None })
    }
    fn side_payload(&mut self) -> anyhow::Result<SidePayload> {
        match self.u8()? {
            mode @ (SIDE_EXACT | SIDE_EXACT_EKFAC) => {
                let c = self.block_payload()?;
                let root = self.opt_block_payload()?;
                let corr =
                    if mode == SIDE_EXACT_EKFAC { self.opt_eig_corr()? } else { None };
                Ok(SidePayload::Exact { c, root, corr })
            }
            mode @ (SIDE_SKETCH | SIDE_SKETCH_EKFAC) => {
                let sketch = self.sketch_payload()?;
                let corr =
                    if mode == SIDE_SKETCH_EKFAC { self.opt_sketch_corr()? } else { None };
                Ok(SidePayload::Sketch { sketch, corr })
            }
            other => bail!("shard wire: unknown side-payload mode {other}"),
        }
    }
    fn state_payload(&mut self) -> anyhow::Result<StatePayload> {
        match self.u8()? {
            mode @ (SP_KRON | SP_KRON_EKFAC) => {
                let l = self.block_payload()?;
                let r = self.block_payload()?;
                let l_root = self.opt_block_payload()?;
                let r_root = self.opt_block_payload()?;
                let (l_corr, r_corr) = if mode == SP_KRON_EKFAC {
                    (self.opt_eig_corr()?, self.opt_eig_corr()?)
                } else {
                    (None, None)
                };
                Ok(StatePayload::Kron { l, r, l_root, r_root, l_corr, r_corr })
            }
            SP_SKETCH => {
                let left = self.side_payload()?;
                let right = self.side_payload()?;
                Ok(StatePayload::Sketch { left, right })
            }
            SP_DIAG => {
                let m = self.block_payload()?;
                let v = self.block_payload()?;
                let t = self.u64()?;
                Ok(StatePayload::Diag { m, v, t })
            }
            other => bail!("shard wire: unknown state-payload mode {other}"),
        }
    }
    fn block_state(&mut self) -> anyhow::Result<BlockStateMsg> {
        let index = self.u32()?;
        let state = self.state_payload()?;
        let mu = self.block_payload()?;
        let graft_v = self.opt_block_payload()?;
        let graft_t = self.u64()?;
        Ok(BlockStateMsg { index, state, mu, graft_v, graft_t })
    }
    fn done(&self) -> anyhow::Result<()> {
        if self.i != self.b.len() {
            bail!("shard wire: {} trailing bytes in frame", self.b.len() - self.i);
        }
        Ok(())
    }
}

/// Decode one frame payload (without the length prefix).
pub fn decode_payload(payload: &[u8]) -> anyhow::Result<WireMsg> {
    let mut d = Dec { b: payload, i: 0 };
    let msg = match d.u8()? {
        TAG_HELLO => WireMsg::Hello { worker_id: d.u32()? },
        tag @ (TAG_INIT | TAG_INIT_V7) => {
            let kind = d.u8()?;
            let rank = d.u32()?;
            let beta2 = d.f64()?;
            let eps = d.f64()?;
            let one_sided = d.boolean()?;
            let graft = d.u8()?;
            let threads = d.u32()?;
            let n = d.u32()? as usize;
            let mut blocks = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                blocks.push(BlockSpec { index: d.u32()?, rows: d.u32()?, cols: d.u32()? });
            }
            let ekfac = if tag == TAG_INIT_V7 { d.boolean()? } else { false };
            WireMsg::Init(InitMsg {
                kind,
                rank,
                beta2,
                eps,
                one_sided,
                graft,
                threads,
                blocks,
                ekfac,
            })
        }
        TAG_STEP => {
            let t = d.u64()?;
            let scale = d.f64()?;
            let preconditioning = d.boolean()?;
            let stat_due = d.boolean()?;
            let lr = d.f64()?;
            let beta1 = d.f64()?;
            let weight_decay = d.f64()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let refresh_due = d.boolean()?;
                let param = d.matrix()?;
                let grad = d.matrix()?;
                entries.push(StepEntry { index, refresh_due, param, grad });
            }
            WireMsg::Step(StepMsg {
                t,
                scale,
                preconditioning,
                stat_due,
                lr,
                beta1,
                weight_decay,
                entries,
            })
        }
        TAG_STEP_OK => {
            let t = d.u64()?;
            let refreshes = d.u32()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let param = d.matrix()?;
                entries.push((index, param));
            }
            WireMsg::StepOk(StepOkMsg { t, refreshes, entries })
        }
        TAG_MEM_STATS => WireMsg::MemStats,
        TAG_MEM_STATS_OK => {
            WireMsg::MemStatsOk { mem_bytes: d.u64()?, second_moment_bytes: d.u64()? }
        }
        TAG_SHUTDOWN => WireMsg::Shutdown,
        TAG_OK => WireMsg::Ok,
        TAG_ERROR => WireMsg::Error { message: d.string()? },
        TAG_HELLO_V2 => WireMsg::HelloV2 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
        },
        TAG_REFRESH_AHEAD => {
            let t_next = d.u64()?;
            let all = d.boolean()?;
            let n = d.u32()? as usize;
            let mut due = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                due.push(d.u32()?);
            }
            WireMsg::RefreshAhead(RefreshAheadMsg { t_next, all, due })
        }
        TAG_REFRESH_AHEAD_OK => {
            let t_next = d.u64()?;
            let count = d.u32()?;
            let n = d.u32()? as usize;
            let mut refreshed = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                refreshed.push(d.u32()?);
            }
            WireMsg::RefreshAheadOk(RefreshAheadOkMsg { t_next, count, refreshed })
        }
        TAG_HELLO_V3 => WireMsg::HelloV3 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
            compress: d.boolean()?,
        },
        TAG_STEP_V3 => {
            let t = d.u64()?;
            let base_t = d.u64()?;
            let resync = d.boolean()?;
            let scale = d.f64()?;
            let preconditioning = d.boolean()?;
            let stat_due = d.boolean()?;
            let lr = d.f64()?;
            let beta1 = d.f64()?;
            let weight_decay = d.f64()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let refresh_due = d.boolean()?;
                let param = d.delta_mat()?;
                let grad = d.delta_mat()?;
                entries.push(StepEntryV3 { index, refresh_due, param, grad });
            }
            WireMsg::StepV3(StepV3Msg {
                t,
                base_t,
                resync,
                scale,
                preconditioning,
                stat_due,
                lr,
                beta1,
                weight_decay,
                entries,
            })
        }
        TAG_STEP_OK_V3 => {
            let t = d.u64()?;
            let base_t = d.u64()?;
            let refreshes = d.u32()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let dm = d.delta_mat()?;
                entries.push((index, dm));
            }
            WireMsg::StepOkV3(StepOkV3Msg { t, base_t, refreshes, entries })
        }
        TAG_HELLO_V4 => WireMsg::HelloV4 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
            compress: d.boolean()?,
            state: d.boolean()?,
        },
        TAG_STEP_V4 => {
            let t = d.u64()?;
            let base_t = d.u64()?;
            let resync = d.boolean()?;
            let scale = d.f64()?;
            let preconditioning = d.boolean()?;
            let stat_due = d.boolean()?;
            let lr = d.f64()?;
            let beta1 = d.f64()?;
            let weight_decay = d.f64()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let refresh_due = d.boolean()?;
                let param = d.block_payload()?;
                let grad = d.block_payload()?;
                entries.push(StepEntryV4 { index, refresh_due, param, grad });
            }
            WireMsg::StepV4(StepV4Msg {
                t,
                base_t,
                resync,
                scale,
                preconditioning,
                stat_due,
                lr,
                beta1,
                weight_decay,
                entries,
            })
        }
        TAG_STEP_OK_V4 => {
            let t = d.u64()?;
            let base_t = d.u64()?;
            let refreshes = d.u32()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let index = d.u32()?;
                let p = d.block_payload()?;
                entries.push((index, p));
            }
            WireMsg::StepOkV4(StepOkV4Msg { t, base_t, refreshes, entries })
        }
        TAG_REFRESH_AHEAD_OK_V4 => {
            let t_next = d.u64()?;
            let count = d.u32()?;
            let n = d.u32()? as usize;
            let mut refreshed = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                refreshed.push(d.u32()?);
            }
            let n = d.u32()? as usize;
            let mut escaped = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let i = d.u32()?;
                let rho = d.f64()?;
                escaped.push((i, rho));
            }
            WireMsg::RefreshAheadOkV4(RefreshAheadOkV4Msg { t_next, count, refreshed, escaped })
        }
        TAG_STATE_SNAP => {
            let n = d.u32()? as usize;
            let mut want = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                want.push(d.u32()?);
            }
            WireMsg::StateSnap(StateSnapMsg { want })
        }
        TAG_STATE_SNAP_OK => {
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                entries.push(d.block_state()?);
            }
            WireMsg::StateSnapOk(StateSnapOkMsg { entries })
        }
        TAG_STATE_RESTORE => {
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                entries.push(d.block_state()?);
            }
            WireMsg::StateRestore(StateRestoreMsg { entries })
        }
        TAG_HELLO_V5 => WireMsg::HelloV5 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
            compress: d.boolean()?,
            state: d.boolean()?,
            member: d.boolean()?,
        },
        TAG_ADOPT => WireMsg::Adopt { epoch: d.u64()?, shard: d.u32()? },
        TAG_ADOPT_OK => WireMsg::AdoptOk { epoch: d.u64()?, shard: d.u32()? },
        TAG_HELLO_V6 => WireMsg::HelloV6 {
            worker_id: d.u32()?,
            proto: d.u32()?,
            overlap: d.boolean()?,
            compress: d.boolean()?,
            state: d.boolean()?,
            member: d.boolean()?,
            heartbeat: d.boolean()?,
        },
        TAG_PING => WireMsg::Ping { seq: d.u64()? },
        TAG_PONG => WireMsg::Pong { seq: d.u64()? },
        other => bail!("shard wire: unknown message tag {other}"),
    };
    d.done()?;
    Ok(msg)
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly (EOF before any length byte).
pub fn read_msg_opt<R: Read>(r: &mut R) -> anyhow::Result<Option<WireMsg>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = r.read(&mut len_buf[got..]).context("shard wire: read frame length")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("shard wire: connection closed mid-length ({got}/4 bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("shard wire: frame length {len} exceeds cap {MAX_FRAME_BYTES}");
    }
    // Grow the payload buffer as bytes actually arrive instead of
    // trusting the prefix with one up-front `vec![0; len]`: four corrupt
    // bytes under the cap would otherwise trigger a transient ~1 GB
    // allocation before the read even fails.
    let mut payload = Vec::with_capacity(len.min(1 << 16));
    let got = Read::by_ref(r)
        .take(len as u64)
        .read_to_end(&mut payload)
        .context("shard wire: read frame payload")?;
    if got < len {
        bail!("shard wire: connection closed mid-payload ({got}/{len} bytes)");
    }
    decode_payload(&payload).map(Some)
}

/// Read one frame, treating EOF as an error (driver side: a reply is
/// always expected).
pub fn read_msg<R: Read>(r: &mut R) -> anyhow::Result<WireMsg> {
    match read_msg_opt(r)? {
        Some(msg) => Ok(msg),
        None => bail!("shard wire: connection closed while awaiting reply"),
    }
}

/// Incremental frame reader for supervised (polling) reply loops.
///
/// [`read_msg`] assumes a blocking read: if the stream times out
/// mid-frame, any bytes already consumed are lost and the stream
/// desyncs. The supervisor needs to poll a link on a short quantum
/// (`--shard-heartbeat-ms`) while waiting out a much longer liveness
/// deadline, so partial frames must survive across polls. A
/// `FrameReader` accumulates bytes across any number of timed-out
/// reads and yields the message only once the frame is complete.
#[derive(Debug, Default)]
pub struct FrameReader {
    /// Accumulated frame bytes (length prefix included).
    buf: Vec<u8>,
    /// Total frame size (4 + payload) once the length prefix is known.
    need: Option<usize>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Poll `r` for the next frame. Returns `Ok(None)` when the read
    /// timed out (`TimedOut`/`WouldBlock`) — call again after the
    /// supervisor's clock tick; any partial frame is retained. EOF is
    /// always an error here: a polling driver is awaiting a reply.
    pub fn poll<R: Read>(&mut self, r: &mut R) -> anyhow::Result<Option<WireMsg>> {
        let mut chunk = [0u8; 4096];
        loop {
            let target = self.need.unwrap_or(4);
            while self.buf.len() < target {
                let want = (target - self.buf.len()).min(chunk.len());
                match r.read(&mut chunk[..want]) {
                    Ok(0) => bail!(
                        "shard wire: connection closed while awaiting reply ({}/{target} bytes)",
                        self.buf.len()
                    ),
                    Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                        ) =>
                    {
                        return Ok(None);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(anyhow::Error::new(e).context("shard wire: poll frame")),
                }
            }
            if self.need.is_none() {
                let len =
                    u32::from_le_bytes(self.buf[..4].try_into().expect("4-byte prefix")) as usize;
                if len > MAX_FRAME_BYTES {
                    bail!("shard wire: frame length {len} exceeds cap {MAX_FRAME_BYTES}");
                }
                self.need = Some(4 + len);
                continue;
            }
            let msg = decode_payload(&self.buf[4..])?;
            self.buf.clear();
            self.need = None;
            return Ok(Some(msg));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn roundtrip(msg: WireMsg) {
        let frame = encode_frame(&msg).unwrap();
        let mut cursor = &frame[..];
        let got = read_msg(&mut cursor).unwrap();
        assert_eq!(got, msg);
        assert!(cursor.is_empty(), "frame not fully consumed");
    }

    /// One exemplar frame per tag in the registry. This is the closed
    /// tag audit the linter's wire rules point at: adding a `TAG_*`
    /// const without extending this table fails the count assertion,
    /// and every exemplar must byte-roundtrip, reject every strict
    /// prefix, and carry a unique tag byte.
    #[test]
    fn every_wire_tag_has_a_named_exemplar_frame() {
        let m = || Matrix::from_vec(1, 2, vec![1.0, -2.5]);
        let init = InitMsg {
            kind: 1,
            rank: 4,
            beta2: 0.9,
            eps: 1e-6,
            one_sided: false,
            graft: 1,
            threads: 0,
            blocks: vec![BlockSpec { index: 0, rows: 2, cols: 2 }],
            ekfac: false,
        };
        let exemplars: Vec<(u8, &str, WireMsg)> = vec![
            (TAG_HELLO, "TAG_HELLO", WireMsg::Hello { worker_id: 3 }),
            (TAG_INIT, "TAG_INIT", WireMsg::Init(init.clone())),
            (TAG_INIT_V7, "TAG_INIT_V7", WireMsg::Init(InitMsg { ekfac: true, ..init })),
            (
                TAG_STEP,
                "TAG_STEP",
                WireMsg::Step(StepMsg {
                    t: 1,
                    scale: 1.0,
                    preconditioning: true,
                    stat_due: false,
                    lr: 0.1,
                    beta1: 0.9,
                    weight_decay: 0.0,
                    entries: vec![StepEntry::new(0, false, m(), m())],
                }),
            ),
            (
                TAG_STEP_OK,
                "TAG_STEP_OK",
                WireMsg::StepOk(StepOkMsg { t: 1, refreshes: 0, entries: vec![(0, m())] }),
            ),
            (TAG_MEM_STATS, "TAG_MEM_STATS", WireMsg::MemStats),
            (
                TAG_MEM_STATS_OK,
                "TAG_MEM_STATS_OK",
                WireMsg::MemStatsOk { mem_bytes: 1, second_moment_bytes: 2 },
            ),
            (TAG_SHUTDOWN, "TAG_SHUTDOWN", WireMsg::Shutdown),
            (TAG_OK, "TAG_OK", WireMsg::Ok),
            (TAG_ERROR, "TAG_ERROR", WireMsg::Error { message: "boom".into() }),
            (
                TAG_HELLO_V2,
                "TAG_HELLO_V2",
                WireMsg::HelloV2 { worker_id: 1, proto: 2, overlap: true },
            ),
            (
                TAG_REFRESH_AHEAD,
                "TAG_REFRESH_AHEAD",
                WireMsg::RefreshAhead(RefreshAheadMsg { t_next: 5, all: false, due: vec![1, 2] }),
            ),
            (
                TAG_REFRESH_AHEAD_OK,
                "TAG_REFRESH_AHEAD_OK",
                WireMsg::RefreshAheadOk(RefreshAheadOkMsg { t_next: 5, count: 1, refreshed: vec![1] }),
            ),
            (
                TAG_HELLO_V3,
                "TAG_HELLO_V3",
                WireMsg::HelloV3 { worker_id: 1, proto: 3, overlap: true, compress: true },
            ),
            (
                TAG_STEP_V3,
                "TAG_STEP_V3",
                WireMsg::StepV3(StepV3Msg {
                    t: 2,
                    base_t: 1,
                    resync: false,
                    scale: 1.0,
                    preconditioning: true,
                    stat_due: true,
                    lr: 0.1,
                    beta1: 0.9,
                    weight_decay: 0.01,
                    entries: vec![StepEntryV3::new(
                        0,
                        true,
                        DeltaMat::Raw(m()),
                        DeltaMat::Full { rows: 1, cols: 2, comp: vec![1, 2, 3] },
                    )],
                }),
            ),
            (
                TAG_STEP_OK_V3,
                "TAG_STEP_OK_V3",
                WireMsg::StepOkV3(StepOkV3Msg {
                    t: 2,
                    base_t: 1,
                    refreshes: 1,
                    entries: vec![(0, DeltaMat::Delta { rows: 1, cols: 2, comp: vec![9] })],
                }),
            ),
            (
                TAG_HELLO_V4,
                "TAG_HELLO_V4",
                WireMsg::HelloV4 { worker_id: 1, proto: 4, overlap: true, compress: true, state: true },
            ),
            (
                TAG_STEP_V4,
                "TAG_STEP_V4",
                WireMsg::StepV4(StepV4Msg {
                    t: 3,
                    base_t: 2,
                    resync: false,
                    scale: 1.0,
                    preconditioning: true,
                    stat_due: false,
                    lr: 0.1,
                    beta1: 0.9,
                    weight_decay: 0.0,
                    entries: vec![StepEntryV4 {
                        index: 0,
                        refresh_due: false,
                        param: BlockPayload::Dense(DeltaMat::Raw(m())),
                        grad: BlockPayload::Diag(DeltaMat::Raw(m())),
                    }],
                }),
            ),
            (
                TAG_STEP_OK_V4,
                "TAG_STEP_OK_V4",
                WireMsg::StepOkV4(StepOkV4Msg {
                    t: 3,
                    base_t: 2,
                    refreshes: 0,
                    entries: vec![(0, BlockPayload::Dense(DeltaMat::Raw(m())))],
                }),
            ),
            (
                TAG_REFRESH_AHEAD_OK_V4,
                "TAG_REFRESH_AHEAD_OK_V4",
                WireMsg::RefreshAheadOkV4(RefreshAheadOkV4Msg {
                    t_next: 9,
                    count: 1,
                    refreshed: vec![4],
                    escaped: vec![(4, 0.25)],
                }),
            ),
            (
                TAG_STATE_SNAP,
                "TAG_STATE_SNAP",
                WireMsg::StateSnap(StateSnapMsg { want: vec![0, 1] }),
            ),
            (
                TAG_STATE_SNAP_OK,
                "TAG_STATE_SNAP_OK",
                WireMsg::StateSnapOk(StateSnapOkMsg { entries: vec![] }),
            ),
            (
                TAG_STATE_RESTORE,
                "TAG_STATE_RESTORE",
                WireMsg::StateRestore(StateRestoreMsg { entries: vec![] }),
            ),
            (
                TAG_HELLO_V5,
                "TAG_HELLO_V5",
                WireMsg::HelloV5 {
                    worker_id: 1,
                    proto: 5,
                    overlap: true,
                    compress: true,
                    state: true,
                    member: true,
                },
            ),
            (TAG_ADOPT, "TAG_ADOPT", WireMsg::Adopt { epoch: 7, shard: 2 }),
            (TAG_ADOPT_OK, "TAG_ADOPT_OK", WireMsg::AdoptOk { epoch: 7, shard: 2 }),
            (
                TAG_HELLO_V6,
                "TAG_HELLO_V6",
                WireMsg::HelloV6 {
                    worker_id: 1,
                    proto: 6,
                    overlap: true,
                    compress: true,
                    state: true,
                    member: true,
                    heartbeat: true,
                },
            ),
            (TAG_PING, "TAG_PING", WireMsg::Ping { seq: 11 }),
            (TAG_PONG, "TAG_PONG", WireMsg::Pong { seq: 11 }),
        ];
        let mut seen = std::collections::BTreeSet::new();
        for (tag, name, msg) in &exemplars {
            let frame = encode_frame(msg).unwrap();
            assert_eq!(frame[4], *tag, "{name}: exemplar encodes under the wrong tag");
            let decoded = decode_payload(&frame[4..]).unwrap();
            assert_eq!(&decoded, msg, "{name}: decode is not the inverse of encode");
            assert_eq!(encode_frame(&decoded).unwrap(), frame, "{name}: re-encode differs");
            for cut in 4..frame.len() {
                assert!(
                    decode_payload(&frame[4..cut]).is_err(),
                    "{name}: strict {}-byte payload prefix decoded",
                    cut - 4
                );
            }
            assert!(seen.insert(*tag), "{name}: tag byte {tag} reused in the exemplar table");
        }
        assert_eq!(
            seen.len(),
            29,
            "tag registry drifted: extend the exemplar table for the new frame"
        );
    }

    #[test]
    fn all_messages_roundtrip() {
        let mut rng = Pcg64::new(77);
        roundtrip(WireMsg::Hello { worker_id: 3 });
        roundtrip(WireMsg::HelloV2 { worker_id: 5, proto: 2, overlap: true });
        roundtrip(WireMsg::HelloV2 { worker_id: 0, proto: 7, overlap: false });
        roundtrip(WireMsg::HelloV3 {
            worker_id: 2,
            proto: PROTO_VERSION,
            overlap: true,
            compress: true,
        });
        roundtrip(WireMsg::HelloV3 { worker_id: 9, proto: 4, overlap: false, compress: false });
        roundtrip(WireMsg::StepV3(StepV3Msg {
            t: 7,
            base_t: 6,
            resync: false,
            scale: 1.0,
            preconditioning: true,
            stat_due: false,
            lr: 1e-3,
            beta1: 0.9,
            weight_decay: 0.0,
            entries: vec![StepEntryV3 {
                index: 3,
                refresh_due: true,
                param: DeltaMat::Delta { rows: 2, cols: 3, comp: vec![1, 2, 3] },
                grad: DeltaMat::Raw(Matrix::randn(2, 3, &mut rng)),
            }],
        }));
        roundtrip(WireMsg::StepOkV3(StepOkV3Msg {
            t: 7,
            base_t: 0,
            refreshes: 1,
            entries: vec![
                (3, DeltaMat::Full { rows: 2, cols: 3, comp: vec![9] }),
                (4, DeltaMat::Raw(Matrix::randn(1, 2, &mut rng))),
            ],
        }));
        roundtrip(WireMsg::RefreshAhead(RefreshAheadMsg {
            t_next: 9,
            all: true,
            due: vec![0, 3, u32::MAX],
        }));
        roundtrip(WireMsg::RefreshAhead(RefreshAheadMsg { t_next: 0, all: false, due: vec![] }));
        roundtrip(WireMsg::RefreshAheadOk(RefreshAheadOkMsg {
            t_next: 9,
            count: 4,
            refreshed: vec![1, 2],
        }));
        roundtrip(WireMsg::RefreshAheadOk(RefreshAheadOkMsg {
            t_next: u64::MAX,
            count: 0,
            refreshed: vec![],
        }));
        roundtrip(WireMsg::Init(InitMsg {
            kind: 1,
            rank: 16,
            beta2: 0.999,
            eps: 1e-6,
            one_sided: true,
            graft: 4,
            threads: 0,
            blocks: vec![
                BlockSpec { index: 0, rows: 7, cols: 5 },
                BlockSpec { index: 3, rows: 4, cols: 4 },
            ],
            ekfac: false,
        }));
        // v7 init frame (ekfac on → TAG_INIT_V7).
        roundtrip(WireMsg::Init(InitMsg {
            kind: 0,
            rank: 0,
            beta2: 0.95,
            eps: 1e-8,
            one_sided: false,
            graft: 1,
            threads: 2,
            blocks: vec![BlockSpec { index: 1, rows: 3, cols: 3 }],
            ekfac: true,
        }));
        roundtrip(WireMsg::Step(StepMsg {
            t: 42,
            scale: 0.5,
            preconditioning: true,
            stat_due: false,
            lr: 1e-3,
            beta1: 0.9,
            weight_decay: 1e-4,
            entries: vec![StepEntry {
                index: 7,
                refresh_due: true,
                param: Matrix::randn(3, 4, &mut rng),
                grad: Matrix::randn(3, 4, &mut rng),
            }],
        }));
        roundtrip(WireMsg::StepOk(StepOkMsg {
            t: 42,
            refreshes: 2,
            entries: vec![(7, Matrix::randn(3, 4, &mut rng))],
        }));
        roundtrip(WireMsg::MemStats);
        roundtrip(WireMsg::MemStatsOk { mem_bytes: 1024, second_moment_bytes: 512 });
        roundtrip(WireMsg::Shutdown);
        roundtrip(WireMsg::Ok);
        roundtrip(WireMsg::Error { message: "shard 2: boom".into() });
        // v4 typed-payload layer.
        roundtrip(WireMsg::HelloV4 {
            worker_id: 1,
            proto: PROTO_VERSION,
            overlap: true,
            compress: true,
            state: true,
        });
        roundtrip(WireMsg::HelloV4 {
            worker_id: 0,
            proto: 9,
            overlap: false,
            compress: false,
            state: false,
        });
        roundtrip(WireMsg::HelloV5 {
            worker_id: 3,
            proto: PROTO_VERSION,
            overlap: true,
            compress: true,
            state: true,
            member: true,
        });
        roundtrip(WireMsg::HelloV5 {
            worker_id: 0,
            proto: 11,
            overlap: false,
            compress: false,
            state: false,
            member: false,
        });
        roundtrip(WireMsg::Adopt { epoch: 0, shard: 0 });
        roundtrip(WireMsg::Adopt { epoch: u64::MAX, shard: u32::MAX });
        roundtrip(WireMsg::AdoptOk { epoch: 7, shard: 2 });
        // v6 liveness layer.
        roundtrip(WireMsg::HelloV6 {
            worker_id: 4,
            proto: PROTO_VERSION,
            overlap: true,
            compress: true,
            state: true,
            member: true,
            heartbeat: true,
        });
        roundtrip(WireMsg::HelloV6 {
            worker_id: 0,
            proto: 13,
            overlap: false,
            compress: false,
            state: false,
            member: false,
            heartbeat: false,
        });
        roundtrip(WireMsg::Ping { seq: 0 });
        roundtrip(WireMsg::Ping { seq: u64::MAX });
        roundtrip(WireMsg::Pong { seq: 99 });
        roundtrip(WireMsg::StepV4(StepV4Msg {
            t: 11,
            base_t: 10,
            resync: true,
            scale: 0.25,
            preconditioning: true,
            stat_due: true,
            lr: 1e-2,
            beta1: 0.9,
            weight_decay: 1e-4,
            entries: vec![StepEntryV4::new(
                5,
                false,
                DeltaMat::Full { rows: 2, cols: 2, comp: vec![4, 5] },
                DeltaMat::Raw(Matrix::randn(2, 2, &mut rng)),
            )],
        }));
        roundtrip(WireMsg::StepOkV4(StepOkV4Msg {
            t: 11,
            base_t: 0,
            refreshes: 3,
            entries: vec![(5, BlockPayload::Dense(DeltaMat::Raw(Matrix::randn(2, 2, &mut rng))))],
        }));
        roundtrip(WireMsg::RefreshAheadOkV4(RefreshAheadOkV4Msg {
            t_next: 12,
            count: 2,
            refreshed: vec![0, 5],
            escaped: vec![(5, 0.125)],
        }));
        roundtrip(WireMsg::StateSnap(StateSnapMsg { want: vec![] }));
        roundtrip(WireMsg::StateSnap(StateSnapMsg { want: vec![1, 4, u32::MAX] }));
        let sketch = SketchPayload {
            basis: DeltaMat::Raw(Matrix::randn(6, 2, &mut rng)),
            eigvals: vec![2.0, 0.0],
            escaped_mass: 0.5,
            last_rho: 0.25,
            steps: 40,
        };
        let block_state = BlockStateMsg {
            index: 4,
            state: StatePayload::Sketch {
                left: SidePayload::Sketch { sketch: sketch.clone(), corr: None },
                right: SidePayload::Exact {
                    c: BlockPayload::dense(&Matrix::randn(2, 2, &mut rng)),
                    root: Some(BlockPayload::dense(&Matrix::randn(2, 2, &mut rng))),
                    corr: None,
                },
            },
            mu: BlockPayload::dense(&Matrix::randn(6, 2, &mut rng)),
            graft_v: Some(BlockPayload::dense(&Matrix::randn(6, 2, &mut rng))),
            graft_t: 7,
        };
        roundtrip(WireMsg::StateSnapOk(StateSnapOkMsg { entries: vec![block_state.clone()] }));
        roundtrip(WireMsg::StateRestore(StateRestoreMsg { entries: vec![block_state] }));
        roundtrip(WireMsg::StateSnapOk(StateSnapOkMsg {
            entries: vec![BlockStateMsg {
                index: 0,
                state: StatePayload::Kron {
                    l: BlockPayload::dense(&Matrix::randn(3, 3, &mut rng)),
                    r: BlockPayload::dense(&Matrix::randn(2, 2, &mut rng)),
                    l_root: None,
                    r_root: None,
                    l_corr: None,
                    r_corr: None,
                },
                mu: BlockPayload::dense(&Matrix::randn(3, 2, &mut rng)),
                graft_v: None,
                graft_t: 0,
            }],
        }));
        // v7 ekfac corrector payloads, in every position they can ride.
        roundtrip(WireMsg::StateSnapOk(StateSnapOkMsg {
            entries: vec![BlockStateMsg {
                index: 1,
                state: StatePayload::Kron {
                    l: BlockPayload::dense(&Matrix::randn(3, 3, &mut rng)),
                    r: BlockPayload::dense(&Matrix::randn(2, 2, &mut rng)),
                    l_root: None,
                    r_root: None,
                    l_corr: Some(EigCorrPayload {
                        basis: BlockPayload::dense(&Matrix::randn(3, 3, &mut rng)),
                        diag: vec![2.0, 1.0, -0.0],
                    }),
                    r_corr: None,
                },
                mu: BlockPayload::dense(&Matrix::randn(3, 2, &mut rng)),
                graft_v: None,
                graft_t: 3,
            }],
        }));
        roundtrip(WireMsg::StateRestore(StateRestoreMsg {
            entries: vec![BlockStateMsg {
                index: 5,
                state: StatePayload::Sketch {
                    left: SidePayload::Sketch {
                        sketch,
                        corr: Some(SketchCorrPayload { diag: vec![4.0, 0.5], tail: 0.125 }),
                    },
                    right: SidePayload::Exact {
                        c: BlockPayload::dense(&Matrix::randn(2, 2, &mut rng)),
                        root: None,
                        corr: Some(EigCorrPayload {
                            basis: BlockPayload::dense(&Matrix::randn(2, 2, &mut rng)),
                            diag: vec![1.0, 1.0 / 3.0],
                        }),
                    },
                },
                mu: BlockPayload::dense(&Matrix::randn(6, 2, &mut rng)),
                graft_v: None,
                graft_t: 11,
            }],
        }));
        roundtrip(WireMsg::StateSnapOk(StateSnapOkMsg {
            entries: vec![BlockStateMsg {
                index: 2,
                state: StatePayload::Diag {
                    m: BlockPayload::diag(&Matrix::randn(2, 2, &mut rng)),
                    v: BlockPayload::diag(&Matrix::randn(2, 2, &mut rng)),
                    t: 9,
                },
                mu: BlockPayload::dense(&Matrix::randn(2, 2, &mut rng)),
                graft_v: None,
                graft_t: 9,
            }],
        }));
    }

    #[test]
    fn f64_payloads_are_bitwise_exact() {
        // Values that decimal formatting would mangle: subnormals, -0.0,
        // NaN payloads, and an irrational-looking mantissa.
        let vals =
            [f64::MIN_POSITIVE / 2.0, -0.0, f64::from_bits(0x7ff8_0000_dead_beef), 1.0 / 3.0];
        let m = Matrix::from_vec(1, 4, vals.to_vec());
        let msg = WireMsg::StepOk(StepOkMsg { t: 1, refreshes: 0, entries: vec![(0, m.clone())] });
        let frame = encode_frame(&msg).unwrap();
        let got = read_msg(&mut &frame[..]).unwrap();
        match got {
            WireMsg::StepOk(ok) => {
                for (a, b) in ok.entries[0].1.as_slice().iter().zip(m.as_slice()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn clean_eof_is_none_truncation_is_error() {
        let frame = encode_frame(&WireMsg::Ok).unwrap();
        assert_eq!(read_msg_opt(&mut std::io::empty()).unwrap(), None);
        // Cut inside the length prefix.
        assert!(read_msg_opt(&mut &frame[..2]).is_err());
        // Cut inside the payload.
        assert!(read_msg_opt(&mut &frame[..frame.len() - 1]).is_err());
    }

    /// Yields scripted byte slices one `read` at a time, interposing a
    /// `TimedOut` error between every pair of slices — the shape of a
    /// slow link under a short poll quantum.
    struct TricklingReader {
        chunks: Vec<Vec<u8>>,
        next: usize,
        timed_out: bool,
    }

    impl Read for TricklingReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if !self.timed_out {
                self.timed_out = true;
                return Err(std::io::Error::from(std::io::ErrorKind::TimedOut));
            }
            self.timed_out = false;
            match self.chunks.get(self.next) {
                None => Ok(0),
                Some(chunk) => {
                    let n = chunk.len().min(out.len());
                    out[..n].copy_from_slice(&chunk[..n]);
                    if n < chunk.len() {
                        self.chunks[self.next] = chunk[n..].to_vec();
                    } else {
                        self.next += 1;
                    }
                    Ok(n)
                }
            }
        }
    }

    #[test]
    fn frame_reader_survives_timeouts_mid_frame() {
        let msg = WireMsg::StepOk(StepOkMsg {
            t: 9,
            refreshes: 1,
            entries: vec![(2, Matrix::from_vec(1, 3, vec![1.0, -0.0, f64::NAN]))],
        });
        let frame = encode_frame(&msg).unwrap();
        // Deliver the frame one byte per successful read, a timeout
        // between each: the reader must retain partial state and
        // produce the message only on the final poll.
        let mut r = TricklingReader {
            chunks: frame.iter().map(|b| vec![*b]).collect(),
            next: 0,
            timed_out: false,
        };
        let mut fr = FrameReader::new();
        let mut polls = 0usize;
        let got = loop {
            polls += 1;
            assert!(polls < 10 * frame.len(), "frame reader failed to make progress");
            if let Some(m) = fr.poll(&mut r).unwrap() {
                break m;
            }
        };
        let want_frame = encode_frame(&got).unwrap();
        assert_eq!(want_frame, frame, "re-encoded poll result differs");
        // A second frame on the same reader decodes from a clean slate.
        let frame2 = encode_frame(&WireMsg::Pong { seq: 7 }).unwrap();
        let mut r2 = TricklingReader { chunks: vec![frame2], next: 0, timed_out: false };
        loop {
            match fr.poll(&mut r2).unwrap() {
                Some(m) => {
                    assert_eq!(m, WireMsg::Pong { seq: 7 });
                    break;
                }
                None => continue,
            }
        }
        // EOF mid-frame is an error, not a silent None.
        let half = encode_frame(&msg).unwrap();
        let mut r3 =
            TricklingReader { chunks: vec![half[..3].to_vec()], next: 0, timed_out: false };
        let mut fr3 = FrameReader::new();
        let err = loop {
            match fr3.poll(&mut r3) {
                Ok(Some(_)) => panic!("decoded from a truncated stream"),
                Ok(None) => continue,
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("closed"), "unexpected error: {err}");
    }

    // -----------------------------------------------------------------
    // Property-style coverage: every message kind, adversarial payloads.
    // -----------------------------------------------------------------

    /// f64 bit patterns decimal formatting would mangle (and equality
    /// would lie about): NaNs with payloads, ±0, subnormals, infinities.
    fn adversarial_f64(rng: &mut Pcg64) -> f64 {
        match rng.below(8) {
            0 => f64::from_bits(0x7ff8_0000_dead_beef), // quiet NaN w/ payload
            1 => f64::from_bits(0xfff0_0000_0000_0001), // signaling-ish NaN
            2 => -0.0,
            3 => f64::MIN_POSITIVE / 4.0, // subnormal
            4 => f64::INFINITY,
            5 => f64::NEG_INFINITY,
            6 => f64::from_bits(rng.next_u64()), // arbitrary bits
            _ => rng.gaussian(),
        }
    }

    fn adversarial_matrix(rng: &mut Pcg64) -> Matrix {
        let rows = 1 + rng.below(4);
        let cols = 1 + rng.below(4);
        let data = (0..rows * cols).map(|_| adversarial_f64(rng)).collect();
        Matrix::from_vec(rows, cols, data)
    }

    fn arbitrary_delta_mat(rng: &mut Pcg64) -> DeltaMat {
        let rows = 1 + rng.below(4) as u32;
        let cols = 1 + rng.below(4) as u32;
        match rng.below(3) {
            0 => DeltaMat::Raw(adversarial_matrix(rng)),
            1 => {
                let n = rng.below(32);
                DeltaMat::Full {
                    rows,
                    cols,
                    comp: (0..n).map(|_| rng.next_u64() as u8).collect(),
                }
            }
            _ => {
                let n = rng.below(32);
                DeltaMat::Delta {
                    rows,
                    cols,
                    comp: (0..n).map(|_| rng.next_u64() as u8).collect(),
                }
            }
        }
    }

    fn arbitrary_sketch_payload(rng: &mut Pcg64) -> SketchPayload {
        let n = rng.below(5);
        SketchPayload {
            basis: arbitrary_delta_mat(rng),
            eigvals: (0..n).map(|_| adversarial_f64(rng)).collect(),
            escaped_mass: adversarial_f64(rng),
            last_rho: adversarial_f64(rng),
            steps: rng.next_u64(),
        }
    }

    fn arbitrary_block_payload(rng: &mut Pcg64) -> BlockPayload {
        match rng.below(3) {
            0 => BlockPayload::Dense(arbitrary_delta_mat(rng)),
            1 => BlockPayload::Sketch(arbitrary_sketch_payload(rng)),
            _ => BlockPayload::Diag(arbitrary_delta_mat(rng)),
        }
    }

    fn arbitrary_opt_block_payload(rng: &mut Pcg64) -> Option<BlockPayload> {
        if rng.bernoulli(0.5) { Some(arbitrary_block_payload(rng)) } else { None }
    }

    fn arbitrary_eig_corr(rng: &mut Pcg64) -> Option<EigCorrPayload> {
        if !rng.bernoulli(0.5) {
            return None;
        }
        let n = rng.below(4);
        Some(EigCorrPayload {
            basis: arbitrary_block_payload(rng),
            diag: (0..n).map(|_| adversarial_f64(rng)).collect(),
        })
    }

    fn arbitrary_sketch_corr(rng: &mut Pcg64) -> Option<SketchCorrPayload> {
        if !rng.bernoulli(0.5) {
            return None;
        }
        let n = rng.below(4);
        Some(SketchCorrPayload {
            diag: (0..n).map(|_| adversarial_f64(rng)).collect(),
            tail: adversarial_f64(rng),
        })
    }

    fn arbitrary_side_payload(rng: &mut Pcg64) -> SidePayload {
        if rng.bernoulli(0.5) {
            SidePayload::Sketch {
                sketch: arbitrary_sketch_payload(rng),
                corr: arbitrary_sketch_corr(rng),
            }
        } else {
            SidePayload::Exact {
                c: arbitrary_block_payload(rng),
                root: arbitrary_opt_block_payload(rng),
                corr: arbitrary_eig_corr(rng),
            }
        }
    }

    fn arbitrary_state_payload(rng: &mut Pcg64) -> StatePayload {
        match rng.below(3) {
            0 => StatePayload::Kron {
                l: arbitrary_block_payload(rng),
                r: arbitrary_block_payload(rng),
                l_root: arbitrary_opt_block_payload(rng),
                r_root: arbitrary_opt_block_payload(rng),
                l_corr: arbitrary_eig_corr(rng),
                r_corr: arbitrary_eig_corr(rng),
            },
            1 => StatePayload::Sketch {
                left: arbitrary_side_payload(rng),
                right: arbitrary_side_payload(rng),
            },
            _ => StatePayload::Diag {
                m: arbitrary_block_payload(rng),
                v: arbitrary_block_payload(rng),
                t: rng.next_u64(),
            },
        }
    }

    fn arbitrary_block_state(rng: &mut Pcg64, index: u32) -> BlockStateMsg {
        BlockStateMsg {
            index,
            state: arbitrary_state_payload(rng),
            mu: arbitrary_block_payload(rng),
            graft_v: arbitrary_opt_block_payload(rng),
            graft_t: rng.next_u64(),
        }
    }

    fn arbitrary_msg(rng: &mut Pcg64) -> WireMsg {
        match rng.below(28) {
            0 => WireMsg::Hello { worker_id: rng.next_u64() as u32 },
            1 => WireMsg::HelloV2 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
            },
            2 => {
                // Block lists from empty up to a large (max-len-ish) run.
                let n = [0, 1, 7, 4096][rng.below(4)];
                let blocks = (0..n)
                    .map(|i| BlockSpec {
                        index: i as u32,
                        rows: 1 + rng.below(64) as u32,
                        cols: 1 + rng.below(64) as u32,
                    })
                    .collect();
                WireMsg::Init(InitMsg {
                    kind: rng.below(3) as u8,
                    rank: rng.below(512) as u32,
                    beta2: adversarial_f64(rng),
                    eps: adversarial_f64(rng),
                    one_sided: rng.bernoulli(0.5),
                    graft: rng.below(6) as u8,
                    threads: rng.below(64) as u32,
                    blocks,
                    ekfac: rng.bernoulli(0.5),
                })
            }
            3 => {
                let n = rng.below(4);
                let entries = (0..n)
                    .map(|i| StepEntry {
                        index: i as u32,
                        refresh_due: rng.bernoulli(0.5),
                        param: adversarial_matrix(rng),
                        grad: adversarial_matrix(rng),
                    })
                    .collect();
                WireMsg::Step(StepMsg {
                    t: rng.next_u64(),
                    scale: adversarial_f64(rng),
                    preconditioning: rng.bernoulli(0.5),
                    stat_due: rng.bernoulli(0.5),
                    lr: adversarial_f64(rng),
                    beta1: adversarial_f64(rng),
                    weight_decay: adversarial_f64(rng),
                    entries,
                })
            }
            4 => {
                let n = rng.below(4);
                let entries =
                    (0..n).map(|i| (i as u32, adversarial_matrix(rng))).collect();
                WireMsg::StepOk(StepOkMsg {
                    t: rng.next_u64(),
                    refreshes: rng.next_u64() as u32,
                    entries,
                })
            }
            5 => WireMsg::MemStats,
            6 => WireMsg::MemStatsOk {
                mem_bytes: rng.next_u64(),
                second_moment_bytes: rng.next_u64(),
            },
            7 => WireMsg::Shutdown,
            8 => WireMsg::Ok,
            9 => {
                let len = [0, 1, 200][rng.below(3)];
                let message: String =
                    (0..len).map(|_| char::from(b'a' + rng.below(26) as u8)).collect();
                WireMsg::Error { message }
            }
            10 => {
                let n = [0, 3, 1000][rng.below(3)];
                WireMsg::RefreshAhead(RefreshAheadMsg {
                    t_next: rng.next_u64(),
                    all: rng.bernoulli(0.5),
                    due: (0..n).map(|_| rng.next_u64() as u32).collect(),
                })
            }
            11 => {
                let n = rng.below(16);
                WireMsg::RefreshAheadOk(RefreshAheadOkMsg {
                    t_next: rng.next_u64(),
                    count: rng.next_u64() as u32,
                    refreshed: (0..n).map(|_| rng.next_u64() as u32).collect(),
                })
            }
            12 => WireMsg::HelloV3 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
                compress: rng.bernoulli(0.5),
            },
            13 => {
                let n = rng.below(4);
                let entries = (0..n)
                    .map(|i| StepEntryV3 {
                        index: i as u32,
                        refresh_due: rng.bernoulli(0.5),
                        param: arbitrary_delta_mat(rng),
                        grad: arbitrary_delta_mat(rng),
                    })
                    .collect();
                WireMsg::StepV3(StepV3Msg {
                    t: rng.next_u64(),
                    base_t: rng.next_u64(),
                    resync: rng.bernoulli(0.5),
                    scale: adversarial_f64(rng),
                    preconditioning: rng.bernoulli(0.5),
                    stat_due: rng.bernoulli(0.5),
                    lr: adversarial_f64(rng),
                    beta1: adversarial_f64(rng),
                    weight_decay: adversarial_f64(rng),
                    entries,
                })
            }
            14 => {
                let n = rng.below(4);
                let entries =
                    (0..n).map(|i| (i as u32, arbitrary_delta_mat(rng))).collect();
                WireMsg::StepOkV3(StepOkV3Msg {
                    t: rng.next_u64(),
                    base_t: rng.next_u64(),
                    refreshes: rng.next_u64() as u32,
                    entries,
                })
            }
            15 => WireMsg::HelloV4 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
                compress: rng.bernoulli(0.5),
                state: rng.bernoulli(0.5),
            },
            16 => {
                let n = rng.below(4);
                let entries = (0..n)
                    .map(|i| StepEntryV4 {
                        index: i as u32,
                        refresh_due: rng.bernoulli(0.5),
                        param: arbitrary_block_payload(rng),
                        grad: arbitrary_block_payload(rng),
                    })
                    .collect();
                WireMsg::StepV4(StepV4Msg {
                    t: rng.next_u64(),
                    base_t: rng.next_u64(),
                    resync: rng.bernoulli(0.5),
                    scale: adversarial_f64(rng),
                    preconditioning: rng.bernoulli(0.5),
                    stat_due: rng.bernoulli(0.5),
                    lr: adversarial_f64(rng),
                    beta1: adversarial_f64(rng),
                    weight_decay: adversarial_f64(rng),
                    entries,
                })
            }
            17 => {
                let n = rng.below(4);
                let entries =
                    (0..n).map(|i| (i as u32, arbitrary_block_payload(rng))).collect();
                WireMsg::StepOkV4(StepOkV4Msg {
                    t: rng.next_u64(),
                    base_t: rng.next_u64(),
                    refreshes: rng.next_u64() as u32,
                    entries,
                })
            }
            18 => {
                let n = rng.below(8);
                let m = rng.below(8);
                WireMsg::RefreshAheadOkV4(RefreshAheadOkV4Msg {
                    t_next: rng.next_u64(),
                    count: rng.next_u64() as u32,
                    refreshed: (0..n).map(|_| rng.next_u64() as u32).collect(),
                    escaped: (0..m)
                        .map(|_| (rng.next_u64() as u32, adversarial_f64(rng)))
                        .collect(),
                })
            }
            19 => {
                let n = [0, 1, 9][rng.below(3)];
                WireMsg::StateSnap(StateSnapMsg {
                    want: (0..n).map(|_| rng.next_u64() as u32).collect(),
                })
            }
            20 => {
                let n = rng.below(3);
                WireMsg::StateSnapOk(StateSnapOkMsg {
                    entries: (0..n).map(|i| arbitrary_block_state(rng, i as u32)).collect(),
                })
            }
            21 => {
                let n = rng.below(3);
                WireMsg::StateRestore(StateRestoreMsg {
                    entries: (0..n).map(|i| arbitrary_block_state(rng, i as u32)).collect(),
                })
            }
            22 => WireMsg::HelloV5 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
                compress: rng.bernoulli(0.5),
                state: rng.bernoulli(0.5),
                member: rng.bernoulli(0.5),
            },
            23 => WireMsg::Adopt { epoch: rng.next_u64(), shard: rng.next_u64() as u32 },
            24 => WireMsg::AdoptOk { epoch: rng.next_u64(), shard: rng.next_u64() as u32 },
            25 => WireMsg::HelloV6 {
                worker_id: rng.next_u64() as u32,
                proto: rng.next_u64() as u32,
                overlap: rng.bernoulli(0.5),
                compress: rng.bernoulli(0.5),
                state: rng.bernoulli(0.5),
                member: rng.bernoulli(0.5),
                heartbeat: rng.bernoulli(0.5),
            },
            26 => WireMsg::Ping { seq: rng.next_u64() },
            _ => WireMsg::Pong { seq: rng.next_u64() },
        }
    }

    #[test]
    fn every_message_kind_roundtrips_over_adversarial_payloads() {
        // encode → decode → re-encode identity, compared at the byte
        // level: `Matrix` equality uses f64 `==`, which would falsely
        // reject NaN payloads that in fact round-tripped bit-exactly.
        crate::util::proptest::for_all_msg(
            0x5117e,
            300,
            arbitrary_msg,
            |msg| {
                let frame = encode_frame(msg).map_err(|e| format!("encode: {e}"))?;
                let decoded = decode_payload(&frame[4..]).map_err(|e| format!("decode: {e}"))?;
                let reframe = encode_frame(&decoded).map_err(|e| format!("re-encode: {e}"))?;
                if frame == reframe {
                    Ok(())
                } else {
                    Err("re-encoded frame differs from original".to_string())
                }
            },
        );
    }

    #[test]
    fn every_truncation_of_every_kind_is_rejected() {
        // For one representative frame of each message kind, every
        // strict prefix must fail to read (no silent partial decode).
        let mut rng = Pcg64::new(0x7c);
        let mut kinds_seen = std::collections::HashSet::new();
        for _ in 0..600 {
            let msg = arbitrary_msg(&mut rng);
            let tag = std::mem::discriminant(&msg);
            if !kinds_seen.insert(tag) {
                continue;
            }
            let frame = encode_frame(&msg).unwrap();
            for cut in 0..frame.len() {
                assert!(
                    read_msg(&mut &frame[..cut]).is_err(),
                    "prefix of {cut}/{} bytes decoded for {msg:?}",
                    frame.len()
                );
            }
        }
        assert!(kinds_seen.len() >= 28, "generator missed kinds: {}", kinds_seen.len());
    }

    #[test]
    fn bad_lengths_are_rejected_without_allocation_blowup() {
        // A list-count field claiming u32::MAX entries in a tiny frame
        // must fail on the missing bytes, not try to allocate for it.
        let mut payload = vec![TAG_REFRESH_AHEAD];
        payload.extend_from_slice(&7u64.to_le_bytes()); // t_next
        payload.push(0); // all = false
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // due count lie
        assert!(decode_payload(&payload).is_err());
        // Same lie on a matrix-bearing message.
        let mut payload = vec![TAG_STEP_OK];
        payload.extend_from_slice(&1u64.to_le_bytes()); // t
        payload.extend_from_slice(&0u32.to_le_bytes()); // refreshes
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // entry count lie
        assert!(decode_payload(&payload).is_err());
        // Implausible matrix shapes are rejected before the data reads.
        let mut payload = vec![TAG_STEP_OK];
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes()); // one entry
        payload.extend_from_slice(&0u32.to_le_bytes()); // index
        payload.extend_from_slice(&((1u32 << 21).to_le_bytes())); // rows too big
        payload.extend_from_slice(&1u32.to_le_bytes()); // cols
        assert!(decode_payload(&payload).is_err());
        // A frame length prefix longer than the stream is a read error.
        let frame = encode_frame(&WireMsg::Ok).unwrap();
        let mut lying = frame.clone();
        lying[0] = 200; // declares 200 payload bytes; only 1 follows
        assert!(read_msg_opt(&mut &lying[..]).is_err());
        // A corrupt prefix claiming a near-cap (512 MB) payload fails on
        // the missing bytes — the reader grows its buffer with arriving
        // data rather than allocating the full declared length up front.
        let mut huge = (1u32 << 29).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 16]);
        assert!(read_msg_opt(&mut &huge[..]).is_err());
        // Bad bool byte inside an otherwise valid frame.
        let mut payload = vec![TAG_REFRESH_AHEAD];
        payload.extend_from_slice(&7u64.to_le_bytes());
        payload.push(2); // bool must be 0 or 1
        payload.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode_payload(&payload).is_err());
    }

    // -----------------------------------------------------------------
    // v4 payload layer: typed block-state payloads.
    // -----------------------------------------------------------------

    #[test]
    fn sketch_payload_count_lies_are_rejected_before_allocation() {
        // An eigenvalue-count field claiming 2^30 entries in a tiny frame
        // must fail on plausibility/missing bytes, not allocate for it.
        let mut payload = vec![TAG_STATE_SNAP_OK];
        payload.extend_from_slice(&1u32.to_le_bytes()); // one entry
        payload.extend_from_slice(&0u32.to_le_bytes()); // index
        payload.push(SP_SKETCH);
        payload.push(SIDE_SKETCH);
        payload.push(DM_RAW); // basis: 1x1 raw matrix
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&1.0f64.to_bits().to_le_bytes());
        payload.extend_from_slice(&(1u32 << 30).to_le_bytes()); // eigval count lie
        assert!(decode_payload(&payload).is_err());
        // Same lie on the block-state entry count itself.
        let mut payload = vec![TAG_STATE_RESTORE];
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_payload(&payload).is_err());
        // And on a StateSnap `want` list.
        let mut payload = vec![TAG_STATE_SNAP];
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_payload(&payload).is_err());
    }

    #[test]
    fn adversarial_rank_fields_are_rejected_against_the_block_table() {
        // A sketch payload whose declared basis shape / eigval count do
        // not match the driver's block table (dim x rank) must be
        // rejected by `validate` before any resolve/allocation happens.
        let good = SketchPayload {
            basis: DeltaMat::from_matrix(&Matrix::zeros(12, 4)),
            eigvals: vec![1.0; 4],
            escaped_mass: 0.5,
            last_rho: 0.25,
            steps: 3,
        };
        assert!(good.validate(12, 4).is_ok());
        assert!(good.clone().into_state(12, 4).is_ok());
        // Rank-field lies: basis wider than the table's rank, eigval
        // list longer/shorter than rank, dim mismatch.
        assert!(good.validate(12, 3).is_err());
        assert!(good.validate(11, 4).is_err());
        let mut short = good.clone();
        short.eigvals.truncate(2);
        assert!(short.validate(12, 4).is_err());
        // A compressed basis lying about its own shape is caught by the
        // declared-dims check without decompressing.
        let bomb = SketchPayload {
            basis: DeltaMat::Full { rows: 1 << 19, cols: 1 << 7, comp: vec![] },
            eigvals: vec![],
            escaped_mass: 0.0,
            last_rho: 0.0,
            steps: 0,
        };
        assert!(bomb.validate(12, 4).is_err());
        assert!(bomb.into_state(12, 4).is_err());
        // Delta-mode bases are meaningless for standalone state payloads
        // (no baseline exists on the restoring side).
        let delta = SketchPayload {
            basis: DeltaMat::Delta { rows: 12, cols: 4, comp: vec![] },
            eigvals: vec![0.0; 4],
            escaped_mass: 0.0,
            last_rho: 0.0,
            steps: 0,
        };
        assert!(delta.validate(12, 4).is_err());
        // Payload kind must match the block table's unit kind.
        let diag = StatePayload::Diag {
            m: BlockPayload::diag(&Matrix::zeros(2, 2)),
            v: BlockPayload::diag(&Matrix::zeros(2, 2)),
            t: 1,
        };
        let kron_exp = StateExpect { rows: 2, cols: 2, kind: 0, rank: 0, one_sided: false };
        assert!(diag.into_state(&kron_exp).is_err());
        // Dense payloads resolve only after the shape check passes.
        let dense = BlockPayload::dense(&Matrix::zeros(3, 2));
        assert!(dense.resolve_dense(3, 2, None).is_ok());
        assert!(dense.resolve_dense(2, 3, None).is_err());
        assert!(dense.resolve_dense(1 << 19, 1 << 9, None).is_err());
    }

    #[test]
    fn precond_state_roundtrips_bitwise_through_wire_payloads() {
        use crate::optim::precond::{AdamUnit, KroneckerUnit, Preconditioner, SketchUnit};

        // Encode a unit's state as a StateSnapOk frame; bitwise identity
        // is checked by comparing the re-encoded frames of the original
        // and the restored unit (f64 `==` would falsely reject NaN).
        fn state_frame(u: &dyn Preconditioner, exp: &StateExpect) -> Vec<u8> {
            let msg = BlockStateMsg {
                index: 0,
                state: StatePayload::from_state(&u.state_payload()),
                mu: BlockPayload::dense(&Matrix::zeros(exp.rows, exp.cols)),
                graft_v: None,
                graft_t: 0,
            };
            encode_frame(&WireMsg::StateSnapOk(StateSnapOkMsg { entries: vec![msg] })).unwrap()
        }
        fn check(mut mk: impl FnMut() -> Box<dyn Preconditioner>, exp: StateExpect) {
            let mut rng = Pcg64::new(0x51a7e);
            let mut unit = mk();
            for _ in 0..7 {
                unit.ingest(&Matrix::randn(exp.rows, exp.cols, &mut rng));
            }
            unit.refresh();
            unit.ingest(&Matrix::randn(exp.rows, exp.cols, &mut rng));
            let frame = state_frame(unit.as_ref(), &exp);
            // Wire roundtrip, then restore into a fresh unit.
            let decoded = decode_payload(&frame[4..]).unwrap();
            let WireMsg::StateSnapOk(ok) = decoded else { panic!("wrong kind") };
            let entry = ok.entries.into_iter().next().unwrap();
            let state = entry.state.into_state(&exp).unwrap();
            let mut fresh = mk();
            fresh.restore_payload(state).unwrap();
            assert_eq!(
                state_frame(unit.as_ref(), &exp),
                state_frame(fresh.as_ref(), &exp),
                "restored state is not bitwise identical"
            );
            // Restored unit must evolve identically.
            let g = Matrix::randn(exp.rows, exp.cols, &mut rng);
            unit.ingest(&g);
            fresh.ingest(&g);
            unit.refresh();
            fresh.refresh();
            assert_eq!(state_frame(unit.as_ref(), &exp), state_frame(fresh.as_ref(), &exp));
        }

        check(
            || Box::new(KroneckerUnit::new((6, 4), 0.999, 1e-6, false)),
            StateExpect { rows: 6, cols: 4, kind: 0, rank: 0, one_sided: false },
        );
        // Sketched unit with one sketched side (rows > rank) and one
        // exact side (cols <= rank) — the mixed case.
        check(
            || Box::new(SketchUnit::new((12, 3), 4, 0.999, 1e-6, false)),
            StateExpect { rows: 12, cols: 3, kind: 1, rank: 4, one_sided: false },
        );
        check(
            || Box::new(SketchUnit::new((12, 9), 4, 0.999, 1e-6, true)),
            StateExpect { rows: 12, cols: 9, kind: 1, rank: 4, one_sided: true },
        );
        check(
            || Box::new(AdamUnit::new((5, 5), 0.9, 0.999, 1e-8)),
            StateExpect { rows: 5, cols: 5, kind: 2, rank: 0, one_sided: false },
        );
    }

    // -----------------------------------------------------------------
    // v3 payload layer: RLE/varint compressor + DeltaMat codec.
    // -----------------------------------------------------------------

    #[test]
    fn rle_roundtrips_and_crushes_zero_runs() {
        // Hand-picked shapes: empty, all-zero, no zeros, lone zeros,
        // alternating runs, trailing run.
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0; 4096],
            (1..=200u8).collect(),
            vec![1, 0, 2, 0, 3],
            vec![0, 0, 0, 7, 7, 0, 0, 1, 0],
            vec![5, 5, 5, 0, 0, 0, 0],
        ];
        for data in &cases {
            let comp = rle_compress(data);
            let back = rle_decompress(&comp, data.len()).unwrap();
            assert_eq!(&back, data);
        }
        // The all-zero case must actually compress.
        assert!(rle_compress(&[0u8; 4096]).len() < 8);
        // Random property sweep (zero-biased bytes so both token kinds
        // fire).
        crate::util::proptest::for_all_msg(
            0x41e,
            200,
            |rng| {
                let n = rng.below(600);
                (0..n)
                    .map(|_| if rng.bernoulli(0.6) { 0u8 } else { rng.next_u64() as u8 })
                    .collect::<Vec<u8>>()
            },
            |data| {
                let comp = rle_compress(data);
                let back =
                    rle_decompress(&comp, data.len()).map_err(|e| format!("decompress: {e}"))?;
                if &back == data {
                    Ok(())
                } else {
                    Err("rle roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn rle_decompress_rejects_corrupt_streams() {
        let comp = rle_compress(&[1, 2, 0, 0, 0, 3]);
        // Wrong expected length (both directions).
        assert!(rle_decompress(&comp, 5).is_err());
        assert!(rle_decompress(&comp, 7).is_err());
        // Truncated literal.
        let mut lit = Vec::new();
        super::push_varint(&mut lit, (8 << 1) | 1);
        lit.extend_from_slice(&[1, 2, 3]); // claims 8 literal bytes, has 3
        assert!(rle_decompress(&lit, 8).is_err());
        // A zero-run token claiming far more than `expected` must fail
        // before allocating for it.
        let mut bomb = Vec::new();
        super::push_varint(&mut bomb, u64::MAX & !1);
        assert!(rle_decompress(&bomb, 64).is_err());
        // Zero-length tokens cannot loop forever.
        let zero_tok = vec![0u8];
        assert!(rle_decompress(&zero_tok, 0).is_err());
        // Truncated varint.
        assert!(rle_decompress(&[0x80], 4).is_err());
        // Varint longer than u64.
        assert!(rle_decompress(&[0xff; 11], 4).is_err());
    }

    #[test]
    fn delta_mat_encodes_losslessly_in_every_mode() {
        let mut rng = Pcg64::new(0xd31a);
        for _ in 0..50 {
            let rows = 1 + rng.below(5);
            let cols = 1 + rng.below(5);
            let cur: Vec<u64> = (0..rows * cols)
                .map(|_| adversarial_f64(&mut rng).to_bits())
                .collect();
            // Baseline close to `cur` (sparse delta), far, and absent.
            let mut near = cur.clone();
            if !near.is_empty() {
                let k = rng.below(near.len());
                near[k] ^= 1;
            }
            let far: Vec<u64> = (0..cur.len()).map(|_| rng.next_u64()).collect();
            for base in [Some(&near), Some(&far), None] {
                let dm = DeltaMat::encode(rows, cols, &cur, base.map(|b| b.as_slice()));
                assert_eq!(dm.shape(), (rows, cols));
                let back = dm.resolve(base.map(|b| b.as_slice())).unwrap();
                assert_eq!(back, cur, "delta codec must be bit-lossless");
            }
        }
        // An unchanged payload deltas down to almost nothing.
        let cur = vec![0x3ff0_0000_0000_0001u64; 256];
        let dm = DeltaMat::encode(16, 16, &cur, Some(&cur));
        match &dm {
            DeltaMat::Delta { comp, .. } => assert!(comp.len() < 8, "got {} bytes", comp.len()),
            other => panic!("unchanged payload should pick Delta, got {other:?}"),
        }
        // Incompressible data without a baseline falls back to Raw.
        let mut rng = Pcg64::new(0xd31b);
        let noise: Vec<u64> = (0..64).map(|_| rng.next_u64() | 0x0101_0101_0101_0101).collect();
        assert!(matches!(DeltaMat::encode(8, 8, &noise, None), DeltaMat::Raw(_)));
    }

    #[test]
    fn delta_mat_resolve_rejects_bad_baselines() {
        let cur = vec![1u64, 2, 3, 4];
        let base = vec![9u64, 9, 9, 9];
        let dm = DeltaMat::encode(2, 2, &cur, Some(&base));
        assert!(matches!(dm, DeltaMat::Delta { .. }));
        // Delta without a baseline is an error, not garbage bits.
        assert!(dm.resolve(None).is_err());
        // Wrong-length baseline is rejected.
        assert!(dm.resolve(Some(&base[..2])).is_err());
        // Corrupt compressed body cannot satisfy the expected length.
        let bad = DeltaMat::Delta { rows: 2, cols: 2, comp: vec![0x03, 0xff] };
        assert!(bad.resolve(Some(&base)).is_err());
    }

    #[test]
    fn corrupt_frames_fail_loudly() {
        // Oversized length prefix.
        let mut bad = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        bad.extend_from_slice(&[0; 8]);
        assert!(read_msg_opt(&mut &bad[..]).is_err());
        // Unknown tag.
        let mut frame = encode_frame(&WireMsg::Ok).unwrap();
        frame[4] = 0xEE;
        assert!(read_msg_opt(&mut &frame[..]).is_err());
        // Trailing garbage inside a valid-length frame.
        let mut frame = encode_frame(&WireMsg::Shutdown).unwrap();
        frame[0] = 2; // payload length 2: tag + 1 junk byte
        frame.push(0);
        assert!(read_msg_opt(&mut &frame[..]).is_err());
    }
}
