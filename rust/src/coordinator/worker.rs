//! Leader/worker data-parallel step execution.
//!
//! Each worker computes (loss, grads) for its own microbatch — in
//! production through the PJRT gradient artifact — and the leader
//! averages losses and tree-allreduces gradients. The [`GradientWorker`]
//! abstraction keeps the coordinator testable without artifacts and lets
//! the E10 driver plug the runtime in.

use super::allreduce::{tree_allreduce, AllreduceStats};
use crate::tensor::Matrix;

/// Computes one microbatch's gradients. Implementations must be callable
/// from multiple worker threads (`Sync`).
pub trait GradientWorker: Sync {
    /// (loss, grads) for the microbatch owned by `worker` at `step`.
    fn compute(&self, step: usize, worker: usize) -> anyhow::Result<(f64, Vec<Matrix>)>;
}

/// Outcome of one data-parallel step.
#[derive(Debug)]
pub struct StepResult {
    /// Mean loss across workers.
    pub loss: f64,
    /// Mean gradients (allreduced).
    pub grads: Vec<Matrix>,
    pub allreduce: AllreduceStats,
}

/// Run one data-parallel step across `workers` threads.
pub fn data_parallel_step(
    gw: &dyn GradientWorker,
    step: usize,
    workers: usize,
) -> anyhow::Result<StepResult> {
    assert!(workers >= 1);
    let results: Vec<anyhow::Result<(f64, Vec<Matrix>)>> = if workers == 1 {
        vec![gw.compute(step, 0)]
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| scope.spawn(move || gw.compute(step, w)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        })
    };
    let mut losses = Vec::with_capacity(workers);
    let mut shards = Vec::with_capacity(workers);
    for r in results {
        let (loss, grads) = r?;
        losses.push(loss);
        shards.push(grads);
    }
    let loss = losses.iter().sum::<f64>() / workers as f64;
    let (grads, allreduce) = tree_allreduce(shards)?;
    Ok(StepResult { loss, grads, allreduce })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct FakeWorker {
        calls: AtomicUsize,
    }

    impl GradientWorker for FakeWorker {
        fn compute(&self, step: usize, worker: usize) -> anyhow::Result<(f64, Vec<Matrix>)> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            // Deterministic per-(step, worker) gradient.
            let g = Matrix::from_fn(2, 2, |i, j| {
                (step * 100 + worker * 10 + i * 2 + j) as f64
            });
            Ok((worker as f64, vec![g]))
        }
    }

    #[test]
    fn step_averages_losses_and_grads() {
        let fw = FakeWorker { calls: AtomicUsize::new(0) };
        let res = data_parallel_step(&fw, 3, 4).unwrap();
        assert_eq!(fw.calls.load(Ordering::SeqCst), 4);
        // Mean loss of 0,1,2,3.
        assert_eq!(res.loss, 1.5);
        // Mean gradient: step*100 + mean(worker)*10 + i*2 + j.
        let want = Matrix::from_fn(2, 2, |i, j| 300.0 + 15.0 + (i * 2 + j) as f64);
        assert!(res.grads[0].max_diff(&want) < 1e-12);
        assert_eq!(res.allreduce.rounds, 2);
    }

    #[test]
    fn single_worker_step() {
        let fw = FakeWorker { calls: AtomicUsize::new(0) };
        let res = data_parallel_step(&fw, 0, 1).unwrap();
        assert_eq!(res.loss, 0.0);
        assert_eq!(res.allreduce.rounds, 0);
    }

    struct FailingWorker;
    impl GradientWorker for FailingWorker {
        fn compute(&self, _s: usize, w: usize) -> anyhow::Result<(f64, Vec<Matrix>)> {
            if w == 2 {
                anyhow::bail!("injected failure on worker 2");
            }
            Ok((0.0, vec![Matrix::zeros(1, 1)]))
        }
    }

    #[test]
    fn worker_failure_is_propagated() {
        let err = data_parallel_step(&FailingWorker, 0, 4).unwrap_err();
        assert!(err.to_string().contains("worker 2"));
    }

    #[test]
    fn parallel_equals_serial() {
        // Same worker function run with 1 thread per shard vs serially
        // composed must agree (determinism of the coordinator).
        let fw = FakeWorker { calls: AtomicUsize::new(0) };
        let par = data_parallel_step(&fw, 7, 8).unwrap();
        // Serial recomputation.
        let mut shards = vec![];
        let mut losses = vec![];
        for w in 0..8 {
            let (l, g) = fw.compute(7, w).unwrap();
            losses.push(l);
            shards.push(g);
        }
        let (serial, _) = crate::coordinator::tree_allreduce(shards).unwrap();
        assert!(par.grads[0].max_diff(&serial[0]) < 1e-12);
        assert_eq!(par.loss, losses.iter().sum::<f64>() / 8.0);
    }
}
