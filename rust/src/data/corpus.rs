//! Synthetic language-modeling corpus for the end-to-end driver (E10).
//!
//! Tokens are drawn from a seeded order-2 Markov chain whose transition
//! table has low entropy (≈2.5 bits vs log₂|V| for uniform), so a
//! transformer LM has real structure to learn and the loss curve
//! separates optimizers. Batches are emitted as (inputs, targets) token
//! id arrays shaped [batch, seq_len].

use crate::util::rng::Pcg64;

/// Order-2 Markov token source.
pub struct MarkovCorpus {
    pub vocab: usize,
    /// For each (prev2, prev1) context, a small set of likely next tokens
    /// with geometric-ish weights.
    table: Vec<[u32; 4]>,
    rng: Pcg64,
    state: (u32, u32),
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 8);
        let mut rng = Pcg64::new(seed);
        // Each context maps to 4 candidate successors.
        let table = (0..vocab * vocab)
            .map(|_| {
                [
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                ]
            })
            .collect();
        MarkovCorpus { vocab, table, rng: rng.split(), state: (0, 1) }
    }

    /// Next token id.
    pub fn next_token(&mut self) -> u32 {
        let ctx = (self.state.0 as usize) * self.vocab + self.state.1 as usize;
        let cands = &self.table[ctx];
        // Geometric-ish selection: P(cand_0) = 0.55, 0.25, 0.12, 0.05,
        // plus 3% uniform smoothing over the vocab.
        let u = self.rng.uniform();
        let tok = if u < 0.03 {
            self.rng.below(self.vocab) as u32
        } else if u < 0.58 {
            cands[0]
        } else if u < 0.83 {
            cands[1]
        } else if u < 0.95 {
            cands[2]
        } else {
            cands[3]
        };
        self.state = (self.state.1, tok);
        tok
    }

    /// Emit a [batch, seq+1] token block; callers split into input/target.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<Vec<u32>> {
        (0..batch)
            .map(|_| (0..=seq).map(|_| self.next_token()).collect())
            .collect()
    }

    /// Empirical unigram entropy in nats over `n` samples (diagnostics:
    /// the LM loss should drop below this).
    pub fn unigram_entropy(&mut self, n: usize) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for _ in 0..n {
            counts[self.next_token() as usize] += 1;
        }
        let mut h = 0.0;
        for c in counts {
            if c > 0 {
                let p = c as f64 / n as f64;
                h -= p * p.ln();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_and_deterministic() {
        let mut c1 = MarkovCorpus::new(32, 5);
        let mut c2 = MarkovCorpus::new(32, 5);
        for _ in 0..200 {
            let t1 = c1.next_token();
            assert_eq!(t1, c2.next_token());
            assert!((t1 as usize) < 32);
        }
    }

    #[test]
    fn batch_shape() {
        let mut c = MarkovCorpus::new(16, 6);
        let b = c.batch(4, 8);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|row| row.len() == 9));
    }

    #[test]
    fn structure_is_learnable() {
        // Bigram predictability: the most likely successor of each
        // context should fire clearly above chance.
        let mut c = MarkovCorpus::new(16, 7);
        let mut hits = 0;
        let mut total = 0;
        // Estimate: after observing a context, next token equals the
        // table's top candidate with probability ≈ 0.55 + smoothing.
        for _ in 0..5000 {
            let ctx = (c.state.0 as usize) * c.vocab + c.state.1 as usize;
            let top = c.table[ctx][0];
            let tok = c.next_token();
            if tok == top {
                hits += 1;
            }
            total += 1;
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.4, "top-candidate rate {rate} ≈ chance");
    }

    #[test]
    fn entropy_below_uniform() {
        let mut c = MarkovCorpus::new(64, 8);
        let h = c.unigram_entropy(20_000);
        assert!(h < (64f64).ln() + 1e-9);
        assert!(h > 1.0);
    }
}
