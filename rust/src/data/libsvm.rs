//! LIBSVM text format parser (Chang & Lin [44]).
//!
//! The App. A experiments run on synthetic stand-ins by default (no
//! network in this environment), but `sketchy repro tbl3 --libsvm DIR`
//! will read the real `gisette_scale` / `a9a` / `cifar10` files if the
//! user supplies them. Format: `label idx:val idx:val ...` with 1-based
//! indices.

/// Parsed dataset: dense feature rows (with an appended intercept column)
/// and ±1 labels.
pub struct LibsvmData {
    pub features: Vec<Vec<f64>>,
    pub labels: Vec<f64>,
    pub dim: usize,
}

/// Parse LIBSVM text. `dim_hint` fixes the feature count (0 = infer from
/// max index). An all-ones intercept column is appended, matching the
/// paper's preprocessing.
pub fn parse_libsvm(text: &str, dim_hint: usize) -> Result<LibsvmData, String> {
    let mut rows: Vec<Vec<(usize, f64)>> = vec![];
    let mut labels = vec![];
    let mut max_idx = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().ok_or(format!("line {}: empty", ln + 1))?;
        let label: f64 = label_tok
            .parse()
            .map_err(|_| format!("line {}: bad label {label_tok}", ln + 1))?;
        // Normalize to ±1 (cifar10 multiclass is binarized: class 0 vs rest,
        // the standard binary reduction for logistic experiments).
        let y = if label > 0.0 { 1.0 } else { -1.0 };
        let mut row = vec![];
        for p in parts {
            let (i_s, v_s) = p
                .split_once(':')
                .ok_or(format!("line {}: bad pair {p}", ln + 1))?;
            let idx: usize = i_s
                .parse()
                .map_err(|_| format!("line {}: bad index {i_s}", ln + 1))?;
            let val: f64 = v_s
                .parse()
                .map_err(|_| format!("line {}: bad value {v_s}", ln + 1))?;
            if idx == 0 {
                return Err(format!("line {}: LIBSVM indices are 1-based", ln + 1));
            }
            max_idx = max_idx.max(idx);
            row.push((idx - 1, val));
        }
        rows.push(row);
        labels.push(y);
    }
    let d = if dim_hint > 0 { dim_hint.max(max_idx) } else { max_idx };
    // Densifying costs rows x (d + 1) cells: one stray huge index in a
    // small file must be a named error, not a multi-gigabyte allocation.
    const MAX_DENSE_CELLS: usize = 1 << 28;
    if rows.len().saturating_mul(d + 1) > MAX_DENSE_CELLS {
        return Err(format!(
            "dense expansion needs {} x {} cells — implausible max feature index for this file",
            rows.len(),
            d + 1
        ));
    }
    let features = rows
        .into_iter()
        .map(|sparse| {
            let mut dense = vec![0.0; (d + 1).min(MAX_DENSE_CELLS)];
            for (i, v) in sparse {
                dense[i] = v;
            }
            dense[d] = 1.0; // intercept
            dense
        })
        .collect();
    Ok(LibsvmData { features, labels, dim: d + 1 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.0\n-1 2:2.0\n";
        let data = parse_libsvm(text, 0).unwrap();
        assert_eq!(data.dim, 4); // 3 features + intercept
        assert_eq!(data.features[0], vec![0.5, 0.0, 1.0, 1.0]);
        assert_eq!(data.features[1], vec![0.0, 2.0, 0.0, 1.0]);
        assert_eq!(data.labels, vec![1.0, -1.0]);
    }

    #[test]
    fn multiclass_binarized() {
        let text = "3 1:1\n0 1:1\n";
        let data = parse_libsvm(text, 0).unwrap();
        assert_eq!(data.labels, vec![1.0, -1.0]);
    }

    #[test]
    fn dim_hint_and_blank_lines() {
        let text = "\n+1 2:1\n\n# comment\n";
        let data = parse_libsvm(text, 10).unwrap();
        assert_eq!(data.dim, 11);
        assert_eq!(data.features.len(), 1);
    }

    #[test]
    fn errors_on_malformed() {
        assert!(parse_libsvm("+1 0:1\n", 0).is_err()); // 0-based index
        assert!(parse_libsvm("+1 a:b\n", 0).is_err());
        assert!(parse_libsvm("xx 1:1\n", 0).is_err());
    }

    #[test]
    fn implausible_index_is_a_named_error_not_an_allocation() {
        let err = parse_libsvm("+1 4000000000:1.0\n", 0).unwrap_err();
        assert!(err.contains("dense expansion"), "{err}");
    }
}
