//! Data substrate (system S6): synthetic replacements for the paper's
//! datasets (see DESIGN.md §6 for the substitution rationale), a LIBSVM
//! parser for dropping in the real convex datasets, and workload
//! generators for the LM / image / audio / graph proxy tasks.

pub mod corpus;
pub mod libsvm;
pub mod proxy;
pub mod synthetic;

pub use corpus::MarkovCorpus;
pub use libsvm::parse_libsvm;
pub use synthetic::{DatasetKind, SyntheticLogistic};
