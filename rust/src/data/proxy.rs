//! Synthetic proxy workloads for the three Fig. 2 tasks (DESIGN.md §6):
//!
//! - **image** (ResNet-50 / ImageNet →) anisotropic class-mean Gaussians
//!   rendered as H×W×1 images with augmentation noise;
//! - **audio** (Conformer / Librispeech →) spectrogram-like sequences
//!   from a latent-state chain, sequence classification;
//! - **graph** (GNN / ogbg-molpcba →) random molecular-ish graphs with
//!   dense adjacency, node features and multi-task binary labels.
//!
//! All generators emit flat `f32` buffers matching the AOT artifact input
//! layouts, plus integer labels. Everything is seeded.

use crate::util::rng::Pcg64;

/// A generated classification batch: flat row-major features + labels.
pub struct Batch {
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
    /// Per-example feature element count.
    pub feature_len: usize,
}

/// Image proxy: `classes` Gaussian class templates over an h×w grid with
/// structured (low-frequency) patterns and additive noise.
pub struct ImageProxy {
    pub h: usize,
    pub w: usize,
    pub classes: usize,
    templates: Vec<Vec<f32>>,
    rng: Pcg64,
}

impl ImageProxy {
    pub fn new(h: usize, w: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let templates = (0..classes)
            .map(|_| {
                // Low-frequency template: sum of a few random 2-D cosines.
                let fx1 = rng.uniform_in(0.5, 3.0);
                let fy1 = rng.uniform_in(0.5, 3.0);
                let fx2 = rng.uniform_in(0.5, 3.0);
                let fy2 = rng.uniform_in(0.5, 3.0);
                let p1 = rng.uniform_in(0.0, 6.28);
                let p2 = rng.uniform_in(0.0, 6.28);
                (0..h * w)
                    .map(|i| {
                        let y = (i / w) as f64 / h as f64;
                        let x = (i % w) as f64 / w as f64;
                        ((fx1 * x * 6.28 + fy1 * y * 6.28 + p1).cos()
                            + 0.5 * (fx2 * x * 6.28 - fy2 * y * 6.28 + p2).cos())
                            as f32
                    })
                    .collect()
            })
            .collect();
        ImageProxy { h, w, classes, templates, rng: rng.split() }
    }

    /// Same task (identical class templates), independent sample stream —
    /// for held-out evaluation.
    pub fn fork_stream(&self, stream_seed: u64) -> Self {
        ImageProxy {
            h: self.h,
            w: self.w,
            classes: self.classes,
            templates: self.templates.clone(),
            rng: Pcg64::new(stream_seed),
        }
    }

    pub fn batch(&mut self, n: usize) -> Batch {
        let fl = self.h * self.w;
        let mut features = Vec::with_capacity(n * fl);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let c = self.rng.below(self.classes);
            labels.push(c as i32);
            // Augmentation: random gain/shift plus pixel noise (stands in
            // for the crop/flip augmentations of the real pipeline).
            let gain = 0.6 + 0.8 * self.rng.uniform();
            let shift = 0.3 * self.rng.gaussian();
            for &t in &self.templates[c] {
                let v = gain as f32 * t + shift as f32 + 1.3 * self.rng.gaussian() as f32;
                features.push(v);
            }
        }
        Batch { features, labels, feature_len: fl }
    }
}

/// Audio proxy: sequences of `frames`×`bins` spectrogram frames emitted by
/// a class-dependent latent-state chain (phoneme-like).
pub struct AudioProxy {
    pub frames: usize,
    pub bins: usize,
    pub classes: usize,
    /// Per class: sequence of band centers over a few latent states.
    state_bands: Vec<Vec<f64>>,
    rng: Pcg64,
}

impl AudioProxy {
    pub fn new(frames: usize, bins: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let state_bands = (0..classes)
            .map(|_| (0..4).map(|_| rng.uniform_in(0.1, 0.9)).collect())
            .collect();
        AudioProxy { frames, bins, classes, state_bands, rng: rng.split() }
    }

    /// Same task (identical state bands), independent sample stream.
    pub fn fork_stream(&self, stream_seed: u64) -> Self {
        AudioProxy {
            frames: self.frames,
            bins: self.bins,
            classes: self.classes,
            state_bands: self.state_bands.clone(),
            rng: Pcg64::new(stream_seed),
        }
    }

    pub fn batch(&mut self, n: usize) -> Batch {
        let fl = self.frames * self.bins;
        let mut features = Vec::with_capacity(n * fl);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let c = self.rng.below(self.classes);
            labels.push(c as i32);
            let bands = &self.state_bands[c];
            for f in 0..self.frames {
                // Latent state advances every few frames.
                let st = (f / (self.frames / 4).max(1)).min(3);
                let center = bands[st] * self.bins as f64 + 1.5 * self.rng.gaussian();
                let width = 2.0 + 2.0 * self.rng.uniform();
                for b in 0..self.bins {
                    let z = (b as f64 - center) / width;
                    let energy = (-0.5 * z * z).exp();
                    features.push((energy + 0.8 * self.rng.gaussian().abs()) as f32);
                }
            }
        }
        Batch { features, labels, feature_len: fl }
    }
}

/// Graph proxy: `nodes`-node molecular-ish graphs (random tree plus ring
/// closures), node features, dense adjacency, `tasks` binary labels
/// derived from structural motifs (ring count, feature sums) + noise.
pub struct GraphProxy {
    pub nodes: usize,
    pub feat: usize,
    pub tasks: usize,
    rng: Pcg64,
}

/// One generated graph batch: adjacency [n, nodes, nodes], features
/// [n, nodes, feat], labels [n, tasks] in {0,1}.
pub struct GraphBatch {
    pub adjacency: Vec<f32>,
    pub features: Vec<f32>,
    pub labels: Vec<f32>,
}

impl GraphProxy {
    pub fn new(nodes: usize, feat: usize, tasks: usize, seed: u64) -> Self {
        GraphProxy { nodes, feat, tasks, rng: Pcg64::new(seed) }
    }

    pub fn batch(&mut self, n: usize) -> GraphBatch {
        let nn = self.nodes;
        let mut adjacency = vec![0.0f32; n * nn * nn];
        let mut features = vec![0.0f32; n * nn * self.feat];
        let mut labels = vec![0.0f32; n * self.tasks];
        for g in 0..n {
            let a = &mut adjacency[g * nn * nn..(g + 1) * nn * nn];
            // Random tree.
            for v in 1..nn {
                let u = self.rng.below(v);
                a[v * nn + u] = 1.0;
                a[u * nn + v] = 1.0;
            }
            // Ring closures (cycles — the motif the labels detect).
            let rings = self.rng.below(4);
            for _ in 0..rings {
                let u = self.rng.below(nn);
                let v = self.rng.below(nn);
                if u != v {
                    a[v * nn + u] = 1.0;
                    a[u * nn + v] = 1.0;
                }
            }
            // Self-loops for message passing stability.
            for v in 0..nn {
                a[v * nn + v] = 1.0;
            }
            // Node features: "atom type" one-hot-ish + degree signal.
            let f = &mut features[g * nn * self.feat..(g + 1) * nn * self.feat];
            let mut heavy_atoms = 0usize;
            for v in 0..nn {
                let atom = self.rng.below(self.feat.min(6));
                f[v * self.feat + atom] = 1.0;
                if atom >= 3 {
                    heavy_atoms += 1;
                }
                let degree: f32 = (0..nn).map(|u| a[v * nn + u]).sum();
                if self.feat > 6 {
                    f[v * self.feat + 6] = degree / 4.0;
                }
            }
            // Multi-task labels: motif-derived with 10% flips.
            let l = &mut labels[g * self.tasks..(g + 1) * self.tasks];
            for t in 0..self.tasks {
                let raw = match t % 3 {
                    0 => rings >= 1,
                    1 => heavy_atoms * 2 >= nn,
                    _ => (rings + heavy_atoms + t) % 2 == 0,
                };
                let y = if self.rng.bernoulli(0.1) { !raw } else { raw };
                l[t] = if y { 1.0 } else { 0.0 };
            }
        }
        GraphBatch { adjacency, features, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batch_shapes_and_determinism() {
        let mut p1 = ImageProxy::new(8, 8, 4, 30);
        let mut p2 = ImageProxy::new(8, 8, 4, 30);
        let b1 = p1.batch(5);
        let b2 = p2.batch(5);
        assert_eq!(b1.features.len(), 5 * 64);
        assert_eq!(b1.labels.len(), 5);
        assert_eq!(b1.features, b2.features);
        assert!(b1.labels.iter().all(|&l| (0..4).contains(&l)));
    }

    #[test]
    fn image_classes_are_separable() {
        // Template correlation within class ≫ across class.
        let p = ImageProxy::new(16, 16, 3, 31);
        let t0 = p.templates[0].clone();
        let t1 = p.templates[1].clone();
        let dot = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&x, &y)| (x * y) as f64).sum()
        };
        let n0 = dot(&t0, &t0).sqrt();
        let n1 = dot(&t1, &t1).sqrt();
        let cross = dot(&t0, &t1) / (n0 * n1);
        assert!(cross.abs() < 0.9, "templates nearly identical: {cross}");
    }

    #[test]
    fn audio_batch_energy_concentrates_in_band() {
        let mut p = AudioProxy::new(8, 16, 2, 32);
        let b = p.batch(3);
        assert_eq!(b.features.len(), 3 * 8 * 16);
        // Each frame's max bin should be well above its median bin.
        let frame = &b.features[0..16];
        let mut sorted: Vec<f32> = frame.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(sorted[15] > 2.0 * sorted[8].max(0.05));
    }

    #[test]
    fn graph_batch_is_symmetric_with_self_loops() {
        let mut p = GraphProxy::new(10, 8, 4, 33);
        let b = p.batch(2);
        for g in 0..2 {
            let a = &b.adjacency[g * 100..(g + 1) * 100];
            for i in 0..10 {
                assert_eq!(a[i * 10 + i], 1.0);
                for j in 0..10 {
                    assert_eq!(a[i * 10 + j], a[j * 10 + i]);
                }
            }
        }
        assert_eq!(b.labels.len(), 2 * 4);
        assert!(b.labels.iter().all(|&l| l == 0.0 || l == 1.0));
    }

    #[test]
    fn graph_is_connected_tree_plus_rings() {
        let mut p = GraphProxy::new(12, 8, 2, 34);
        let b = p.batch(1);
        // BFS from node 0 must reach everything (tree backbone).
        let a = &b.adjacency[0..144];
        let mut seen = vec![false; 12];
        let mut queue = vec![0usize];
        seen[0] = true;
        while let Some(v) = queue.pop() {
            for u in 0..12 {
                if a[v * 12 + u] > 0.0 && !seen[u] {
                    seen[u] = true;
                    queue.push(u);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
