//! Synthetic logistic-regression streams shaped like the paper's Tbl. 2
//! datasets (gisette, a9a, cifar10 from LIBSVM [44]).
//!
//! Examples are generated *on demand* from a per-row seed (a 50000×3073
//! dense matrix would be 1.2 GB; the stream needs O(d) live memory),
//! which also makes every pass bit-reproducible. Each dataset plants a
//! ground-truth direction with margin noise and label flips so the
//! optimal average loss is strictly positive, like the real datasets.
//! The last feature is the all-constant intercept column, matching
//! App. A's setup ("the feature count includes an all-constant intercept
//! column").

use crate::util::rng::Pcg64;

/// Which Tbl. 2 dataset shape to mimic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 6000 × 5001 dense, [0,1]-ish features (gisette_scale).
    Gisette,
    /// 32561 × 124 sparse binary (~15 active features/row) (a9a).
    A9a,
    /// 50000 × 3073 dense pixel features (cifar10, binarized labels).
    Cifar10,
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Gisette => "gisette",
            DatasetKind::A9a => "a9a",
            DatasetKind::Cifar10 => "cifar10",
        }
    }

    /// (examples, features) per Tbl. 2.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            DatasetKind::Gisette => (6000, 5001),
            DatasetKind::A9a => (32561, 124),
            DatasetKind::Cifar10 => (50000, 3073),
        }
    }
}

/// Deterministic synthetic logistic dataset with planted structure.
pub struct SyntheticLogistic {
    pub kind: DatasetKind,
    pub n: usize,
    pub d: usize,
    seed: u64,
    /// Planted separator (unit norm), including the intercept coordinate.
    w_star: Vec<f64>,
    /// Low-rank mixing directions giving the feature covariance a decaying
    /// spectrum (what makes sketched preconditioning pay off, §5.2).
    mix: Vec<Vec<f64>>,
    /// Label noise rate.
    flip: f64,
}

impl SyntheticLogistic {
    pub fn new(kind: DatasetKind, seed: u64) -> Self {
        let (n, d) = kind.shape();
        Self::with_size(kind, n, d, seed)
    }

    /// Shape-overridden constructor (tests, scaled-down runs).
    pub fn with_size(kind: DatasetKind, n: usize, d: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed ^ 0x5ce7c41u64);
        let mut w_star: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
        let nw = crate::tensor::norm2(&w_star);
        for w in &mut w_star {
            *w /= nw;
        }
        // A handful of shared directions induce correlated features.
        let k = 8.min(d);
        let mix = (0..k)
            .map(|_| {
                let v: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
                let nv = crate::tensor::norm2(&v);
                v.iter().map(|x| x / nv).collect()
            })
            .collect();
        let flip = match kind {
            DatasetKind::Gisette => 0.03,
            DatasetKind::A9a => 0.15,
            DatasetKind::Cifar10 => 0.10,
        };
        SyntheticLogistic { kind, n, d, seed, w_star, mix, flip }
    }

    /// The i-th example: (features, label ∈ {−1, +1}).
    pub fn example(&self, i: usize) -> (Vec<f64>, f64) {
        assert!(i < self.n);
        let mut rng = Pcg64::new(self.seed.wrapping_mul(0x9e3779b97f4a7c15) ^ i as u64);
        let d = self.d;
        let mut x = vec![0.0; d];
        match self.kind {
            DatasetKind::Gisette | DatasetKind::Cifar10 => {
                // Dense features: iid noise plus low-rank structure with a
                // decaying coefficient spectrum.
                for v in x.iter_mut() {
                    *v = 0.3 * rng.gaussian();
                }
                for (j, dir) in self.mix.iter().enumerate() {
                    let c = rng.gaussian() * 2.0 / (1.0 + j as f64);
                    for (xi, di) in x.iter_mut().zip(dir) {
                        *xi += c * di;
                    }
                }
                if self.kind == DatasetKind::Cifar10 {
                    // Pixel-like: shift/clip to [0, 1].
                    for v in x.iter_mut() {
                        *v = (0.5 + 0.5 * *v).clamp(0.0, 1.0);
                    }
                }
            }
            DatasetKind::A9a => {
                // Sparse binary: ~15 active categorical indicators.
                let active = 10 + rng.below(10);
                for _ in 0..active {
                    x[rng.below(d - 1)] = 1.0;
                }
            }
        }
        // Intercept column (all-constant 1).
        x[d - 1] = 1.0;
        let margin = crate::tensor::dot(&x, &self.w_star) + 0.1 * rng.gaussian();
        let mut y = if margin > 0.0 { 1.0 } else { -1.0 };
        if rng.bernoulli(self.flip) {
            y = -y;
        }
        (x, y)
    }

    /// Iterate the full single pass (App. A streams each dataset once).
    pub fn iter(&self) -> impl Iterator<Item = (Vec<f64>, f64)> + '_ {
        (0..self.n).map(move |i| self.example(i))
    }
}

/// Stream for Observation 2: linear losses with gradients drawn iid from
/// a distribution over `r` orthonormal vectors, with probabilities
/// proportional to a decaying profile (λ_i in the proof).
pub struct ObservationTwoStream {
    /// Orthonormal directions (rows r×d).
    pub dirs: crate::tensor::Matrix,
    /// Sampling probabilities (length r, sums to 1).
    pub probs: Vec<f64>,
    rng: Pcg64,
}

impl ObservationTwoStream {
    pub fn new(d: usize, r: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let q = crate::tensor::random_orthonormal(d, r, &mut rng);
        // λ_i ∝ 1/(i+1): a decaying but full-support distribution.
        let mut probs: Vec<f64> = (0..r).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let s: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= s;
        }
        ObservationTwoStream { dirs: q.t(), probs, rng }
    }

    /// Next gradient g_t = w_i with probability λ_i.
    pub fn next_grad(&mut self) -> Vec<f64> {
        let i = self.rng.categorical(&self.probs);
        self.dirs.row(i).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table2() {
        assert_eq!(DatasetKind::Gisette.shape(), (6000, 5001));
        assert_eq!(DatasetKind::A9a.shape(), (32561, 124));
        assert_eq!(DatasetKind::Cifar10.shape(), (50000, 3073));
    }

    #[test]
    fn examples_are_deterministic() {
        let ds = SyntheticLogistic::with_size(DatasetKind::A9a, 100, 30, 7);
        let (x1, y1) = ds.example(17);
        let (x2, y2) = ds.example(17);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = ds.example(18);
        assert_ne!(x1, x3);
    }

    #[test]
    fn intercept_always_one() {
        for kind in [DatasetKind::Gisette, DatasetKind::A9a, DatasetKind::Cifar10] {
            let ds = SyntheticLogistic::with_size(kind, 50, 20, 3);
            for i in 0..50 {
                assert_eq!(ds.example(i).0[19], 1.0);
            }
        }
    }

    #[test]
    fn a9a_is_sparse_binary() {
        let ds = SyntheticLogistic::with_size(DatasetKind::A9a, 50, 124, 5);
        for i in 0..50 {
            let (x, _) = ds.example(i);
            let nz = x.iter().filter(|&&v| v != 0.0).count();
            assert!(nz <= 21, "too dense: {nz}");
            assert!(x.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn cifar_in_unit_range() {
        let ds = SyntheticLogistic::with_size(DatasetKind::Cifar10, 20, 40, 5);
        for i in 0..20 {
            let (x, _) = ds.example(i);
            assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn labels_are_learnable() {
        // The planted separator must fit better than chance.
        let ds = SyntheticLogistic::with_size(DatasetKind::Gisette, 300, 25, 11);
        let mut correct = 0;
        for i in 0..300 {
            let (x, y) = ds.example(i);
            let pred = if crate::tensor::dot(&x, &ds.w_star) > 0.0 { 1.0 } else { -1.0 };
            if pred == y {
                correct += 1;
            }
        }
        assert!(correct > 240, "separator fits {correct}/300");
    }

    #[test]
    fn obs2_stream_draws_orthonormal_dirs() {
        let mut s = ObservationTwoStream::new(10, 4, 9);
        for _ in 0..20 {
            let g = s.next_grad();
            assert!((crate::tensor::norm2(&g) - 1.0).abs() < 1e-9);
        }
        // Frequencies roughly follow probs.
        let mut counts = [0usize; 4];
        let mut s = ObservationTwoStream::new(6, 4, 10);
        for _ in 0..4000 {
            let g = s.next_grad();
            // Identify which direction fired by max inner product.
            let mut best = 0;
            let mut bv = -1.0;
            for i in 0..4 {
                let v = crate::tensor::dot(&g, s.dirs.row(i)).abs();
                if v > bv {
                    bv = v;
                    best = i;
                }
            }
            counts[best] += 1;
        }
        assert!(counts[0] > counts[3], "decaying profile: {counts:?}");
    }
}
