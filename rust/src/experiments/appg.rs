//! E8 / Appendix G: step-skipping (Epoch AdaGrad, Alg. 5).
//!
//! Stochastic linear costs matching Remark 23's setting (independent
//! bounded gradients with well-conditioned covariance); we sweep the
//! preconditioner-update interval and report regret relative to
//! interval = 1. App. G predicts at most a log T factor of degradation —
//! in particular regret should grow *far* slower than the interval.

use crate::optim::{EpochAdaGrad, VectorOptimizer};
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::fmt::Write;

/// Regret of Epoch AdaGrad with the given interval on a seeded stochastic
/// linear stream over the unit ball.
fn regret_for_interval(d: usize, t: usize, interval: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    // Anisotropic but well-conditioned gradient distribution (Remark 23).
    let scales: Vec<f64> = (0..d).map(|i| 0.5 + 1.0 / (1.0 + i as f64)).collect();
    let mut opt = EpochAdaGrad::new(d, 2.0 / (2.0f64).sqrt(), interval, 1e-8);
    let mut x = vec![0.0; d];
    let mut cum = 0.0;
    let mut gsum = vec![0.0; d];
    for _ in 0..t {
        let g: Vec<f64> = scales.iter().map(|&s| s * rng.gaussian()).collect();
        cum += crate::tensor::dot(&g, &x);
        for i in 0..d {
            gsum[i] += g[i];
        }
        opt.step(&mut x, &g, Some(1.0));
    }
    cum + crate::tensor::norm2(&gsum)
}

pub fn run(args: &Args) -> Result<String> {
    let d = args.get_usize("d", 12);
    let t = args.get_usize("t", 3000);
    let seed = args.get_u64("seed", 7);
    let seeds = args.get_usize("seeds", 3);
    let intervals = [1usize, 2, 5, 10, 20, 50];
    let mut out = String::new();
    writeln!(out, "# App. G — Epoch AdaGrad step-skipping (d={d}, T={t}, {seeds} seeds)\n")?;
    writeln!(out, "| interval k | regret (mean) | ratio vs k=1 | log T reference |")?;
    writeln!(out, "|---|---|---|---|")?;
    let mut base = 0.0;
    let logt = (t as f64).ln();
    let mut worst_ratio: f64 = 0.0;
    for &k in &intervals {
        let mean: f64 = (0..seeds)
            .map(|s| regret_for_interval(d, t, k, seed + s as u64))
            .sum::<f64>()
            / seeds as f64;
        if k == 1 {
            base = mean;
        }
        let ratio = mean / base;
        if k > 1 {
            worst_ratio = worst_ratio.max(ratio);
        }
        writeln!(out, "| {k} | {mean:.1} | {ratio:.3} | {logt:.1} |")?;
    }
    writeln!(
        out,
        "\nWorst degradation across intervals: {worst_ratio:.3}x — App. G predicts \
         at most a log T ≈ {logt:.1} factor; the observed degradation is far \
         below it (and far below the interval itself), validating the paper's \
         step-skipping configuration (preconditioner updates every 10 steps)."
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skipping_degrades_less_than_logt() {
        let r1: f64 = regret_for_interval(8, 1200, 1, 3);
        let r10: f64 = regret_for_interval(8, 1200, 10, 3);
        assert!(r1 > 0.0);
        let ratio: f64 = r10 / r1;
        let logt = (1200f64).ln();
        assert!(
            ratio < logt,
            "interval-10 regret degraded by {ratio:.2}x > log T = {logt:.1}"
        );
    }
}
