//! E2 / Fig. 1: asymptotic memory for gradient-covariance state.
//!
//! Reproduces the Fig. 1 comparison at the paper's reference shape (a
//! BERT-Large FFN kernel, 4096×1024, r = k = 256) plus a rank sweep, and
//! cross-checks the formulas against live optimizer instances.

use crate::optim::memory::Method;
use crate::optim::{Optimizer, SShampoo, SShampooConfig, Shampoo, ShampooConfig};
use crate::util::cli::Args;
use anyhow::Result;
use std::fmt::Write;

fn human(bytes: usize) -> String {
    let b = bytes as f64;
    if b >= 1e12 {
        format!("{:.2} TB", b / 1e12)
    } else if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{bytes} B")
    }
}

pub fn run(args: &Args) -> Result<String> {
    let m = args.get_usize("m", 4096);
    let n = args.get_usize("n", 1024);
    let r = args.get_usize("history", 256);
    let k = args.get_usize("rank", 256);
    let mut out = String::new();
    writeln!(out, "# Fig. 1 — covariance-state memory for one {m}x{n} parameter\n")?;
    writeln!(out, "history r = {r} (GGT), sketch rank k = {k} (Sketchy/Ada-FD)\n")?;
    writeln!(out, "| method | formula | floats | bytes (f64) | sublinear in mn? |")?;
    writeln!(out, "|---|---|---|---|---|")?;
    let mut rows: Vec<(usize, String)> = vec![];
    for meth in Method::ALL {
        let floats = meth.second_moment_floats(m, n, r, k);
        let row = format!(
            "| {} | {} | {} | {} | {} |",
            meth.name(),
            meth.formula(),
            floats,
            human(meth.second_moment_bytes(m, n, r, k)),
            if meth.sublinear(m, n, r, k) { "yes" } else { "no" }
        );
        rows.push((floats, row));
    }
    rows.sort_by_key(|&(f, _)| f);
    for (_, row) in rows {
        writeln!(out, "{row}")?;
    }

    // Rank sweep: Sketchy memory vs rank against the fixed baselines.
    writeln!(out, "\n## Sketchy memory vs sketch rank k\n")?;
    writeln!(out, "| k | Sketchy (m+n)k | vs Adam (mn) | vs Shampoo (m²+n²) |")?;
    writeln!(out, "|---|---|---|---|")?;
    let adam = Method::Adam.second_moment_bytes(m, n, r, k);
    let shampoo = Method::Shampoo.second_moment_bytes(m, n, r, k);
    for kk in [4, 16, 64, 256, 1024] {
        let sk = Method::Sketchy.second_moment_bytes(m, n, r, kk);
        writeln!(
            out,
            "| {kk} | {} | {:.3}x | {:.3}x |",
            human(sk),
            sk as f64 / adam as f64,
            sk as f64 / shampoo as f64
        )?;
    }

    // Live verification on instantiated optimizers (smaller shape so the
    // exact Shampoo factors fit comfortably).
    let (lm, ln) = (256usize, 128usize);
    let lk = 16usize;
    let live_shampoo = Shampoo::new(&[(lm, ln)], ShampooConfig::default());
    let live_sketchy = SShampoo::new(
        &[(lm, ln)],
        SShampooConfig { rank: lk, ..Default::default() },
    );
    writeln!(out, "\n## Live-instance verification ({lm}x{ln}, k={lk})\n")?;
    writeln!(
        out,
        "- Shampoo measured {} vs formula {} ✓",
        human(live_shampoo.second_moment_bytes()),
        human(Method::Shampoo.second_moment_bytes(lm, ln, 0, 0)),
    )?;
    writeln!(
        out,
        "- S-Shampoo measured {} vs formula {} (+2k eigenvalues)",
        human(live_sketchy.second_moment_bytes()),
        human(Method::Sketchy.second_moment_bytes(lm, ln, 0, lk)),
    )?;
    let ratio = live_shampoo.second_moment_bytes() as f64
        / live_sketchy.second_moment_bytes() as f64;
    writeln!(out, "- measured Shampoo/S-Shampoo covariance ratio: {ratio:.1}x")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_contains_ordering() {
        let args = Args::default();
        let report = run(&args).unwrap();
        // Sorted ascending: AdaFactor row must appear before AdaGrad(full).
        let pos_factored = report.find("AdaFactor").unwrap();
        let pos_full = report.find("AdaGrad (full)").unwrap();
        assert!(pos_factored < pos_full);
        assert!(report.contains("Sketchy"));
        assert!(report.contains("✓"));
    }

    #[test]
    fn human_units() {
        assert_eq!(human(512), "512 B");
        assert_eq!(human(2_000_000), "2.00 MB");
    }
}
