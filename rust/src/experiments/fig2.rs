//! E3 / Fig. 2: Adam vs Shampoo vs S-Shampoo on the three proxy DL tasks.
//!
//! Each (task, optimizer, seed) cell trains through the PJRT artifact
//! with the data-parallel coordinator and reports the held-out test
//! metric (classification error / multi-task error — the paper's
//! error-rate / WER / 1−AP analogues). The paper's claim under test:
//! S-Shampoo performs at least as well as Adam and close to Shampoo
//! while using sub-linear covariance memory.

use crate::optim::{
    Adam, GraftType, Optimizer, SShampoo, SShampooConfig, Shampoo, ShampooConfig,
    WarmupCosine,
};
use crate::runtime::Runtime;
use crate::train::{CurveLog, ProxyTask, ProxyTrainer};
use crate::util::cli::Args;
use anyhow::Result;
use std::fmt::Write;
use std::sync::Arc;

fn shampoo_cfg(lr: f64, steps: usize) -> ShampooConfig {
    ShampooConfig {
        lr,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-6,
        weight_decay: 1e-4,
        clip: 10.0,
        // Scaled from the paper's App. C values (start 101 / interval 10
        // at tens of thousands of steps) to these few-hundred-step runs.
        start_preconditioning_step: steps / 20 + 2,
        stat_interval: 2,
        precond_interval: 2,
        graft: GraftType::RmspropNormalized,
        one_sided: false,
    }
}

/// Build an optimizer by row name.
fn make_opt(
    name: &str,
    shapes: &[(usize, usize)],
    lr: f64,
    steps: usize,
    rank: usize,
) -> Box<dyn Optimizer> {
    match name {
        "Adam" => {
            let mut a = Adam::new(shapes, lr);
            a.weight_decay = 1e-4;
            a.clip = 10.0;
            Box::new(a)
        }
        "Shampoo" => Box::new(Shampoo::new(shapes, shampoo_cfg(lr, steps))),
        "S-Shampoo" => Box::new(SShampoo::new(
            shapes,
            SShampooConfig { base: shampoo_cfg(lr, steps), rank },
        )),
        _ => unreachable!(),
    }
}

pub struct CellResult {
    pub optimizer: String,
    pub final_metric: f64,
    pub metric_curve: CurveLog,
    pub train_curve: CurveLog,
    pub covariance_bytes: usize,
}

/// Train one (task, optimizer) cell.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    runtime: Arc<Runtime>,
    task: ProxyTask,
    opt_name: &str,
    steps: usize,
    workers: usize,
    lr: f64,
    rank: usize,
    seed: u64,
) -> Result<CellResult> {
    let mut trainer = ProxyTrainer::new(runtime, task, seed)?;
    let shapes = trainer.shapes.clone();
    let mut opt = make_opt(opt_name, &shapes, lr, steps, rank);
    let schedule = WarmupCosine { peak: lr, warmup: steps / 20 + 1, total: steps };
    let (train_curve, metric_curve) = trainer.train(
        opt.as_mut(),
        steps,
        workers,
        Some(schedule),
        (steps / 10).max(1),
        4,
        None,
    )?;
    Ok(CellResult {
        optimizer: opt_name.to_string(),
        final_metric: metric_curve.tail_mean(2),
        metric_curve,
        train_curve,
        covariance_bytes: opt.second_moment_bytes(),
    })
}

pub fn run(args: &Args) -> Result<String> {
    let runtime = Arc::new(Runtime::load(&args.get_or("artifacts", "artifacts"))?);
    let steps = args.get_usize("steps", 120);
    let workers = args.get_usize("workers", 2);
    let seeds = args.get_usize("seeds", if args.has("full") { 3 } else { 1 });
    let rank = args.get_usize("rank", 16);
    let tasks: Vec<ProxyTask> = match args.get("task") {
        Some("image") => vec![ProxyTask::Image],
        Some("audio") => vec![ProxyTask::Audio],
        Some("graph") => vec![ProxyTask::Graph],
        _ => vec![ProxyTask::Image, ProxyTask::Audio, ProxyTask::Graph],
    };
    let mut out = String::new();
    writeln!(out, "# Fig. 2 — proxy DL tasks ({steps} steps, {workers} workers, {seeds} seed(s), ℓ={rank})\n")?;
    for task in tasks {
        writeln!(out, "## task: {} (metric: {})\n", task.name(), task.metric_name())?;
        writeln!(out, "| optimizer | final metric (mean over seeds) | covariance bytes |")?;
        writeln!(out, "|---|---|---|")?;
        let lr = match task {
            ProxyTask::Image => 2e-3,
            ProxyTask::Audio => 2e-3,
            ProxyTask::Graph => 2e-3,
        };
        let mut finals: Vec<(String, f64)> = vec![];
        for opt_name in ["Adam", "Shampoo", "S-Shampoo"] {
            let mut metrics = vec![];
            let mut bytes = 0;
            for s in 0..seeds {
                let cell = run_cell(
                    runtime.clone(),
                    task,
                    opt_name,
                    steps,
                    workers,
                    lr,
                    rank,
                    100 + s as u64,
                )?;
                // Persist curves for the figure.
                let base = format!("reports/fig2_curves/{}_{}_s{s}", task.name(), opt_name);
                crate::train::metrics::write_report(
                    &format!("{base}_metric.csv"),
                    &cell.metric_curve.to_csv(),
                )?;
                crate::train::metrics::write_report(
                    &format!("{base}_train.csv"),
                    &cell.train_curve.to_csv(),
                )?;
                metrics.push(cell.final_metric);
                bytes = cell.covariance_bytes;
            }
            let mean = metrics.iter().sum::<f64>() / metrics.len() as f64;
            writeln!(out, "| {opt_name} | {mean:.4} | {bytes} |")?;
            finals.push((opt_name.to_string(), mean));
        }
        // The paper-shape checks.
        let get = |n: &str| finals.iter().find(|(m, _)| m == n).unwrap().1;
        let (adam, s_sh) = (get("Adam"), get("S-Shampoo"));
        writeln!(
            out,
            "\nS-Shampoo vs Adam: {} (paper: S-Shampoo at least as good on all tasks)\n",
            if s_sh <= adam + 0.02 { "**competitive or better** ✓" } else { "worse — see seeds/steps" }
        )?;
    }
    writeln!(out, "curves: reports/fig2_curves/*.csv")?;
    Ok(out)
}
