//! E3 / Fig. 2: Adam vs Shampoo vs S-Shampoo on the three proxy DL tasks.
//!
//! Each (task, optimizer, seed) cell trains through the PJRT artifact
//! with the data-parallel coordinator and reports the held-out test
//! metric (classification error / multi-task error — the paper's
//! error-rate / WER / 1−AP analogues). The paper's claim under test:
//! S-Shampoo performs at least as well as Adam and close to Shampoo
//! while using sub-linear covariance memory.
//!
//! Cells also accept the engine-* optimizer names (parallel blocked
//! preconditioner engine), with a bitwise engine ≡ fused pre-flight
//! before any engine curve is recorded, and `--refresh-sweep` records
//! the speedup-vs-quality trade at refresh intervals {4, 8, 16, 32}
//! (the EKFAC stretch story: pass `--ekfac` and the stretched
//! intervals hold quality).

use crate::optim::{
    engine_optimizer, Adam, EngineConfig, GraftType, Optimizer, SShampoo, SShampooConfig,
    Shampoo, ShampooConfig, WarmupCosine,
};
use crate::coordinator::Clock as _;
use crate::runtime::Runtime;
use crate::train::{CurveLog, ProxyTask, ProxyTrainer};
use crate::util::cli::Args;
use anyhow::{anyhow, bail, ensure, Result};
use std::fmt::Write;
use std::sync::Arc;

fn shampoo_cfg(lr: f64, steps: usize, ekfac: bool) -> ShampooConfig {
    ShampooConfig {
        lr,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-6,
        weight_decay: 1e-4,
        clip: 10.0,
        // Scaled from the paper's App. C values (start 101 / interval 10
        // at tens of thousands of steps) to these few-hundred-step runs.
        start_preconditioning_step: steps / 20 + 2,
        stat_interval: 2,
        precond_interval: 2,
        graft: GraftType::RmspropNormalized,
        one_sided: false,
        ekfac,
    }
}

/// Engine-side knobs for a cell. The legacy fused optimizers ignore
/// everything but `ekfac` (which reaches them through
/// [`ShampooConfig`], the shared switch).
#[derive(Clone, Copy, Debug)]
pub struct EngineKnobs {
    /// Eigendecomposition refresh cadence; `None` inherits the fused
    /// `precond_interval` so `shampoo` → `engine-shampoo` does not
    /// silently change refresh frequency.
    pub refresh_interval: Option<usize>,
    /// Spread refreshes across blocks (the production default).
    pub stagger: bool,
    /// EKFAC-style inter-refresh corrections.
    pub ekfac: bool,
}

impl Default for EngineKnobs {
    fn default() -> Self {
        EngineKnobs { refresh_interval: None, stagger: true, ekfac: false }
    }
}

/// The fused optimizer an engine-* name must reproduce bitwise under
/// the matched cadence (refresh = precond_interval, stagger off).
fn fused_counterpart(name: &str) -> Option<&'static str> {
    match name {
        "engine-adam" => Some("Adam"),
        "engine-shampoo" => Some("Shampoo"),
        "engine-s-shampoo" => Some("S-Shampoo"),
        _ => None,
    }
}

/// Build an optimizer by row name — legacy fused ("Adam", "Shampoo",
/// "S-Shampoo") or the engine family ("engine-adam", "engine-shampoo",
/// "engine-s-shampoo"). Unknown names are a named error, not a panic:
/// this is the construction path `--optimizer` reaches from the CLI.
fn make_opt(
    name: &str,
    shapes: &[(usize, usize)],
    lr: f64,
    steps: usize,
    rank: usize,
    knobs: EngineKnobs,
) -> Result<Box<dyn Optimizer>> {
    let base = shampoo_cfg(lr, steps, knobs.ekfac);
    Ok(match name {
        "Adam" => {
            let mut a = Adam::new(shapes, lr);
            a.weight_decay = 1e-4;
            a.clip = 10.0;
            Box::new(a)
        }
        "Shampoo" => Box::new(Shampoo::new(shapes, base)),
        "S-Shampoo" => Box::new(SShampoo::new(shapes, SShampooConfig { base, rank })),
        engine if engine.starts_with("engine-") => {
            let ecfg = EngineConfig {
                refresh_interval: knobs.refresh_interval.unwrap_or(base.precond_interval).max(1),
                stagger: knobs.stagger,
                ekfac: knobs.ekfac,
                ..EngineConfig::default()
            };
            let opt = engine_optimizer(engine, shapes, base, rank, ecfg)
                .ok_or_else(|| anyhow!("unknown optimizer {engine}"))?;
            Box::new(opt)
        }
        other => bail!("unknown optimizer {other} (fused or engine-* names)"),
    })
}

pub struct CellResult {
    pub optimizer: String,
    pub final_metric: f64,
    pub metric_curve: CurveLog,
    pub train_curve: CurveLog,
    pub covariance_bytes: usize,
    /// Wall-clock for the training loop (the refresh-sweep speedup axis).
    pub wall: std::time::Duration,
}

/// Engine ≡ fused pre-flight: before an engine-* cell's curves are
/// recorded, drive a short run of the engine *and* its fused
/// counterpart under the matched cadence (refresh on the fused
/// `precond_interval`, stagger off, same ekfac switch) over the same
/// seeded batch stream, and require bitwise-identical parameters. A
/// knob-plumbing regression fails here with a named error instead of
/// silently skewing a figure.
fn assert_engine_matches_fused(
    runtime: Arc<Runtime>,
    task: ProxyTask,
    name: &str,
    workers: usize,
    lr: f64,
    rank: usize,
    ekfac: bool,
    seed: u64,
) -> Result<()> {
    let fused = fused_counterpart(name)
        .ok_or_else(|| anyhow!("unknown engine optimizer {name}"))?;
    let steps = 40;
    let matched =
        EngineKnobs { refresh_interval: None, stagger: false, ekfac };
    let mut t_eng = ProxyTrainer::new(runtime.clone(), task, seed)?;
    let mut t_fus = ProxyTrainer::new(runtime, task, seed)?;
    let shapes = t_eng.shapes.clone();
    let mut eng = make_opt(name, &shapes, lr, steps, rank, matched)?;
    let mut fus = make_opt(fused, &shapes, lr, steps, rank, matched)?;
    let schedule = WarmupCosine { peak: lr, warmup: steps / 20 + 1, total: steps };
    t_eng.train(eng.as_mut(), steps, workers, Some(schedule), steps, 1, None)?;
    t_fus.train(fus.as_mut(), steps, workers, Some(schedule), steps, 1, None)?;
    for (i, (a, b)) in t_eng.params.iter().zip(&t_fus.params).enumerate() {
        ensure!(
            a.max_diff(b) == 0.0,
            "{name} diverged from {fused} on {} (tensor {i}, max diff {:.3e}) — \
             refusing to record engine curves",
            task.name(),
            a.max_diff(b)
        );
    }
    Ok(())
}

/// Train one (task, optimizer) cell. Engine-* cells run the bitwise
/// engine ≡ fused pre-flight first.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    runtime: Arc<Runtime>,
    task: ProxyTask,
    opt_name: &str,
    steps: usize,
    workers: usize,
    lr: f64,
    rank: usize,
    seed: u64,
    knobs: EngineKnobs,
) -> Result<CellResult> {
    if opt_name.starts_with("engine-") {
        assert_engine_matches_fused(
            runtime.clone(),
            task,
            opt_name,
            workers,
            lr,
            rank,
            knobs.ekfac,
            seed,
        )?;
    }
    let mut trainer = ProxyTrainer::new(runtime, task, seed)?;
    let shapes = trainer.shapes.clone();
    let mut opt = make_opt(opt_name, &shapes, lr, steps, rank, knobs)?;
    let schedule = WarmupCosine { peak: lr, warmup: steps / 20 + 1, total: steps };
    let wall_clock = crate::coordinator::SystemClock::new();
    let t0 = wall_clock.now();
    let (train_curve, metric_curve) = trainer.train(
        opt.as_mut(),
        steps,
        workers,
        Some(schedule),
        (steps / 10).max(1),
        4,
        None,
    )?;
    Ok(CellResult {
        optimizer: opt_name.to_string(),
        final_metric: metric_curve.tail_mean(2),
        metric_curve,
        train_curve,
        covariance_bytes: opt.second_moment_bytes(),
        wall: wall_clock.now().saturating_sub(t0),
    })
}

/// The stretched cadences the refresh sweep records (quality at 32 vs 4
/// is the EKFAC claim the bench gate also enforces).
const REFRESH_SWEEP: [usize; 4] = [4, 8, 16, 32];

pub fn run(args: &Args) -> Result<String> {
    let runtime = Arc::new(Runtime::load(&args.get_or("artifacts", "artifacts"))?);
    let steps = args.get_usize("steps", 120);
    let workers = args.get_usize("workers", 2);
    let seeds = args.get_usize("seeds", if args.has("full") { 3 } else { 1 });
    let rank = args.get_usize("rank", 16);
    let ekfac = args.get_bool("ekfac", false);
    let knobs = EngineKnobs {
        refresh_interval: args.get("refresh-interval").and_then(|s| s.parse().ok()),
        stagger: args.get_bool("stagger-refresh", true),
        ekfac,
    };
    // `--optimizer NAME` restricts the table to one row (fused or
    // engine-*); the CI experiment-smoke leg runs a single
    // engine-s-shampoo --ekfac cell this way.
    let opt_names: Vec<String> = match args.get("optimizer") {
        Some(name) => vec![name.to_string()],
        None => vec!["Adam".into(), "Shampoo".into(), "S-Shampoo".into()],
    };
    let tasks: Vec<ProxyTask> = match args.get("task") {
        Some("image") => vec![ProxyTask::Image],
        Some("audio") => vec![ProxyTask::Audio],
        Some("graph") => vec![ProxyTask::Graph],
        _ => vec![ProxyTask::Image, ProxyTask::Audio, ProxyTask::Graph],
    };
    let mut out = String::new();
    writeln!(out, "# Fig. 2 — proxy DL tasks ({steps} steps, {workers} workers, {seeds} seed(s), ℓ={rank}{})\n",
        if ekfac { ", ekfac" } else { "" })?;
    for task in tasks {
        writeln!(out, "## task: {} (metric: {})\n", task.name(), task.metric_name())?;
        writeln!(out, "| optimizer | final metric (mean over seeds) | covariance bytes |")?;
        writeln!(out, "|---|---|---|")?;
        let lr = match task {
            ProxyTask::Image => 2e-3,
            ProxyTask::Audio => 2e-3,
            ProxyTask::Graph => 2e-3,
        };
        let mut finals: Vec<(String, f64)> = vec![];
        for opt_name in &opt_names {
            let mut metrics = vec![];
            let mut bytes = 0;
            for s in 0..seeds {
                let cell = run_cell(
                    runtime.clone(),
                    task,
                    opt_name,
                    steps,
                    workers,
                    lr,
                    rank,
                    100 + s as u64,
                    knobs,
                )?;
                // Persist curves for the figure.
                let base = format!("reports/fig2_curves/{}_{}_s{s}", task.name(), opt_name);
                crate::train::metrics::write_report(
                    &format!("{base}_metric.csv"),
                    &cell.metric_curve.to_csv(),
                )?;
                crate::train::metrics::write_report(
                    &format!("{base}_train.csv"),
                    &cell.train_curve.to_csv(),
                )?;
                metrics.push(cell.final_metric);
                bytes = cell.covariance_bytes;
            }
            let mean = metrics.iter().sum::<f64>() / metrics.len() as f64;
            writeln!(out, "| {opt_name} | {mean:.4} | {bytes} |")?;
            finals.push((opt_name.to_string(), mean));
        }
        // The paper-shape checks (only meaningful over the full table).
        if let (Some(adam), Some(s_sh)) = (
            finals.iter().find(|(m, _)| m == "Adam").map(|r| r.1),
            finals.iter().find(|(m, _)| m == "S-Shampoo").map(|r| r.1),
        ) {
            writeln!(
                out,
                "\nS-Shampoo vs Adam: {} (paper: S-Shampoo at least as good on all tasks)\n",
                if s_sh <= adam + 0.02 { "**competitive or better** ✓" } else { "worse — see seeds/steps" }
            )?;
        }
        // `--refresh-sweep`: engine speedup-vs-quality curve over the
        // stretched refresh cadences. With --ekfac the stretched rows
        // should hold the interval-4 quality (the corrector claim).
        if args.get_bool("refresh-sweep", false) {
            let name = match args.get("optimizer") {
                Some(n) if n.starts_with("engine-") => n.to_string(),
                _ => "engine-s-shampoo".to_string(),
            };
            writeln!(out, "### refresh sweep: {name}{}\n", if ekfac { " + ekfac" } else { "" })?;
            writeln!(out, "| refresh interval | final metric | speedup vs interval 4 |")?;
            writeln!(out, "|---|---|---|")?;
            let mut sweep_csv = String::from("interval,final_metric,wall_secs\n");
            let mut base_wall = None;
            for interval in REFRESH_SWEEP {
                let cell = run_cell(
                    runtime.clone(),
                    task,
                    &name,
                    steps,
                    workers,
                    lr,
                    rank,
                    100,
                    EngineKnobs { refresh_interval: Some(interval), ..knobs },
                )?;
                let wall = cell.wall.as_secs_f64();
                let speedup = base_wall.get_or_insert(wall).max(1e-9) / wall.max(1e-9);
                writeln!(out, "| {interval} | {:.4} | {speedup:.2}x |", cell.final_metric)?;
                writeln!(sweep_csv, "{interval},{:.6},{wall:.4}", cell.final_metric)
                    .map_err(|e| anyhow!(e))?;
            }
            crate::train::metrics::write_report(
                &format!(
                    "reports/fig2_curves/{}_{}_refresh_sweep{}.csv",
                    task.name(),
                    name,
                    if ekfac { "_ekfac" } else { "" }
                ),
                &sweep_csv,
            )?;
            writeln!(out)?;
        }
    }
    writeln!(out, "curves: reports/fig2_curves/*.csv")?;
    Ok(out)
}
