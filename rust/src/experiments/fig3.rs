//! E4 / Fig. 3 + §5.2: spectral decay of the EMA Kronecker factors.
//!
//! During proxy training we track L_t = Σ β₂^{t-i} G_i G_iᵀ and
//! R_t = Σ β₂^{t-i} G_iᵀ G_i for the largest tensors and report the two
//! Fig. 3 measures over training: top-k spectral-mass fraction and
//! intrinsic dimension tr C / λmax. The §5.2 random-Wishart control
//! (intrinsic dim of EMA'd random covariances) establishes the
//! "emergent, not an EMA artifact" comparison.

use crate::optim::{Adam, WarmupCosine};
use crate::runtime::Runtime;
use crate::spectral::{intrinsic_dim, spectral_mass_topk, wishart_ema_intrinsic_dim, KronTracker};
use crate::train::{ProxyTask, ProxyTrainer};
use crate::util::cli::Args;
use anyhow::Result;
use std::fmt::Write;
use std::sync::Arc;

pub fn run(args: &Args) -> Result<String> {
    let runtime = Arc::new(Runtime::load(&args.get_or("artifacts", "artifacts"))?);
    let steps = args.get_usize("steps", 120);
    let workers = args.get_usize("workers", 2);
    let beta2 = args.get_f64("beta2", 0.999);
    let task = match args.get("task") {
        Some("audio") => ProxyTask::Audio,
        Some("graph") => ProxyTask::Graph,
        _ => ProxyTask::Image,
    };
    let seed = args.get_u64("seed", 21);
    let mut out = String::new();
    writeln!(out, "# Fig. 3 — spectral decay of EMA Kronecker factors (task={}, β₂={beta2})\n", task.name())?;

    let mut trainer = ProxyTrainer::new(runtime, task, seed)?;
    let shapes = trainer.shapes.clone();
    // Track the largest matrix tensor (the paper tracks the first layer's
    // 1024² factors; here the largest proxy kernel).
    let (tensor_idx, &(tm, tn)) = shapes
        .iter()
        .enumerate()
        .max_by_key(|(_, &(r, c))| r * c)
        .unwrap();
    writeln!(
        out,
        "tracked tensor #{tensor_idx} of shape {tm}x{tn} ({}): factors L {tm}x{tm}, R {tn}x{tn}\n",
        trainer.names[tensor_idx]
    )?;
    let mut tracker = KronTracker::new(tm, tn, beta2);
    let mut samples: Vec<(usize, f64, f64, f64, f64)> = vec![];
    {
        let sample_every = (steps / 8).max(1);
        let mut hook = |s: usize, grads: &[crate::tensor::Matrix]| {
            tracker.update(&grads[tensor_idx]);
            if s % sample_every == 0 || s + 1 == steps {
                let kl = (tm / 4).max(1);
                let kr = (tn / 4).max(1);
                samples.push((
                    s,
                    spectral_mass_topk(&tracker.l, kl),
                    intrinsic_dim(&tracker.l),
                    spectral_mass_topk(&tracker.r, kr),
                    intrinsic_dim(&tracker.r),
                ));
            }
        };
        let mut opt = Adam::new(&shapes, 2e-3);
        let schedule = WarmupCosine { peak: 2e-3, warmup: steps / 20 + 1, total: steps };
        trainer.train(
            &mut opt,
            steps,
            workers,
            Some(schedule),
            steps, // metric eval once at the end; this run is about spectra
            1,
            Some(&mut hook),
        )?;
    }
    writeln!(out, "| step | L top-{} mass | L intrinsic dim (of {tm}) | R top-{} mass | R intrinsic dim (of {tn}) |", (tm / 4).max(1), (tn / 4).max(1))?;
    writeln!(out, "|---|---|---|---|---|")?;
    let mut csv = String::from("step,l_mass,l_idim,r_mass,r_idim\n");
    for &(s, lm, li, rm, ri) in &samples {
        writeln!(out, "| {s} | {lm:.3} | {li:.1} | {rm:.3} | {ri:.1} |")?;
        let _ = writeln!(csv, "{s},{lm},{li},{rm},{ri}");
    }
    crate::train::metrics::write_report("reports/fig3_spectra.csv", &csv)?;

    // Paper-shape check: intrinsic dim well below nominal dimension.
    let last = samples.last().unwrap();
    let (li, ri) = (last.2, last.4);
    writeln!(
        out,
        "\nFinal intrinsic dims: L {li:.1}/{tm}, R {ri:.1}/{tn} — the paper \
         observes ≈10x smaller than nominal; here {:.1}x / {:.1}x.\n",
        tm as f64 / li,
        tn as f64 / ri
    )?;

    // §5.2 random-Wishart control, scaled (paper: dim=1024, n=10000,
    // β₂=0.999 → 324.63 (d=1) and 862.13 (d=64)).
    let (dim, n) = if args.has("full") { (1024, 10000) } else { (256, 1500) };
    let control_beta2 = if args.has("full") { 0.999 } else { 0.99 };
    writeln!(out, "## §5.2 random-Wishart control (dim={dim}, n={n}, β₂={control_beta2})\n")?;
    writeln!(out, "| d | intrinsic dim of EMA Wishart | fraction of nominal |")?;
    writeln!(out, "|---|---|---|")?;
    let mut control = vec![];
    for d in [1usize, 64] {
        let id = wishart_ema_intrinsic_dim(dim, d, n, control_beta2, 77 + d as u64);
        writeln!(out, "| {d} | {id:.1} | {:.2} |", id / dim as f64)?;
        control.push(id);
    }
    writeln!(
        out,
        "\nControl intrinsic dims ({:.0}, {:.0}) dwarf the trained factors' \
         ({li:.1}, {ri:.1}) — the fast decay in training covariance is an \
         emergent property of DL training, not an artifact of exponential \
         averaging (the §5.2 argument; paper values at dim=1024: 324.63 / 862.13).",
        control[0], control[1]
    )?;
    Ok(out)
}
