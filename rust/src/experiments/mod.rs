//! Experiment harness: one module per paper table/figure (DESIGN.md §4).
//!
//! Every experiment is runnable through the CLI (`sketchy repro <id>`)
//! and returns a markdown report which the CLI prints and writes under
//! `reports/`. Scaled-down defaults keep each run in seconds-to-minutes
//! on CPU; `--full` switches to paper-scale parameters where feasible.

pub mod appg;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod obs2;
pub mod rank_sweep;
pub mod tbl1;
pub mod tbl3;

use crate::util::cli::Args;
use anyhow::Result;

/// All experiment ids with one-line descriptions.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("tbl1", "Tbl.1: empirical regret vs theory bounds across methods and ranks"),
    ("fig1", "Fig.1: optimizer covariance-memory accounting"),
    ("fig2", "Fig.2: Adam vs Shampoo vs S-Shampoo on the three proxy DL tasks"),
    ("fig3", "Fig.3: spectral decay of EMA Kronecker factors + Wishart control"),
    ("tbl3", "Tbl.2/3 + Fig.4: online convex experiments, 6 algorithms x 3 datasets"),
    ("obs2", "Obs.2: Ada-FD Omega(T^{3/4}) bound growth vs S-AdaGrad"),
    ("appg", "App.G: Epoch AdaGrad step-skipping regret vs update interval"),
    ("rank_sweep", "§5.1: S-Shampoo quality/memory Pareto across sketch ranks"),
];

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &Args) -> Result<String> {
    let report = match id {
        "tbl1" => tbl1::run(args)?,
        "fig1" => fig1::run(args)?,
        "fig2" => fig2::run(args)?,
        "fig3" => fig3::run(args)?,
        "tbl3" => tbl3::run(args)?,
        "obs2" => obs2::run(args)?,
        "appg" => appg::run(args)?,
        "rank_sweep" => rank_sweep::run(args)?,
        other => anyhow::bail!(
            "unknown experiment {other}; known: {:?}",
            EXPERIMENTS.iter().map(|(n, _)| *n).collect::<Vec<_>>()
        ),
    };
    let path = format!("reports/{id}.md");
    crate::train::metrics::write_report(&path, &report)?;
    Ok(report)
}
