//! E7 / Observation 2: Ada-FD's regret bound grows as Ω(T^{3/4}) under
//! stochastic linear costs over r orthonormal directions, while
//! S-AdaGrad stays O(√T).
//!
//! Observation 2 is a statement about the *bound*, driven by the fact
//! that the escaped mass ρ_{1:T} grows linearly in T when ℓ ≤ r (each
//! new direction outside the sketch deflates a full unit of mass). We
//! therefore report three things per horizon T:
//!   1. measured ρ_{1:T} for the FD sketch (expected ≈ c·T),
//!   2. the Ada-FD bound value  η·tr G^{1/2}·max(1, (1+√ρ_{1:T})/δ) +
//!      (D²/2η)·Σ√ρ_t with η, δ tuned per T (expected slope ≈ 3/4),
//!   3. realized regret of both algorithms (with the S-AdaGrad bound
//!      slope ≈ 1/2 for reference).

use crate::data::synthetic::ObservationTwoStream;
use crate::oco::regret::fit_power_law;
use crate::optim::{AdaFd, SAdaGrad, VectorOptimizer};
use crate::util::cli::Args;
use anyhow::Result;
use std::fmt::Write;

struct RunStats {
    regret: f64,
    rho_sum: f64,
    sqrt_rho_sum: f64,
    tr_sqrt: f64,
}

/// Run one algorithm on the Obs. 2 stream for horizon T; returns stats.
/// `rho_of` extracts the sketch's cumulative escaped mass.
fn run_one<O: VectorOptimizer>(
    mut opt: O,
    rho_of: impl Fn(&O) -> f64,
    d: usize,
    r: usize,
    t: usize,
    seed: u64,
) -> RunStats {
    let mut stream = ObservationTwoStream::new(d, r, seed);
    let mut x = vec![0.0; d];
    let mut cum = 0.0;
    let mut gsum = vec![0.0; d];
    let mut cov = crate::tensor::Matrix::zeros(d, d);
    let mut sqrt_rho_sum = 0.0;
    let mut prev_rho = 0.0;
    for _ in 0..t {
        let g = stream.next_grad();
        cum += crate::tensor::dot(&g, &x);
        for i in 0..d {
            gsum[i] += g[i];
            for j in 0..d {
                cov[(i, j)] += g[i] * g[j];
            }
        }
        opt.step(&mut x, &g, Some(1.0));
        let rho = rho_of(&opt);
        sqrt_rho_sum += (rho - prev_rho).max(0.0).sqrt();
        prev_rho = rho;
    }
    let best = -crate::tensor::norm2(&gsum);
    let eig = crate::tensor::eigh(&cov);
    let tr_sqrt = eig.w.iter().map(|&w| w.max(0.0).sqrt()).sum();
    RunStats { regret: cum - best, rho_sum: rho_of(&opt), sqrt_rho_sum, tr_sqrt }
}

/// Ada-FD bound of Observation 2 / Wan & Zhang Thm. 1 at tuned η, δ:
/// min over a δ grid of  η tr(G½) max(1, (1+√ρ)/δ) + (D²/2η) Σ√ρ_t,
/// with η optimized in closed form (balancing the two terms).
fn ada_fd_bound(st: &RunStats) -> f64 {
    let d_sq = 4.0; // D² with D = 2 (unit-ball diameter)
    let mut best = f64::INFINITY;
    // Wide δ grid: the T^{3/4} rate needs δ allowed to grow with √ρ₁:T
    // (the max(1, ·) branch of Wan & Zhang's Thm. 1 saturating at 1).
    for k in 0..90 {
        let delta = 10f64.powf(-6.0 + 12.0 * k as f64 / 89.0);
        let a = st.tr_sqrt * (1.0f64).max((1.0 + st.rho_sum.sqrt()) / delta);
        let b = d_sq / 2.0 * st.sqrt_rho_sum;
        // min_η a·η + b/η = 2√(ab).
        let bound = 2.0 * (a * b).sqrt();
        if bound < best {
            best = bound;
        }
    }
    best
}

/// S-AdaGrad bound (Cor. 4): D(√2 tr G½ + √(d(d−ℓ)ρ/2)).
fn s_adagrad_bound(st: &RunStats, d: usize, ell: usize) -> f64 {
    2.0 * ((2.0f64).sqrt() * st.tr_sqrt
        + (d as f64 * (d - ell) as f64 * st.rho_sum / 2.0).sqrt())
}

pub fn run(args: &Args) -> Result<String> {
    let d = args.get_usize("d", 24);
    let r = args.get_usize("r", 12);
    let ell = args.get_usize("ell", 6);
    let seed = args.get_u64("seed", 5);
    let horizons: Vec<usize> = if args.has("full") {
        vec![500, 1000, 2000, 4000, 8000, 16000]
    } else {
        vec![250, 500, 1000, 2000, 4000]
    };
    let mut out = String::new();
    writeln!(out, "# Obs. 2 — Ada-FD Ω(T^{{3/4}}) vs S-AdaGrad O(√T)  (d={d}, r={r}, ℓ={ell})\n")?;
    writeln!(out, "| T | ρ₁:T (FD) | Ada-FD bound | Ada-FD regret | S-AdaGrad bound | S-AdaGrad regret |")?;
    writeln!(out, "|---|---|---|---|---|---|")?;
    let mut ts = vec![];
    let mut rho_series = vec![];
    let mut afd_bound_series = vec![];
    let mut afd_regret_series = vec![];
    let mut sag_bound_series = vec![];
    let mut sag_regret_series = vec![];
    for &t in &horizons {
        // Both algorithms run with (η, δ) tuned per horizon, as in the
        // Observation 2 statement ("where learning rate and δ are tuned").
        let afd = [0.05, 0.2, 0.5, 2.0]
            .iter()
            .flat_map(|&eta| {
                [1e-3, 1e-1, 1.0, 10.0, 100.0].map(move |delta| (eta, delta))
            })
            .map(|(eta, delta)| {
                run_one(
                    AdaFd::new(d, ell, eta, delta),
                    |o: &AdaFd| o.sketch().escaped_mass(),
                    d,
                    r,
                    t,
                    seed,
                )
            })
            .min_by(|a, b| a.regret.partial_cmp(&b.regret).unwrap())
            .unwrap();
        // S-AdaGrad runs at its theory step size η = D/√2 (Thm. 3) — no
        // tuning needed, which is itself part of the paper's story.
        let sag = run_one(
            SAdaGrad::new(d, ell, 2.0 / (2.0f64).sqrt()),
            |o: &SAdaGrad| o.sketch().escaped_mass(),
            d,
            r,
            t,
            seed ^ 1,
        );
        let afd_b = ada_fd_bound(&afd);
        let sag_b = s_adagrad_bound(&sag, d, ell);
        writeln!(
            out,
            "| {t} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} |",
            afd.rho_sum, afd_b, afd.regret, sag_b, sag.regret
        )?;
        ts.push(t as f64);
        rho_series.push(afd.rho_sum);
        afd_bound_series.push(afd_b);
        afd_regret_series.push(afd.regret.max(1e-9));
        sag_bound_series.push(sag_b);
        sag_regret_series.push(sag.regret.max(1e-9));
    }
    let (rho_slope, _) = fit_power_law(&ts, &rho_series);
    let (afd_slope, _) = fit_power_law(&ts, &afd_bound_series);
    let (sag_slope, _) = fit_power_law(&ts, &sag_bound_series);
    let (afd_reg_slope, _) = fit_power_law(&ts, &afd_regret_series);
    let (sag_reg_slope, _) = fit_power_law(&ts, &sag_regret_series);
    writeln!(out, "\n## Fitted growth exponents (log-log)\n")?;
    writeln!(out, "| quantity | exponent | paper prediction |")?;
    writeln!(out, "|---|---|---|")?;
    writeln!(out, "| escaped mass ρ₁:T | {rho_slope:.2} | 1.0 (linear; the Obs. 2 mechanism) |")?;
    writeln!(out, "| Ada-FD bound | {afd_slope:.2} | 0.75 |")?;
    writeln!(out, "| S-AdaGrad bound | {sag_slope:.2} | 0.5 |")?;
    writeln!(out, "| Ada-FD realized regret | {afd_reg_slope:.2} | grows faster than S-AdaGrad's |")?;
    writeln!(out, "| S-AdaGrad realized regret | {sag_reg_slope:.2} | ≈ 0.5 (noisy at small T) |")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaped_mass_grows_linearly_and_bounds_separate() {
        let mut args = Args::default();
        args.options.insert("d".into(), "12".into());
        args.options.insert("r".into(), "8".into());
        args.options.insert("ell".into(), "4".into());
        let report = run(&args).unwrap();
        // Extract exponent rows.
        let rho_line = report
            .lines()
            .find(|l| l.contains("escaped mass"))
            .unwrap()
            .to_string();
        let parse = |line: &str| -> f64 {
            line.split('|').nth(2).unwrap().trim().parse().unwrap()
        };
        let rho_slope = parse(&rho_line);
        assert!(
            (0.8..1.2).contains(&rho_slope),
            "escaped mass not linear: {rho_slope}\n{report}"
        );
        let afd = parse(report.lines().find(|l| l.starts_with("| Ada-FD bound")).unwrap());
        let sag = parse(report.lines().find(|l| l.starts_with("| S-AdaGrad bound")).unwrap());
        assert!(
            afd > sag + 0.15,
            "bound exponents failed to separate: afd={afd} sag={sag}\n{report}"
        );
    }
}
