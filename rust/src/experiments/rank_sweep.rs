//! E9 / §5.1: the rank Pareto — quality vs covariance memory as the FD
//! sketch rank ℓ varies.
//!
//! The paper's headline: "these results demonstrate a Pareto improvement
//! by using higher-rank approximations" (vs the rank-1 regime of
//! SM3/AdaFactor). We sweep ℓ on one proxy task and report final metric
//! together with covariance bytes; Adam and exact Shampoo anchor the two
//! ends of the tradeoff.

use super::fig2::{run_cell, EngineKnobs};
use crate::runtime::Runtime;
use crate::train::ProxyTask;
use crate::util::cli::Args;
use anyhow::Result;
use std::fmt::Write;
use std::sync::Arc;

pub fn run(args: &Args) -> Result<String> {
    let runtime = Arc::new(Runtime::load(&args.get_or("artifacts", "artifacts"))?);
    let steps = args.get_usize("steps", 120);
    let workers = args.get_usize("workers", 2);
    let seed = args.get_u64("seed", 300);
    let task = match args.get("task") {
        Some("audio") => ProxyTask::Audio,
        Some("graph") => ProxyTask::Graph,
        _ => ProxyTask::Image,
    };
    // `--engine` sweeps the blocked-engine optimizers instead of the
    // fused ones (bitwise pre-flight included in `run_cell`);
    // `--ekfac` / `--refresh-interval` ride along to the engine cells.
    let engine = args.get_bool("engine", false);
    let knobs = EngineKnobs {
        refresh_interval: args.get("refresh-interval").and_then(|s| s.parse().ok()),
        ekfac: args.get_bool("ekfac", false),
        ..EngineKnobs::default()
    };
    let lr = 2e-3;
    let mut out = String::new();
    writeln!(out, "# §5.1 rank sweep — S-Shampoo quality vs memory (task={}, {steps} steps{})\n",
        task.name(), if engine { ", engine" } else { "" })?;
    writeln!(out, "| optimizer | rank ℓ | final metric | covariance bytes |")?;
    writeln!(out, "|---|---|---|---|")?;
    let mut rows = vec![];
    for (fused_name, rank) in [
        ("Adam", 0usize),
        ("S-Shampoo", 2),
        ("S-Shampoo", 4),
        ("S-Shampoo", 8),
        ("S-Shampoo", 16),
        ("S-Shampoo", 32),
        ("Shampoo", 0),
    ] {
        let name = match (engine, fused_name) {
            (false, n) => n.to_string(),
            (true, "Adam") => "engine-adam".to_string(),
            (true, "Shampoo") => "engine-shampoo".to_string(),
            (true, _) => "engine-s-shampoo".to_string(),
        };
        let cell =
            run_cell(runtime.clone(), task, &name, steps, workers, lr, rank.max(1), seed, knobs)?;
        writeln!(
            out,
            "| {name} | {} | {:.4} | {} |",
            if fused_name == "S-Shampoo" { rank.to_string() } else { "—".into() },
            cell.final_metric,
            cell.covariance_bytes
        )?;
        rows.push((fused_name.to_string(), rank, cell.final_metric, cell.covariance_bytes));
    }
    // Pareto check: higher rank should not cost memory beyond Shampoo and
    // should (weakly) improve quality on average.
    let s_rows: Vec<&(String, usize, f64, usize)> =
        rows.iter().filter(|r| r.0 == "S-Shampoo").collect();
    let low = s_rows.first().unwrap().2;
    let high = s_rows.last().unwrap().2;
    writeln!(
        out,
        "\nS-Shampoo metric at ℓ={}: {low:.4} → ℓ={}: {high:.4} ({}).",
        s_rows.first().unwrap().1,
        s_rows.last().unwrap().1,
        if high <= low + 0.02 { "higher rank helps or matches — the Pareto claim" } else { "noisy at this scale; increase --steps" }
    )?;
    Ok(out)
}
