//! E1 / Tbl. 1: empirical regret against the theory bounds.
//!
//! Synthetic OCO instance with controlled covariance decay: linear losses
//! with gradients g_t = Σ_i c_i s_i w_i, s_i = i^{-α}, on the unit ball.
//! For each method we measure realized regret at horizon T and evaluate
//! the paper's bound expressions; the table verifies (a) every realized
//! regret is below its bound, (b) the S-AdaGrad bound tightens toward
//! full-matrix AdaGrad as ℓ grows (the Tbl. 1 story).

use crate::oco::losses::LinearLoss;
use crate::oco::OnlineLoss;
use crate::optim::{AdaFd, AdaGradDiag, AdaGradFull, FdSon, Ogd, SAdaGrad, VectorOptimizer};
use crate::tensor::{eigh, Matrix};
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::fmt::Write;

/// Generate the gradient stream and its exact covariance eigenvalues.
fn make_stream(d: usize, t: usize, alpha: f64, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let dirs = crate::tensor::random_orthonormal(d, d, &mut rng);
    let scales: Vec<f64> = (0..d).map(|i| (1.0 + i as f64).powf(-alpha)).collect();
    let mut grads = Vec::with_capacity(t);
    let mut cov = Matrix::zeros(d, d);
    for _ in 0..t {
        let mut g = vec![0.0; d];
        for i in 0..d {
            let c = rng.gaussian() * scales[i];
            for j in 0..d {
                g[j] += c * dirs[(j, i)];
            }
        }
        for i in 0..d {
            for j in 0..d {
                cov[(i, j)] += g[i] * g[j];
            }
        }
        grads.push(g);
    }
    let eigs = eigh(&cov).w;
    (grads, eigs)
}

/// Realized regret of an optimizer on the linear-loss stream over the
/// unit ball: Σ⟨g_t, x_t⟩ − min_{‖x‖≤1} ⟨Σg, x⟩.
fn realized_regret(opt: &mut dyn VectorOptimizer, grads: &[Vec<f64>], d: usize) -> f64 {
    let mut x = vec![0.0; d];
    let mut cum = 0.0;
    let mut gsum = vec![0.0; d];
    for g in grads {
        let loss = LinearLoss { g: g.clone() };
        cum += loss.loss(&x);
        for i in 0..d {
            gsum[i] += g[i];
        }
        opt.step(&mut x, g, Some(1.0));
    }
    let best = -crate::tensor::norm2(&gsum);
    cum - best
}

/// Bound expressions (D = 2 = ball diameter, per Tbl. 1 / Thm. 3 / Cor. 4).
fn tr_sqrt(eigs: &[f64]) -> f64 {
    eigs.iter().map(|&w| w.max(0.0).sqrt()).sum()
}

fn omega_ell(eigs: &[f64], ell: usize) -> f64 {
    // Ω_ℓ = min_{k<ℓ} (ℓ−k)⁻¹ Σ_{i>k} λ_i.
    let d = eigs.len();
    let mut best = f64::INFINITY;
    let suffix: Vec<f64> = {
        let mut s = vec![0.0; d + 1];
        for i in (0..d).rev() {
            s[i] = s[i + 1] + eigs[i].max(0.0);
        }
        s
    };
    for k in 0..ell {
        let val = suffix[k + 1] / (ell - k) as f64;
        if val < best {
            best = val;
        }
    }
    best
}

fn s_adagrad_bound(eigs: &[f64], ell: usize, d: usize) -> f64 {
    let dd = 2.0; // diameter of the unit ball
    dd * ((2.0f64).sqrt() * tr_sqrt(eigs)
        + (d as f64 * (d - ell) as f64 * omega_ell(eigs, ell) / 2.0).sqrt())
}

fn full_adagrad_bound(eigs: &[f64]) -> f64 {
    2.0 * (2.0f64).sqrt() * tr_sqrt(eigs)
}

pub fn run(args: &Args) -> Result<String> {
    let d = args.get_usize("d", 48);
    let t = args.get_usize("t", 1500);
    let alpha = args.get_f64("alpha", 1.5);
    let seed = args.get_u64("seed", 1);
    let (grads, eigs) = make_stream(d, t, alpha, seed);
    let mut out = String::new();
    writeln!(out, "# Tbl. 1 — regret vs bounds (d={d}, T={t}, spectral decay α={alpha})\n")?;
    writeln!(
        out,
        "covariance spectrum: λ₁={:.1}, λ_d={:.2e}, tr G^(1/2)={:.1}\n",
        eigs[0],
        eigs[d - 1],
        tr_sqrt(&eigs)
    )?;
    writeln!(out, "| method | memory (floats) | realized regret | bound | regret ≤ bound |")?;
    writeln!(out, "|---|---|---|---|---|")?;

    // Full-matrix AdaGrad (the d² reference of Tbl. 1).
    let lr = 2.0f64 / 2.0f64.sqrt(); // η = D/√2
    {
        let mut opt = AdaGradFull::new(d, lr);
        let mem = opt.mem_bytes() / 8;
        let r = realized_regret(&mut opt, &grads, d);
        let b = full_adagrad_bound(&eigs);
        writeln!(out, "| AdaGrad (full) | {mem} | {r:.1} | {b:.1} | {} |",
                 if r <= b { "yes" } else { "NO" })?;
    }
    // S-AdaGrad across ranks: the Tbl. 1 row "this paper".
    let mut bounds = vec![];
    for ell in [4usize, 8, 16, 32].into_iter().filter(|&e| e < d) {
        let mut opt = SAdaGrad::new(d, ell, lr);
        let mem = opt.mem_bytes() / 8;
        let r = realized_regret(&mut opt, &grads, d);
        let b = s_adagrad_bound(&eigs, ell, d);
        bounds.push((ell, b));
        writeln!(out, "| S-AdaGrad ℓ={ell} | {mem} | {r:.1} | {b:.1} | {} |",
                 if r <= b { "yes" } else { "NO" })?;
    }
    // Baselines (no matching additive bound; realized regret only).
    {
        let mut opt = AdaGradDiag::new(d, lr);
        let mem = opt.mem_bytes() / 8;
        let r = realized_regret(&mut opt, &grads, d);
        writeln!(out, "| AdaGrad (diag) | {mem} | {r:.1} | — | — |")?;
    }
    {
        let mut opt = Ogd::new(lr, true);
        let r = realized_regret(&mut opt, &grads, d);
        writeln!(out, "| OGD | 1 | {r:.1} | — | — |")?;
    }
    {
        let mut opt = AdaFd::new(d, 16, lr, 1e-3);
        let mem = opt.mem_bytes() / 8;
        let r = realized_regret(&mut opt, &grads, d);
        writeln!(out, "| Ada-FD ℓ=16 | {mem} | {r:.1} | Ω(T^{{3/4}}) (Obs. 2) | — |")?;
    }
    {
        let mut opt = FdSon::new(d, 16, lr, 1.0);
        let mem = opt.mem_bytes() / 8;
        let r = realized_regret(&mut opt, &grads, d);
        writeln!(out, "| FD-SON ℓ=16 | {mem} | {r:.1} | √(ℓ λ_{{ℓ:d}} T) | — |")?;
    }
    // Bound-tightening check (the Tbl. 1 interpolation claim).
    writeln!(out, "\n## S-AdaGrad bound vs rank (interpolation toward full-matrix)\n")?;
    writeln!(out, "| ℓ | bound | gap to full-matrix bound |")?;
    writeln!(out, "|---|---|---|")?;
    let fb = full_adagrad_bound(&eigs);
    for (ell, b) in &bounds {
        writeln!(out, "| {ell} | {b:.1} | {:.1} |", b - fb)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omega_ell_decreases_with_rank() {
        let eigs: Vec<f64> = (0..16).map(|i| 1.0 / (1 + i) as f64).collect();
        let o4 = omega_ell(&eigs, 4);
        let o8 = omega_ell(&eigs, 8);
        assert!(o8 < o4);
        assert!(o4 > 0.0);
    }

    #[test]
    fn small_run_bounds_hold() {
        let mut args = Args::default();
        args.options.insert("d".into(), "16".into());
        args.options.insert("t".into(), "300".into());
        let report = run(&args).unwrap();
        assert!(!report.contains("| NO |"), "a bound was violated:\n{report}");
        assert!(report.contains("S-AdaGrad ℓ=8"));
    }
}
