//! E5+E6 / Tbl. 2, Tbl. 3, Fig. 4: the online convex experiments.
//!
//! Six algorithms (S-AdaGrad, AdaGrad, OGD, Ada-FD, FD-SON, RFD-SON) on
//! three logistic streams shaped like the paper's LIBSVM datasets
//! (synthetic stand-ins by default — DESIGN.md §6; `--libsvm DIR` loads
//! the real files). η (and δ for the δ>0 methods) tuned on a log grid as
//! in App. A; sketch size fixed to 10; single online pass; metric =
//! average cumulative loss. Fig. 4 curves land in reports/tbl3_curves/.

use crate::data::synthetic::{DatasetKind, SyntheticLogistic};
use crate::oco::losses::LogisticLoss;
use crate::oco::runner::{run_online, OnlineResult};
use crate::oco::OnlineLoss;
use crate::optim::{AdaFd, AdaGradDiag, FdSon, Ogd, RfdSon, SAdaGrad, VectorOptimizer};
use crate::util::cli::Args;
use anyhow::Result;
use std::fmt::Write;

const SKETCH: usize = 10;

/// Data source: synthetic stream or materialized LIBSVM rows.
enum Source {
    Synth(SyntheticLogistic),
    Real(Vec<Vec<f64>>, Vec<f64>),
}

impl Source {
    fn n(&self) -> usize {
        match self {
            Source::Synth(s) => s.n,
            Source::Real(f, _) => f.len(),
        }
    }

    fn d(&self) -> usize {
        match self {
            Source::Synth(s) => s.d,
            Source::Real(f, _) => f[0].len(),
        }
    }

    fn run(&self, opt: &mut dyn VectorOptimizer, samples: usize) -> OnlineResult {
        match self {
            Source::Synth(s) => {
                let mut stream = s.iter().map(|(f, y)| {
                    Box::new(LogisticLoss { features: f, label: y }) as Box<dyn OnlineLoss>
                });
                run_online(opt, &mut stream, s.d, None, samples)
            }
            Source::Real(feats, labels) => {
                let d = feats[0].len();
                let mut stream = feats.iter().zip(labels).map(|(f, &y)| {
                    Box::new(LogisticLoss { features: f.clone(), label: y })
                        as Box<dyn OnlineLoss>
                });
                run_online(opt, &mut stream, d, None, samples)
            }
        }
    }
}

/// Build an optimizer by name with the given η, δ.
fn make_opt(name: &str, d: usize, lr: f64, delta: f64) -> Box<dyn VectorOptimizer> {
    match name {
        "S-AdaGrad" => Box::new(SAdaGrad::new(d, SKETCH, lr)),
        "AdaGrad" => Box::new(AdaGradDiag::new(d, lr)),
        "OGD" => Box::new(Ogd::new(lr, true)),
        "Ada-FD" => Box::new(AdaFd::new(d, SKETCH, lr, delta)),
        "FD-SON" => Box::new(FdSon::new(d, SKETCH, lr, delta)),
        "RFD-SON" => Box::new(RfdSon::new(d, SKETCH, lr, 0.0)),
        _ => unreachable!(),
    }
}

/// Needs a δ grid? (App. A: only the fixed-δ methods.)
fn has_delta(name: &str) -> bool {
    matches!(name, "Ada-FD" | "FD-SON")
}

const ALGOS: [&str; 6] = ["S-AdaGrad", "AdaGrad", "OGD", "Ada-FD", "FD-SON", "RFD-SON"];

/// Log-spaced grid over [lo, hi].
fn log_grid(lo: f64, hi: f64, points: usize) -> Vec<f64> {
    (0..points)
        .map(|i| {
            let f = i as f64 / (points - 1).max(1) as f64;
            (lo.ln() + f * (hi.ln() - lo.ln())).exp()
        })
        .collect()
}

pub fn run(args: &Args) -> Result<String> {
    let full = args.has("full");
    let trials = args.get_usize("trials", if full { 49 } else { 7 });
    let seed = args.get_u64("seed", 17);
    let mut out = String::new();
    writeln!(out, "# Tbl. 2/3 + Fig. 4 — online convex experiments\n")?;
    writeln!(out, "sketch size = {SKETCH}, η grid points = {trials}\n")?;
    writeln!(out, "## Tbl. 2 — dataset shapes\n")?;
    writeln!(out, "| dataset | examples | features | source |")?;
    writeln!(out, "|---|---|---|---|")?;
    let mut sources: Vec<(String, Source)> = vec![];
    for kind in [DatasetKind::Gisette, DatasetKind::A9a, DatasetKind::Cifar10] {
        let source = if let Some(dir) = args.get("libsvm") {
            let fname = match kind {
                DatasetKind::Gisette => "gisette_scale",
                DatasetKind::A9a => "a9a",
                DatasetKind::Cifar10 => "cifar10",
            };
            let path = std::path::Path::new(dir).join(fname);
            let text = std::fs::read_to_string(&path)?;
            let data = crate::data::libsvm::parse_libsvm(&text, 0)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            Source::Real(data.features, data.labels)
        } else if full {
            Source::Synth(SyntheticLogistic::new(kind, seed))
        } else {
            // Scaled-down stand-ins with the same aspect (DESIGN.md §6).
            let (n, d) = kind.shape();
            Source::Synth(SyntheticLogistic::with_size(kind, n / 10, (d / 5).max(40), seed))
        };
        writeln!(
            out,
            "| {} | {} | {} | {} |",
            kind.name(),
            source.n(),
            source.d(),
            if args.get("libsvm").is_some() { "LIBSVM" } else { "synthetic" }
        )?;
        sources.push((kind.name().to_string(), source));
    }

    writeln!(out, "\n## Tbl. 3 — average cumulative online loss (ranked)\n")?;
    let eta_grid = log_grid(1e-4, 1.0, trials);
    let delta_grid = log_grid(1e-6, 1.0, 7);
    for (ds_name, source) in &sources {
        let d = source.d();
        let mut results: Vec<(String, f64, OnlineResult)> = vec![];
        for algo in ALGOS {
            let mut best: Option<(f64, OnlineResult)> = None;
            let deltas: Vec<f64> = if has_delta(algo) {
                delta_grid.clone()
            } else {
                vec![0.0]
            };
            for &delta in &deltas {
                for &eta in &eta_grid {
                    let mut opt = make_opt(algo, d, eta, delta);
                    let res = source.run(opt.as_mut(), 50);
                    let avg = res.total_loss / source.n() as f64;
                    if avg.is_finite()
                        && best.as_ref().map(|(b, _)| avg < *b).unwrap_or(true)
                    {
                        best = Some((avg, res));
                    }
                }
            }
            let (avg, res) = best.unwrap();
            results.push((algo.to_string(), avg, res));
        }
        results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        writeln!(out, "### {ds_name}\n")?;
        writeln!(out, "| place | algorithm | avg loss |")?;
        writeln!(out, "|---|---|---|")?;
        for (place, (name, avg, res)) in results.iter().enumerate() {
            writeln!(out, "| {} | {}{} | {:.4} |",
                place + 1,
                name,
                if name == "S-AdaGrad" { " **(ours)**" } else { "" },
                avg
            )?;
            // Fig. 4 curve CSVs.
            let mut csv = String::from("t,avg_cum_loss\n");
            for &(t, v) in &res.curve {
                let _ = writeln!(csv, "{t},{v}");
            }
            let path = format!("reports/tbl3_curves/{ds_name}_{name}.csv");
            crate::train::metrics::write_report(&path, &csv)?;
        }
        // Paper-shape check: S-AdaGrad should place in the top 3.
        let s_place = results
            .iter()
            .position(|(n, _, _)| n == "S-AdaGrad")
            .unwrap()
            + 1;
        writeln!(
            out,
            "\nS-AdaGrad placed **{s_place}** (paper: top-3 on all datasets).\n"
        )?;
    }
    writeln!(out, "Fig. 4 curves written to reports/tbl3_curves/*.csv")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_tbl3_runs_and_ranks() {
        // Minimal shapes to keep the unit test fast; the real experiment
        // runs through the CLI / integration test.
        let source = Source::Synth(SyntheticLogistic::with_size(
            DatasetKind::A9a,
            300,
            30,
            3,
        ));
        let mut opt = SAdaGrad::new(30, SKETCH, 0.3);
        let res = source.run(&mut opt, 10);
        assert!(res.total_loss.is_finite());
        assert!(res.total_loss / 300.0 < (2f64).ln());
    }

    #[test]
    fn log_grid_spans_range() {
        let g = log_grid(1e-4, 1.0, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 1e-4).abs() < 1e-12);
        assert!((g[4] - 1.0).abs() < 1e-12);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }
}
