//! # Sketchy
//!
//! A production-shaped reproduction of *Sketchy: Memory-efficient Adaptive
//! Regularization with Frequent Directions* (Feinberg, Chen, Sun, Anil,
//! Hazan — NeurIPS 2023), built as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the runtime coordinator: the Sketchy optimizer
//!   family (S-AdaGrad, S-Shampoo and all paper baselines), the Frequent
//!   Directions sketch substrate, a dense linear-algebra substrate, an
//!   online-convex-optimization harness, a data-parallel training
//!   coordinator, and the experiment harness reproducing every table and
//!   figure in the paper.
//! - **L2 (python/compile)** — JAX compute graphs (transformer LM and the
//!   three Fig. 2 proxy models) AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels)** — Pallas kernels for the optimizer's
//!   compute hot-spots, validated against pure-jnp oracles.
//!
//! Python never runs on the training path: artifacts are compiled once by
//! `make artifacts` and executed from Rust through PJRT (`runtime`).

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod oco;
pub mod optim;
pub mod runtime;
pub mod sketch;
pub mod spectral;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
