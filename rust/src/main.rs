//! `sketchy` — launcher CLI for the Sketchy reproduction.
//!
//! Subcommands:
//!   repro <experiment> [--flags]   reproduce a paper table/figure
//!   train [--preset small ...]     end-to-end LM training (E10)
//!   list                           list experiments and artifacts
//!   info                           environment / artifact summary
//!   lint [PATH]                    repo-invariant static analysis
//!
//! Examples:
//!   sketchy list
//!   sketchy repro tbl3 --trials 7
//!   sketchy repro fig2 --task image --steps 200
//!   sketchy train --preset small --steps 300 --optimizer s-shampoo

use anyhow::Context as _;
use sketchy::coordinator::Clock as _;
use sketchy::experiments;
use sketchy::util::cli::Args;

const USAGE: &str = "\
sketchy — Sketchy: Memory-efficient Adaptive Regularization with Frequent
Directions (NeurIPS 2023) — Rust + JAX + Pallas reproduction.

USAGE:
  sketchy list
  sketchy info [--artifacts DIR]
  sketchy repro <experiment> [--seed N] [--full] [experiment flags]
  sketchy train [--preset tiny|small|base] [--steps N] [--workers N]
                [--optimizer adam|shampoo|s-shampoo
                             |engine-adam|engine-shampoo|engine-s-shampoo]
                [--rank L] [--lr F] [--checkpoint PATH] [--resume PATH]
                [--engine-threads N] [--block-size B]
                [--refresh-interval K] [--stagger-refresh BOOL]
                [--overlap-refresh BOOL] [--pool-threads N]
                [--ekfac BOOL]
                [--shards N] [--shard-transport tcp|unix]
                [--shard-proto V] [--shard-compress BOOL]
                [--shard-launch TEMPLATE]
                [--shard-spares N] [--rebalance BOOL]
                [--shard-failover-budget K]
                [--shard-connect-timeout-ms MS] [--shard-reply-timeout-ms MS]
                [--shard-heartbeat-ms MS] [--shard-deadline-ms MS]
                [--journal PATH] [--resume-journal PATH]
                [--crash-at-step K[,K...]]   (test harness: abort after
                                              the listed steps)
  sketchy bench-gate [--baseline F] [--current F] [--tolerance R]
  sketchy lint [--fix-allowlist] [PATH]
  sketchy shard-worker --worker-id N [--transport tcp|unix]
                       [--socket-dir DIR] [--proto-version V]
                       [--listen ADDR] [--advertise-host HOST]
                                                   (internal; spawned
                                                    by --shards runs)

The engine-* optimizers run the parallel blocked preconditioner engine:
per-block statistics/root updates execute concurrently on a persistent
worker pool (pre-sized with --pool-threads; grows on demand otherwise),
with inverse-root (eigendecomposition) refreshes amortized every
--refresh-interval steps and staggered across blocks.
--overlap-refresh pipelines those refreshes: the eigendecompositions
due at step t+1 run in the background while the trainer computes step
t+1's gradients — bitwise identical to the synchronous schedule. With
--shards N the blocks are partitioned across N worker processes (same
binary, localhost TCP or Unix sockets) — bitwise identical to the
in-process engine. Overlap composes with sharding: the t+1 due-set
ships to each worker as a second in-flight RefreshAhead RPC so remote
eigendecompositions also hide behind gradient computation; workers
pinned to the legacy wire protocol (--shard-proto 1) report no such
capability at handshake and the run degrades to synchronous refresh
with a logged notice. From wire protocol v3 the shard links negotiate
delta-compressed block payloads (--shard-compress, default on): each
step ships only the XOR of block bits against the last acked step,
RLE-compressed — bit-lossless, so runs stay bitwise identical while
cross-host traffic shrinks. --shard-launch lifts worker spawning onto
remote hosts via a command template (placeholders {shard}, {program},
{worker_cmd}; e.g. "ssh worker-{shard} /opt/sketchy {worker_cmd}
--listen 0.0.0.0:0 --advertise-host worker-{shard}"); workers pinned
to v2/v1 degrade to uncompressed full frames. From wire protocol v4
(the default) block optimizer state ships in factored form — FD
sketches as rank-L bases + eigenvalues + an escaped-mass scalar, O(dL)
instead of O(d^2) — over the StateSnap/StateRestore RPCs; --checkpoint
embeds that same typed state (checkpoint v2) and --resume restores it,
so a resumed run continues bitwise where the saved one stopped.
Workers pinned to v3 or below keep stepping, but state RPCs are
refused and checkpoints degrade to params only. --shard-spares N keeps
N warm spare workers on standby and turns the fleet elastic (wire
protocol v5): when a worker dies mid-run the driver re-seats its
blocks on a spare from the last synced snapshot, replays the journaled
steps since (at most --shard-failover-budget of them), and the run
continues bitwise identical to an uninterrupted one — refresh
accounting included. --rebalance additionally lets the driver migrate
blocks between live workers at sync points when per-shard step
latencies drift apart; migrations reuse the same deterministic
snapshot/restore path, so numbers never change. Wire protocol v6 adds
driver-side heartbeat supervision to elastic fleets: the driver probes
idle links with Ping every --shard-heartbeat-ms and a worker silent
past --shard-deadline-ms is killed and replaced through the same
spare-adoption path — a *hung* worker (connection up, replies never
arriving) no longer stalls the run until the --shard-reply-timeout-ms
bound. --ekfac turns on EKFAC-style inter-refresh corrections (wire
protocol v7): between eigendecompositions every block folds each
step's gradient second moments into a corrected diagonal in its stale
eigenbasis (FD-sketched blocks: over the rank-L basis plus an
escaped-mass tail) and preconditions with those scales instead of the
frozen eigenvalues, so --refresh-interval stretches 4 -> 32+ without
quality loss — still bitwise identical across threads, shards,
overlap, and crash-resume. Corrector state rides the typed
StateSnap/StateRestore payloads and checkpoints; a fleet with any
worker pinned below v7 is refused at launch rather than silently
dropping the correction. --journal PATH makes the *driver* itself crash-safe: sync-point
snapshots (params + typed sketch-factor optimizer state, never dense
covariance) and a write-ahead record of every step since are fsynced
to PATH, so a killed driver relaunched with --resume-journal PATH
re-adopts surviving workers (or spawns fresh ones), restores the last
sync point, replays at most --shard-failover-budget journaled steps,
and continues bitwise identical to an uninterrupted run. bench-gate
compares a fresh engine bench record against the committed baseline
and exits nonzero on a >tolerance regression (and on *_max ceiling
overruns, e.g. the shard migration / driver-resume replay bounds).
lint runs the repo-invariant static analyzer over PATH (default `.`):
determinism rules (no raw wall-clock/entropy outside the supervise.rs
Clock; no HashMap/HashSet in the deterministic core), wire-protocol
registry rules (unique tags, encode+decode+test coverage, degrade-
matrix coverage of PROTO_VERSION), decode-path allocation bounds, and
config-key registry/README consistency — exit 0 clean, 1 on
violations; audited exceptions live in rust/lint_allow.txt and
--fix-allowlist appends TODO-justified entries for review.

Run `sketchy list` for the experiment catalogue.";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("list") => cmd_list(),
        Some("info") => cmd_info(&args),
        Some("repro") => cmd_repro(&args),
        Some("train") => cmd_train(&args),
        Some("bench-gate") => cmd_bench_gate(&args),
        Some("lint") => cmd_lint(&args),
        Some("shard-worker") => cmd_shard_worker(&args),
        _ => {
            println!("{USAGE}");
            if args.subcommand.is_some() {
                eprintln!("\nunknown subcommand: {:?}", args.subcommand);
                1
            } else {
                0
            }
        }
    };
    std::process::exit(code);
}

fn cmd_list() -> i32 {
    println!("experiments (sketchy repro <id>):");
    for (id, desc) in experiments::EXPERIMENTS {
        println!("  {id:<12} {desc}");
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    println!("sketchy v{}", sketchy::VERSION);
    println!("threads: {}", sketchy::tensor::ops::num_threads());
    let dir = args.get_or("artifacts", "artifacts");
    match sketchy::runtime::Runtime::load(&dir) {
        Ok(rt) => {
            println!("artifacts ({dir}):");
            for name in rt.names() {
                let spec = rt.spec(&name).unwrap();
                println!(
                    "  {name:<24} {} inputs ({} params), {} outputs",
                    spec.inputs.len(),
                    spec.n_params,
                    spec.n_outputs
                );
            }
            0
        }
        Err(e) => {
            eprintln!("no artifacts: {e}");
            1
        }
    }
}

fn cmd_repro(args: &Args) -> i32 {
    let Some(id) = args.positional.first() else {
        eprintln!("usage: sketchy repro <experiment>; see `sketchy list`");
        return 1;
    };
    let clock = sketchy::coordinator::SystemClock::new();
    let t0 = clock.now();
    match experiments::run(id, args) {
        Ok(report) => {
            println!("{report}");
            println!(
                "\n[report written to reports/{id}.md in {:?}]",
                clock.now().saturating_sub(t0)
            );
            0
        }
        Err(e) => {
            eprintln!("experiment {id} failed: {e:#}");
            1
        }
    }
}

fn cmd_train(args: &Args) -> i32 {
    match run_train(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

/// Compare a fresh engine bench record against the committed baseline;
/// exit 1 on regression (the CI bench job gates on this).
fn cmd_bench_gate(args: &Args) -> i32 {
    let baseline = args.get_or("baseline", "bench_out/BENCH_baseline.json");
    let current = args.get_or("current", "bench_out/BENCH_precond_engine.json");
    let tolerance = args.get_f64("tolerance", 0.25);
    match sketchy::util::gate::run_gate(&baseline, &current, tolerance) {
        Ok(report) => {
            print!("{}", report.render());
            if report.passed() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("bench-gate failed: {e:#}");
            2
        }
    }
}

/// Repo-invariant static analysis (`sketchy lint`): exit 0 when the
/// tree is clean, 1 on violations, 2 when the scan itself failed.
fn cmd_lint(args: &Args) -> i32 {
    let root = args.positional.first().cloned().unwrap_or_else(|| ".".into());
    let fix = args.get_bool("fix-allowlist", false);
    match sketchy::analysis::run_lint(&root, fix) {
        Ok(report) => {
            print!("{}", report.render());
            if report.clean() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("lint failed: {e:#}");
            2
        }
    }
}

/// Shard-worker mode: spawned (from this same binary) by a `--shards N`
/// run; serves its block shard over the wire protocol until shutdown.
fn cmd_shard_worker(args: &Args) -> i32 {
    match sketchy::coordinator::shard::serve_worker(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("shard worker failed: {e:#}");
            1
        }
    }
}

fn run_train(args: &Args) -> anyhow::Result<()> {
    use sketchy::coordinator::{ShardConfig, ShardLaunch};
    use sketchy::data::MarkovCorpus;
    use sketchy::optim::{
        engine_optimizer, sharded_engine_optimizer, Adam, EngineConfig, GraftType, Optimizer,
        SShampoo, SShampooConfig, Shampoo, ShampooConfig, WarmupCosine,
    };
    use sketchy::train::LmTrainer;
    use std::sync::Arc;

    // Config file first (configs/*.toml), CLI flags override.
    let cfg_file = match args.get("config") {
        Some(path) => sketchy::util::config::Config::load(path)?,
        None => sketchy::util::config::Config::default(),
    };
    // Fail fast on typo'd config keys in every section this launcher
    // reads — a misspelled knob (`overlap_refres`) must be a named
    // error, never a silent default. `[engine]` and `[shard]` validate
    // inside their own resolvers.
    cfg_file.ensure_known_keys("train", &["preset", "steps", "workers", "lr", "optimizer"])?;
    cfg_file.ensure_known_keys(
        "s_shampoo",
        &[
            "rank",
            "beta2",
            "weight_decay",
            "clip",
            "stat_interval",
            "precond_interval",
            "graft",
            "one_sided",
        ],
    )?;
    let preset = args
        .get("preset")
        .map(|s| s.to_string())
        .unwrap_or_else(|| cfg_file.str_or("train.preset", "small"));
    let steps = args.get_usize("steps", cfg_file.usize_or("train.steps", 200));
    let workers = args.get_usize("workers", cfg_file.usize_or("train.workers", 2));
    let lr = args.get_f64("lr", cfg_file.f64_or("train.lr", 1e-3));
    let rank = args.get_usize("rank", cfg_file.usize_or("s_shampoo.rank", 16));
    let seed = args.get_u64("seed", 0);
    let opt_name = args
        .get("optimizer")
        .map(|s| s.to_string())
        .unwrap_or_else(|| cfg_file.str_or("train.optimizer", "s-shampoo"));
    let runtime = Arc::new(sketchy::runtime::Runtime::load(
        &args.get_or("artifacts", "artifacts"),
    )?);
    let mut trainer = LmTrainer::new(runtime, &preset, seed)?;
    println!(
        "LM preset={preset}: {} params in {} tensors; vocab={} seq={} batch={} workers={workers}",
        trainer.param_count(),
        trainer.shapes.len(),
        trainer.vocab,
        trainer.seq,
        trainer.batch
    );
    let shapes = trainer.shapes.clone();
    let base = ShampooConfig {
        lr,
        beta2: cfg_file.f64_or("s_shampoo.beta2", 0.999),
        weight_decay: cfg_file.f64_or("s_shampoo.weight_decay", 1e-4),
        clip: cfg_file.f64_or("s_shampoo.clip", 10.0),
        start_preconditioning_step: steps / 20 + 2,
        stat_interval: cfg_file.usize_or("s_shampoo.stat_interval", 2),
        precond_interval: cfg_file.usize_or("s_shampoo.precond_interval", 2),
        graft: GraftType::parse(&cfg_file.str_or("s_shampoo.graft", "rmsprop_normalized"))
            .unwrap_or(GraftType::RmspropNormalized),
        one_sided: cfg_file.bool_or("s_shampoo.one_sided", false),
        ..Default::default()
    };
    let mut ecfg = EngineConfig::resolve(args, &cfg_file)?;
    // Unless the engine knob is set explicitly, inherit the Shampoo
    // `precond_interval` cadence so `shampoo` → `engine-shampoo` does not
    // silently change refresh frequency.
    if args.get("refresh-interval").is_none() && cfg_file.get("engine.refresh_interval").is_none() {
        ecfg.refresh_interval = base.precond_interval.max(1);
    }
    // --shards N (or [shard] count) lifts the block engine across N
    // worker processes; 0 keeps the in-process work queue. Sharding only
    // exists for the engine-* family — refuse it loudly elsewhere rather
    // than silently running in-process.
    let shard_cfg = ShardConfig::resolve(args, &cfg_file)?;
    if shard_cfg.enabled() && !opt_name.starts_with("engine-") {
        anyhow::bail!(
            "--shards requires an engine-* optimizer (engine-shampoo, engine-s-shampoo, \
             engine-adam); got {opt_name}"
        );
    }
    if shard_cfg.journal.is_some() && !shard_cfg.enabled() {
        anyhow::bail!("--journal/--resume-journal needs a shard fleet; pass --shards N");
    }
    anyhow::ensure!(
        shard_cfg.resume_journal.is_none() || args.get("resume").is_none(),
        "--resume and --resume-journal are mutually exclusive"
    );
    // --resume-journal PATH: load the durable write-ahead journal a
    // killed driver left behind — before the fleet launches, so the
    // journaled worker addresses can be re-adopted instead of spawning
    // duplicates. A missing file means the previous driver died before
    // its first journaled step: start fresh (journaling to that path).
    let resume_journal = match shard_cfg.resume_journal.as_deref() {
        Some(path) if std::path::Path::new(path).exists() => {
            let jc = sketchy::train::load_journal(path)
                .with_context(|| format!("resume journal {path}"))?;
            if jc.torn {
                eprintln!(
                    "resume journal {path}: torn tail dropped; resuming from the last \
                     consistent step ({})",
                    jc.sync_t as usize + jc.steps.len()
                );
            }
            Some(jc)
        }
        Some(path) => {
            eprintln!("resume journal {path} not found; starting fresh (journaling to it)");
            None
        }
        None => None,
    };
    let mut opt: Box<dyn Optimizer> = match opt_name.as_str() {
        "adam" => {
            let mut a = Adam::new(&shapes, lr);
            a.weight_decay = 1e-4;
            a.clip = 10.0;
            Box::new(a)
        }
        "shampoo" => Box::new(Shampoo::new(&shapes, base)),
        "s-shampoo" => Box::new(SShampoo::new(&shapes, SShampooConfig { base, rank })),
        name => {
            // Overlap composes with sharding: the engine resolves the
            // knob against the executor's capability report (workers on
            // the legacy protocol degrade to synchronous refresh with a
            // logged notice).
            let engine = if shard_cfg.enabled() {
                let launch = ShardLaunch::current_exe(&shard_cfg)?;
                let mut membership = shard_cfg.membership();
                if let Some(jc) = &resume_journal {
                    membership.resume_addrs = Some(jc.addrs.clone());
                }
                sharded_engine_optimizer(name, &shapes, base, rank, ecfg, &launch, &membership)?
            } else {
                engine_optimizer(name, &shapes, base, rank, ecfg)
            };
            match engine {
                Some(engine) => {
                    println!(
                        "engine: {} blocks, refresh every {} steps (stagger={}, overlap={}), {}",
                        engine.blocks().len(),
                        engine.ecfg.refresh_interval,
                        engine.ecfg.stagger,
                        // Post-resolution: reports what actually runs
                        // (off when a worker lacks the capability).
                        engine.ecfg.overlap,
                        if shard_cfg.enabled() {
                            // The executor caps shards at the block
                            // count; report what actually launched.
                            format!(
                                "{} shards over {}{}{}{}",
                                shard_cfg.shards.min(engine.blocks().len()),
                                shard_cfg.transport,
                                if shard_cfg.compress { ", delta-compressed" } else { "" },
                                if shard_cfg.launch.is_some() { ", templated launch" } else { "" },
                                if shard_cfg.membership().elastic() {
                                    format!(
                                        ", elastic ({} spares, rebalance={}, budget={})",
                                        shard_cfg.spares,
                                        shard_cfg.rebalance,
                                        shard_cfg.failover_budget
                                    )
                                } else {
                                    String::new()
                                }
                            )
                        } else {
                            format!("{} threads", ecfg.effective_threads(engine.blocks().len()))
                        }
                    );
                    Box::new(engine)
                }
                None => anyhow::bail!("unknown optimizer {name}"),
            }
        }
    };
    println!(
        "optimizer {} — covariance bytes {}",
        opt.name(),
        opt.second_moment_bytes()
    );
    let mut corpus = MarkovCorpus::new(trainer.vocab, seed ^ 0xc0).into();
    let schedule = WarmupCosine { peak: lr, warmup: steps / 20 + 1, total: steps };
    // --resume PATH: reload params and, when the checkpoint carries the
    // typed optimizer state (v2 with engine-* optimizers), the full
    // block states + step counter — the resumed run continues exactly
    // where the saved one stopped.
    let mut start_step = 0usize;
    if let Some(path) = args.get("resume") {
        let (step, params, state) = sketchy::train::load_checkpoint_full(path)?;
        anyhow::ensure!(
            params.len() == trainer.params.len(),
            "resume: checkpoint has {} tensors, model has {}",
            params.len(),
            trainer.params.len()
        );
        for (i, (dst, src)) in trainer.params.iter_mut().zip(params).enumerate() {
            anyhow::ensure!(
                dst.rows() == src.rows() && dst.cols() == src.cols(),
                "resume: tensor {i} is {}x{} in the checkpoint, {}x{} in the model",
                src.rows(),
                src.cols(),
                dst.rows(),
                dst.cols()
            );
            *dst = src;
        }
        match state {
            Some(entries) => {
                opt.restore_payloads(step, entries)
                    .with_context(|| format!("resume: restore optimizer state from {path}"))?;
                println!("resumed from {path} at step {step} (params + optimizer state)");
            }
            None => println!("resumed from {path} at step {step} (params only)"),
        }
        start_step = step.min(steps);
    }
    // --resume-journal: restore the journaled sync-point snapshot and
    // replay the write-ahead step records through the optimizer — the
    // relaunched driver rejoins the run bitwise where the killed one
    // left off (the fleet was re-seated from the journal's worker
    // addresses at launch; replay re-drives it from the snapshot).
    if let Some(jc) = resume_journal {
        anyhow::ensure!(
            jc.params.len() == trainer.params.len(),
            "resume journal: {} tensors journaled, model has {}",
            jc.params.len(),
            trainer.params.len()
        );
        for (i, (dst, src)) in trainer.params.iter_mut().zip(jc.params).enumerate() {
            anyhow::ensure!(
                dst.rows() == src.rows() && dst.cols() == src.cols(),
                "resume journal: tensor {i} is {}x{} in the journal, {}x{} in the model",
                src.rows(),
                src.cols(),
                dst.rows(),
                dst.cols()
            );
            *dst = src;
        }
        match jc.snaps {
            Some(snaps) => opt
                .restore_payloads(jc.sync_t as usize, snaps)
                .context("resume journal: restore optimizer state")?,
            None => anyhow::ensure!(
                jc.sync_t == 0,
                "resume journal: sync point t={} carries no state snapshot",
                jc.sync_t
            ),
        }
        let replayed = jc.steps.len();
        for rs in jc.steps {
            opt.set_lr(rs.lr);
            opt.try_step(&mut trainer.params, &rs.grads)
                .with_context(|| format!("resume journal: replay step t={}", rs.t))?;
        }
        start_step = (jc.sync_t as usize + replayed).min(steps);
        println!(
            "resumed from journal at step {start_step} (sync point t={}, {replayed} steps replayed)",
            jc.sync_t
        );
        // Wind the corpus RNG to where the crashed driver's was: draw
        // and discard exactly the batches steps 0..start_step consumed,
        // so the continued run samples the same data an uninterrupted
        // one would.
        for _ in 0..start_step {
            for _ in 0..workers {
                let _ = corpus.batch(trainer.batch, trainer.seq);
            }
        }
    }
    // --crash-at-step: scripted driver kills for the crash-resume
    // harness — abort (no unwinding, no flush) right after the listed
    // steps complete, leaving only the write-ahead journal behind.
    let mut kill_plan = match args.get("crash-at-step") {
        Some(spec) => {
            sketchy::coordinator::DriverKillPlan::parse(spec).map_err(|e| anyhow::anyhow!(e))?
        }
        None => sketchy::coordinator::DriverKillPlan::none(),
    };
    let wall = sketchy::coordinator::SystemClock::new();
    let t0 = wall.now();
    let mut last_log = wall.now();
    let mut curve = sketchy::train::CurveLog::new(&opt.name());
    for s in start_step..steps {
        opt.set_lr(schedule.at(s));
        let (loss, _) = trainer.step(opt.as_mut(), &mut corpus, workers)?;
        curve.push(s, loss);
        if kill_plan.should_kill((s + 1) as u64) {
            eprintln!("crash-at-step: aborting after step {}", s + 1);
            std::process::abort();
        }
        if wall.now().saturating_sub(last_log).as_secs() >= 2 || s == 0 || s + 1 == steps {
            let sps = (s + 1) as f64 / wall.now().saturating_sub(t0).as_secs_f64();
            println!("step {s:>5}  loss {loss:.4}  lr {:.2e}  {sps:.2} steps/s", schedule.at(s));
            last_log = wall.now();
        }
    }
    let eval = trainer.eval(&mut corpus, 4)?;
    println!(
        "done in {:?}: final train loss {:.4}, eval loss {eval:.4}",
        wall.now().saturating_sub(t0),
        curve.tail_mean(5)
    );
    sketchy::train::metrics::write_report(
        &format!("reports/train_{preset}_{}.csv", opt.name()),
        &curve.to_csv(),
    )?;
    if let Some(path) = args.get("checkpoint") {
        // Engine optimizers contribute their typed block state (FD
        // sketches as rank-ℓ factors); anything else — or a sharded run
        // degraded below wire v4 — falls back to a params-only save
        // rather than failing the whole run at the finish line.
        let state = match opt.state_payloads() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("checkpoint: optimizer state unavailable ({e:#}); saving params only");
                None
            }
        };
        match state {
            Some(entries) => {
                sketchy::train::save_checkpoint_with_state(path, steps, &trainer.params, Some(&entries))?;
                println!("checkpoint written to {path} (+{} block states)", entries.len());
            }
            None => {
                sketchy::train::save_checkpoint(path, steps, &trainer.params)?;
                println!("checkpoint written to {path} (params only)");
            }
        }
    }
    Ok(())
}
