//! Online loss functions: logistic (App. A) and linear (Obs. 2).

/// One round's convex cost f_t.
pub trait OnlineLoss {
    fn loss(&self, x: &[f64]) -> f64;
    fn grad(&self, x: &[f64]) -> Vec<f64>;
}

/// Binary logistic loss over a linear predictor:
/// f(x) = log(1 + exp(−y ⟨x, φ⟩)), y ∈ {−1, +1}.
#[derive(Clone, Debug)]
pub struct LogisticLoss {
    pub features: Vec<f64>,
    pub label: f64,
}

/// Numerically-stable log(1 + e^z).
#[inline]
pub fn log1p_exp(z: f64) -> f64 {
    if z > 30.0 {
        z
    } else if z < -30.0 {
        0.0
    } else {
        (1.0 + z.exp()).ln()
    }
}

/// Stable logistic sigmoid.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl OnlineLoss for LogisticLoss {
    fn loss(&self, x: &[f64]) -> f64 {
        let margin = self.label * crate::tensor::dot(x, &self.features);
        log1p_exp(-margin)
    }

    fn grad(&self, x: &[f64]) -> Vec<f64> {
        let margin = self.label * crate::tensor::dot(x, &self.features);
        let coef = -self.label * sigmoid(-margin);
        self.features.iter().map(|&f| coef * f).collect()
    }
}

/// Linear loss f(x) = ⟨g, x⟩ (the Observation 2 adversary).
#[derive(Clone, Debug)]
pub struct LinearLoss {
    pub g: Vec<f64>,
}

impl OnlineLoss for LinearLoss {
    fn loss(&self, x: &[f64]) -> f64 {
        crate::tensor::dot(&self.g, x)
    }

    fn grad(&self, _x: &[f64]) -> Vec<f64> {
        self.g.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logistic_loss_and_grad_consistent() {
        let l = LogisticLoss { features: vec![1.0, -2.0], label: 1.0 };
        let x = vec![0.3, 0.1];
        // Finite differences.
        let g = l.grad(&x);
        for i in 0..2 {
            let mut xp = x.clone();
            xp[i] += 1e-6;
            let mut xm = x.clone();
            xm[i] -= 1e-6;
            let fd = (l.loss(&xp) - l.loss(&xm)) / 2e-6;
            assert!((fd - g[i]).abs() < 1e-5, "coord {i}: fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn logistic_extremes_are_stable() {
        let l = LogisticLoss { features: vec![1000.0], label: -1.0 };
        let loss = l.loss(&[1.0]);
        assert!(loss.is_finite() && loss > 900.0);
        let l2 = LogisticLoss { features: vec![1000.0], label: 1.0 };
        assert!(l2.loss(&[1.0]) >= 0.0 && l2.loss(&[1.0]) < 1e-10);
        assert!(l2.grad(&[1.0]).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn linear_loss_grad_constant() {
        let l = LinearLoss { g: vec![1.0, 2.0] };
        assert_eq!(l.grad(&[5.0, 5.0]), vec![1.0, 2.0]);
        assert_eq!(l.loss(&[1.0, 1.0]), 3.0);
    }

    #[test]
    fn sigmoid_symmetry() {
        assert!((sigmoid(3.0) + sigmoid(-3.0) - 1.0).abs() < 1e-12);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
    }
}
