//! Online convex optimization harness (system S5) — the setting of
//! Sec. 2 and the convex experiments of Appendix A / Observation 2.

pub mod losses;
pub mod regret;
pub mod runner;

pub use losses::{LinearLoss, LogisticLoss, OnlineLoss};
pub use regret::{fit_power_law, RegretCurve};
pub use runner::{run_online, OnlineResult};
