//! Regret bookkeeping and scaling-exponent fits.
//!
//! Observation 2 distinguishes algorithms by the *exponent* of their
//! regret growth (Ada-FD: Ω(T^{3/4}), S-AdaGrad: O(T^{1/2})); E7 estimates
//! these exponents by least-squares on log T vs log Regret_T.

/// Regret curve: Regret_t = Σ_{s≤t} f_s(x_s) − min_x Σ_{s≤t} f_s(x),
/// evaluated at checkpoints.
#[derive(Clone, Debug)]
pub struct RegretCurve {
    pub name: String,
    /// (t, regret at t) pairs.
    pub points: Vec<(usize, f64)>,
}

impl RegretCurve {
    /// Fitted growth exponent α where Regret_T ≈ c·T^α (log-log least
    /// squares over points with positive regret).
    pub fn exponent(&self) -> f64 {
        let pts: Vec<(f64, f64)> = self
            .points
            .iter()
            .filter(|&&(_, r)| r > 0.0)
            .map(|&(t, r)| ((t as f64).ln(), r.ln()))
            .collect();
        fit_slope(&pts)
    }
}

/// Least-squares slope of y against x.
pub fn fit_slope(pts: &[(f64, f64)]) -> f64 {
    let n = pts.len() as f64;
    if pts.len() < 2 {
        return f64::NAN;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// Fit `y ≈ c·xᵃ`, returning (a, c).
pub fn fit_power_law(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .collect();
    let a = fit_slope(&pts);
    let n = pts.len() as f64;
    let mean_x: f64 = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let mean_y: f64 = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let c = (mean_y - a * mean_x).exp();
    (a, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_known_exponent() {
        let xs: Vec<f64> = (1..100).map(|t| t as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&t| 3.0 * t.powf(0.75)).collect();
        let (a, c) = fit_power_law(&xs, &ys);
        assert!((a - 0.75).abs() < 1e-9);
        assert!((c - 3.0).abs() < 1e-6);
    }

    #[test]
    fn curve_exponent() {
        let curve = RegretCurve {
            name: "x".into(),
            points: (1..50).map(|t| (t * 10, 2.0 * ((t * 10) as f64).sqrt())).collect(),
        };
        assert!((curve.exponent() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn degenerate_fit_is_nan() {
        assert!(fit_slope(&[(1.0, 1.0)]).is_nan());
    }
}
