//! Streaming online-optimization runner: plays an optimizer against a
//! loss sequence, recording the cumulative loss curve (the App. A metric)
//! and supporting bounded domains (the Obs. 2 setting).

use super::losses::OnlineLoss;
use crate::optim::VectorOptimizer;

/// Result of one online pass.
#[derive(Clone, Debug)]
pub struct OnlineResult {
    /// Algorithm display name.
    pub name: String,
    /// Total Σ f_t(x_t).
    pub total_loss: f64,
    /// Average cumulative loss at sampled points: (t, Σ_{s≤t} f_s(x_s)/t).
    pub curve: Vec<(usize, f64)>,
    /// Cumulative loss at every step (for regret computation).
    pub cum_loss: Vec<f64>,
    /// Final iterate.
    pub x: Vec<f64>,
}

/// Run one online pass of `opt` over `losses` starting at x = 0,
/// projecting onto the radius-`radius` ball if given. `samples` is the
/// number of curve points to keep (log-spaced would hide the early curve;
/// App. A's Fig. 4 uses linear percent-of-dataset, so we sample evenly).
pub fn run_online(
    opt: &mut dyn VectorOptimizer,
    losses: &mut dyn Iterator<Item = Box<dyn OnlineLoss>>,
    d: usize,
    radius: Option<f64>,
    samples: usize,
) -> OnlineResult {
    let mut x = vec![0.0; d];
    let mut total = 0.0;
    let mut cum_loss = vec![];
    for loss in losses {
        let f = loss.loss(&x);
        let g = loss.grad(&x);
        total += f;
        cum_loss.push(total);
        opt.step(&mut x, &g, radius);
    }
    let t_max = cum_loss.len();
    let stride = (t_max / samples.max(1)).max(1);
    let curve = (0..t_max)
        .filter(|t| (t + 1) % stride == 0 || *t + 1 == t_max)
        .map(|t| (t + 1, cum_loss[t] / (t + 1) as f64))
        .collect();
    OnlineResult { name: opt.name(), total_loss: total, curve, cum_loss, x }
}

/// Offline comparator for logistic streams: minimize the *total* loss
/// Σ_t f_t(x) by gradient descent with backtracking — gives the
/// `min_x Σ f_t(x)` term of the regret.
pub fn best_fixed_logistic(
    features: &[Vec<f64>],
    labels: &[f64],
    iters: usize,
) -> (Vec<f64>, f64) {
    use super::losses::{log1p_exp, sigmoid};
    let d = features[0].len();
    let n = features.len();
    let total = |x: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            let m = labels[i] * crate::tensor::dot(x, &features[i]);
            s += log1p_exp(-m);
        }
        s
    };
    let grad = |x: &[f64]| -> Vec<f64> {
        let mut g = vec![0.0; d];
        for i in 0..n {
            let m = labels[i] * crate::tensor::dot(x, &features[i]);
            let c = -labels[i] * sigmoid(-m);
            for j in 0..d {
                g[j] += c * features[i][j];
            }
        }
        g
    };
    let mut x = vec![0.0; d];
    let mut fx = total(&x);
    let mut step = 1.0 / n as f64;
    for _ in 0..iters {
        let g = grad(&x);
        let gn2 = crate::tensor::dot(&g, &g);
        if gn2 < 1e-18 {
            break;
        }
        // Backtracking line search on the Armijo condition.
        let mut accepted = false;
        for _bt in 0..40 {
            let cand: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi - step * gi).collect();
            let fc = total(&cand);
            if fc <= fx - 0.25 * step * gn2 {
                x = cand;
                fx = fc;
                step *= 1.5;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            break;
        }
    }
    (x, fx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oco::losses::{LinearLoss, LogisticLoss};
    use crate::optim::{AdaGradDiag, Ogd};
    use crate::util::rng::Pcg64;

    fn toy_logistic_stream(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let w_true: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
        let mut feats = vec![];
        let mut labels = vec![];
        for _ in 0..n {
            let f: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
            let y = if crate::tensor::dot(&w_true, &f) > 0.0 { 1.0 } else { -1.0 };
            feats.push(f);
            labels.push(y);
        }
        (feats, labels)
    }

    #[test]
    fn online_logistic_beats_zero_predictor() {
        let (feats, labels) = toy_logistic_stream(400, 5, 200);
        let mut opt = AdaGradDiag::new(5, 0.5);
        let mut stream = feats.iter().zip(&labels).map(|(f, &y)| {
            Box::new(LogisticLoss { features: f.clone(), label: y }) as Box<dyn OnlineLoss>
        });
        let res = run_online(&mut opt, &mut stream, 5, None, 10);
        // Zero predictor suffers ln 2 per round.
        assert!(res.total_loss < 400.0 * (2f64).ln() * 0.8, "loss={}", res.total_loss);
        assert_eq!(res.cum_loss.len(), 400);
        assert!(res.curve.len() >= 10);
        // Curve is the running average of cum_loss.
        let (t, v) = res.curve[res.curve.len() - 1];
        assert_eq!(t, 400);
        assert!((v - res.total_loss / 400.0).abs() < 1e-12);
    }

    #[test]
    fn regret_vs_offline_comparator_is_sublinear() {
        let (feats, labels) = toy_logistic_stream(600, 4, 201);
        let (_, best) = best_fixed_logistic(&feats, &labels, 200);
        let mut opt = AdaGradDiag::new(4, 1.0);
        let mut stream = feats.iter().zip(&labels).map(|(f, &y)| {
            Box::new(LogisticLoss { features: f.clone(), label: y }) as Box<dyn OnlineLoss>
        });
        let res = run_online(&mut opt, &mut stream, 4, None, 5);
        let regret = res.total_loss - best;
        assert!(regret >= -1e-6, "regret must be ≥ 0: {regret}");
        // Sub-linear: far below T.
        assert!(regret < 100.0, "regret={regret}");
    }

    #[test]
    fn best_fixed_improves_over_zero() {
        let (feats, labels) = toy_logistic_stream(200, 3, 202);
        let (x, fx) = best_fixed_logistic(&feats, &labels, 100);
        assert!(fx < 200.0 * (2f64).ln());
        assert!(crate::tensor::norm2(&x) > 0.1);
    }

    #[test]
    fn bounded_domain_respected_with_linear_losses() {
        let mut rng = Pcg64::new(203);
        let mut opt = Ogd::new(1.0, true);
        let gs: Vec<Vec<f64>> = (0..50).map(|_| rng.gaussian_vec(3)).collect();
        let mut stream = gs
            .iter()
            .map(|g| Box::new(LinearLoss { g: g.clone() }) as Box<dyn OnlineLoss>);
        let res = run_online(&mut opt, &mut stream, 3, Some(1.0), 5);
        assert!(crate::tensor::norm2(&res.x) <= 1.0 + 1e-9);
    }
}
