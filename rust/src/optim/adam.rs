//! Adam (Kingma & Ba [1]) and SGD-with-momentum — the first-order
//! baselines of Fig. 2, with decoupled weight decay (AdamW-style, [58])
//! as used throughout the paper's experiments.

use super::matrix_opt::Optimizer;
use crate::tensor::Matrix;

/// Adam with decoupled weight decay and optional gradient clipping.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    /// Global-norm gradient clip (0 disables) — App. D/E tune this.
    pub clip: f64,
    m: Vec<Matrix>,
    v: Vec<Matrix>,
    t: usize,
}

impl Adam {
    pub fn new(shapes: &[(usize, usize)], lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            clip: 0.0,
            m: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
            v: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
            t: 0,
        }
    }
}

/// Global L2 norm across a gradient list.
pub fn global_norm(grads: &[Matrix]) -> f64 {
    grads
        .iter()
        .map(|g| {
            let n = g.fro_norm();
            n * n
        })
        .sum::<f64>()
        .sqrt()
}

/// Clip scale factor for a global-norm clip threshold (1.0 = no clip).
pub fn clip_scale(grads: &[Matrix], clip: f64) -> f64 {
    if clip <= 0.0 {
        return 1.0;
    }
    let n = global_norm(grads);
    if n > clip {
        clip / n
    } else {
        1.0
    }
}

impl Optimizer for Adam {
    fn name(&self) -> String {
        "Adam".into()
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let scale = clip_scale(grads, self.clip);
        for (i, (p, g)) in params.iter_mut().zip(grads.iter()).enumerate() {
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            let ms = m.as_mut_slice();
            let vs = v.as_mut_slice();
            let gs = g.as_slice();
            let ps = p.as_mut_slice();
            for j in 0..gs.len() {
                let gj = gs[j] * scale;
                ms[j] = self.beta1 * ms[j] + (1.0 - self.beta1) * gj;
                vs[j] = self.beta2 * vs[j] + (1.0 - self.beta2) * gj * gj;
                let mhat = ms[j] / bc1;
                let vhat = vs[j] / bc2;
                ps[j] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * ps[j]);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.m.iter().map(|m| m.mem_bytes()).sum::<usize>()
            + self.v.iter().map(|m| m.mem_bytes()).sum::<usize>()
    }

    fn second_moment_bytes(&self) -> usize {
        self.v.iter().map(|m| m.mem_bytes()).sum()
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// SGD with (heavy-ball) momentum and decoupled weight decay.
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    mu: Vec<Matrix>,
    t: usize,
}

impl Sgd {
    pub fn new(shapes: &[(usize, usize)], lr: f64, momentum: f64) -> Self {
        Sgd {
            lr,
            momentum,
            weight_decay: 0.0,
            mu: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
            t: 0,
        }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "SGD".into()
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        self.t += 1;
        for (i, (p, g)) in params.iter_mut().zip(grads.iter()).enumerate() {
            let mu = &mut self.mu[i];
            let ms = mu.as_mut_slice();
            let gs = g.as_slice();
            let ps = p.as_mut_slice();
            for j in 0..gs.len() {
                ms[j] = self.momentum * ms[j] + gs[j];
                ps[j] -= self.lr * (ms[j] + self.weight_decay * ps[j]);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.mu.iter().map(|m| m.mem_bytes()).sum()
    }

    fn second_moment_bytes(&self) -> usize {
        0
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn quad_loss_grads(params: &[Matrix], targets: &[Matrix]) -> Vec<Matrix> {
        params
            .iter()
            .zip(targets)
            .map(|(p, a)| p.sub(a))
            .collect()
    }

    #[test]
    fn adam_converges_multi_tensor() {
        let shapes = [(3, 2), (4, 1)];
        let mut rng = Pcg64::new(140);
        let targets: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
        let mut params: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut opt = Adam::new(&shapes, 0.05);
        for _ in 0..2000 {
            let grads = quad_loss_grads(&params, &targets);
            opt.step(&mut params, &grads);
        }
        for (p, a) in params.iter().zip(&targets) {
            assert!(p.max_diff(a) < 0.05);
        }
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction ⇒ first step magnitude ≈ lr regardless of g scale.
        let shapes = [(1, 1)];
        let mut opt = Adam::new(&shapes, 0.1);
        let mut params = vec![Matrix::zeros(1, 1)];
        let grads = vec![Matrix::from_rows(&[vec![1234.5]])];
        opt.step(&mut params, &grads);
        assert!((params[0][(0, 0)].abs() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn sgd_with_momentum_converges() {
        let shapes = [(2, 2)];
        let target = vec![Matrix::from_rows(&[vec![1.0, -1.0], vec![0.5, 2.0]])];
        let mut params = vec![Matrix::zeros(2, 2)];
        let mut opt = Sgd::new(&shapes, 0.05, 0.9);
        for _ in 0..1000 {
            let grads = quad_loss_grads(&params, &target);
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target[0]) < 1e-3);
    }

    #[test]
    fn clip_bounds_update() {
        let shapes = [(1, 2)];
        let mut opt = Adam::new(&shapes, 0.1);
        opt.clip = 1.0;
        let g = vec![Matrix::from_rows(&[vec![300.0, 400.0]])]; // norm 500
        let s = clip_scale(&g, 1.0);
        assert!((s - 1.0 / 500.0).abs() < 1e-12);
        let mut params = vec![Matrix::zeros(1, 2)];
        opt.step(&mut params, &g);
        assert!(params[0].max_abs() <= 0.1 + 1e-9);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let shapes = [(1, 1)];
        let mut opt = Adam::new(&shapes, 0.1);
        opt.weight_decay = 0.5;
        let mut params = vec![Matrix::from_rows(&[vec![1.0]])];
        let zero_g = vec![Matrix::zeros(1, 1)];
        let before = params[0][(0, 0)];
        opt.step(&mut params, &zero_g);
        assert!(params[0][(0, 0)] < before);
    }

    #[test]
    fn memory_accounting() {
        let shapes = [(10, 10), (5, 1)];
        let opt = Adam::new(&shapes, 0.1);
        assert_eq!(opt.second_moment_bytes(), (100 + 5) * 8);
        assert_eq!(opt.mem_bytes(), 2 * (100 + 5) * 8);
        let sgd = Sgd::new(&shapes, 0.1, 0.9);
        assert_eq!(sgd.second_moment_bytes(), 0);
    }
}
