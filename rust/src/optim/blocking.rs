//! Blocked Shampoo (§3.4 / Anil et al. [9]): view each m×n tensor as a
//! grid of b×b blocks and precondition each block independently.
//!
//! The paper uses block size 1024 so every covariance factor is at most
//! 1024×1024 (Fig. 3's setup); we implement blocking as a generic wrapper
//! over any [`Optimizer`], so it composes with Shampoo, S-Shampoo, and
//! Adam alike (the composability §3.2 calls out).

use super::matrix_opt::Optimizer;
use crate::tensor::Matrix;

/// One block of a parameter tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Block {
    /// Index of the source tensor.
    pub tensor: usize,
    pub r0: usize,
    pub r1: usize,
    pub c0: usize,
    pub c1: usize,
}

impl Block {
    pub fn shape(&self) -> (usize, usize) {
        (self.r1 - self.r0, self.c1 - self.c0)
    }
}

/// Partition tensor shapes into blocks of at most `b` per dimension.
pub fn partition(shapes: &[(usize, usize)], b: usize) -> Vec<Block> {
    assert!(b >= 1);
    let mut blocks = vec![];
    for (tensor, &(m, n)) in shapes.iter().enumerate() {
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + b).min(m);
            let mut c0 = 0;
            while c0 < n {
                let c1 = (c0 + b).min(n);
                blocks.push(Block { tensor, r0, r1, c0, c1 });
                c0 = c1;
            }
            r0 = r1;
        }
    }
    blocks
}

/// Wrapper running an inner optimizer over the blocked view of the
/// parameter list.
pub struct Blocked<O: Optimizer> {
    pub inner: O,
    blocks: Vec<Block>,
    /// Scratch block-parameter buffers, kept in sync with the real params.
    scratch: Vec<Matrix>,
}

impl<O: Optimizer> Blocked<O> {
    /// `make_inner` receives the block shapes and constructs the inner
    /// optimizer (which sees one "tensor" per block).
    pub fn new(
        shapes: &[(usize, usize)],
        block_size: usize,
        make_inner: impl FnOnce(&[(usize, usize)]) -> O,
    ) -> Self {
        let blocks = partition(shapes, block_size);
        let block_shapes: Vec<(usize, usize)> = blocks.iter().map(|b| b.shape()).collect();
        let scratch = block_shapes
            .iter()
            .map(|&(r, c)| Matrix::zeros(r, c))
            .collect();
        Blocked { inner: make_inner(&block_shapes), blocks, scratch }
    }

    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }
}

impl<O: Optimizer> Optimizer for Blocked<O> {
    fn name(&self) -> String {
        format!("Blocked<{}>", self.inner.name())
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        // Gather blocks.
        let block_grads: Vec<Matrix> = self
            .blocks
            .iter()
            .map(|b| grads[b.tensor].slice(b.r0, b.r1, b.c0, b.c1))
            .collect();
        for (i, b) in self.blocks.iter().enumerate() {
            self.scratch[i] = params[b.tensor].slice(b.r0, b.r1, b.c0, b.c1);
        }
        self.inner.step(&mut self.scratch, &block_grads);
        // Scatter back.
        for (i, b) in self.blocks.iter().enumerate() {
            params[b.tensor].set_slice(b.r0, b.c0, &self.scratch[i]);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.inner.mem_bytes() + self.scratch.iter().map(|m| m.mem_bytes()).sum::<usize>()
    }

    fn second_moment_bytes(&self) -> usize {
        self.inner.second_moment_bytes()
    }

    fn set_lr(&mut self, lr: f64) {
        self.inner.set_lr(lr);
    }

    fn steps(&self) -> usize {
        self.inner.steps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adam::Adam;
    use crate::util::rng::Pcg64;

    #[test]
    fn partition_covers_exactly() {
        let shapes = [(5, 3), (4, 4)];
        let blocks = partition(&shapes, 2);
        // Tensor 0: rows {0-2,2-4,4-5} × cols {0-2,2-3} = 6 blocks;
        // tensor 1: 2×2 = 4 blocks.
        assert_eq!(blocks.len(), 10);
        // Every cell covered exactly once.
        for (t, &(m, n)) in shapes.iter().enumerate() {
            let mut cover = vec![vec![0; n]; m];
            for b in blocks.iter().filter(|b| b.tensor == t) {
                for r in b.r0..b.r1 {
                    for c in b.c0..b.c1 {
                        cover[r][c] += 1;
                    }
                }
            }
            assert!(cover.iter().flatten().all(|&x| x == 1));
        }
    }

    #[test]
    fn blocked_adam_equals_plain_adam() {
        // Adam is elementwise, so blocking must not change anything.
        let shapes = [(5, 4)];
        let mut rng = Pcg64::new(170);
        let mut plain = Adam::new(&shapes, 0.05);
        let mut blocked = Blocked::new(&shapes, 2, |bs| Adam::new(bs, 0.05));
        let mut p1 = vec![Matrix::zeros(5, 4)];
        let mut p2 = p1.clone();
        for _ in 0..20 {
            let g = vec![Matrix::randn(5, 4, &mut rng)];
            plain.step(&mut p1, &g);
            blocked.step(&mut p2, &g);
            assert!(p1[0].max_diff(&p2[0]) < 1e-12);
        }
    }

    #[test]
    fn blocked_shampoo_bounds_factor_size() {
        use crate::optim::shampoo::{Shampoo, ShampooConfig};
        let shapes = [(10, 6)];
        let blocked = Blocked::new(&shapes, 4, |bs| {
            Shampoo::new(bs, ShampooConfig::default())
        });
        // Largest block is 4×4 ⇒ second-moment ≤ Σ (16+16)·8 per block.
        for b in blocked.blocks() {
            let (r, c) = b.shape();
            assert!(r <= 4 && c <= 4);
        }
        // 10×6 with b=4 → rows {4,4,2} cols {4,2} → 6 blocks.
        assert_eq!(blocked.blocks().len(), 6);
    }

    #[test]
    fn blocked_shampoo_converges() {
        use crate::optim::grafting::GraftType;
        use crate::optim::shampoo::{Shampoo, ShampooConfig};
        let shapes = [(6, 6)];
        let mut rng = Pcg64::new(171);
        let target = Matrix::randn(6, 6, &mut rng);
        let mut params = vec![Matrix::zeros(6, 6)];
        let mut opt = Blocked::new(&shapes, 3, |bs| {
            Shampoo::new(
                bs,
                ShampooConfig {
                    lr: 0.05,
                    start_preconditioning_step: 2,
                    graft: GraftType::Rmsprop,
                    ..Default::default()
                },
            )
        });
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
    }
}
