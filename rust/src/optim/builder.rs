//! One construction path for every engine/executor combination.
//!
//! PR 7 collapses the old constructor sprawl (`PrecondEngine::new` /
//! `sharded` / `with_executor` plus `ShardExecutor::launch` /
//! `launch_in_proc`) into a single fleet-builder API, so elastic
//! membership has exactly one place to thread its knobs through:
//!
//! ```ignore
//! // In-process engine (the old PrecondEngine::new):
//! let engine = ExecutorBuilder::local().build(&shapes, kind, base, ecfg)?;
//!
//! // Elastic process fleet with two warm spares:
//! let engine = ExecutorBuilder::sharded(launch)
//!     .spares(2)
//!     .rebalance(true)
//!     .failover_budget(8)
//!     .build(&shapes, kind, base, ecfg)?;
//!
//! // Test harness: in-proc shard workers over scripted transports:
//! let engine = ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
//!     .build(&shapes, kind, base, ecfg)?;
//! ```
//!
//! Every variant funnels into [`PrecondEngine::build_with`], so knob
//! resolution (overlap capability, thread budgets, block planning) is
//! identical across local, process-sharded, and in-proc harness
//! engines — the builder-equivalence tests pin old ≡ new bitwise.

use super::engine::{BlockExecutor, EngineConfig, LocalExecutor, PrecondEngine, UnitKind};
use super::shampoo::ShampooConfig;
use crate::coordinator::fault::FaultInjectingTransport;
use crate::coordinator::membership::MembershipConfig;
use crate::coordinator::shard::{ShardExecutor, ShardLaunch};
use crate::coordinator::supervise::{Clock, SystemClock};
use crate::optim::Block;
use anyhow::ensure;
use std::sync::Arc;

/// Factory closure variant: anything implementing [`BlockExecutor`].
type CustomBuild = Box<
    dyn FnOnce(&[Block], UnitKind, &ShampooConfig, usize) -> anyhow::Result<Box<dyn BlockExecutor>>,
>;

enum Mode {
    Local,
    Sharded(ShardLaunch),
    InProc { transports: Vec<Arc<FaultInjectingTransport>>, proto: u32, compress: bool },
    Custom(CustomBuild),
}

/// Builder for a [`PrecondEngine`] over any executor backend. See the
/// module docs for the migration map from the old constructors.
pub struct ExecutorBuilder {
    mode: Mode,
    membership: MembershipConfig,
    clock: Option<Arc<dyn Clock>>,
}

impl ExecutorBuilder {
    /// In-process engine over the thread-pool executor (the old
    /// `PrecondEngine::new`).
    pub fn local() -> ExecutorBuilder {
        ExecutorBuilder { mode: Mode::Local, membership: MembershipConfig::default(), clock: None }
    }

    /// Cross-process shard fleet described by `launch` (the old
    /// `PrecondEngine::sharded`). The membership/journal knobs carried
    /// in [`ShardLaunch::membership`] seed the builder — nothing the
    /// CLI resolved into the launch plan is dropped — and the elastic
    /// knobs ([`Self::spares`], [`Self::rebalance`],
    /// [`Self::membership`]) override from there.
    pub fn sharded(launch: ShardLaunch) -> ExecutorBuilder {
        let membership = launch.membership.clone();
        ExecutorBuilder { mode: Mode::Sharded(launch), membership, clock: None }
    }

    /// In-proc shard workers over scripted fault-injection transports
    /// (the old `ShardExecutor::launch_in_proc` under an engine). Under
    /// elastic membership the last [`Self::spares`] transports back
    /// warm spare workers instead of seats.
    pub fn in_proc(
        transports: Vec<Arc<FaultInjectingTransport>>,
        proto: u32,
        compress: bool,
    ) -> ExecutorBuilder {
        ExecutorBuilder {
            mode: Mode::InProc { transports, proto, compress },
            membership: MembershipConfig::default(),
            clock: None,
        }
    }

    /// Engine over an executor built by the caller (the old
    /// `PrecondEngine::with_executor`).
    pub fn custom<F>(build: F) -> ExecutorBuilder
    where
        F: FnOnce(
                &[Block],
                UnitKind,
                &ShampooConfig,
                usize,
            ) -> anyhow::Result<Box<dyn BlockExecutor>>
            + 'static,
    {
        ExecutorBuilder {
            mode: Mode::Custom(Box::new(build)),
            membership: MembershipConfig::default(),
            clock: None,
        }
    }

    /// Warm spare workers to launch alongside the fleet (elastic
    /// membership; sharded/in-proc modes only).
    pub fn spares(mut self, spares: usize) -> ExecutorBuilder {
        self.membership.spares = spares;
        self
    }

    /// Enable latency-fed rebalancing at sync points (elastic
    /// membership; sharded/in-proc modes only).
    pub fn rebalance(mut self, on: bool) -> ExecutorBuilder {
        self.membership.rebalance = on;
        self
    }

    /// Steps between journal sync points — the bound on how many steps
    /// a migration ever replays. Must be ≥ 1.
    pub fn failover_budget(mut self, steps: u64) -> ExecutorBuilder {
        self.membership.failover_budget = steps;
        self
    }

    /// Replace the whole membership config at once (the CLI resolution
    /// path hands over a [`MembershipConfig`] it already validated).
    pub fn membership(mut self, membership: MembershipConfig) -> ExecutorBuilder {
        self.membership = membership;
        self
    }

    /// Inject a [`Clock`] for heartbeat supervision (in-proc mode; the
    /// process-fleet modes always run on the system clock). Tests hand
    /// a `VirtualClock` here so hung-worker deadlines trip on observed
    /// polls instead of wall time. Defaults to [`SystemClock`].
    pub fn clock(mut self, clock: Arc<dyn Clock>) -> ExecutorBuilder {
        self.clock = Some(clock);
        self
    }

    /// Build the engine: plan blocks, stand up the executor, resolve
    /// the overlap knob against its capability report.
    pub fn build(
        self,
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
    ) -> anyhow::Result<PrecondEngine> {
        let ExecutorBuilder { mode, membership, clock } = self;
        if matches!(mode, Mode::Local | Mode::Custom(_)) {
            ensure!(
                !membership.elastic(),
                "elastic membership (spares/rebalance) needs a shard fleet; \
                 use ExecutorBuilder::sharded or ::in_proc"
            );
        }
        match mode {
            Mode::Local => {
                PrecondEngine::build_with(shapes, kind, base, ecfg, |blocks, kind, base, threads| {
                    Ok(Box::new(LocalExecutor::new(blocks, kind, base, threads)))
                })
            }
            Mode::Sharded(launch) => {
                PrecondEngine::build_with(shapes, kind, base, ecfg, |blocks, kind, base, threads| {
                    Ok(Box::new(ShardExecutor::launch_with(
                        &launch,
                        blocks,
                        kind,
                        base,
                        threads,
                        &membership,
                    )?))
                })
            }
            Mode::InProc { transports, proto, compress } => {
                let clock = clock.unwrap_or_else(|| Arc::new(SystemClock::new()));
                PrecondEngine::build_with(shapes, kind, base, ecfg, |blocks, kind, base, threads| {
                    Ok(Box::new(ShardExecutor::launch_in_proc_clocked(
                        blocks,
                        kind,
                        base,
                        threads,
                        &transports,
                        proto,
                        compress,
                        &membership,
                        clock,
                    )?))
                })
            }
            Mode::Custom(build) => PrecondEngine::build_with(shapes, kind, base, ecfg, build),
        }
    }
}
