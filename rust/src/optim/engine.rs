//! Parallel blocked preconditioner engine.
//!
//! §3.4 and §7 of the paper make the production argument: blocked
//! Kronecker factors bound every eigendecomposition at the block size,
//! and data-parallel execution amortizes the (batch-size-independent)
//! optimizer cost. This module supplies the missing half of that story
//! for the Rust layer — per-block statistics updates, root refreshes and
//! preconditioner applications run **concurrently across blocks** on a
//! self-scheduling work queue (the coordinator's [`BoundedQueue`], the
//! same pool discipline as `coordinator/worker.rs`), instead of
//! serializing inside the step loop.
//!
//! Two schedules compose with the parallelism:
//!
//! - `stat_interval` / `refresh_interval` — the App. C cadence: fold
//!   statistics every k-th step, recompute inverse roots every r-th step
//!   (a *stale-preconditioner* schedule; applying with older roots is the
//!   standard Shampoo production trick).
//! - `stagger` — phase-shift each block's refresh slot by its index, so
//!   at most ⌈blocks/r⌉ eigendecompositions land on any one step rather
//!   than all of them landing on the same step every r steps.
//!
//! Every block's computation is self-contained (disjoint state, disjoint
//! parameter region, no cross-block reductions), so the engine's output
//! is **bitwise identical** for any thread count — `threads = 1` is the
//! serial reference path, asserted by `tests/engine_determinism.rs`.

use super::adam::clip_scale;
use super::blocking::{partition, Block};
use super::grafting::GraftType;
use super::matrix_opt::Optimizer;
use super::precond::{
    drive_block, AdamUnit, BlockState, KroneckerUnit, Preconditioner, SketchUnit, StepCtx,
};
use super::shampoo::ShampooConfig;
use crate::coordinator::BoundedQueue;
use crate::sketch::FdSketch;
use crate::tensor::{ops, Matrix};
use crate::util::cli::Args;
use crate::util::config::Config;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Engine knobs, resolvable from CLI flags and `[engine]` config keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads for the block phase (0 = auto, capped at the block
    /// count).
    pub threads: usize,
    /// Block size for the §3.4 partition (0 = one block per tensor).
    pub block_size: usize,
    /// Recompute inverse roots every k-th step (stale-preconditioner
    /// schedule; 1 = always fresh).
    pub refresh_interval: usize,
    /// Phase-shift refresh slots across blocks so eigendecompositions
    /// spread over the interval instead of bunching on one step.
    pub stagger: bool,
}

impl Default for EngineConfig {
    /// The production defaults (shared by [`EngineConfig::resolve`]):
    /// auto threads, no blocking, roots refreshed every 10th step with
    /// staggering — the App. C amortized cadence.
    fn default() -> Self {
        EngineConfig { threads: 0, block_size: 0, refresh_interval: 10, stagger: true }
    }
}

impl EngineConfig {
    /// Resolve knobs from CLI flags (`--engine-threads`, `--block-size`,
    /// `--refresh-interval`, `--stagger-refresh`) with `[engine]` config
    /// keys as fallback (`engine.threads`, `engine.block_size`,
    /// `engine.refresh_interval`, `engine.stagger_refresh`) and
    /// [`EngineConfig::default`] as the final fallback.
    pub fn resolve(args: &Args, cfg: &Config) -> EngineConfig {
        let d = EngineConfig::default();
        EngineConfig {
            threads: args.get_usize("engine-threads", cfg.usize_or("engine.threads", d.threads)),
            block_size: args
                .get_usize("block-size", cfg.usize_or("engine.block_size", d.block_size)),
            refresh_interval: args
                .get_usize(
                    "refresh-interval",
                    cfg.usize_or("engine.refresh_interval", d.refresh_interval),
                )
                .max(1),
            stagger: args
                .get_bool("stagger-refresh", cfg.bool_or("engine.stagger_refresh", d.stagger)),
        }
    }

    /// Worker-thread count actually used for `blocks` tasks.
    pub fn effective_threads(&self, blocks: usize) -> usize {
        let t = if self.threads == 0 { ops::num_threads() } else { self.threads };
        t.clamp(1, blocks.max(1))
    }
}

/// Which preconditioner family the engine drives per block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitKind {
    /// Exact Kronecker factors (Shampoo).
    Shampoo,
    /// FD-sketched factors (S-Shampoo) with sketch size ℓ.
    Sketched { rank: usize },
    /// Diagonal Adam.
    Adam,
}

impl UnitKind {
    fn make(&self, shape: (usize, usize), base: &ShampooConfig) -> Box<dyn Preconditioner> {
        match *self {
            UnitKind::Shampoo => {
                Box::new(KroneckerUnit::new(shape, base.beta2, base.eps, base.one_sided))
            }
            UnitKind::Sketched { rank } => {
                Box::new(SketchUnit::new(shape, rank, base.beta2, base.eps, base.one_sided))
            }
            // Adam-standard moments: β₁ = 0.9, ε = 1e-8 (the fused
            // `Adam` defaults), second moment decay from the shared β₂.
            UnitKind::Adam => Box::new(AdamUnit::new(shape, 0.9, base.beta2, 1e-8)),
        }
    }

    fn label(&self) -> String {
        match *self {
            UnitKind::Shampoo => "Shampoo".into(),
            UnitKind::Sketched { rank } => format!("S-Shampoo(l={rank})"),
            UnitKind::Adam => "Adam".into(),
        }
    }
}

/// Engine-driven blocked optimizer: any [`UnitKind`] over the §3.4 block
/// partition, stepped in parallel.
pub struct PrecondEngine {
    pub base: ShampooConfig,
    pub ecfg: EngineConfig,
    kind: UnitKind,
    blocks: Vec<Block>,
    states: Vec<Mutex<BlockState>>,
    t: usize,
    refreshes: AtomicUsize,
}

impl PrecondEngine {
    pub fn new(
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
    ) -> Self {
        // Adam is fully handled inside AdamUnit (its own β₁ momentum,
        // bias correction, per-step moments): normalize the driver config
        // so `engine-adam` reproduces the fused `Adam` exactly instead of
        // stacking grafting / second momentum / delayed preconditioning
        // on top. Only lr / β₂ / weight decay / clip pass through.
        let base = if kind == UnitKind::Adam {
            ShampooConfig {
                beta1: 0.0,
                graft: GraftType::None,
                stat_interval: 1,
                precond_interval: 1,
                start_preconditioning_step: 1,
                ..base
            }
        } else {
            base
        };
        // block_size = 0 means "no blocking": use the largest dimension so
        // the partition yields exactly one block per tensor.
        let bsize = if ecfg.block_size == 0 {
            shapes.iter().map(|&(m, n)| m.max(n)).max().unwrap_or(1).max(1)
        } else {
            ecfg.block_size
        };
        let blocks = partition(shapes, bsize);
        let states = blocks
            .iter()
            .map(|b| {
                let shape = b.shape();
                Mutex::new(BlockState::new(kind.make(shape, &base), base.graft, shape, base.beta2))
            })
            .collect();
        PrecondEngine {
            base,
            ecfg,
            kind,
            blocks,
            states,
            t: 0,
            refreshes: AtomicUsize::new(0),
        }
    }

    /// Exact-Kronecker (Shampoo) engine.
    pub fn shampoo(shapes: &[(usize, usize)], base: ShampooConfig, ecfg: EngineConfig) -> Self {
        PrecondEngine::new(shapes, UnitKind::Shampoo, base, ecfg)
    }

    /// FD-sketched (S-Shampoo) engine.
    pub fn sketched(
        shapes: &[(usize, usize)],
        rank: usize,
        base: ShampooConfig,
        ecfg: EngineConfig,
    ) -> Self {
        PrecondEngine::new(shapes, UnitKind::Sketched { rank }, base, ecfg)
    }

    /// Diagonal-Adam engine (useful as the parallel-overhead baseline).
    pub fn adam(shapes: &[(usize, usize)], base: ShampooConfig, ecfg: EngineConfig) -> Self {
        PrecondEngine::new(shapes, UnitKind::Adam, base, ecfg)
    }

    /// The §3.4 block partition.
    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    /// Total inverse-root refreshes (eigendecompositions) performed so
    /// far — the quantity the stale schedule amortizes.
    pub fn refreshes(&self) -> usize {
        self.refreshes.load(Ordering::Relaxed)
    }

    /// Visit every live FD sketch across blocks (invariant checks).
    pub fn for_each_sketch(&mut self, mut f: impl FnMut(&FdSketch)) {
        for st in &mut self.states {
            let st = st.get_mut().unwrap();
            for fd in st.unit.sketches() {
                f(fd);
            }
        }
    }
}

impl Optimizer for PrecondEngine {
    fn name(&self) -> String {
        format!(
            "Engine<{}>(blocks={}, threads={}, refresh={})",
            self.kind.label(),
            self.blocks.len(),
            self.ecfg.effective_threads(self.blocks.len()),
            self.ecfg.refresh_interval,
        )
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let t = self.t;
        let scale = clip_scale(grads, self.base.clip);
        let preconditioning = t >= self.base.start_preconditioning_step;
        let stat_due = t % self.base.stat_interval == 0;
        // Gather: copy each block's parameter/gradient window into its
        // state scratch (allocation-free) so the parallel phase touches
        // fully disjoint data.
        for (i, b) in self.blocks.iter().enumerate() {
            let st = self.states[i].get_mut().unwrap();
            params[b.tensor].slice_into(b.r0, b.r1, b.c0, b.c1, &mut st.param);
            grads[b.tensor].slice_into(b.r0, b.r1, b.c0, b.c1, &mut st.grad);
        }
        let n = self.blocks.len();
        let threads = self.ecfg.effective_threads(n);
        let refresh_interval = self.ecfg.refresh_interval.max(1);
        let stagger = self.ecfg.stagger;
        let base = &self.base;
        let ctx_for = |i: usize| {
            let phase = if stagger { i % refresh_interval } else { 0 };
            StepCtx {
                t,
                scale,
                preconditioning,
                refresh_due: (t + phase) % refresh_interval == 0,
                lr: base.lr,
                beta1: base.beta1,
                weight_decay: base.weight_decay,
                stat_due,
                graft: base.graft,
            }
        };
        let refreshes = &self.refreshes;
        if threads <= 1 {
            // Serial reference path (identical math, no pool).
            for i in 0..n {
                let st = self.states[i].get_mut().unwrap();
                if drive_block(st, &ctx_for(i)) {
                    refreshes.fetch_add(1, Ordering::Relaxed);
                }
            }
        } else {
            // Self-scheduling work queue: whichever worker frees up first
            // takes the next block, so one slow eigendecomposition never
            // idles the rest of the pool.
            let queue = BoundedQueue::work_list(0..n);
            let states = &self.states;
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {
                        // Pin dense kernels to one thread per worker: the
                        // engine already owns the parallelism, so nested
                        // kernel threading would only oversubscribe cores.
                        ops::with_single_thread(|| {
                            while let Some(i) = queue.pop() {
                                let mut st = states[i].lock().unwrap();
                                if drive_block(&mut st, &ctx_for(i)) {
                                    refreshes.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        });
                    });
                }
            });
        }
        // Scatter: write updated parameter blocks back.
        for (i, b) in self.blocks.iter().enumerate() {
            let st = self.states[i].get_mut().unwrap();
            params[b.tensor].set_slice(b.r0, b.c0, &st.param);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| {
                let st = s.lock().unwrap();
                st.unit.mem_bytes()
                    + st.graft.mem_bytes()
                    + st.mu.mem_bytes()
                    + st.param.mem_bytes()
                    + st.grad.mem_bytes()
            })
            .sum()
    }

    fn second_moment_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| s.lock().unwrap().unit.second_moment_bytes())
            .sum()
    }

    fn set_lr(&mut self, lr: f64) {
        self.base.lr = lr;
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// Optimizer factory for the engine-backed family, keyed by the CLI
/// names: `engine-shampoo`, `engine-s-shampoo`, `engine-adam`.
pub fn engine_optimizer(
    name: &str,
    shapes: &[(usize, usize)],
    base: ShampooConfig,
    rank: usize,
    ecfg: EngineConfig,
) -> Option<PrecondEngine> {
    match name {
        "engine-shampoo" => Some(PrecondEngine::shampoo(shapes, base, ecfg)),
        "engine-s-shampoo" => Some(PrecondEngine::sketched(shapes, rank, base, ecfg)),
        "engine-adam" => Some(PrecondEngine::adam(shapes, base, ecfg)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::grafting::GraftType;
    use crate::util::rng::Pcg64;

    fn base_cfg() -> ShampooConfig {
        ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        }
    }

    #[test]
    fn engine_blocks_cover_parameters() {
        let ecfg = EngineConfig { block_size: 3, ..Default::default() };
        let eng = PrecondEngine::shampoo(&[(7, 5), (4, 1)], base_cfg(), ecfg);
        // 7×5 at b=3 → rows {3,3,1} × cols {3,2} = 6; 4×1 → 2×1 = 2.
        assert_eq!(eng.blocks().len(), 8);
        let mut cells = 0;
        for b in eng.blocks() {
            let (r, c) = b.shape();
            assert!(r <= 3 && c <= 3);
            cells += r * c;
        }
        assert_eq!(cells, 7 * 5 + 4);
    }

    #[test]
    fn engine_converges_on_quadratic() {
        let shapes = [(6, 6)];
        let mut rng = Pcg64::new(210);
        let target = Matrix::randn(6, 6, &mut rng);
        let mut params = vec![Matrix::zeros(6, 6)];
        let ecfg = EngineConfig {
            threads: 2,
            block_size: 3,
            refresh_interval: 2,
            stagger: true,
        };
        let mut opt = PrecondEngine::shampoo(&shapes, base_cfg(), ecfg);
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
        assert!(opt.refreshes() > 0);
        assert_eq!(opt.steps(), 3000);
    }

    #[test]
    fn sketched_engine_converges() {
        let shapes = [(12, 12)];
        let mut rng = Pcg64::new(211);
        let target = Matrix::randn(12, 12, &mut rng);
        let mut params = vec![Matrix::zeros(12, 12)];
        let ecfg = EngineConfig { threads: 3, block_size: 6, ..Default::default() };
        let mut opt = PrecondEngine::sketched(&shapes, 4, base_cfg(), ecfg);
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
    }

    #[test]
    fn config_resolution_precedence() {
        let cfg = Config::parse(
            "[engine]\nthreads = 3\nblock_size = 256\nrefresh_interval = 5\nstagger_refresh = false",
        )
        .unwrap();
        let args = Args::parse(
            ["train", "--engine-threads", "8", "--stagger-refresh", "true"]
                .iter()
                .map(|s| s.to_string()),
        );
        let e = EngineConfig::resolve(&args, &cfg);
        // CLI beats config; config beats defaults.
        assert_eq!(e.threads, 8);
        assert_eq!(e.block_size, 256);
        assert_eq!(e.refresh_interval, 5);
        assert!(e.stagger);
        let defaults = EngineConfig::resolve(&Args::default(), &Config::default());
        assert_eq!(defaults.threads, 0);
        assert_eq!(defaults.refresh_interval, 10);
        assert!(defaults.stagger);
    }

    #[test]
    fn factory_names() {
        let shapes = [(4, 4)];
        for name in ["engine-shampoo", "engine-s-shampoo", "engine-adam"] {
            let opt = engine_optimizer(name, &shapes, base_cfg(), 2, EngineConfig::default());
            assert!(opt.is_some(), "{name} should resolve");
        }
        let unknown = engine_optimizer("sgd", &shapes, base_cfg(), 2, EngineConfig::default());
        assert!(unknown.is_none());
    }
}
