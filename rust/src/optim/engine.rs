//! Parallel blocked preconditioner engine.
//!
//! §3.4 and §7 of the paper make the production argument: blocked
//! Kronecker factors bound every eigendecomposition at the block size,
//! and data-parallel execution amortizes the (batch-size-independent)
//! optimizer cost. This module supplies the missing half of that story
//! for the Rust layer — per-block statistics updates, root refreshes and
//! preconditioner applications run **concurrently across blocks** on the
//! persistent worker pool (`crate::runtime::pool`, claiming blocks
//! self-scheduled like the PR-1 work queue), instead of serializing
//! inside the step loop.
//!
//! Two schedules compose with the parallelism:
//!
//! - `stat_interval` / `refresh_interval` — the App. C cadence: fold
//!   statistics every k-th step, recompute inverse roots every r-th step
//!   (a *stale-preconditioner* schedule; applying with older roots is the
//!   standard Shampoo production trick).
//! - `stagger` — phase-shift each block's refresh slot by its index, so
//!   at most ⌈blocks/r⌉ eigendecompositions land on any one step rather
//!   than all of them landing on the same step every r steps.
//!
//! Every block's computation is self-contained (disjoint state, disjoint
//! parameter region, no cross-block reductions), so the engine's output
//! is **bitwise identical** for any thread count — `threads = 1` is the
//! serial reference path, asserted by `tests/engine_determinism.rs`.
//!
//! ## Runtime substrate
//!
//! Parallel block phases run on the persistent worker pool
//! ([`crate::runtime::pool`]) — long-lived threads with a phase barrier —
//! instead of spawning a `std::thread::scope` per step. Task claiming is
//! the same self-scheduling discipline as the PR-1 work queue, and the
//! pool never changes what is computed, so the pool-backed step is
//! bitwise identical to the scoped-thread path.
//!
//! ## RefreshAhead (pipelined refresh overlap)
//!
//! With [`EngineConfig::overlap`] on, the engine prefetches the next
//! step's inverse-root refreshes: at the end of step `t` it knows which
//! blocks' `refresh_due` slots fire at `t + 1` (the stagger schedule is
//! a pure function of the step index), so it spawns the
//! eigendecompositions of exactly those blocks as a background pool job
//! while the trainer computes step `t + 1`'s gradients. The job is
//! joined at the top of step `t + 1`, and prefetched blocks skip their
//! in-step refresh.
//!
//! Overlap is **bitwise identical** to the synchronous schedule by
//! construction: a refresh only moves ahead when step `t + 1` folds no
//! statistics (`stat_due` false), in which case the roots computed from
//! post-step-`t` statistics are exactly the roots the synchronous path
//! would compute mid-step. Steps that do fold statistics refresh
//! synchronously, as before (`tests/pool_runtime.rs` pins the 50-step
//! equivalence). With the App. C cadence (`stat_interval` > 1) most
//! staggered refresh slots land on prefetchable steps, so their
//! eigendecompositions vanish from the step's critical path — the
//! `engine/overlap_refresh` bench measures the win.
//!
//! ## Executors
//!
//! The step loop is split from the compute substrate by the
//! [`BlockExecutor`] trait: the engine gathers per-block windows,
//! computes one [`StepCtx`] per block, and hands the batch to an
//! executor. Two implementations exist:
//!
//! - [`LocalExecutor`] — the in-process work queue described above
//!   (bit-for-bit the PR-1 engine);
//! - [`crate::coordinator::shard::ShardExecutor`] — blocks partitioned
//!   across `sketchy shard-worker` processes over a length-prefixed
//!   wire protocol ([`crate::coordinator::wire`]), with the same
//!   bitwise-determinism contract (`tests/shard_determinism.rs`).

use super::adam::clip_scale;
use super::blocking::{partition, Block};
use super::grafting::GraftType;
use super::matrix_opt::Optimizer;
use super::precond::{
    drive_block, AdamUnit, BlockState, BlockStateSnap, KroneckerUnit, Preconditioner, SketchUnit,
    StepCtx,
};
use super::shampoo::ShampooConfig;
use crate::coordinator::membership::MembershipConfig;
use crate::coordinator::shard::ShardLaunch;
use crate::coordinator::wire::{BlockStateMsg, StateExpect};
use crate::runtime::pool;
use crate::sketch::FdSketch;
use crate::tensor::{ops, Matrix};
use crate::util::cli::Args;
use crate::util::config::Config;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Engine knobs, resolvable from CLI flags and `[engine]` config keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads for the block phase (0 = auto, capped at the block
    /// count).
    pub threads: usize,
    /// Block size for the §3.4 partition (0 = one block per tensor).
    pub block_size: usize,
    /// Recompute inverse roots every k-th step (stale-preconditioner
    /// schedule; 1 = always fresh).
    pub refresh_interval: usize,
    /// Phase-shift refresh slots across blocks so eigendecompositions
    /// spread over the interval instead of bunching on one step.
    pub stagger: bool,
    /// Pipelined refresh overlap: run the next step's due
    /// eigendecompositions in the background while the trainer computes
    /// gradients (bitwise identical to the synchronous schedule; see the
    /// module docs). In-process executors only — sharded engines ignore
    /// it and refresh synchronously.
    pub overlap: bool,
    /// Pre-size the persistent worker pool to this many threads at
    /// engine construction (0 = grow on demand). Purely a warmup knob —
    /// never changes results.
    pub pool_threads: usize,
    /// EKFAC-style inter-refresh corrections (George et al.): between
    /// eigendecompositions each unit folds per-step gradient second
    /// moments into a corrected diagonal in its stale eigenbasis and
    /// applies with those scales instead of the frozen eigenvalues,
    /// letting `refresh_interval` stretch 4 → 32+ without quality loss.
    /// Resolved once at construction; sharded fleets require every
    /// worker link at wire protocol v7+.
    pub ekfac: bool,
}

impl Default for EngineConfig {
    /// The production defaults (shared by [`EngineConfig::resolve`]):
    /// auto threads, no blocking, roots refreshed every 10th step with
    /// staggering — the App. C amortized cadence. Overlap is off by
    /// default (opt in with `--overlap-refresh`).
    fn default() -> Self {
        EngineConfig {
            threads: 0,
            block_size: 0,
            refresh_interval: 10,
            stagger: true,
            overlap: false,
            pool_threads: 0,
            ekfac: false,
        }
    }
}

impl EngineConfig {
    /// `[engine]` config keys [`EngineConfig::resolve`] understands —
    /// anything else in the section is a named error, not a silent
    /// no-op (the same contract `[shard]` has had since PR 7).
    pub const KNOWN_KEYS: &'static [&'static str] = &[
        "threads",
        "block_size",
        "refresh_interval",
        "stagger_refresh",
        "overlap_refresh",
        "pool_threads",
        "ekfac",
    ];

    /// Resolve knobs from CLI flags (`--engine-threads`, `--block-size`,
    /// `--refresh-interval`, `--stagger-refresh`, `--overlap-refresh`,
    /// `--pool-threads`, `--ekfac`) with `[engine]` config keys as
    /// fallback (`engine.threads`, `engine.block_size`,
    /// `engine.refresh_interval`, `engine.stagger_refresh`,
    /// `engine.overlap_refresh`, `engine.pool_threads`, `engine.ekfac`)
    /// and [`EngineConfig::default`] as the final fallback. Unknown
    /// `[engine]` keys are an error — a typo like `overlap_refres` must
    /// not silently run without overlap.
    pub fn resolve(args: &Args, cfg: &Config) -> anyhow::Result<EngineConfig> {
        cfg.ensure_known_keys("engine", Self::KNOWN_KEYS)?;
        let d = EngineConfig::default();
        Ok(EngineConfig {
            threads: args.get_usize("engine-threads", cfg.usize_or("engine.threads", d.threads)),
            block_size: args
                .get_usize("block-size", cfg.usize_or("engine.block_size", d.block_size)),
            refresh_interval: args
                .get_usize(
                    "refresh-interval",
                    cfg.usize_or("engine.refresh_interval", d.refresh_interval),
                )
                .max(1),
            stagger: args
                .get_bool("stagger-refresh", cfg.bool_or("engine.stagger_refresh", d.stagger)),
            overlap: args
                .get_bool("overlap-refresh", cfg.bool_or("engine.overlap_refresh", d.overlap)),
            pool_threads: args
                .get_usize("pool-threads", cfg.usize_or("engine.pool_threads", d.pool_threads)),
            ekfac: args.get_bool("ekfac", cfg.bool_or("engine.ekfac", d.ekfac)),
        })
    }

    /// Worker-thread count actually used for `blocks` tasks.
    pub fn effective_threads(&self, blocks: usize) -> usize {
        effective_worker_threads(self.threads, blocks)
    }
}

/// Resolve a thread knob (0 = auto) against a task count: at least one
/// thread, never more threads than tasks.
pub(crate) fn effective_worker_threads(knob: usize, tasks: usize) -> usize {
    let t = if knob == 0 { ops::num_threads() } else { knob };
    t.clamp(1, tasks.max(1))
}

/// Which preconditioner family the engine drives per block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitKind {
    /// Exact Kronecker factors (Shampoo).
    Shampoo,
    /// FD-sketched factors (S-Shampoo) with sketch size ℓ.
    Sketched { rank: usize },
    /// Diagonal Adam.
    Adam,
}

impl UnitKind {
    pub(crate) fn make(
        &self,
        shape: (usize, usize),
        base: &ShampooConfig,
    ) -> Box<dyn Preconditioner> {
        match *self {
            UnitKind::Shampoo => Box::new(
                KroneckerUnit::new(shape, base.beta2, base.eps, base.one_sided).ekfac(base.ekfac),
            ),
            UnitKind::Sketched { rank } => Box::new(
                SketchUnit::new(shape, rank, base.beta2, base.eps, base.one_sided)
                    .ekfac(base.ekfac),
            ),
            // Adam-standard moments: β₁ = 0.9, ε = 1e-8 (the fused
            // `Adam` defaults), second moment decay from the shared β₂.
            UnitKind::Adam => Box::new(AdamUnit::new(shape, 0.9, base.beta2, 1e-8)),
        }
    }

    fn label(&self) -> String {
        match *self {
            UnitKind::Shampoo => "Shampoo".into(),
            UnitKind::Sketched { rank } => format!("S-Shampoo(l={rank})"),
            UnitKind::Adam => "Adam".into(),
        }
    }

    /// FD sketch size ℓ (0 for non-sketched kinds) — wire encoding.
    pub(crate) fn rank(&self) -> usize {
        match *self {
            UnitKind::Sketched { rank } => rank,
            _ => 0,
        }
    }

    /// Stable one-byte code for the shard wire protocol.
    pub(crate) fn code(&self) -> u8 {
        match *self {
            UnitKind::Shampoo => 0,
            UnitKind::Sketched { .. } => 1,
            UnitKind::Adam => 2,
        }
    }

    /// Inverse of [`UnitKind::code`] (`rank` applies to Sketched only).
    pub(crate) fn from_code(code: u8, rank: usize) -> Option<UnitKind> {
        Some(match code {
            0 => UnitKind::Shampoo,
            1 => UnitKind::Sketched { rank },
            2 => UnitKind::Adam,
            _ => return None,
        })
    }
}

// ---------------------------------------------------------------------------
// Block executors.
// ---------------------------------------------------------------------------

/// Executes one engine step over a batch of blocks: gather each block's
/// parameter/gradient window, drive ingest/refresh/apply with the
/// supplied per-block [`StepCtx`], and scatter updated parameters back.
///
/// The contract every implementation must honor: blocks are disjoint and
/// self-contained, and the result is **bitwise identical** to driving
/// the blocks serially in index order — execution strategy (threads,
/// processes, hosts) is never allowed to change the numbers.
///
/// Ctx batch shape: the engine emits one [`StepCtx`] per block where
/// only `refresh_due` varies across blocks (the stagger schedule); all
/// other fields are step-wide. The shard wire protocol ships the shared
/// fields once per shard and *rejects* heterogeneous batches, so keep
/// that invariant if you drive an executor directly.
pub trait BlockExecutor: Send {
    /// Drive all `blocks` one step. Returns the number of inverse-root
    /// refreshes (eigendecompositions) that ran.
    fn step_blocks(
        &mut self,
        blocks: &[Block],
        params: &mut [Matrix],
        grads: &[Matrix],
        ctxs: &[StepCtx],
    ) -> anyhow::Result<usize>;

    /// Total heap bytes of executor-owned optimizer state.
    fn mem_bytes(&self) -> usize;

    /// Bytes of second-moment (covariance) state only.
    fn second_moment_bytes(&self) -> usize;

    /// Visit every live FD sketch (invariant checks). Remote executors
    /// hold their sketches out-of-process and visit nothing.
    fn for_each_sketch(&mut self, _f: &mut dyn FnMut(&FdSketch)) {}

    /// Whether this executor can run the RefreshAhead stage at all —
    /// reported once, at construction time, so the engine can resolve
    /// the `--overlap-refresh` knob explicitly (with a logged notice)
    /// instead of silently latching it off after a declined first step.
    /// The sharded executor derives this from the per-worker capability
    /// reports in the version handshake.
    fn overlap_capable(&self) -> bool {
        false
    }

    /// Start the RefreshAhead stage: recompute inverse roots *now*, in
    /// the background, for every block whose refresh slot fires at the
    /// next step (`plan.due`) or whose roots are still missing. Returns
    /// `false` if nothing was scheduled (the engine then refreshes
    /// synchronously, which is always correct).
    fn begin_refresh_ahead(&mut self, _plan: RefreshAheadPlan) -> bool {
        false
    }

    /// Join the in-flight RefreshAhead job, if any: which blocks were
    /// refreshed ahead plus the eigendecomposition count. A task panic
    /// in the background job surfaces here as an error naming the task.
    fn finish_refresh_ahead(&mut self) -> anyhow::Result<Option<RefreshAheadDone>> {
        Ok(None)
    }

    /// Short human label for `Optimizer::name` (e.g. `threads=4`,
    /// `shards=2/tcp`).
    fn label(&self) -> String;

    /// Snapshot every block's typed optimizer state, in block order —
    /// the payload behind checkpoint format v2 and the wire v4
    /// `StateSnap` RPC. Sketched blocks export O(dℓ) factors. Default:
    /// unsupported (executors that cannot reach their state, e.g. a
    /// degraded shard link, report an error instead of lying).
    fn state_snapshot(&mut self) -> anyhow::Result<Vec<BlockStateSnap>> {
        anyhow::bail!("executor {} does not support state snapshots", self.label())
    }

    /// Restore a [`BlockExecutor::state_snapshot`] (one snap per block,
    /// in block order). On success the executor's state is bitwise
    /// identical to the snapshotted one.
    fn state_restore(&mut self, _snaps: Vec<BlockStateSnap>) -> anyhow::Result<()> {
        anyhow::bail!("executor {} does not support state restore", self.label())
    }

    /// Control handle over this executor's worker fleet (kill/sever
    /// fault injection, membership epoch and stats, staged rebalance).
    /// `None` for executors without a fleet (the local executor).
    fn fleet_control(&self) -> Option<crate::coordinator::shard::FleetControl> {
        None
    }
}

/// Plan for the RefreshAhead stage: the engine's stagger schedule is a
/// pure function of the step index, so the set of blocks due at step
/// `t + 1` is known while step `t + 1`'s gradients are still being
/// computed.
#[derive(Clone, Debug)]
pub struct RefreshAheadPlan {
    /// Per-block: this block's refresh slot fires at the next step.
    pub due: Vec<bool>,
    /// Visit every block, not just the due subset — set for the first
    /// preconditioning step, where blocks without roots refresh
    /// regardless of their slot.
    pub all: bool,
    /// The step being prefetched (`t + 1`). Remote executors ship it as
    /// the idempotent-replay key for an overlap request that races a
    /// reconnect; the local executor ignores it.
    pub t_next: usize,
}

/// Result of a joined RefreshAhead job.
#[derive(Clone, Debug)]
pub struct RefreshAheadDone {
    /// Per-block: roots were recomputed ahead, so the step must not
    /// refresh them again.
    pub refreshed: Vec<bool>,
    /// Eigendecompositions that ran ahead (refresh accounting).
    pub count: usize,
}

/// Lock a block state, recovering from a poisoned mutex. A panic inside
/// a block phase is caught and surfaced as a named-task `Err` by
/// [`drive_all`], which poisons the engine — so the step path can never
/// silently keep stepping on half-updated state. What this recovery
/// buys is the paths that legitimately run *after* that failure:
/// diagnostics (memory accounting, sketch visits) and error reporting
/// must not die on a bare `PoisonError`.
pub(crate) fn lock_state(m: &Mutex<BlockState>) -> std::sync::MutexGuard<'_, BlockState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Drive `states[i]` with `ctxs[i]` for all i, serially or on the
/// persistent worker pool. Returns the number of eigendecomposition
/// refreshes; a panicking block surfaces as an `Err` naming it (the
/// engine poisons itself on that error, and a shard worker reports it
/// over the wire instead of dying). Shared by [`LocalExecutor`] and the
/// shard-worker server — both sides of the wire run exactly this loop.
///
/// The pool path keeps the PR-1 work-queue discipline (self-scheduling:
/// whichever worker frees up first takes the next block, so one slow
/// eigendecomposition never idles the rest) without spawning scoped
/// threads per step — and, since per-block work is self-contained, its
/// output is bitwise identical to the serial path.
pub(crate) fn drive_all(
    states: &[Mutex<BlockState>],
    ctxs: &[StepCtx],
    threads: usize,
) -> anyhow::Result<usize> {
    let n = states.len();
    debug_assert_eq!(n, ctxs.len());
    if threads <= 1 {
        // Serial reference path: inline on the caller, with no kernel
        // pin — a serial engine keeps nested dense-kernel parallelism,
        // exactly as before the pool.
        let mut refreshes = 0;
        for i in 0..n {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut st = lock_state(&states[i]);
                drive_block(&mut st, &ctxs[i])
            }));
            match r {
                Ok(true) => refreshes += 1,
                Ok(false) => {}
                Err(payload) => {
                    anyhow::bail!("block {i} panicked: {}", pool::panic_message(&payload))
                }
            }
        }
        Ok(refreshes)
    } else {
        let refreshes = AtomicUsize::new(0);
        pool::global()
            .try_run(threads, n, |i| {
                // Pin dense kernels to one thread per task: the engine
                // already owns the parallelism, so nested kernel
                // threading would only oversubscribe cores.
                ops::with_single_thread(|| {
                    let mut st = lock_state(&states[i]);
                    if drive_block(&mut st, &ctxs[i]) {
                        refreshes.fetch_add(1, Ordering::Relaxed);
                    }
                });
            })
            .map_err(|m| anyhow::anyhow!("block phase: {m}"))?;
        Ok(refreshes.load(Ordering::Relaxed))
    }
}

/// In-process executor: per-block states driven on the persistent pool.
/// Numerically this is the PR-1 engine path, preserved bit-for-bit.
///
/// States live behind an `Arc` so the RefreshAhead background job can
/// hold them across the gap between steps; the per-block `Mutex` is the
/// double-buffer handoff — the job writes fresh roots into the unit's
/// root slots under the lock, and the next step's `apply` picks them up
/// bitwise-identically to a synchronous refresh.
pub struct LocalExecutor {
    states: Arc<Vec<Mutex<BlockState>>>,
    /// Raw thread knob (0 = auto).
    threads: usize,
    /// In-flight RefreshAhead job (overlap mode).
    pending: Option<PendingRefresh>,
}

/// Handle + result slots of a spawned RefreshAhead job.
struct PendingRefresh {
    handle: pool::JobHandle,
    flags: Arc<Vec<AtomicBool>>,
    count: Arc<AtomicUsize>,
}

impl LocalExecutor {
    pub fn new(blocks: &[Block], kind: UnitKind, base: &ShampooConfig, threads: usize) -> Self {
        let states = blocks
            .iter()
            .map(|b| {
                let shape = b.shape();
                Mutex::new(BlockState::new(kind.make(shape, base), base.graft, shape, base.beta2))
            })
            .collect();
        LocalExecutor { states: Arc::new(states), threads, pending: None }
    }
}

impl BlockExecutor for LocalExecutor {
    fn step_blocks(
        &mut self,
        blocks: &[Block],
        params: &mut [Matrix],
        grads: &[Matrix],
        ctxs: &[StepCtx],
    ) -> anyhow::Result<usize> {
        // Join-and-discard a RefreshAhead the caller never finished (the
        // engine always joins first; direct executor drivers may not) —
        // the same cancel path as the sharded executor. Discarding is
        // bitwise-safe: the step's own refresh slot recomputes roots
        // from current statistics. Letting the background job race
        // `drive_all` on the same block states would not be.
        if self.pending.is_some() {
            self.finish_refresh_ahead()?;
        }
        // Gather: copy each block's parameter/gradient window into its
        // state scratch (allocation-free) so the parallel phase touches
        // fully disjoint data.
        for (i, b) in blocks.iter().enumerate() {
            let mut st = lock_state(&self.states[i]);
            params[b.tensor].slice_into(b.r0, b.r1, b.c0, b.c1, &mut st.param);
            grads[b.tensor].slice_into(b.r0, b.r1, b.c0, b.c1, &mut st.grad);
        }
        let threads = effective_worker_threads(self.threads, blocks.len());
        let refreshes = drive_all(&self.states, ctxs, threads)?;
        // Scatter: write updated parameter blocks back.
        for (i, b) in blocks.iter().enumerate() {
            let st = lock_state(&self.states[i]);
            params[b.tensor].set_slice(b.r0, b.c0, &st.param);
        }
        Ok(refreshes)
    }

    fn mem_bytes(&self) -> usize {
        self.states.iter().map(|s| lock_state(s).mem_bytes()).sum()
    }

    fn second_moment_bytes(&self) -> usize {
        self.states.iter().map(|s| lock_state(s).second_moment_bytes()).sum()
    }

    fn for_each_sketch(&mut self, f: &mut dyn FnMut(&FdSketch)) {
        for st in self.states.iter() {
            let st = lock_state(st);
            for fd in st.unit.sketches() {
                f(fd);
            }
        }
    }

    fn overlap_capable(&self) -> bool {
        true
    }

    fn begin_refresh_ahead(&mut self, plan: RefreshAheadPlan) -> bool {
        debug_assert!(self.pending.is_none(), "refresh-ahead already in flight");
        let n = self.states.len();
        debug_assert_eq!(plan.due.len(), n);
        // One task per block that can actually have work: the due subset
        // in steady state, every block on the first preconditioning step
        // (`plan.all`, where not-yet-ready blocks refresh regardless of
        // slot). Blocks outside the target set never spawn a task, so
        // the background job does not steal pool workers from the
        // trainer's own gradient kernels just to check a flag.
        let mut targets: Vec<usize> = Vec::new();
        for (i, &d) in plan.due.iter().enumerate() {
            if plan.all || d {
                targets.push(i);
            }
        }
        if targets.is_empty() {
            return false;
        }
        let flags: Arc<Vec<AtomicBool>> =
            Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());
        let count = Arc::new(AtomicUsize::new(0));
        let states = Arc::clone(&self.states);
        let due = plan.due;
        let job_flags = Arc::clone(&flags);
        let job_count = Arc::clone(&count);
        let parallelism = effective_worker_threads(self.threads, targets.len());
        let handle = pool::global().spawn(parallelism, targets.len(), move |j| {
            let i = targets[j];
            // Same per-task kernel pin as the step phase.
            ops::with_single_thread(|| {
                let mut st = lock_state(&states[i]);
                // Mirror of drive_block's refresh condition (the engine
                // only schedules the job on preconditioning steps that
                // fold no statistics, so the stats a synchronous refresh
                // would see are exactly the current ones).
                if !st.unit.ready() || due[i] {
                    if st.unit.refresh() {
                        job_count.fetch_add(1, Ordering::Relaxed);
                    }
                    job_flags[i].store(true, Ordering::Relaxed);
                }
            });
        });
        self.pending = Some(PendingRefresh { handle, flags, count });
        true
    }

    fn finish_refresh_ahead(&mut self) -> anyhow::Result<Option<RefreshAheadDone>> {
        let Some(p) = self.pending.take() else {
            return Ok(None);
        };
        p.handle
            .wait()
            .map_err(|m| anyhow::anyhow!("refresh-ahead job failed: {m}"))?;
        let refreshed = p.flags.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        Ok(Some(RefreshAheadDone { refreshed, count: p.count.load(Ordering::Relaxed) }))
    }

    fn label(&self) -> String {
        format!("threads={}", effective_worker_threads(self.threads, self.states.len()))
    }

    fn state_snapshot(&mut self) -> anyhow::Result<Vec<BlockStateSnap>> {
        // Join any in-flight RefreshAhead first so the snapshot can't
        // race the background job on the block states.
        self.finish_refresh_ahead()?;
        Ok(self.states.iter().map(|s| lock_state(s).snapshot()).collect())
    }

    fn state_restore(&mut self, snaps: Vec<BlockStateSnap>) -> anyhow::Result<()> {
        self.finish_refresh_ahead()?;
        anyhow::ensure!(
            snaps.len() == self.states.len(),
            "state restore: {} snaps for {} blocks",
            snaps.len(),
            self.states.len()
        );
        for (i, (s, snap)) in self.states.iter().zip(snaps).enumerate() {
            lock_state(s)
                .restore(snap)
                .map_err(|e| anyhow::anyhow!("block {i}: {e}"))?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The engine.
// ---------------------------------------------------------------------------

/// Engine-driven blocked optimizer: any [`UnitKind`] over the §3.4 block
/// partition, stepped in parallel by a [`BlockExecutor`] — in-process
/// threads by default, cross-process shards via [`PrecondEngine::sharded`].
pub struct PrecondEngine {
    pub base: ShampooConfig,
    pub ecfg: EngineConfig,
    kind: UnitKind,
    blocks: Vec<Block>,
    executor: Box<dyn BlockExecutor>,
    t: usize,
    refreshes: usize,
    /// Set when a step failed partway: a sharded step error can leave
    /// some shards having applied the step and others not, so retrying
    /// would silently diverge from the single-process run. A poisoned
    /// engine refuses further steps instead.
    poisoned: Option<String>,
}

/// Normalize the driver config per unit kind, and compute the §3.4 block
/// partition (shared by the local and sharded constructors so both paths
/// see identical blocks and hyperparameters).
fn plan(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    base: ShampooConfig,
    ecfg: &EngineConfig,
) -> (ShampooConfig, Vec<Block>) {
    // Adam is fully handled inside AdamUnit (its own β₁ momentum,
    // bias correction, per-step moments): normalize the driver config
    // so `engine-adam` reproduces the fused `Adam` exactly instead of
    // stacking grafting / second momentum / delayed preconditioning
    // on top. Only lr / β₂ / weight decay / clip pass through.
    let base = if kind == UnitKind::Adam {
        // (ekfac corrects eigenbases; a diagonal unit has none, so the
        // knob is forced off rather than silently carried around.)
        ShampooConfig {
            beta1: 0.0,
            graft: GraftType::None,
            stat_interval: 1,
            precond_interval: 1,
            start_preconditioning_step: 1,
            ekfac: false,
            ..base
        }
    } else {
        // The engine-level `--ekfac` knob and the shared ShampooConfig
        // field are one switch: either surface turns the corrector on,
        // and the normalized base is what ships in the shard InitMsg.
        ShampooConfig { ekfac: base.ekfac || ecfg.ekfac, ..base }
    };
    // block_size = 0 means "no blocking": use the largest dimension so
    // the partition yields exactly one block per tensor.
    let bsize = if ecfg.block_size == 0 {
        shapes.iter().map(|&(m, n)| m.max(n)).max().unwrap_or(1).max(1)
    } else {
        ecfg.block_size
    };
    let blocks = partition(shapes, bsize);
    (base, blocks)
}

/// Resolve the `--overlap-refresh` knob against the executor's
/// capability report, **once, at construction**: an executor that cannot
/// run the RefreshAhead stage (e.g. a shard fleet containing a
/// protocol-v1 worker) gets the knob turned off with a logged one-time
/// notice — replacing the old behavior of silently latching overlap off
/// after the first declined step, which left `name()` claiming
/// "+overlap" for a run that never overlapped anything.
fn resolve_overlap(ecfg: &mut EngineConfig, executor: &dyn BlockExecutor) {
    if ecfg.overlap && !executor.overlap_capable() {
        eprintln!(
            "note: --overlap-refresh requested, but executor '{}' reports no RefreshAhead \
             capability; refreshes run synchronously (numerics are identical either way)",
            executor.label()
        );
        ecfg.overlap = false;
    }
}

impl PrecondEngine {
    /// Engine over an executor built by a factory closure: the single
    /// internal construction path behind [`ExecutorBuilder`] and the
    /// deprecated constructor shims. `build` receives the planned block
    /// partition, the (normalized) unit config, and the thread knob.
    ///
    /// [`ExecutorBuilder`]: crate::optim::ExecutorBuilder
    pub(crate) fn build_with(
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
        build: impl FnOnce(
            &[Block],
            UnitKind,
            &ShampooConfig,
            usize,
        ) -> anyhow::Result<Box<dyn BlockExecutor>>,
    ) -> anyhow::Result<Self> {
        let (base, blocks) = plan(shapes, kind, base, &ecfg);
        if ecfg.pool_threads > 0 {
            pool::global().ensure_workers(ecfg.pool_threads);
        }
        let executor = build(&blocks, kind, &base, ecfg.threads)?;
        let mut ecfg = ecfg;
        resolve_overlap(&mut ecfg, executor.as_ref());
        Ok(PrecondEngine { base, ecfg, kind, blocks, executor, t: 0, refreshes: 0, poisoned: None })
    }

    /// In-process engine over the thread-pool executor.
    #[deprecated(note = "use optim::ExecutorBuilder::local().build(...)")]
    pub fn new(
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
    ) -> Self {
        PrecondEngine::build_with(shapes, kind, base, ecfg, |blocks, kind, base, threads| {
            Ok(Box::new(LocalExecutor::new(blocks, kind, base, threads)))
        })
        .expect("local executor construction is infallible")
    }

    /// Cross-process engine: blocks are sharded across `sketchy
    /// shard-worker` processes described by `launch`; statistics are
    /// shipped, driven and scattered over the wire protocol. Numerics
    /// are bitwise identical to the in-process engine. With
    /// `ecfg.overlap` the t+1 due-set ships to the workers as a second
    /// in-flight `RefreshAhead` RPC per shard (degrading to synchronous
    /// refresh when any worker lacks the capability).
    ///
    /// Elastic-membership / journal knobs travel inside
    /// [`ShardLaunch::membership`] and are forwarded — this shim used
    /// to substitute `MembershipConfig::default()` silently, so a
    /// launch plan resolved from `--shard-spares`/`--journal` lost its
    /// knobs unless the caller migrated to the builder.
    #[deprecated(note = "use optim::ExecutorBuilder::sharded(launch).build(...)")]
    pub fn sharded(
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
        launch: &ShardLaunch,
    ) -> anyhow::Result<Self> {
        crate::optim::ExecutorBuilder::sharded(launch.clone()).build(shapes, kind, base, ecfg)
    }

    /// Engine over an executor built by the caller.
    #[deprecated(note = "use optim::ExecutorBuilder::custom(build).build(...)")]
    pub fn with_executor(
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
        build: impl FnOnce(
            &[Block],
            UnitKind,
            &ShampooConfig,
            usize,
        ) -> anyhow::Result<Box<dyn BlockExecutor>>,
    ) -> anyhow::Result<Self> {
        PrecondEngine::build_with(shapes, kind, base, ecfg, build)
    }

    /// In-process engine (non-deprecated spelling used by the local
    /// convenience constructors below and the optimizer factories).
    fn local(
        shapes: &[(usize, usize)],
        kind: UnitKind,
        base: ShampooConfig,
        ecfg: EngineConfig,
    ) -> Self {
        PrecondEngine::build_with(shapes, kind, base, ecfg, |blocks, kind, base, threads| {
            Ok(Box::new(LocalExecutor::new(blocks, kind, base, threads)))
        })
        .expect("local executor construction is infallible")
    }

    /// Exact-Kronecker (Shampoo) engine.
    pub fn shampoo(shapes: &[(usize, usize)], base: ShampooConfig, ecfg: EngineConfig) -> Self {
        PrecondEngine::local(shapes, UnitKind::Shampoo, base, ecfg)
    }

    /// FD-sketched (S-Shampoo) engine.
    pub fn sketched(
        shapes: &[(usize, usize)],
        rank: usize,
        base: ShampooConfig,
        ecfg: EngineConfig,
    ) -> Self {
        PrecondEngine::local(shapes, UnitKind::Sketched { rank }, base, ecfg)
    }

    /// Diagonal-Adam engine (useful as the parallel-overhead baseline).
    pub fn adam(shapes: &[(usize, usize)], base: ShampooConfig, ecfg: EngineConfig) -> Self {
        PrecondEngine::local(shapes, UnitKind::Adam, base, ecfg)
    }

    /// The §3.4 block partition.
    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    /// Total inverse-root refreshes (eigendecompositions) performed so
    /// far — the quantity the stale schedule amortizes. For sharded
    /// engines this aggregates worker-reported counts.
    pub fn refreshes(&self) -> usize {
        self.refreshes
    }

    /// Visit every live FD sketch across blocks (invariant checks;
    /// in-process executors only — sharded state lives out-of-process).
    pub fn for_each_sketch(&mut self, mut f: impl FnMut(&FdSketch)) {
        self.executor.for_each_sketch(&mut f);
    }

    /// Control handle over the executor's worker fleet (kill/sever
    /// fault injection, membership epoch + stats, staged rebalancing).
    /// `None` for engines over the in-process executor.
    pub fn fleet_control(&self) -> Option<crate::coordinator::shard::FleetControl> {
        self.executor.fleet_control()
    }

    /// Re-seat the step counter after a [`PrecondEngine::state_restore`]:
    /// the stagger/stat/refresh schedules are pure functions of `t`,
    /// which travels in checkpoint metadata rather than in the block
    /// payloads, so resume wires it back explicitly.
    pub fn set_steps(&mut self, t: usize) {
        self.t = t;
    }

    /// Typed snapshot of every block's optimizer state, in block order —
    /// the checkpoint-v2 payload. Sharded engines fetch it over the wire
    /// v4 `StateSnap` RPC; executors without the capability (degraded
    /// links, pre-v4 workers) return an error rather than a dense dump.
    pub fn state_snapshot(&mut self) -> anyhow::Result<Vec<BlockStateSnap>> {
        if let Some(why) = &self.poisoned {
            anyhow::bail!("engine poisoned by earlier step failure: {why}");
        }
        self.executor.state_snapshot()
    }

    /// Restore a [`PrecondEngine::state_snapshot`] (one snap per block,
    /// in block order). Restores are bitwise: a restored engine steps
    /// identically to the snapshotted one.
    pub fn state_restore(&mut self, snaps: Vec<BlockStateSnap>) -> anyhow::Result<()> {
        if let Some(why) = &self.poisoned {
            anyhow::bail!("engine poisoned by earlier step failure: {why}");
        }
        anyhow::ensure!(
            snaps.len() == self.blocks.len(),
            "state restore: {} snaps for {} blocks",
            snaps.len(),
            self.blocks.len()
        );
        self.executor.state_restore(snaps)
    }

    /// Per-block decode expectations for the typed state codec, derived
    /// from the engine's own block table — never from payload headers —
    /// so adversarial rank/shape fields in a checkpoint or wire frame
    /// cannot drive allocations.
    pub fn state_expects(&self) -> Vec<StateExpect> {
        self.blocks
            .iter()
            .map(|b| {
                let (rows, cols) = b.shape();
                StateExpect {
                    rows,
                    cols,
                    kind: self.kind.code(),
                    rank: self.kind.rank(),
                    one_sided: self.base.one_sided,
                }
            })
            .collect()
    }

    /// Whether block `i`'s refresh slot fires at step `t` — the stagger
    /// schedule, a pure function of the indices (which is what makes the
    /// RefreshAhead due-set known one step early).
    fn refresh_due_at(&self, i: usize, t: usize) -> bool {
        let refresh_interval = self.ecfg.refresh_interval.max(1);
        let phase = if self.ecfg.stagger { i % refresh_interval } else { 0 };
        (t + phase) % refresh_interval == 0
    }

    /// Kick off the RefreshAhead stage for step `t + 1`, when doing so
    /// is bitwise-safe: the next step must precondition and must not
    /// fold statistics (otherwise a synchronous refresh would see newer
    /// statistics than a prefetched one — those steps stay synchronous).
    fn schedule_refresh_ahead(&mut self) {
        let t_next = self.t + 1;
        if t_next < self.base.start_preconditioning_step {
            return;
        }
        if t_next % self.base.stat_interval == 0 {
            return; // next step ingests: roots would differ — stay sync
        }
        let due: Vec<bool> =
            (0..self.blocks.len()).map(|i| self.refresh_due_at(i, t_next)).collect();
        // First preconditioning step refreshes every not-yet-ready block
        // regardless of its slot; otherwise skip the spawn when no slot
        // fires (after the first refresh all blocks stay ready).
        let all = t_next == self.base.start_preconditioning_step;
        if !all && !due.iter().any(|&d| d) {
            return;
        }
        // A `false` return means nothing was scheduled this step (e.g. a
        // shard link refused the send); the step then refreshes
        // synchronously, which is always bitwise-correct. Capability is
        // resolved once at construction (`resolve_overlap`), so there is
        // no silent knob-latching here.
        let _ = self.executor.begin_refresh_ahead(RefreshAheadPlan { due, all, t_next });
    }

    /// Fallible step — the sharded executor surfaces worker/transport
    /// failures here instead of panicking.
    ///
    /// An `Err` is **terminal** for this engine: the failed step may
    /// have applied on some shards but not others, so the engine
    /// poisons itself and every subsequent step fails fast rather than
    /// silently diverging from the single-process run. Recovery is a
    /// fresh engine (and, for sharded runs, fresh workers).
    pub fn try_step(&mut self, params: &mut [Matrix], grads: &[Matrix]) -> anyhow::Result<()> {
        assert_eq!(params.len(), grads.len());
        if let Some(why) = &self.poisoned {
            anyhow::bail!("engine poisoned by earlier step failure ({why})");
        }
        self.t += 1;
        let t = self.t;
        // Join the RefreshAhead job spawned at the end of the previous
        // step (if any): those blocks' roots are already fresh, so their
        // in-step refresh slot is cleared below.
        let ahead = match self.executor.finish_refresh_ahead() {
            Ok(a) => a,
            Err(e) => {
                self.poisoned = Some(format!("step {t}: {e:#}"));
                return Err(e);
            }
        };
        let scale = clip_scale(grads, self.base.clip);
        let preconditioning = t >= self.base.start_preconditioning_step;
        let stat_due = t % self.base.stat_interval == 0;
        let base = &self.base;
        let mut ctxs: Vec<StepCtx> = (0..self.blocks.len())
            .map(|i| StepCtx {
                t,
                scale,
                preconditioning,
                refresh_due: self.refresh_due_at(i, t),
                lr: base.lr,
                beta1: base.beta1,
                weight_decay: base.weight_decay,
                stat_due,
                graft: base.graft,
            })
            .collect();
        if let Some(done) = &ahead {
            for (ctx, &pre) in ctxs.iter_mut().zip(&done.refreshed) {
                if pre {
                    ctx.refresh_due = false;
                }
            }
        }
        let refreshed = match self.executor.step_blocks(&self.blocks, params, grads, &ctxs) {
            Ok(n) => n,
            Err(e) => {
                self.poisoned = Some(format!("step {t}: {e:#}"));
                return Err(e);
            }
        };
        self.refreshes += refreshed + ahead.map(|d| d.count).unwrap_or(0);
        if self.ecfg.overlap {
            self.schedule_refresh_ahead();
        }
        Ok(())
    }
}

impl Optimizer for PrecondEngine {
    fn name(&self) -> String {
        format!(
            "Engine<{}>(blocks={}, {}, refresh={}{}{})",
            self.kind.label(),
            self.blocks.len(),
            self.executor.label(),
            self.ecfg.refresh_interval,
            if self.ecfg.overlap { "+overlap" } else { "" },
            if self.base.ekfac { "+ekfac" } else { "" },
        )
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        if let Err(e) = PrecondEngine::try_step(self, params, grads) {
            // The infallible entry point cannot surface executor errors;
            // the trainers drive `Optimizer::try_step` instead.
            panic!("engine step failed: {e:#}");
        }
    }

    fn try_step(&mut self, params: &mut [Matrix], grads: &[Matrix]) -> anyhow::Result<()> {
        PrecondEngine::try_step(self, params, grads)
    }

    fn mem_bytes(&self) -> usize {
        self.executor.mem_bytes()
    }

    fn second_moment_bytes(&self) -> usize {
        self.executor.second_moment_bytes()
    }

    fn set_lr(&mut self, lr: f64) {
        self.base.lr = lr;
    }

    fn steps(&self) -> usize {
        self.t
    }

    fn state_payloads(&mut self) -> anyhow::Result<Option<Vec<BlockStateMsg>>> {
        let snaps = PrecondEngine::state_snapshot(self)?;
        Ok(Some(
            snaps.iter().enumerate().map(|(i, s)| BlockStateMsg::from_snap(i as u32, s)).collect(),
        ))
    }

    fn restore_payloads(&mut self, step: usize, entries: Vec<BlockStateMsg>) -> anyhow::Result<()> {
        let expects = self.state_expects();
        anyhow::ensure!(
            entries.len() == expects.len(),
            "state restore: {} entries for {} blocks",
            entries.len(),
            expects.len()
        );
        let mut snaps = Vec::with_capacity(entries.len());
        for (i, e) in entries.into_iter().enumerate() {
            anyhow::ensure!(
                e.index as usize == i,
                "state restore: entry {i} carries block index {}",
                e.index
            );
            snaps.push(
                e.into_snap(&expects[i]).map_err(|err| anyhow::anyhow!("block {i}: {err:#}"))?,
            );
        }
        PrecondEngine::state_restore(self, snaps)?;
        self.t = step;
        Ok(())
    }
}

/// Optimizer factory for the engine-backed family, keyed by the CLI
/// names: `engine-shampoo`, `engine-s-shampoo`, `engine-adam`.
pub fn engine_optimizer(
    name: &str,
    shapes: &[(usize, usize)],
    base: ShampooConfig,
    rank: usize,
    ecfg: EngineConfig,
) -> Option<PrecondEngine> {
    engine_unit_kind(name, rank).map(|kind| PrecondEngine::local(shapes, kind, base, ecfg))
}

/// Sharded variant of [`engine_optimizer`]: same names, blocks driven by
/// `launch.shards` worker processes. `membership` configures the elastic
/// fleet (spares, rebalancing, failover budget); pass
/// `MembershipConfig::default()` for a fixed fleet.
pub fn sharded_engine_optimizer(
    name: &str,
    shapes: &[(usize, usize)],
    base: ShampooConfig,
    rank: usize,
    ecfg: EngineConfig,
    launch: &ShardLaunch,
    membership: &MembershipConfig,
) -> anyhow::Result<Option<PrecondEngine>> {
    match engine_unit_kind(name, rank) {
        Some(kind) => Ok(Some(
            crate::optim::ExecutorBuilder::sharded(launch.clone())
                .membership(membership.clone())
                .build(shapes, kind, base, ecfg)?,
        )),
        None => Ok(None),
    }
}

/// CLI optimizer name → engine unit kind.
fn engine_unit_kind(name: &str, rank: usize) -> Option<UnitKind> {
    match name {
        "engine-shampoo" => Some(UnitKind::Shampoo),
        "engine-s-shampoo" => Some(UnitKind::Sketched { rank }),
        "engine-adam" => Some(UnitKind::Adam),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::grafting::GraftType;
    use crate::util::rng::Pcg64;

    fn base_cfg() -> ShampooConfig {
        ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        }
    }

    #[test]
    fn engine_blocks_cover_parameters() {
        let ecfg = EngineConfig { block_size: 3, ..Default::default() };
        let eng = PrecondEngine::shampoo(&[(7, 5), (4, 1)], base_cfg(), ecfg);
        // 7×5 at b=3 → rows {3,3,1} × cols {3,2} = 6; 4×1 → 2×1 = 2.
        assert_eq!(eng.blocks().len(), 8);
        let mut cells = 0;
        for b in eng.blocks() {
            let (r, c) = b.shape();
            assert!(r <= 3 && c <= 3);
            cells += r * c;
        }
        assert_eq!(cells, 7 * 5 + 4);
    }

    #[test]
    fn engine_state_snapshot_restore_is_bitwise() {
        // A restored engine must continue bitwise-identically to the
        // original — the contract checkpoint v2 and the wire v4 state
        // RPCs are built on.
        let shapes = [(9, 4), (3, 5)];
        let ecfg = EngineConfig {
            threads: 2,
            block_size: 4,
            refresh_interval: 2,
            stagger: true,
            ..Default::default()
        };
        let mut rng = Pcg64::new(0x5a51);
        let mut opt = PrecondEngine::sketched(&shapes, 3, base_cfg(), ecfg);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
        for _ in 0..7 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
            opt.try_step(&mut params, &grads).unwrap();
        }
        let snaps = opt.state_snapshot().unwrap();
        let mut fresh = PrecondEngine::sketched(&shapes, 3, base_cfg(), ecfg);
        // Snap count must match the partition.
        assert_eq!(snaps.len(), fresh.blocks().len());
        fresh.state_restore(snaps).unwrap();
        let mut params2 = params.clone();
        // Seat the restored engine's step counter the way the trainer
        // does on resume: the stagger/stat schedules are functions of
        // `t`, which travels in checkpoint metadata, not block payloads.
        fresh.set_steps(opt.steps());
        for _ in 0..6 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
            opt.try_step(&mut params, &grads).unwrap();
            fresh.try_step(&mut params2, &grads).unwrap();
            for (a, b) in params.iter().zip(&params2) {
                for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
        // Mismatched snap counts are rejected.
        let snaps = opt.state_snapshot().unwrap();
        let mut wrong = PrecondEngine::sketched(&[(9, 4)], 3, base_cfg(), EngineConfig::default());
        assert!(wrong.state_restore(snaps).is_err());
    }

    #[test]
    fn engine_converges_on_quadratic() {
        let shapes = [(6, 6)];
        let mut rng = Pcg64::new(210);
        let target = Matrix::randn(6, 6, &mut rng);
        let mut params = vec![Matrix::zeros(6, 6)];
        let ecfg = EngineConfig {
            threads: 2,
            block_size: 3,
            refresh_interval: 2,
            stagger: true,
            ..Default::default()
        };
        let mut opt = PrecondEngine::shampoo(&shapes, base_cfg(), ecfg);
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
        assert!(opt.refreshes() > 0);
        assert_eq!(opt.steps(), 3000);
    }

    #[test]
    fn sketched_engine_converges() {
        let shapes = [(12, 12)];
        let mut rng = Pcg64::new(211);
        let target = Matrix::randn(12, 12, &mut rng);
        let mut params = vec![Matrix::zeros(12, 12)];
        let ecfg = EngineConfig { threads: 3, block_size: 6, ..Default::default() };
        let mut opt = PrecondEngine::sketched(&shapes, 4, base_cfg(), ecfg);
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
    }

    #[test]
    fn config_resolution_precedence() {
        let cfg = Config::parse(
            "[engine]\nthreads = 3\nblock_size = 256\nrefresh_interval = 5\nstagger_refresh = false\noverlap_refresh = true\npool_threads = 6",
        )
        .unwrap();
        let args = Args::parse(
            ["train", "--engine-threads", "8", "--stagger-refresh", "true", "--pool-threads", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let e = EngineConfig::resolve(&args, &cfg).unwrap();
        // CLI beats config; config beats defaults.
        assert_eq!(e.threads, 8);
        assert_eq!(e.block_size, 256);
        assert_eq!(e.refresh_interval, 5);
        assert!(e.stagger);
        assert!(e.overlap);
        assert_eq!(e.pool_threads, 2);
        assert!(!e.ekfac);
        let defaults = EngineConfig::resolve(&Args::default(), &Config::default()).unwrap();
        assert_eq!(defaults.threads, 0);
        assert_eq!(defaults.refresh_interval, 10);
        assert!(defaults.stagger);
        assert!(!defaults.overlap);
        assert_eq!(defaults.pool_threads, 0);
        assert!(!defaults.ekfac);
        // The ekfac knob resolves from either surface, CLI first.
        let cfg = Config::parse("[engine]\nekfac = true").unwrap();
        assert!(EngineConfig::resolve(&Args::default(), &cfg).unwrap().ekfac);
        let args = Args::parse(["train", "--ekfac", "false"].iter().map(|s| s.to_string()));
        assert!(!EngineConfig::resolve(&args, &cfg).unwrap().ekfac);
        let args = Args::parse(["train", "--ekfac", "true"].iter().map(|s| s.to_string()));
        assert!(EngineConfig::resolve(&args, &Config::default()).unwrap().ekfac);
    }

    #[test]
    fn unknown_engine_config_keys_are_named_errors() {
        // The satellite bug: `overlap_refres = true` used to silently
        // run without overlap. Now every unknown `[engine]` key is a
        // named error listing the valid ones.
        let cfg = Config::parse("[engine]\noverlap_refres = true").unwrap();
        let err = EngineConfig::resolve(&Args::default(), &cfg).unwrap_err().to_string();
        assert!(err.contains("overlap_refres"), "error should name the bad key: {err}");
        assert!(err.contains("overlap_refresh"), "error should list known keys: {err}");
        // Other sections are not this section's business.
        let cfg = Config::parse("[shard]\nbogus = 1\n[engine]\nthreads = 2").unwrap();
        assert_eq!(EngineConfig::resolve(&Args::default(), &cfg).unwrap().threads, 2);
    }

    #[test]
    fn ekfac_knob_reaches_units_and_name() {
        let ecfg = EngineConfig { block_size: 4, ekfac: true, ..Default::default() };
        let eng = PrecondEngine::shampoo(&[(6, 4)], base_cfg(), ecfg);
        assert!(eng.base.ekfac, "plan() must fold the engine knob into the unit config");
        assert!(eng.name().contains("+ekfac"), "name: {}", eng.name());
        // Adam has no eigenbasis to correct: the knob is forced off.
        let adam = PrecondEngine::adam(&[(6, 4)], base_cfg(), ecfg);
        assert!(!adam.base.ekfac);
        assert!(!adam.name().contains("ekfac"), "name: {}", adam.name());
        // The ShampooConfig surface alone also turns it on.
        let base = ShampooConfig { ekfac: true, ..base_cfg() };
        let eng =
            PrecondEngine::sketched(&[(6, 4)], 3, base, EngineConfig { ..Default::default() });
        assert!(eng.base.ekfac);
    }

    #[test]
    fn ekfac_engine_converges_on_quadratic() {
        let shapes = [(8, 8)];
        let mut rng = Pcg64::new(219);
        let target = Matrix::randn(8, 8, &mut rng);
        let mut params = vec![Matrix::zeros(8, 8)];
        let ecfg = EngineConfig {
            threads: 2,
            block_size: 4,
            refresh_interval: 16,
            stagger: true,
            ekfac: true,
            ..Default::default()
        };
        let mut opt = PrecondEngine::shampoo(&shapes, base_cfg(), ecfg);
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
        assert!(opt.refreshes() > 0);
    }

    #[test]
    fn factory_names() {
        let shapes = [(4, 4)];
        for name in ["engine-shampoo", "engine-s-shampoo", "engine-adam"] {
            let opt = engine_optimizer(name, &shapes, base_cfg(), 2, EngineConfig::default());
            assert!(opt.is_some(), "{name} should resolve");
        }
        let unknown = engine_optimizer("sgd", &shapes, base_cfg(), 2, EngineConfig::default());
        assert!(unknown.is_none());
    }

    #[test]
    fn unit_kind_codes_roundtrip() {
        for kind in [UnitKind::Shampoo, UnitKind::Sketched { rank: 9 }, UnitKind::Adam] {
            assert_eq!(UnitKind::from_code(kind.code(), kind.rank()), Some(kind));
        }
        assert_eq!(UnitKind::from_code(77, 0), None);
    }

    #[test]
    fn overlap_knob_resolves_against_executor_capability_at_construction() {
        // Satellite bugfix pin: an executor that reports no RefreshAhead
        // capability must get the overlap knob turned off *at
        // construction* (with the logged notice), not silently latched
        // off after a declined first step — and `name()` must reflect
        // what actually runs.
        struct NoOverlap(LocalExecutor);
        impl BlockExecutor for NoOverlap {
            fn step_blocks(
                &mut self,
                blocks: &[Block],
                params: &mut [Matrix],
                grads: &[Matrix],
                ctxs: &[StepCtx],
            ) -> anyhow::Result<usize> {
                self.0.step_blocks(blocks, params, grads, ctxs)
            }
            fn mem_bytes(&self) -> usize {
                self.0.mem_bytes()
            }
            fn second_moment_bytes(&self) -> usize {
                self.0.second_moment_bytes()
            }
            fn label(&self) -> String {
                "no-overlap".into()
            }
            // overlap_capable stays the default `false`; begin/finish
            // stay the decline defaults.
        }
        let shapes = [(6usize, 6usize)];
        let ecfg = EngineConfig { block_size: 3, overlap: true, ..Default::default() };
        let mut incapable = crate::optim::ExecutorBuilder::custom(|blocks, kind, base, threads| {
            Ok(Box::new(NoOverlap(LocalExecutor::new(blocks, kind, base, threads)))
                as Box<dyn BlockExecutor>)
        })
        .build(&shapes, UnitKind::Shampoo, base_cfg(), ecfg)
        .unwrap();
        assert!(!incapable.ecfg.overlap, "knob must resolve off for incapable executors");
        assert!(!incapable.name().contains("overlap"), "name: {}", incapable.name());
        // A capable (local) executor keeps the knob on.
        let capable = PrecondEngine::shampoo(&shapes, base_cfg(), ecfg);
        assert!(capable.ecfg.overlap);
        assert!(capable.name().contains("+overlap"), "name: {}", capable.name());
        // And the incapable engine still steps correctly (synchronous
        // refreshes), bitwise equal to a plain sync engine.
        let sync_ecfg = EngineConfig { block_size: 3, overlap: false, ..Default::default() };
        let mut sync = PrecondEngine::shampoo(&shapes, base_cfg(), sync_ecfg);
        let mut p1 = vec![Matrix::zeros(6, 6)];
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(218);
        for _ in 0..8 {
            let grads = vec![Matrix::randn(6, 6, &mut rng)];
            sync.step(&mut p1, &grads);
            incapable.step(&mut p2, &grads);
            assert_eq!(p1[0].max_diff(&p2[0]), 0.0);
        }
        assert_eq!(sync.refreshes(), incapable.refreshes());
    }

    #[test]
    fn local_executor_label_reports_effective_threads() {
        let ecfg = EngineConfig { threads: 6, block_size: 4, ..Default::default() };
        // 8×8 at b=4 → 4 blocks; 6 requested threads clamp to 4.
        let eng = PrecondEngine::shampoo(&[(8, 8)], base_cfg(), ecfg);
        assert!(eng.name().contains("threads=4"), "name: {}", eng.name());
    }
}
