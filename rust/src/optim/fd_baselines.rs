//! FD-sketched baselines from the related work (Tbl. 1, Appendix A):
//! Ada-FD [26], FD-SON [27], RFD-SON [43].
//!
//! These differ from S-AdaGrad (Alg. 2) in exactly the dimension the
//! paper's analysis isolates: how the sketch's missing mass is put back.
//! Ada-FD adds a *fixed* δI (Observation 2 shows this costs Ω(T^{3/4}));
//! FD-SON is a sketched Online Newton Step with fixed δI and an H⁻¹
//! (not H^{-1/2}) update; RFD-SON robustly adds *half* the escaped mass.

use super::vector::VectorOptimizer;
use crate::sketch::FdSketch;

/// Ada-FD (Wan & Zhang [26]): preconditioner H_t = Ḡ_t + δI with a fixed
/// δ > 0; update x ← x − η H_t^{-1/2} g.
pub struct AdaFd {
    pub lr: f64,
    pub delta: f64,
    sketch: FdSketch,
    t: usize,
}

impl AdaFd {
    pub fn new(d: usize, ell: usize, lr: f64, delta: f64) -> Self {
        AdaFd { lr, delta, sketch: FdSketch::new(d, ell, 1.0), t: 0 }
    }

    pub fn sketch(&self) -> &FdSketch {
        &self.sketch
    }
}

impl VectorOptimizer for AdaFd {
    fn name(&self) -> String {
        "Ada-FD".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        self.sketch.update_vec(g);
        // Fixed diagonal regularization — no escaped-mass compensation.
        let pre = self.sketch.shifted(self.delta);
        let dir = pre.apply_inv_root_vec(2.0, g);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        if let Some(r) = radius {
            let projected = pre.project_ball(x, r);
            x.copy_from_slice(&projected);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.sketch.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// FD-SON (Luo et al. [27]): sketched Online Newton Step,
/// H_t = Ḡ_t + δI, x ← x − η H_t^{-1} g.
pub struct FdSon {
    pub lr: f64,
    pub delta: f64,
    sketch: FdSketch,
    t: usize,
}

impl FdSon {
    pub fn new(d: usize, ell: usize, lr: f64, delta: f64) -> Self {
        FdSon { lr, delta, sketch: FdSketch::new(d, ell, 1.0), t: 0 }
    }
}

impl VectorOptimizer for FdSon {
    fn name(&self) -> String {
        "FD-SON".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        self.sketch.update_vec(g);
        let pre = self.sketch.shifted(self.delta);
        // Newton-style inverse (p = 1).
        let dir = pre.apply_inv_root_vec(1.0, g);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        if let Some(r) = radius {
            let projected = pre.project_ball(x, r);
            x.copy_from_slice(&projected);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.sketch.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// RFD-SON (Luo et al. [43]): robust FD — the preconditioner adds *half*
/// the cumulative escaped mass, H_t = Ḡ_t + (ρ_{1:t}/2 + δ)I, with δ = 0
/// allowed (the paper's main RFD₀ variant); x ← x − η H_t^{-1} g.
pub struct RfdSon {
    pub lr: f64,
    pub delta: f64,
    sketch: FdSketch,
    t: usize,
}

impl RfdSon {
    pub fn new(d: usize, ell: usize, lr: f64, delta: f64) -> Self {
        RfdSon { lr, delta, sketch: FdSketch::new(d, ell, 1.0), t: 0 }
    }
}

impl VectorOptimizer for RfdSon {
    fn name(&self) -> String {
        "RFD-SON".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        self.sketch.update_vec(g);
        let shift = 0.5 * self.sketch.escaped_mass() + self.delta;
        let pre = self.sketch.shifted(shift);
        let dir = pre.apply_inv_root_vec(1.0, g);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        if let Some(r) = radius {
            let projected = pre.project_ball(x, r);
            x.copy_from_slice(&projected);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.sketch.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn converges(opt: &mut dyn VectorOptimizer, tol: f64) {
        let a = [0.5, -1.0, 0.25];
        let mut x = [0.0; 3];
        for _ in 0..4000 {
            let g: Vec<f64> = (0..3).map(|i| x[i] - a[i]).collect();
            opt.step(&mut x, &g, None);
        }
        for i in 0..3 {
            assert!((x[i] - a[i]).abs() < tol, "{}: x={x:?}", opt.name());
        }
    }

    #[test]
    fn all_baselines_converge() {
        // Newton-style (H⁻¹) methods contract like t^{-η}, so they need
        // η > 1 on quadratics; the sqrt methods use standard rates.
        converges(&mut AdaFd::new(3, 2, 0.5, 1e-3), 0.05);
        converges(&mut FdSon::new(3, 2, 2.0, 0.5), 0.05);
        converges(&mut RfdSon::new(3, 2, 2.0, 0.5), 0.05);
    }

    #[test]
    fn ada_fd_ignores_escaped_mass() {
        // Feed a full-rank stream: Ada-FD's shift stays δ while
        // S-AdaGrad's grows with ρ — the Observation 2 mechanism.
        let mut rng = Pcg64::new(120);
        let d = 6;
        let mut ada = AdaFd::new(d, 2, 0.1, 1e-6);
        let mut x = vec![0.0; d];
        for _ in 0..50 {
            let g = rng.gaussian_vec(d);
            ada.step(&mut x, &g, None);
        }
        // Escaped mass accumulated in the sketch but NOT in the shift.
        assert!(ada.sketch().escaped_mass() > 1.0);
        assert_eq!(ada.delta, 1e-6);
    }

    #[test]
    fn rfd_shift_tracks_escaped_mass() {
        let mut rng = Pcg64::new(121);
        let d = 6;
        let mut rfd = RfdSon::new(d, 2, 0.1, 0.0);
        let mut x = vec![0.0; d];
        for _ in 0..50 {
            let g = rng.gaussian_vec(d);
            rfd.step(&mut x, &g, None);
        }
        assert!(rfd.sketch.escaped_mass() > 0.0);
    }

    #[test]
    fn projections_feasible() {
        let mut rng = Pcg64::new(122);
        for opt in [
            &mut AdaFd::new(4, 2, 2.0, 1e-3) as &mut dyn VectorOptimizer,
            &mut FdSon::new(4, 2, 2.0, 1e-3),
            &mut RfdSon::new(4, 2, 2.0, 0.0),
        ] {
            let mut x = vec![0.0; 4];
            for _ in 0..10 {
                let g = rng.gaussian_vec(4);
                opt.step(&mut x, &g, Some(1.0));
                assert!(crate::tensor::norm2(&x) <= 1.0 + 1e-9, "{}", opt.name());
            }
        }
    }
}
