//! First-order vector baselines: OGD and diagonal AdaGrad.
//!
//! These are the `OGD` and `Adagrad` rows of Tbl. 3 / Fig. 4. Diagonal
//! AdaGrad is also the quality reference the paper's sublinear-memory
//! discussion (§3.2) compares against.

use super::vector::{project_l2, VectorOptimizer};

/// Online gradient descent, x ← x − η_t g with η_t = η/√t by default
/// (the standard OCO schedule) or constant η.
pub struct Ogd {
    pub lr: f64,
    /// If true use η/√t, else constant η.
    pub sqrt_decay: bool,
    t: usize,
}

impl Ogd {
    pub fn new(lr: f64, sqrt_decay: bool) -> Self {
        Ogd { lr, sqrt_decay, t: 0 }
    }
}

impl VectorOptimizer for Ogd {
    fn name(&self) -> String {
        "OGD".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        let eta = if self.sqrt_decay {
            self.lr / (self.t as f64).sqrt()
        } else {
            self.lr
        };
        for i in 0..x.len() {
            x[i] -= eta * g[i];
        }
        if let Some(r) = radius {
            project_l2(x, r);
        }
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// Diagonal AdaGrad (Duchi et al. [2]): h += g², x ← x − η g / (√h + ε).
pub struct AdaGradDiag {
    pub lr: f64,
    pub eps: f64,
    h: Vec<f64>,
    t: usize,
}

impl AdaGradDiag {
    pub fn new(d: usize, lr: f64) -> Self {
        AdaGradDiag { lr, eps: 1e-12, h: vec![0.0; d], t: 0 }
    }
}

impl VectorOptimizer for AdaGradDiag {
    fn name(&self) -> String {
        "AdaGrad".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        for i in 0..x.len() {
            self.h[i] += g[i] * g[i];
            x[i] -= self.lr * g[i] / (self.h[i].sqrt() + self.eps);
        }
        if let Some(r) = radius {
            // Projection in the ‖·‖_{H^{1/2}} norm, solved by bisection on
            // the KKT multiplier (diagonal case closed form per ν).
            project_diag_norm(x, &self.h, r);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.h.capacity() * 8
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// Projection of y onto {‖x‖₂ ≤ r} in the norm diag(h)^{1/4}... precisely:
/// minimize (x−y)ᵀ diag(√h) (x−y) s.t. ‖x‖₂ ≤ r.
/// KKT: x_i = √h_i y_i / (√h_i + ν); ‖x(ν)‖ monotone ↓ in ν → bisection.
pub fn project_diag_norm(x: &mut [f64], h: &[f64], radius: f64) {
    let n2: f64 = x.iter().map(|v| v * v).sum();
    if n2 <= radius * radius {
        return;
    }
    let m: Vec<f64> = h.iter().map(|&v| v.max(0.0).sqrt()).collect();
    let y = x.to_vec();
    let norm_at = |nu: f64| -> f64 {
        let mut s = 0.0;
        for i in 0..y.len() {
            let c = if m[i] + nu > 0.0 { m[i] / (m[i] + nu) * y[i] } else { 0.0 };
            s += c * c;
        }
        s
    };
    let mut lo = 0.0;
    let mut hi = 1.0;
    while norm_at(hi) > radius * radius && hi < 1e18 {
        hi *= 2.0;
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if norm_at(mid) > radius * radius {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let nu = 0.5 * (lo + hi);
    for i in 0..x.len() {
        x[i] = if m[i] + nu > 0.0 { m[i] / (m[i] + nu) * y[i] } else { 0.0 };
    }
    project_l2(x, radius); // numerical guard
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(x) = ½‖x − a‖² with each optimizer; both must converge.
    fn quad_converges(opt: &mut dyn VectorOptimizer) {
        let a = [1.0, -2.0, 3.0];
        let mut x = [0.0; 3];
        for _ in 0..4000 {
            let g: Vec<f64> = (0..3).map(|i| x[i] - a[i]).collect();
            opt.step(&mut x, &g, None);
        }
        for i in 0..3 {
            assert!((x[i] - a[i]).abs() < 0.05, "x={x:?}");
        }
    }

    #[test]
    fn ogd_converges() {
        quad_converges(&mut Ogd::new(0.5, true));
    }

    #[test]
    fn adagrad_converges() {
        quad_converges(&mut AdaGradDiag::new(3, 0.5));
    }

    #[test]
    fn projection_respected() {
        let mut opt = Ogd::new(10.0, false);
        let mut x = [0.0; 2];
        opt.step(&mut x, &[-1.0, -1.0], Some(1.0));
        assert!(crate::tensor::norm2(&x) <= 1.0 + 1e-12);
    }

    #[test]
    fn diag_projection_optimality() {
        // Compare against brute-force search on a 2-d problem.
        let h = [4.0, 1.0];
        let y = [2.0, 2.0];
        let mut x = y;
        project_diag_norm(&mut x, &h, 1.0);
        assert!((x[0] * x[0] + x[1] * x[1]).sqrt() <= 1.0 + 1e-9);
        let obj = |p: &[f64]| {
            h.iter()
                .zip(p.iter().zip(y.iter()))
                .map(|(&hi, (&pi, &yi))| hi.sqrt() * (pi - yi) * (pi - yi))
                .sum::<f64>()
        };
        let xobj = obj(&x);
        // Grid over the boundary.
        for k in 0..200 {
            let th = 2.0 * std::f64::consts::PI * k as f64 / 200.0;
            let p = [th.cos(), th.sin()];
            assert!(xobj <= obj(&p) + 1e-6);
        }
    }

    #[test]
    fn adagrad_adapts_per_coordinate() {
        // Coordinate with larger gradients should get a smaller step.
        let mut opt = AdaGradDiag::new(2, 1.0);
        let mut x = [0.0, 0.0];
        opt.step(&mut x, &[10.0, 0.1], None);
        // First step: x_i = -lr * g/√(g²) = -lr * sign(g): equal.
        assert!((x[0] + 1.0).abs() < 1e-9 && (x[1] + 1.0).abs() < 1e-6);
        let before = x;
        opt.step(&mut x, &[10.0, 0.1], None);
        let d0 = (x[0] - before[0]).abs();
        let d1 = (x[1] - before[1]).abs();
        assert!((d0 - d1).abs() < 1e-9, "equal per-coordinate normalized steps");
    }
}
