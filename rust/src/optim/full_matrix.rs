//! Full-matrix AdaGrad (Duchi et al. [2]) and Epoch AdaGrad (Alg. 5,
//! App. G) — the d² baselines of Tbl. 1 and the step-skipping experiment.
//!
//! These materialize the d×d covariance, so they are only used at the
//! small dimensions of the theory experiments (E1, E8). The inverse root
//! is recomputed spectrally; Epoch AdaGrad recomputes it only at the
//! epoch boundaries t_k, which is exactly the step-skipping scheme the
//! paper justifies in Appendix G.

use super::vector::{project_l2, VectorOptimizer};
use crate::tensor::{eigh, matvec, Matrix};

/// Full-matrix AdaGrad: G += g gᵀ, x ← x − η G^{-1/2} g (pseudo-inverse).
pub struct AdaGradFull {
    pub lr: f64,
    /// ε ridge added to the spectrum before inversion (0 = pseudo-inverse).
    pub eps: f64,
    g: Matrix,
    t: usize,
}

impl AdaGradFull {
    pub fn new(d: usize, lr: f64) -> Self {
        AdaGradFull { lr, eps: 0.0, g: Matrix::zeros(d, d), t: 0 }
    }

    /// Current preconditioner inverse root (recomputed; O(d³)).
    fn inv_sqrt(&self) -> Matrix {
        if self.eps > 0.0 {
            crate::tensor::inv_pth_root(&self.g, 2.0, self.eps)
        } else {
            crate::tensor::pinv_sqrt(&self.g, 1e-12)
        }
    }
}

impl VectorOptimizer for AdaGradFull {
    fn name(&self) -> String {
        "AdaGrad-Full".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        for i in 0..g.len() {
            for j in 0..g.len() {
                self.g[(i, j)] += g[i] * g[j];
            }
        }
        let p = self.inv_sqrt();
        let dir = matvec(&p, g);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        if let Some(r) = radius {
            project_full_norm(x, &self.g, r);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.g.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

/// Projection onto {‖x‖₂ ≤ r} in the ‖·‖_{G^{1/2}} norm via the
/// eigenbasis of G (O(d³); theory-scale dims only).
pub fn project_full_norm(x: &mut [f64], g: &Matrix, radius: f64) {
    let n2: f64 = x.iter().map(|v| v * v).sum();
    if n2 <= radius * radius {
        return;
    }
    let e = eigh(g);
    let m: Vec<f64> = e.w.iter().map(|&w| w.max(0.0).sqrt()).collect();
    // Coefficients in the eigenbasis.
    let c = crate::tensor::matvec_t(&e.q, x);
    let norm_at = |nu: f64| -> f64 {
        c.iter()
            .zip(&m)
            .map(|(&ci, &mi)| {
                let v = if mi + nu > 0.0 { mi / (mi + nu) * ci } else { 0.0 };
                v * v
            })
            .sum()
    };
    let mut lo = 0.0;
    let mut hi = 1.0;
    while norm_at(hi) > radius * radius && hi < 1e18 {
        hi *= 2.0;
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if norm_at(mid) > radius * radius {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let nu = 0.5 * (lo + hi);
    let cnew: Vec<f64> = c
        .iter()
        .zip(&m)
        .map(|(&ci, &mi)| if mi + nu > 0.0 { mi / (mi + nu) * ci } else { 0.0 })
        .collect();
    let xnew = matvec(&e.q, &cnew);
    x.copy_from_slice(&xnew);
    project_l2(x, radius);
}

/// Generic Epoch AdaGrad (Alg. 5): statistics update every round, inverse
/// root refresh only every `interval` rounds (update points t_k).
pub struct EpochAdaGrad {
    pub lr: f64,
    pub interval: usize,
    /// G_0 = eps0·I ≻ 0 per Alg. 5's requirement.
    g: Matrix,
    cached_inv_sqrt: Matrix,
    t: usize,
}

impl EpochAdaGrad {
    pub fn new(d: usize, lr: f64, interval: usize, eps0: f64) -> Self {
        assert!(interval >= 1);
        let mut g = Matrix::zeros(d, d);
        g.add_diag(eps0);
        let cached = crate::tensor::inv_pth_root(&g, 2.0, 0.0);
        EpochAdaGrad { lr, interval, g, cached_inv_sqrt: cached, t: 0 }
    }
}

impl VectorOptimizer for EpochAdaGrad {
    fn name(&self) -> String {
        format!("EpochAdaGrad(k={})", self.interval)
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        for i in 0..g.len() {
            for j in 0..g.len() {
                self.g[(i, j)] += g[i] * g[j];
            }
        }
        // Refresh preconditioner at epoch boundaries (Alg. 5 uses the
        // preconditioner fixed at t_k throughout the epoch).
        if self.t % self.interval == 0 || self.t == 1 {
            self.cached_inv_sqrt = crate::tensor::pinv_sqrt(&self.g, 1e-12);
        }
        let dir = matvec(&self.cached_inv_sqrt, g);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        if let Some(r) = radius {
            project_full_norm(x, &self.g, r);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.g.mem_bytes() + self.cached_inv_sqrt.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn full_adagrad_converges_on_ill_conditioned_quadratic() {
        // f(x) = ½ xᵀ D x, D = diag(100, 1): full-matrix AdaGrad adapts.
        let mut opt = AdaGradFull::new(2, 1.0);
        let mut x = [1.0, 1.0];
        for _ in 0..2000 {
            let g = [100.0 * x[0], x[1]];
            opt.step(&mut x, &g, None);
        }
        assert!(x[0].abs() < 1e-2 && x[1].abs() < 1e-2, "x={x:?}");
    }

    #[test]
    fn epoch_interval_one_matches_full() {
        let mut rng = Pcg64::new(100);
        let d = 4;
        let mut full = AdaGradFull::new(d, 0.1);
        let mut epoch = EpochAdaGrad::new(d, 0.1, 1, 0.0);
        let mut x1 = vec![0.0; d];
        let mut x2 = vec![0.0; d];
        for _ in 0..30 {
            let g = rng.gaussian_vec(d);
            full.step(&mut x1, &g, None);
            epoch.step(&mut x2, &g, None);
        }
        for i in 0..d {
            assert!(
                (x1[i] - x2[i]).abs() < 1e-8,
                "interval=1 deviates: {x1:?} vs {x2:?}"
            );
        }
    }

    #[test]
    fn epoch_interval_reduces_root_recomputation_but_tracks() {
        // With interval 10, iterates stay close to interval 1 on a
        // stationary stream (App. G's claim: only log-factor regret loss).
        let mut rng = Pcg64::new(101);
        let d = 3;
        // G₀ = I ≻ 0 per Alg. 5, avoiding the tiny-spectrum first-step blowup.
        let mut a = EpochAdaGrad::new(d, 0.1, 1, 1.0);
        let mut b = EpochAdaGrad::new(d, 0.1, 10, 1.0);
        let mut xa = vec![0.0; d];
        let mut xb = vec![0.0; d];
        let target = [1.0, -1.0, 0.5];
        for _ in 0..1500 {
            let ga: Vec<f64> = (0..d).map(|i| xa[i] - target[i] + 0.05 * rng.gaussian()).collect();
            let gb: Vec<f64> = (0..d).map(|i| xb[i] - target[i] + 0.05 * rng.gaussian()).collect();
            a.step(&mut xa, &ga, None);
            b.step(&mut xb, &gb, None);
        }
        for i in 0..d {
            assert!((xa[i] - target[i]).abs() < 0.15, "interval=1: {xa:?}");
            assert!((xb[i] - target[i]).abs() < 0.15, "interval=10: {xb:?}");
        }
    }

    #[test]
    fn full_projection_feasible_and_better_than_scaling() {
        let mut g = Matrix::zeros(2, 2);
        g[(0, 0)] = 100.0;
        g[(1, 1)] = 1.0;
        let mut x = [2.0, 2.0];
        project_full_norm(&mut x, &g, 1.0);
        assert!(crate::tensor::norm2(&x) <= 1.0 + 1e-9);
        // The M-norm projection should preserve the heavy coordinate more.
        assert!(x[0] > x[1]);
    }
}
