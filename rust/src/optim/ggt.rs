//! GGT (Agarwal et al. [6]) — the gradient-history low-rank baseline.
//!
//! Keeps the last r gradients as columns of a buffer H ∈ R^{d×r} and
//! preconditions with (H Hᵀ)^{-1/2} (pseudo-inverse, computed through
//! the r×r Gram eigendecomposition). This is the §3.1 related-work
//! method whose O(d·r) *whole-model* memory is what restricts it to
//! small models — the contrast motivating Sketchy's per-factor
//! sketching (Fig. 1 row "GGT").

use super::vector::{project_l2, VectorOptimizer};
use crate::tensor::{at_a, eigh, matvec, matvec_t, Matrix};

/// GGT with a circular gradient-history window.
pub struct Ggt {
    pub lr: f64,
    pub eps: f64,
    /// History buffer, d×r (columns = recent gradients).
    h: Matrix,
    /// Number of valid columns so far.
    filled: usize,
    /// Next column to overwrite.
    cursor: usize,
    t: usize,
}

impl Ggt {
    pub fn new(d: usize, history: usize, lr: f64) -> Self {
        assert!(history >= 1);
        Ggt { lr, eps: 1e-12, h: Matrix::zeros(d, history), filled: 0, cursor: 0, t: 0 }
    }

    pub fn history(&self) -> usize {
        self.h.cols()
    }
}

impl VectorOptimizer for Ggt {
    fn name(&self) -> String {
        format!("GGT(r={})", self.h.cols())
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        let r = self.h.cols();
        self.h.set_col(self.cursor, g);
        self.cursor = (self.cursor + 1) % r;
        self.filled = (self.filled + 1).min(r);
        // (H Hᵀ)^{-1/2} g via the small Gram: HᵀH = V Λ Vᵀ ⇒
        // (HHᵀ)^{-1/2} g = U Λ^{-1/2} Uᵀ g with U = H V Λ^{-1/2}
        //               = H V Λ^{-3/2} Vᵀ Hᵀ g  (+ 0 on the complement).
        let gram = at_a(&self.h); // r×r
        let e = eigh(&gram);
        let hg = matvec_t(&self.h, g); // r
        let c = matvec_t(&e.q, &hg); // coefficients Vᵀ Hᵀ g
        let wmax = e.w.first().copied().unwrap_or(0.0).max(0.0);
        let cut = 1e-10 * (1.0 + wmax);
        let scaled: Vec<f64> = c
            .iter()
            .zip(&e.w)
            .map(|(&ci, &wi)| if wi > cut { ci * wi.powf(-1.5) } else { 0.0 })
            .collect();
        let back = matvec(&e.q, &scaled);
        let dir = matvec(&self.h, &back);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        if let Some(rad) = radius {
            project_l2(x, rad);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.h.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Ggt::new(3, 8, 0.5);
        let a = [1.0, -2.0, 0.5];
        let mut x = [0.0; 3];
        for _ in 0..3000 {
            let g: Vec<f64> = (0..3).map(|i| x[i] - a[i]).collect();
            opt.step(&mut x, &g, None);
        }
        for i in 0..3 {
            assert!((x[i] - a[i]).abs() < 0.05, "x={x:?}");
        }
    }

    #[test]
    fn direction_matches_full_pinv_sqrt_of_window() {
        // With d small we can materialize H Hᵀ and compare directions.
        let mut rng = Pcg64::new(600);
        let d = 6;
        let r = 4;
        let mut opt = Ggt::new(d, r, 1.0);
        let mut x = vec![0.0; d];
        let mut grads = vec![];
        for _ in 0..r {
            let g = rng.gaussian_vec(d);
            grads.push(g.clone());
            opt.step(&mut x, &g, None);
        }
        // Recompute the last direction manually.
        let mut h = Matrix::zeros(d, r);
        for (j, g) in grads.iter().enumerate() {
            h.set_col(j, g);
        }
        let cov = crate::tensor::a_at(&h);
        let pinv = crate::tensor::pinv_sqrt(&cov, 1e-10);
        let want = matvec(&pinv, &grads[r - 1]);
        // Re-run the optimizer's internal computation on the same state.
        let mut opt2 = Ggt::new(d, r, 1.0);
        let mut x2 = vec![0.0; d];
        for g in &grads[..r - 1] {
            opt2.step(&mut x2, g, None);
        }
        let before = x2.clone();
        opt2.step(&mut x2, &grads[r - 1], None);
        for i in 0..d {
            let step = before[i] - x2[i];
            assert!(
                (step - want[i]).abs() < 1e-8,
                "direction mismatch at {i}: {} vs {}",
                step,
                want[i]
            );
        }
    }

    #[test]
    fn memory_is_d_times_r() {
        let opt = Ggt::new(1000, 16, 0.1);
        assert_eq!(opt.mem_bytes(), 1000 * 16 * 8);
    }
}
