//! Layer-wise grafting (Agarwal et al. [61], used by Shampoo per App. C).
//!
//! Grafting runs a cheap diagonal method alongside the preconditioned one
//! and *transplants its per-tensor step magnitude* onto the Shampoo
//! direction: `update = ‖graft_step‖_F · shampoo_dir / ‖shampoo_dir‖_F`.
//! This disentangles the learning-rate schedule (carried by the diagonal
//! method) from the update geometry (carried by Shampoo). The paper's
//! tuning script fixes RMSPROP_NORMALIZED for the DL experiments.

use crate::tensor::Matrix;

/// Which diagonal method supplies the step magnitude.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraftType {
    /// No grafting: use the raw preconditioned direction.
    None,
    /// SGD magnitude: ‖g‖_F.
    Sgd,
    /// RMSProp: v ← β₂v + (1−β₂)g², step g/√(v+ε).
    Rmsprop,
    /// RMSProp over unit-normalized gradients (RMSPROP_NORMALIZED).
    RmspropNormalized,
    /// AdaGrad: v ← v + g², step g/(√v+ε).
    Adagrad,
    /// AdaGrad over unit-normalized gradients.
    AdagradNormalized,
}

impl GraftType {
    pub fn parse(s: &str) -> Option<GraftType> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" => GraftType::None,
            "sgd" => GraftType::Sgd,
            "rmsprop" => GraftType::Rmsprop,
            "rmsprop_normalized" => GraftType::RmspropNormalized,
            "adagrad" => GraftType::Adagrad,
            "adagrad_normalized" => GraftType::AdagradNormalized,
            _ => return None,
        })
    }

    /// Stable one-byte code for the shard wire protocol
    /// ([`crate::coordinator::wire`]).
    pub fn code(&self) -> u8 {
        match self {
            GraftType::None => 0,
            GraftType::Sgd => 1,
            GraftType::Rmsprop => 2,
            GraftType::RmspropNormalized => 3,
            GraftType::Adagrad => 4,
            GraftType::AdagradNormalized => 5,
        }
    }

    /// Inverse of [`GraftType::code`].
    pub fn from_code(code: u8) -> Option<GraftType> {
        Some(match code {
            0 => GraftType::None,
            1 => GraftType::Sgd,
            2 => GraftType::Rmsprop,
            3 => GraftType::RmspropNormalized,
            4 => GraftType::Adagrad,
            5 => GraftType::AdagradNormalized,
            _ => return None,
        })
    }
}

/// Per-tensor grafting state.
pub struct Graft {
    pub kind: GraftType,
    pub beta2: f64,
    pub eps: f64,
    /// Diagonal accumulator (same shape as the tensor), when needed.
    v: Option<Matrix>,
    t: usize,
}

impl Graft {
    pub fn new(kind: GraftType, shape: (usize, usize), beta2: f64) -> Self {
        let v = match kind {
            GraftType::None | GraftType::Sgd => None,
            _ => Some(Matrix::zeros(shape.0, shape.1)),
        };
        Graft { kind, beta2, eps: 1e-8, v, t: 0 }
    }

    /// Advance the diagonal state with gradient `g` and return the
    /// grafting step (the diagonal method's update direction, pre-lr).
    pub fn step(&mut self, g: &Matrix) -> Matrix {
        self.t += 1;
        let normalized;
        let g_eff: &Matrix = match self.kind {
            GraftType::RmspropNormalized | GraftType::AdagradNormalized => {
                let n = g.fro_norm().max(1e-30);
                normalized = g.scale(1.0 / n);
                &normalized
            }
            _ => g,
        };
        match self.kind {
            GraftType::None | GraftType::Sgd => g.clone(),
            GraftType::Rmsprop | GraftType::RmspropNormalized => {
                let v = self.v.as_mut().unwrap();
                for (vi, gi) in v.as_mut_slice().iter_mut().zip(g_eff.as_slice()) {
                    *vi = self.beta2 * *vi + (1.0 - self.beta2) * gi * gi;
                }
                // Bias-correct the EMA so early steps aren't inflated by
                // the zero initialization (Adam-style 1/(1−β₂ᵗ)).
                let bc = 1.0 - self.beta2.powi(self.t as i32);
                let mut out = g_eff.clone();
                for (oi, vi) in out.as_mut_slice().iter_mut().zip(v.as_slice()) {
                    *oi /= (vi / bc).sqrt() + self.eps;
                }
                out
            }
            GraftType::Adagrad | GraftType::AdagradNormalized => {
                let v = self.v.as_mut().unwrap();
                for (vi, gi) in v.as_mut_slice().iter_mut().zip(g_eff.as_slice()) {
                    *vi += gi * gi;
                }
                let mut out = g_eff.clone();
                for (oi, vi) in out.as_mut_slice().iter_mut().zip(v.as_slice()) {
                    *oi /= vi.sqrt() + self.eps;
                }
                out
            }
        }
    }

    pub fn mem_bytes(&self) -> usize {
        self.v.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
    }

    /// Serializable snapshot of the mutable grafting state (accumulator
    /// + step counter); the hyperparameters stay construction-owned.
    pub fn snapshot(&self) -> (Option<Matrix>, u64) {
        (self.v.clone(), self.t as u64)
    }

    /// Restore a [`Graft::snapshot`]. The accumulator's presence and
    /// shape must match this graft's kind/shape (a kind needing no
    /// accumulator refuses one, and vice versa).
    pub fn restore(&mut self, v: Option<Matrix>, t: u64) -> anyhow::Result<()> {
        match (&self.v, &v) {
            (Some(cur), Some(new)) => {
                anyhow::ensure!(
                    cur.rows() == new.rows() && cur.cols() == new.cols(),
                    "graft restore: accumulator shape {}x{} != expected {}x{}",
                    new.rows(),
                    new.cols(),
                    cur.rows(),
                    cur.cols()
                );
            }
            (None, None) => {}
            (Some(_), None) => anyhow::bail!("graft restore: missing accumulator for {:?}", self.kind),
            (None, Some(_)) => {
                anyhow::bail!("graft restore: unexpected accumulator for {:?}", self.kind)
            }
        }
        self.v = v;
        self.t = t as usize;
        Ok(())
    }
}

/// Transplant the grafting magnitude onto a preconditioned direction:
/// `‖graft‖_F · dir / ‖dir‖_F` (zero-safe).
pub fn transplant(graft_step: &Matrix, dir: &Matrix) -> Matrix {
    let gn = graft_step.fro_norm();
    let dn = dir.fro_norm();
    if dn < 1e-30 {
        return graft_step.clone();
    }
    dir.scale(gn / dn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn wire_codes_roundtrip() {
        let kinds = [
            GraftType::None,
            GraftType::Sgd,
            GraftType::Rmsprop,
            GraftType::RmspropNormalized,
            GraftType::Adagrad,
            GraftType::AdagradNormalized,
        ];
        for k in kinds {
            assert_eq!(GraftType::from_code(k.code()), Some(k));
        }
        assert_eq!(GraftType::from_code(200), None);
    }

    #[test]
    fn transplant_preserves_magnitude_and_direction() {
        let mut rng = Pcg64::new(130);
        let g = Matrix::randn(4, 3, &mut rng);
        let dir = Matrix::randn(4, 3, &mut rng);
        let out = transplant(&g, &dir);
        assert!((out.fro_norm() - g.fro_norm()).abs() < 1e-10);
        // Same direction as dir: cosine similarity 1.
        let dot: f64 = out
            .as_slice()
            .iter()
            .zip(dir.as_slice())
            .map(|(a, b)| a * b)
            .sum();
        let cos = dot / (out.fro_norm() * dir.fro_norm());
        assert!((cos - 1.0).abs() < 1e-10);
    }

    #[test]
    fn rmsprop_normalizes_scale() {
        // After many identical gradients, the RMSProp step approaches
        // g/|g| elementwise (scale-free).
        let g = Matrix::from_rows(&[vec![10.0, -0.1]]);
        let mut graft = Graft::new(GraftType::Rmsprop, (1, 2), 0.9);
        let mut last = Matrix::zeros(1, 2);
        for _ in 0..500 {
            last = graft.step(&g);
        }
        assert!((last[(0, 0)] - 1.0).abs() < 1e-3);
        assert!((last[(0, 1)] + 1.0).abs() < 1e-3);
    }

    #[test]
    fn normalized_variant_is_gradient_scale_invariant() {
        let mut a = Graft::new(GraftType::RmspropNormalized, (1, 2), 0.9);
        let mut b = Graft::new(GraftType::RmspropNormalized, (1, 2), 0.9);
        let g = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let g_scaled = g.scale(100.0);
        let mut out_a = Matrix::zeros(1, 2);
        let mut out_b = Matrix::zeros(1, 2);
        for _ in 0..10 {
            out_a = a.step(&g);
            out_b = b.step(&g_scaled);
        }
        assert!(out_a.max_diff(&out_b) < 1e-10);
    }

    #[test]
    fn parse_names() {
        assert_eq!(GraftType::parse("rmsprop_normalized"), Some(GraftType::RmspropNormalized));
        assert_eq!(GraftType::parse("none"), Some(GraftType::None));
        assert_eq!(GraftType::parse("bogus"), None);
    }

    #[test]
    fn sgd_graft_passes_gradient_through() {
        let g = Matrix::from_rows(&[vec![3.0, 4.0]]);
        let mut graft = Graft::new(GraftType::Sgd, (1, 2), 0.9);
        assert_eq!(graft.step(&g), g);
        assert_eq!(graft.mem_bytes(), 0);
    }
}
